"""Scene-scale benchmark: throughput vs scene size, replicated vs
gaussian-sharded COMMITTED handles (DESIGN.md §10/§11).

For each scene size the same 4-camera batch is rendered through two engine
handles — one committed replicated (scene_shards=1), one committed
gaussian-sharded — and the steady-state walltime is compared. Both handles
are warmed through the EXACT call path that is then timed (same handle,
same mesh, same pad shape): the sharded handle compiles a different program
(per-shard frontend + merge) against differently-committed inputs, so
warming one does not warm the other.

On a multi-device host the shard axis lays over the mesh 'model' axis and
the benchmark shows where scene sharding starts paying; on one device the
shard axis is logical, so the sharded column isolates the pure engine-side
overhead of the per-shard frontend + merge stage (the price of fitting a
scene that could not be replicated at all). The report includes the
crossover scene size, if any, where sharded dispatch matches replicated
throughput. Parity (bitwise image) is asserted at the smallest size.
Handles are closed per size, which also evicts their host scene layouts —
the benchmark's host memory stays flat as sizes grow.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro import engine
from repro.core.camera import orbit_cameras
from repro.core.gaussians import random_scene
from repro.core.pipeline import RenderConfig, render_cache_clear
from repro.launch.mesh import make_render_mesh, render_mesh_shards

SIZES = (2_000, 8_000, 24_000)
N_CAMS = 4
RES = (128, 128)


def measured_temp_mb(handle, cams):
    """Compiled temp-buffer MB of the handle's batched renderer, from XLA's
    memory analysis — the MEASURED side of the per-camera feature scaling
    claim (DESIGN.md §12). Returns None when the backend does not report
    temp sizes (CPU reports 0); the analytic budget-model numbers
    (``feature_mb_per_device`` in the handle stats) are always emitted."""
    import jax

    from repro.core.pipeline import (
        CameraBatch,
        _background_array,
        _render_with_traced_camera,
    )

    batch = CameraBatch.from_cameras(cams)
    one = _render_with_traced_camera(
        handle.cfg, batch.width, batch.height, batch.znear, batch.zfar
    )
    fn = jax.jit(jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0, None)))
    try:
        lowered = fn.lower(
            handle.committed_scene,
            batch.R, batch.t, batch.fx, batch.fy, batch.cx, batch.cy,
            _background_array(None),
        )
        temp = getattr(
            lowered.compile().memory_analysis(), "temp_size_in_bytes", 0
        )
        return temp / 2**20 if temp else None
    except Exception:
        return None


def run() -> dict:
    n_dev = len(jax.devices())
    shards = n_dev if n_dev > 1 else 2   # logical shard axis on one device
    cfg = RenderConfig(
        mode="gstg", tile=16, group=64,
        group_capacity=512, tile_capacity=512, span=6,
    )
    cams = orbit_cameras(N_CAMS, 4.5, *RES)
    meshes = {
        1: make_render_mesh(),
        shards: make_render_mesh(
            scene_shards=render_mesh_shards(n_dev, shards)
        ),
    }

    render_cache_clear()
    rows = []
    for size in SIZES:
        scene = random_scene(jax.random.key(size), size, extent=3.0)
        row = {"gaussians": size}
        outs = {}
        handles = {
            d: engine.open(scene, cfg, mesh=meshes[d], scene_shards=d)
            for d in (1, shards)
        }
        for d in (1, shards):
            fn = lambda d=d: handles[d].render_batch(cams)
            us, out = timed(fn, reps=3)   # timed() warms with one extra call
            outs[d] = out
            key = "replicated" if d == 1 else "sharded"
            row[f"{key}_us"] = us
            row[f"{key}_fps"] = N_CAMS / (us * 1e-6)
            hs = handles[d].stats()
            row[f"{key}_feature_mb_model"] = hs["feature_mb_per_device"]
            row[f"{key}_gather"] = hs["feature_gather"]
            row[f"{key}_temp_mb_measured"] = measured_temp_mb(
                handles[d], cams
            )
        # The §12 scaling claim, asserted on the budget model: with the psum
        # gathers over a PHYSICAL 'model' axis the per-camera feature bytes
        # per device are ~1/D of the replicated path's (exactly N_pad/D vs
        # N). On one device the shard axis is logical and the model must
        # report FULL N for both — feature sharding cannot save memory a
        # mesh does not realize.
        phys = render_mesh_shards(n_dev, shards)
        rep_feat = row["replicated_feature_mb_model"]
        sh_feat = row["sharded_feature_mb_model"]
        if phys > 1:
            pad_slack = 1.0 + shards / size
            assert sh_feat <= rep_feat / shards * pad_slack, (
                f"feature model not ~1/D: {sh_feat} vs {rep_feat}/{shards}"
            )
        else:
            assert sh_feat >= rep_feat, (
                "logical shard axis must not claim feature-memory savings"
            )
        if size == SIZES[0]:
            assert (
                np.asarray(outs[1].image) == np.asarray(outs[shards].image)
            ).all(), "sharded handle diverges from replicated"
        for handle in handles.values():
            handle.close()
        row["sharded_over_replicated"] = row["sharded_us"] / row["replicated_us"]
        rows.append(row)
        measured = row["sharded_temp_mb_measured"]
        emit(
            f"scene_scale_n{size}", row["sharded_us"],
            f"repl={row['replicated_fps']:.2f}fps "
            f"shard={row['sharded_fps']:.2f}fps "
            f"ratio={row['sharded_over_replicated']:.2f}x "
            f"feat_mb {row['replicated_feature_mb_model']:.2f}->"
            f"{row['sharded_feature_mb_model']:.2f} "
            f"({row['sharded_gather']}"
            + (f", temp={measured:.2f}MB" if measured else "")
            + ")",
        )

    crossover = next(
        (r["gaussians"] for r in rows if r["sharded_us"] <= r["replicated_us"]),
        None,
    )
    emit(
        "scene_scale_crossover", 0.0,
        f"crossover_gaussians={crossover} devices={n_dev} shards={shards}",
    )
    return {
        "devices": n_dev,
        "scene_shards": shards,
        "cameras": N_CAMS,
        "resolution": RES,
        "rows": rows,
        "crossover_gaussians": crossover,
    }


if __name__ == "__main__":
    run()
