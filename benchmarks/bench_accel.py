"""Figs 14/15: accelerator speedup + energy efficiency across the six scenes.

Baseline = conventional per-tile ellipse pipeline on the same accelerator
(paper's baseline); GSCore modeled as the per-tile OBB pipeline (its published
configuration); GS-TG = ellipse+ellipse with BGM||GSM overlap.
"""
from __future__ import annotations

import numpy as np

import dataclasses

import jax

from benchmarks.common import ALL_SCENES, emit, render_stats, scene_and_camera
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.gaussians import random_scene
from repro.core.pipeline import RenderConfig
from repro.core import make_camera


def _fullres_train() -> dict:
    """Paper-resolution measurement (1952x1088, 120k Gaussians): the primary
    Fig 14 artifact — sorting share matches the paper's profile here."""
    scene = random_scene(jax.random.key(7), 120_000, extent=5.0)
    cam = make_camera((0.0, 1.75, 7.5), (0, 0, 0), 1952, 1088, fov_x_deg=62.0)
    mk = lambda mode, bg="ellipse", bt="ellipse": RenderConfig(
        mode=mode, tile=16, group=64, boundary_group=bg, boundary_tile=bt,
        tile_capacity=2048, group_capacity=4096, span=6)
    base = render_stats(scene, cam, mk("tile_baseline"))
    gstg = render_stats(scene, cam, mk("gstg"))
    opt = render_stats(scene, cam, mk("gstg", "ellipse_opacity", "ellipse_opacity"))
    cb = estimate(base, GSTG_ASIC, mode="tile_baseline")
    cg = estimate(gstg, GSTG_ASIC, mode="gstg", execution="asic")
    co = estimate(opt, GSTG_ASIC, mode="gstg", execution="asic")
    cf = estimate(dataclasses.replace(opt, fifo_ops=opt.fifo_ops * 0),
                  GSTG_ASIC, mode="gstg", execution="asic")
    out = {
        "pairs_reduction": float(base.n_pairs_sort) / float(gstg.n_pairs_sort),
        "speedup_faithful": cb.total_s / cg.total_s,
        "speedup_opacity": cb.total_s / co.total_s,
        "speedup_fused": cb.total_s / cf.total_s,
        "energy_faithful": cb.energy_j / cg.energy_j,
    }
    emit(
        "fig14_fullres_train",
        0.0,
        f"faithful={out['speedup_faithful']:.2f}x "
        f"+opacity={out['speedup_opacity']:.2f}x "
        f"+fusedRM={out['speedup_fused']:.2f}x (paper max 1.58x)",
    )
    return out


def run() -> dict:
    results = {}
    results["train_fullres"] = _fullres_train()
    for name in ALL_SCENES:
        scene, cam = scene_and_camera(name)
        mk = lambda **kw: RenderConfig(
            tile=16, group=64, tile_capacity=1024, group_capacity=1024,
            span=6, **kw,
        )
        base = render_stats(scene, cam, mk(mode="tile_baseline", boundary_tile="ellipse"))
        gscore = render_stats(scene, cam, mk(mode="tile_baseline", boundary_tile="obb"))
        ours = render_stats(scene, cam, mk(mode="gstg"))

        c_base = estimate(base, GSTG_ASIC, boundary_group="ellipse",
                          boundary_tile="ellipse", mode="tile_baseline")
        c_gscore = estimate(gscore, GSTG_ASIC, boundary_group="obb",
                            boundary_tile="obb", mode="tile_baseline")
        c_ours = estimate(ours, GSTG_ASIC, mode="gstg", execution="asic")
        results[name] = {
            "speedup_vs_baseline": c_base.total_s / c_ours.total_s,
            "speedup_vs_gscore": c_gscore.total_s / c_ours.total_s,
            "energy_eff_vs_baseline": c_base.energy_j / c_ours.energy_j,
            "energy_eff_vs_gscore": c_gscore.energy_j / c_ours.energy_j,
        }
    geo = lambda k: float(
        np.exp(np.mean([np.log(results[s][k]) for s in ALL_SCENES]))
    )
    results["geomean"] = {k: geo(k) for k in results[ALL_SCENES[0]]}
    g = results["geomean"]
    emit(
        "fig14_accel_speedup",
        0.0,
        f"geomean vs baseline={g['speedup_vs_baseline']:.2f}x "
        f"vs GSCore={g['speedup_vs_gscore']:.2f}x "
        f"(paper: 1.33x / up to 1.54x)",
    )
    emit(
        "fig15_energy",
        0.0,
        f"geomean energy-eff vs baseline={g['energy_eff_vs_baseline']:.2f}x "
        f"(paper: 2.12x)",
    )
    return results


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
