"""Fig 12: GS-TG speedup across boundary-method combinations, GPU execution
model (bitmask generation serializes with sorting), normalized to the
AABB tile baseline."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PROFILE_SCENES, emit, scene_and_camera
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render

METHODS = ("aabb", "obb", "ellipse")


def run() -> dict:
    results = {}
    for name in PROFILE_SCENES:
        scene, cam = scene_and_camera(name)
        row = {}
        # baselines: conventional per-tile pipeline per method
        base_stats = {}
        for m in METHODS:
            cfg = RenderConfig(
                mode="tile_baseline", tile=16, group=64, boundary_tile=m,
                tile_capacity=1024, group_capacity=1024, span=6,
            )
            base_stats[m] = render(scene, cam, cfg).stats
        t_ref = estimate(
            base_stats["aabb"], GSTG_ASIC,
            boundary_group="aabb", boundary_tile="aabb", mode="tile_baseline",
        ).total_s
        for m in METHODS:
            t = estimate(
                base_stats[m], GSTG_ASIC,
                boundary_group=m, boundary_tile=m, mode="tile_baseline",
            ).total_s
            row[f"baseline/{m}"] = t_ref / t
        # GS-TG combos: group method x bitmask method
        for mg in METHODS:
            for mt in METHODS:
                cfg = RenderConfig(
                    mode="gstg", tile=16, group=64,
                    boundary_group=mg, boundary_tile=mt,
                    tile_capacity=1024, group_capacity=1024, span=6,
                )
                s = render(scene, cam, cfg).stats
                t = estimate(
                    s, GSTG_ASIC, boundary_group=mg, boundary_tile=mt,
                    mode="gstg", execution="gpu",
                ).total_s
                row[f"ours/{mg}+{mt}"] = t_ref / t
        results[name] = row
    keys = results[PROFILE_SCENES[0]].keys()
    avg = {k: float(np.mean([results[s][k] for s in PROFILE_SCENES])) for k in keys}
    results["average"] = avg
    emit(
        "fig12_boundary_combos",
        0.0,
        f"ours/ellipse+ellipse={avg['ours/ellipse+ellipse']:.2f}x "
        f"vs baseline/ellipse={avg['baseline/ellipse']:.2f}x (norm to aabb)",
    )
    return results


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
