"""Table I: % of Gaussians shared with adjacent tiles, per tile size."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PROFILE_SCENES, emit, scene_and_camera, timed
from repro.core.grouping import GridSpec, identify
from repro.core.projection import project

TILE_SIZES = (8, 16, 32, 64)


def shared_fraction(scene, cam, tile: int) -> float:
    """Fraction of visible Gaussians intersecting >= 2 tiles of size `tile`."""
    proj = project(scene, cam)
    grid = GridSpec(
        width=(cam.width // tile) * tile or tile,
        height=(cam.height // tile) * tile or tile,
        tile=tile,
        group=tile * 4,
        span=8,
    )
    pairs = identify(proj, grid, "tile", "aabb")
    counts = jnp.zeros((scene.num_gaussians,), jnp.int32).at[
        pairs.gauss_idx
    ].add(pairs.valid.astype(jnp.int32))
    vis = counts > 0
    shared = counts >= 2
    return float(jnp.sum(shared) / jnp.maximum(jnp.sum(vis), 1))


def run() -> dict:
    rows = {}
    for scene_name in PROFILE_SCENES:
        scene, cam = scene_and_camera(scene_name)
        row = {}
        for t in TILE_SIZES:
            us, frac = timed(lambda: shared_fraction(scene, cam, t), reps=1)
            row[t] = frac
        rows[scene_name] = row
    avg = {t: float(np.mean([rows[s][t] for s in rows])) for t in TILE_SIZES}
    rows["average"] = avg
    derived = ";".join(f"{t}px={100*avg[t]:.1f}%" for t in TILE_SIZES)
    emit("table1_shared_gaussians", 0.0, derived)
    return rows


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
