"""Shared benchmark substrate: paper scenes (synthetic stand-ins) + helpers."""
from __future__ import annotations

import time
import zlib
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.configs.gs_scenes import EVAL_RESOLUTION, PAPER_SCENES
from repro.core import make_camera
from repro.core.gaussians import scene_like_paper
from repro.core.pipeline import RenderConfig

# The four scenes the paper profiles in Figs 3/5/7/11/12/13 + the two
# high-res scenes added for Figs 14/15.
PROFILE_SCENES = ("train", "truck", "drjohnson", "playroom")
ALL_SCENES = PROFILE_SCENES + ("rubble", "residence")


def scene_and_camera(
    name: str,
    n_gaussians: int | None = None,
    width: int | None = None,
    height: int | None = None,
):
    """Scene + its eval camera; width/height override the paper resolution
    (smoke renders) while keeping the single source of truth for the
    viewpoint formula."""
    spec = PAPER_SCENES[name]
    w, h = EVAL_RESOLUTION[name]
    # crc32, not hash(): str hash is salted per process, which made every
    # process render a DIFFERENT realization of the same named scene.
    seed = zlib.crc32(name.encode()) % 2**31
    scene = scene_like_paper(jax.random.key(seed), name, n_gaussians)
    cam = make_camera(
        (0.0, spec.extent * 0.35, spec.extent * 1.5),
        (0, 0, 0),
        width or w,
        height or h,
        fov_x_deg=62.0,
    )
    return scene, cam


def render_stats(scene, cam, cfg: RenderConfig):
    """Counters via the module-default engine handle (shared committed scene
    + executable across cameras of the same resolution and equal configs)."""
    from repro import engine

    out = engine.default_renderer(scene, cfg).render(cam)
    return jax.tree.map(np.asarray, out.stats)


def timed(fn, *args, reps: int = 3) -> Tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / reps
    return dt * 1e6, out  # us


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
