"""Residency bench: serve 2-4x more scenes than fit the device budget.

The many-scene overcommit story (DESIGN.md §17), measured honestly on one
host: commit every PAPER scene to a ``RenderServer`` whose budget holds
only ``budget_scenes`` of them, replay a round-robin load for ``laps``
laps (the worst case for LRU — every request touches the coldest scene),
and compare against the identical run with no budget:

  * parity: every budgeted image must be BITWISE-identical to the
    unbudgeted run — paging must be invisible in the pixels;
  * thrash cost: budgeted vs unbudgeted wall time, with the page-in /
    eviction counters that explain the delta;
  * the overcommit ratio actually served (committed MB / budget MB).

Writes the schema-versioned ``BENCH_residency_<host>.json`` at the repo
root (committed trajectory, like BENCH_gateway/BENCH_stream). ``--smoke``
runs a tiny config and validates the schema only, writing under results/.
"""
from __future__ import annotations

import json
import platform
import re
import time

SCHEMA = "repro.bench_residency/v1"

DEFAULT_SCENES = ("train", "truck", "drjohnson", "playroom",
                  "rubble", "residence")
DEFAULT_GAUSSIANS = 3000
DEFAULT_LAPS = 3
DEFAULT_BUDGET_SCENES = 2


def _host() -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "-", platform.node() or "unknown")


def default_out_path(host: str | None = None) -> str:
    return f"BENCH_residency_{host or _host()}.json"


def validate_bench(doc: dict) -> list:
    """Schema + invariant check; returns problems (empty = valid)."""
    errs = []
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, expected {SCHEMA!r}")
    for key in ("host", "timestamp", "backend", "config", "unbudgeted",
                "budgeted", "parity"):
        if key not in doc:
            errs.append(f"missing top-level key {key!r}")
    cfg = doc.get("config") or {}
    for k in ("budget_mb", "per_scene_mb", "overcommit_frac", "requests"):
        if not isinstance(cfg.get(k), (int, float)):
            errs.append(f"config: non-numeric {k!r}")
    if isinstance(cfg.get("overcommit_frac"), (int, float)) and \
            cfg["overcommit_frac"] < 2.0:
        errs.append(
            f"overcommit {cfg['overcommit_frac']:.1f}x below the 2x floor "
            "— the bench is not actually overcommitting the budget")
    for phase in ("unbudgeted", "budgeted"):
        ph = doc.get(phase) or {}
        for k in ("wall_s", "fps", "completed", "page_ins", "page_outs",
                  "evictions"):
            if not isinstance(ph.get(k), (int, float)):
                errs.append(f"{phase}: non-numeric {k!r}")
        if ph.get("completed") != cfg.get("requests"):
            errs.append(f"{phase}: completed {ph.get('completed')} != "
                        f"requests {cfg.get('requests')}")
    if (doc.get("unbudgeted") or {}).get("page_outs", -1) != 0:
        errs.append("unbudgeted run paged — budget accounting is broken")
    if (doc.get("budgeted") or {}).get("evictions", 0) < 1:
        errs.append("budgeted overcommit produced no evictions")
    pa = doc.get("parity") or {}
    if pa.get("mismatches", -1) != 0:
        errs.append(f"parity: {pa.get('mismatches')} budgeted images "
                    "diverge from the unbudgeted run")
    if pa.get("compared", 0) < 1:
        errs.append("parity: nothing compared")
    return errs


def run(
    scenes=DEFAULT_SCENES,
    n_gaussians: int = DEFAULT_GAUSSIANS,
    width: int = 96,
    height: int = 96,
    backend: str = "reference",
    laps: int = DEFAULT_LAPS,
    budget_scenes: int = DEFAULT_BUDGET_SCENES,
    max_batch: int = 4,
    out_path: str | None = None,
) -> dict:
    import jax
    import numpy as np

    from benchmarks.common import emit
    from repro import engine
    from repro.core import orbit_cameras
    from repro.core.gaussians import scene_like_paper
    from repro.core.pipeline import RenderConfig
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer

    scene_ids = list(scenes)
    cfg = RenderConfig(mode="gstg", backend=backend, span=6)
    built = {
        sid: scene_like_paper(jax.random.key(i), sid, n_gaussians)
        for i, sid in enumerate(scene_ids)
    }
    cams = orbit_cameras(8, 4.5, width, height)

    # Size the budget off the real committed cost (params + per-camera
    # features, per device) so `budget_scenes` fit and the rest page.
    probe = engine.open(built[scene_ids[0]], cfg)
    st = probe.stats()
    per_scene_mb = st["scene_mb_per_device"] + st["feature_mb_per_device"]
    probe.close()
    budget_mb = budget_scenes * per_scene_mb * 1.1
    overcommit = len(scene_ids) * per_scene_mb / budget_mb

    requests = laps * len(scene_ids)
    load = [
        (0.0, RenderRequest(i, scene_ids[i % len(scene_ids)],
                            cams[i % len(cams)], cfg))
        for i in range(requests)
    ]

    def serve(budget):
        server = RenderServer(built, max_batch=max_batch, max_wait=0.0,
                              device_budget_mb=budget)
        for sid in scene_ids:
            server.commit(sid, cfg)
        # One warm dispatch compiles the (shared) program so the timed
        # window measures paging + dispatch, not jit.
        server.run([(0.0, RenderRequest(-1, scene_ids[0], cams[0], cfg))],
                   realtime=False)
        server.results.clear()
        rs0 = dict(server.residency.stats())
        t0 = time.perf_counter()
        res = server.run(load, realtime=False)
        wall = time.perf_counter() - t0
        rs1 = server.residency.stats()
        images = {i: np.asarray(r.image) for i, r in res.items()}
        server.close()
        counters = {k: rs1[k] - rs0[k]
                    for k in ("page_ins", "page_outs", "evictions", "hits",
                              "prefetches", "over_budget")}
        return {
            "wall_s": wall,
            "fps": requests / wall,
            "completed": len(images),
            "resident_entries": rs1["resident_entries"],
            **counters,
        }, images

    unbudgeted, ref_images = serve(None)
    budgeted, paged_images = serve(budget_mb)

    mismatches = sum(
        0 if np.array_equal(paged_images[i], ref_images[i]) else 1
        for i in ref_images
    )
    parity = {"compared": len(ref_images), "mismatches": mismatches}

    emit("residency_overcommit",
         budgeted["wall_s"] / requests * 1e6,
         f"{len(scene_ids)} scenes in a {budget_scenes}-scene budget "
         f"({overcommit:.1f}x): {budgeted['page_ins']} page-ins, "
         f"{budgeted['evictions']} evictions, "
         f"{unbudgeted['fps']:.1f} -> {budgeted['fps']:.1f} fps, "
         f"{mismatches} parity mismatches")

    doc = {
        "schema": SCHEMA,
        "host": _host(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "jax_backend": jax.default_backend(),
        "backend": backend,
        "config": {
            "scenes": scene_ids,
            "n_gaussians": n_gaussians,
            "width": width,
            "height": height,
            "laps": laps,
            "requests": requests,
            "max_batch": max_batch,
            "budget_scenes": budget_scenes,
            "budget_mb": budget_mb,
            "per_scene_mb": per_scene_mb,
            "overcommit_frac": overcommit,
        },
        "unbudgeted": unbudgeted,
        "budgeted": budgeted,
        "parity": parity,
        "paging_penalty_frac":
            (budgeted["wall_s"] - unbudgeted["wall_s"])
            / unbudgeted["wall_s"],
    }
    errs = validate_bench(doc)
    if errs:
        raise AssertionError("BENCH document invalid: " + "; ".join(errs))
    out = out_path or default_out_path()
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    emit("bench_residency_written", 0.0, out)
    return doc


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, schema-only validation, writes under "
                         "results/ (never clobbers the committed BENCH)")
    ap.add_argument("--gaussians", type=int, default=None)
    ap.add_argument("--laps", type=int, default=None)
    ap.add_argument("--backend", default="reference")
    args = ap.parse_args(argv)
    if args.smoke:
        import os

        os.makedirs("results", exist_ok=True)
        run(
            scenes=DEFAULT_SCENES[:4],
            n_gaussians=args.gaussians or 300,
            width=64, height=64,
            laps=args.laps or 2,
            budget_scenes=1,
            backend=args.backend,
            out_path="results/BENCH_residency_smoke.json",
        )
    else:
        run(
            n_gaussians=args.gaussians or DEFAULT_GAUSSIANS,
            laps=args.laps or DEFAULT_LAPS,
            backend=args.backend,
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
