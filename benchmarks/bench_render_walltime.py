"""Wall-time microbenchmark of the actual JAX renderer on this host (CPU):
GS-TG vs per-tile baseline vs large-tile baseline, jit-compiled, plus the
batched multi-camera entry (render_batch) vs an N-call per-camera loop.

This measures the ALGORITHM on the XLA substrate (sorting-key reduction shows
up directly in the binning time); the accelerator-level speedups are the cost
model's job (bench_accel)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit, scene_and_camera, timed
from repro import engine
from repro.core.camera import orbit_cameras
from repro.core.gaussians import random_scene
from repro.core.pipeline import (
    CameraBatch,
    RenderConfig,
    render_batch,
)


def run() -> dict:
    scene, cam = scene_and_camera("train", n_gaussians=12_000)
    out = {}
    for mode in ("tile_baseline", "gstg", "group_baseline"):
        cfg = RenderConfig(
            mode=mode, tile=16, group=64,
            tile_capacity=1024, group_capacity=1024, span=6,
        )
        with engine.open(scene, cfg) as r:
            us, _ = timed(lambda: r.render(cam).image, reps=3)
        out[mode] = us
    emit(
        "render_walltime_cpu",
        out["gstg"],
        f"gstg={out['gstg']/1e3:.1f}ms tile_baseline={out['tile_baseline']/1e3:.1f}ms "
        f"group_baseline={out['group_baseline']/1e3:.1f}ms",
    )

    # --- batched multi-camera rendering: ONE jit call vs N-call loops ---
    # Cold path (first trajectory at a new resolution/config): the pre-engine
    # idiom jits a fresh closure per camera and compiles N times; a committed
    # handle compiles ONE executable — either shared across a .render() loop
    # or fused into a single vmapped .render_batch() program. Steady-state,
    # the batch further collapses N dispatches into one (≈parity on this CPU,
    # where compute dominates; the dispatch amortization is the point on
    # accelerators and at serving batch sizes).
    n_views = 8
    bscene = random_scene(jax.random.key(0), 800, extent=3.0)
    cams = orbit_cameras(n_views, 4.5, 128, 128)
    bcfg = RenderConfig(
        mode="gstg", tile=16, group=64,
        tile_capacity=256, group_capacity=256, span=6,
    )
    batch = CameraBatch.from_cameras(cams)

    def cold(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return (time.perf_counter() - t0) * 1e6  # us

    from repro.core.pipeline import render, render_cache_clear

    render_cache_clear()
    percam_cold_us = cold(
        lambda: [
            jax.jit(lambda s, c=c: render(s, c, bcfg).image)(bscene)
            for c in cams
        ]
    )
    batch_cold_us = cold(lambda: render_batch(bscene, batch, bcfg).image)

    with engine.open(bscene, bcfg) as r:
        loop_us, _ = timed(
            lambda: [r.render(c).image for c in cams], reps=3
        )
        batch_us, _ = timed(lambda: r.render_batch(batch).image, reps=3)
    out["multicam_percam_jit_cold"] = percam_cold_us
    out["multicam_batch_cold"] = batch_cold_us
    out["multicam_loop"] = loop_us
    out["multicam_batch"] = batch_us
    out["batch_cold_speedup"] = percam_cold_us / batch_cold_us
    out["batch_speedup"] = loop_us / batch_us
    emit(
        "render_batch_multicam",
        batch_us,
        f"{n_views} views cold: batch={batch_cold_us/1e6:.1f}s "
        f"per-cam-jit loop={percam_cold_us/1e6:.1f}s "
        f"({out['batch_cold_speedup']:.2f}x); steady: batch={batch_us/1e3:.1f}ms "
        f"loop={loop_us/1e3:.1f}ms ({out['batch_speedup']:.2f}x)",
    )
    return out


if __name__ == "__main__":
    print(run())
