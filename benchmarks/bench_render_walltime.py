"""Wall-time microbenchmark of the actual JAX renderer on this host (CPU):
GS-TG vs per-tile baseline vs large-tile baseline, jit-compiled.

This measures the ALGORITHM on the XLA substrate (sorting-key reduction shows
up directly in the binning time); the accelerator-level speedups are the cost
model's job (bench_accel)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, scene_and_camera, timed
from repro.core.pipeline import RenderConfig, render


def run() -> dict:
    scene, cam = scene_and_camera("train", n_gaussians=12_000)
    out = {}
    for mode in ("tile_baseline", "gstg", "group_baseline"):
        cfg = RenderConfig(
            mode=mode, tile=16, group=64,
            tile_capacity=1024, group_capacity=1024, span=6,
        )
        fn = jax.jit(lambda s: render(s, cam, cfg).image)
        us, _ = timed(fn, scene, reps=3)
        out[mode] = us
    emit(
        "render_walltime_cpu",
        out["gstg"],
        f"gstg={out['gstg']/1e3:.1f}ms tile_baseline={out['tile_baseline']/1e3:.1f}ms "
        f"group_baseline={out['group_baseline']/1e3:.1f}ms",
    )
    return out


if __name__ == "__main__":
    print(run())
