"""Figs 3/5/7: tile-size trade-off — intersecting tiles per Gaussian (Fig 5),
Gaussians processed per pixel (Fig 7), and stage runtime breakdown via the
cost model (Fig 3), for tile sizes 8..64 and AABB/ellipse boundaries."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PROFILE_SCENES, emit, scene_and_camera
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render

TILE_SIZES = (8, 16, 32, 64)


def profile_scene(scene, cam, tile: int, boundary: str):
    w = (cam.width // tile) * tile
    h = (cam.height // tile) * tile
    import dataclasses

    cam2 = dataclasses.replace(cam, width=w, height=h)
    cfg = RenderConfig(
        mode="tile_baseline",
        tile=tile,
        group=tile * 2,
        boundary_tile=boundary,
        tile_capacity=1024,
        group_capacity=1024,
        span=6,
    )
    out = render(scene, cam2, cfg)
    s = out.stats
    n_vis = max(int(s.n_visible), 1)
    tiles_per_gaussian = float(s.n_pairs_sort) / n_vis
    gauss_per_pixel = float(s.tile_entries) * tile * tile / (w * h)
    cost = estimate(s, GSTG_ASIC, boundary_group=boundary,
                    boundary_tile=boundary, mode="tile_baseline")
    return {
        "tiles_per_gaussian": tiles_per_gaussian,
        "gaussians_per_pixel": gauss_per_pixel,
        "preprocess_s": cost.preprocess_s,
        "sort_s": cost.sort_s,
        "raster_s": cost.raster_s,
        "total_s": cost.total_s,
        "overflow": int(s.overflow),
    }


def run() -> dict:
    results = {}
    for boundary in ("aabb", "ellipse"):
        for name in PROFILE_SCENES:
            scene, cam = scene_and_camera(name)
            for t in TILE_SIZES:
                results[(boundary, name, t)] = profile_scene(scene, cam, t, boundary)

    # headline: ratio of tiles/gaussian at 8px vs 64px (paper: up to 18.3x),
    # and gaussians/pixel at 64 vs 8 (paper: up to 10.6x)
    r8 = np.mean([results[("aabb", s, 8)]["tiles_per_gaussian"] for s in PROFILE_SCENES])
    r64 = np.mean([results[("aabb", s, 64)]["tiles_per_gaussian"] for s in PROFILE_SCENES])
    g8 = np.mean([results[("ellipse", s, 8)]["gaussians_per_pixel"] for s in PROFILE_SCENES])
    g64 = np.mean([results[("ellipse", s, 64)]["gaussians_per_pixel"] for s in PROFILE_SCENES])
    emit(
        "fig5_tiles_per_gaussian",
        0.0,
        f"aabb 8px/64px ratio={r8 / max(r64, 1e-9):.1f}x",
    )
    emit(
        "fig7_gaussians_per_pixel",
        0.0,
        f"ellipse 64px/8px ratio={g64 / max(g8, 1e-9):.1f}x",
    )
    best = {}
    for name in PROFILE_SCENES:
        totals = {t: results[("ellipse", name, t)]["total_s"] for t in TILE_SIZES}
        best[name] = min(totals, key=totals.get)
    emit("fig3_best_tile_size", 0.0,
         ";".join(f"{k}={v}" for k, v in best.items()))
    return {f"{b}/{s}/{t}": v for (b, s, t), v in results.items()}


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
