"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus a JSON dump per benchmark
under results/bench/). Figures covered:
  Table I     -> bench_sharing     Fig 12 -> bench_boundaries
  Fig 3/5/7/11-> bench_autotune    Fig 13 -> bench_stages
  (the tile/group sweep)           Fig 14/15 -> bench_accel
plus the wall-time microbenchmark of the JAX renderer itself.
bench_autotune additionally refreshes ``BENCH_autotune_<host>.json`` at the
repo root — the committed perf trajectory (DESIGN.md §13) — and
bench_stream refreshes ``BENCH_stream_<host>.json``, the stream-session
exact-reuse speedup trajectory (DESIGN.md §15).
"""
from __future__ import annotations

import json
import os
import time
import traceback


def main() -> None:
    from benchmarks import (
        bench_accel,
        bench_autotune,
        bench_boundaries,
        bench_gateway,
        bench_render_walltime,
        bench_residency,
        bench_scene_scale,
        bench_serving,
        bench_sharing,
        bench_stages,
        bench_stream,
    )

    os.makedirs("results/bench", exist_ok=True)
    suites = [
        ("table1_sharing", bench_sharing.run),
        ("autotune_sweep", bench_autotune.run),
        ("fig12_boundaries", bench_boundaries.run),
        ("fig13_stages", bench_stages.run),
        ("fig1415_accel", bench_accel.run),
        ("render_walltime", bench_render_walltime.run),
        ("serving", bench_serving.run),
        ("scene_scale", bench_scene_scale.run),
        ("stream_reuse", bench_stream.run),
        ("gateway_fleet", bench_gateway.run),
        ("residency_overcommit", bench_residency.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        try:
            result = fn()
            with open(f"results/bench/{name}.json", "w") as f:
                json.dump(result, f, indent=1, default=str)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
        finally:
            print(f"# {name} took {time.time()-t0:.1f}s")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
