"""Gateway fleet bench: scheduling overhead + failover episode cost.

Two honest measurements of the gateway tier (DESIGN.md §16), both over
IN-PROCESS workers (one shared jax runtime — subprocess workers would
measure child cold-start, and in-process dispatches serialize on the
runtime lock, so fleet *scaling* is only real multi-host; what is
measurable here is what the gateway itself costs):

  * overhead: the same request sequence rendered by a worker directly
    (batched ``dispatch`` calls, no gateway) vs routed through
    ``RenderGateway`` with that single worker — admission, routing,
    dispatcher-thread handoff, and resolve bookkeeping are the delta.
    Acceptance floor: overhead <= MAX_OVERHEAD_FRAC of the direct run.
  * chaos: 2 workers under the same load with one killed after 25% of
    completions — reports completion ratio (must be 1.0: no request is
    silently dropped), failovers/retries, and the p99 penalty vs the
    healthy 2-worker run.

Writes the schema-versioned ``BENCH_gateway_<host>.json`` at the repo root
(committed trajectory, like BENCH_autotune/BENCH_stream). ``--smoke`` runs
a tiny config and validates the schema without the overhead floor.
"""
from __future__ import annotations

import json
import platform
import re
import time

SCHEMA = "repro.bench_gateway/v1"

DEFAULT_GAUSSIANS = 4000
DEFAULT_REQUESTS = 48
MAX_OVERHEAD_FRAC = 0.35


def _host() -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "-", platform.node() or "unknown")


def default_out_path(host: str | None = None) -> str:
    return f"BENCH_gateway_{host or _host()}.json"


def validate_bench(doc: dict, max_overhead: float | None = None) -> list:
    """Schema check; returns problems (empty = valid). ``max_overhead``
    additionally enforces the gateway-overhead acceptance ceiling."""
    errs = []
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, expected {SCHEMA!r}")
    for key in ("host", "timestamp", "backend", "config", "overhead",
                "chaos"):
        if key not in doc:
            errs.append(f"missing top-level key {key!r}")
    ov = doc.get("overhead") or {}
    for k in ("direct_s", "gateway_s", "overhead_frac", "requests"):
        if not isinstance(ov.get(k), (int, float)):
            errs.append(f"overhead: non-numeric {k!r}")
    ch = doc.get("chaos") or {}
    for k in ("requests", "completed", "failed", "failovers", "retries",
              "p99_ms", "healthy_p99_ms"):
        if not isinstance(ch.get(k), (int, float)):
            errs.append(f"chaos: non-numeric {k!r}")
    if ch.get("completed") != ch.get("requests"):
        errs.append(
            f"chaos: completed {ch.get('completed')} != requests "
            f"{ch.get('requests')} — a request was lost under failover")
    if ch.get("failed", 0) != 0:
        errs.append(f"chaos: {ch.get('failed')} requests failed")
    if isinstance(ch.get("failovers"), (int, float)) and ch["failovers"] < 1:
        errs.append("chaos: induced kill produced no failover")
    if max_overhead is not None:
        frac = ov.get("overhead_frac")
        if isinstance(frac, (int, float)) and frac > max_overhead:
            errs.append(
                f"gateway overhead {frac:.2%} above the "
                f"{max_overhead:.0%} acceptance ceiling")
    return errs


def _load(scene_ids, cams, cfg, n, base_id=0):
    from repro.serving.queue import RenderRequest

    return [
        (0.0, RenderRequest(base_id + i, scene_ids[i % len(scene_ids)],
                            cams[i % len(cams)], cfg))
        for i in range(n)
    ]


def run(
    scenes=("train", "truck"),
    n_gaussians: int = DEFAULT_GAUSSIANS,
    width: int = 96,
    height: int = 96,
    backend: str = "reference",
    requests: int = DEFAULT_REQUESTS,
    max_batch: int = 4,
    out_path: str | None = None,
    max_overhead: float | None = MAX_OVERHEAD_FRAC,
) -> dict:
    import jax

    from benchmarks.common import emit
    from repro.core import orbit_cameras
    from repro.core.gaussians import scene_like_paper
    from repro.core.pipeline import RenderConfig
    from repro.gateway import RenderGateway
    from repro.gateway.worker import InprocWorker
    from repro.serving.queue import RenderRequest
    from repro.serving.stats import percentile

    scene_ids = list(scenes)
    cfg = RenderConfig(mode="gstg", backend=backend, span=6)
    built = {
        sid: scene_like_paper(jax.random.key(i), sid, n_gaussians)
        for i, sid in enumerate(scene_ids)
    }
    cams = orbit_cameras(8, 4.5, width, height)

    def make_worker(wid):
        w = InprocWorker(wid, built, max_batch=max_batch)
        for j, sid in enumerate(scene_ids):      # warm every program
            w.dispatch([RenderRequest(-(hash(wid) % 1000) * 10 - j - 1,
                                      sid, cams[0], cfg)])
        return w

    # -- overhead: direct worker dispatch vs the same load via the gateway --
    w = make_worker("direct")
    load = _load(scene_ids, cams, cfg, requests)
    t0 = time.perf_counter()
    for i in range(0, len(load), max_batch):
        w.dispatch([r for _, r in load[i:i + max_batch]])
    direct_s = time.perf_counter() - t0
    w.shutdown()

    w = make_worker("gw0")
    gw = RenderGateway([w])
    t0 = time.perf_counter()
    res = gw.run(load)
    gateway_s = time.perf_counter() - t0
    assert len(res) == requests, gw.failed
    gw.close()
    overhead = {
        "requests": requests,
        "direct_s": direct_s,
        "gateway_s": gateway_s,
        "overhead_frac": (gateway_s - direct_s) / direct_s,
        "direct_fps": requests / direct_s,
        "gateway_fps": requests / gateway_s,
    }
    emit("gateway_overhead", gateway_s / requests * 1e6,
         f"{overhead['overhead_frac']:+.1%} vs direct "
         f"({overhead['direct_fps']:.1f} -> "
         f"{overhead['gateway_fps']:.1f} fps)")

    # -- chaos: 2 workers, one killed after 25% of completions --------------
    def fleet_run(kill: bool):
        ws = [make_worker("c0" if kill else "h0"),
              make_worker("c1" if kill else "h1")]
        gw = RenderGateway(ws, retry_backoff_s=0.005)
        kw = ws[0].worker_id if kill else None
        res = gw.run(
            _load(scene_ids, cams, cfg, requests, base_id=1000),
            kill_worker=kw,
            kill_after=max(requests // 4, 1) if kill else None,
        )
        summary = gw.summary()
        lat = [r.latency_s for r in res.values()]
        gw.close()
        return res, summary, percentile(lat, 99) * 1e3

    _, healthy, healthy_p99 = fleet_run(kill=False)
    res, chaos_sum, chaos_p99 = fleet_run(kill=True)
    chaos = {
        "requests": requests,
        "completed": len(res),
        "failed": chaos_sum["failed"],
        "failovers": chaos_sum["failovers"],
        "retries": chaos_sum["retries"],
        "duplicates": chaos_sum["duplicates"],
        "p99_ms": chaos_p99,
        "healthy_p99_ms": healthy_p99,
        "p99_penalty_frac": (chaos_p99 - healthy_p99) / healthy_p99
        if healthy_p99 else 0.0,
    }
    emit("gateway_chaos", chaos_p99 * 1e3,
         f"{chaos['completed']}/{requests} after kill "
         f"({chaos['failovers']} failovers, {chaos['retries']} retries, "
         f"p99 {healthy_p99:.0f}->{chaos_p99:.0f}ms)")

    doc = {
        "schema": SCHEMA,
        "host": _host(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "jax_backend": jax.default_backend(),
        "backend": backend,
        "config": {
            "scenes": scene_ids,
            "n_gaussians": n_gaussians,
            "width": width,
            "height": height,
            "requests": requests,
            "max_batch": max_batch,
        },
        "overhead": overhead,
        "chaos": chaos,
    }
    errs = validate_bench(doc, max_overhead=max_overhead)
    if errs:
        raise AssertionError("BENCH document invalid: " + "; ".join(errs))
    out = out_path or default_out_path()
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    emit("bench_gateway_written", 0.0, out)
    return doc


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, schema-only validation, writes under "
                         "results/ (never clobbers the committed BENCH)")
    ap.add_argument("--gaussians", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--backend", default="reference")
    args = ap.parse_args(argv)
    if args.smoke:
        import os

        os.makedirs("results", exist_ok=True)
        run(
            scenes=("train",),
            n_gaussians=args.gaussians or 300,
            width=64, height=64,
            requests=args.requests or 12,
            backend=args.backend,
            out_path="results/BENCH_gateway_smoke.json",
            max_overhead=None,
        )
    else:
        run(
            n_gaussians=args.gaussians or DEFAULT_GAUSSIANS,
            requests=args.requests or DEFAULT_REQUESTS,
            backend=args.backend,
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
