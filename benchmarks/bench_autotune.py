"""The (tile x group x capacity) sweep benchmark + persisted BENCH trajectory.

One sweep, three outputs (DESIGN.md §13):

  * the paper figures the two retired standalone benches covered —
    Figs 3/5/7 tile-size effects (bench_tilesize) and the Fig 11 tile+group
    speedup grid (bench_groupsize) — now derived from the SAME phase-1
    stats passes the autotune search runs;
  * real measured walltime for EVERY feasible grid point through the exact
    jit'd engine-handle path (``repro.autotune.sweep``), so the selected
    config's walltime is <= every other swept point by construction;
  * a schema-versioned ``BENCH_autotune_<host>.json`` at the repo root —
    the persisted perf trajectory the ROADMAP asks for (committed, so it
    survives re-anchors; re-running the bench refreshes it).

Defaults are CPU-tractable (reduced gaussian counts at the paper's reduced
eval resolutions); on real hardware raise ``--gaussians`` / pass
``--backend pallas`` (with ``REPRO_PALLAS_INTERPRET=0`` the kernels
compile, DESIGN.md §13). ``--smoke`` is the CI entry: a 2x2 (group x
capacity) grid at the default tile on a tiny scene, schema-validated, and
the tuned config is asserted BITWISE-identical to the default config
(group/capacity are the lossless axes; the tile axis only reassociates fp).
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import re
import sys
import time

import numpy as np

SCHEMA = "repro.bench_autotune/v1"

DEFAULT_SCENES = ("train", "truck")
DEFAULT_GAUSSIANS = 6000


def _host() -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "-", platform.node() or "unknown")


def default_out_path(host: str | None = None) -> str:
    return f"BENCH_autotune_{host or _host()}.json"


def validate_bench(doc: dict, min_points: int = 1) -> list:
    """Schema check for a BENCH_autotune document. Returns a list of
    problems (empty = valid). ``min_points`` is the required number of
    distinct (tile, group) points per scene — 9 for the real trajectory,
    lower for the CI smoke grid."""
    from repro.core.cost_model import StageCosts

    errs = []
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, expected {SCHEMA!r}")
    for key in ("host", "timestamp", "backend", "config", "scenes"):
        if key not in doc:
            errs.append(f"missing top-level key {key!r}")
    scenes = doc.get("scenes") or {}
    if not scenes:
        errs.append("no scenes")
    for name, sc in scenes.items():
        grid = sc.get("grid") or []
        points = {(e.get("tile"), e.get("group")) for e in grid}
        if len(points) < min_points:
            errs.append(
                f"scene {name}: {len(points)} (tile, group) points "
                f"< required {min_points}"
            )
        measured = []
        for e in grid:
            where = f"scene {name} point {e.get('tile')}+{e.get('group')}"
            for k in ("tile", "group", "tile_capacity"):
                if not isinstance(e.get(k), int):
                    errs.append(f"{where}: non-int {k!r}")
            try:
                StageCosts.from_dict(e["est"])
            except (KeyError, TypeError, ValueError) as exc:
                errs.append(f"{where}: bad cost estimate ({exc})")
            if e.get("feasible"):
                if not isinstance(e.get("measured_ms"), (int, float)):
                    errs.append(f"{where}: feasible but no measured_ms")
                else:
                    measured.append(e)
        sel = sc.get("selected")
        if not sel:
            errs.append(f"scene {name}: no selected config")
        elif measured:
            best = min(measured, key=lambda e: e["measured_ms"])
            if sel.get("measured_ms") > best["measured_ms"]:
                errs.append(
                    f"scene {name}: selected measured_ms "
                    f"{sel.get('measured_ms')} > best swept point "
                    f"{best['measured_ms']} — selection must be the minimum"
                )
    return errs


def _scene_report(scene, cam, base_cfg, tiles, factors, capacities,
                  warmup, reps):
    """Sweep one scene; fold in the retired benches' figure headlines."""
    from repro.autotune import Candidate, config_for, stats_pass, sweep
    from repro.core.cost_model import GSTG_ASIC, estimate

    res = sweep(
        scene, cam, base_cfg,
        tiles=tiles, group_factors=factors, capacities=capacities,
        warmup=warmup, reps=reps,
    )

    # Fig 11 normalization + Figs 5/7 ratios: tile_baseline stats passes at
    # the swept extremes and the paper's 16px reference tile.
    cap = max(capacities)
    t_lo, t_hi = min(tiles), max(tiles)
    base_stats = {}
    for t in {t_lo, t_hi, 16}:
        cfg_t = dataclasses.replace(
            config_for(base_cfg, Candidate(t, 2 * t, cap)),
            mode="tile_baseline",
        )
        base_stats[t] = stats_pass(scene, cam, cfg_t)
    est_base16 = estimate(
        base_stats[16], GSTG_ASIC, mode="tile_baseline", execution="gpu",
    ).total_s

    for e in res.trajectory:
        e["speedup_est_vs_16px_baseline"] = (
            est_base16 / e["est_total_s"] if e["est_total_s"] > 0 else None
        )

    def _tpg(t):   # Fig 5: intersecting tiles per gaussian
        s = base_stats[t]
        return float(s.n_pairs_sort) / max(int(s.n_visible), 1)

    def _gpp(t):   # Fig 7: gaussians processed per pixel
        s = base_stats[t]
        return float(s.tile_entries) * t * t / (cam.width * cam.height)

    best_est = min(
        (e for e in res.trajectory if e["feasible"]),
        key=lambda e: e["est_total_s"],
    )
    headlines = {
        "tiles_per_gaussian_ratio": _tpg(t_lo) / max(_tpg(t_hi), 1e-9),
        "gaussians_per_pixel_ratio": _gpp(t_hi) / max(_gpp(t_lo), 1e-9),
        "best_combo_est": f"{best_est['tile']}+{best_est['group']}",
        "best_combo_est_speedup": best_est["speedup_est_vs_16px_baseline"],
        "selected_speedup_est": next(
            e["speedup_est_vs_16px_baseline"] for e in res.trajectory
            if (e["tile"], e["group"], e["tile_capacity"])
            == (res.tile, res.group, res.tile_capacity)
        ),
    }
    return {
        "signature": repr(res.signature),
        "grid": res.trajectory,
        "selected": {
            "tile": res.tile,
            "group": res.group,
            "tile_capacity": res.tile_capacity,
            "measured_ms": res.measured_ms,
        },
        "headlines": headlines,
    }


def run(
    scenes=DEFAULT_SCENES,
    n_gaussians: int = DEFAULT_GAUSSIANS,
    width: int | None = None,
    height: int | None = None,
    backend: str = "reference",
    tiles=None,
    factors=None,
    capacities=None,
    warmup: int = 1,
    reps: int = 3,
    out_path: str | None = None,
    min_points: int | None = None,
) -> dict:
    """The sweep over ``scenes``; writes the BENCH json and returns the doc.

    ``out_path=None`` writes ``BENCH_autotune_<host>.json`` in the current
    directory (the repo root under ``benchmarks/run.py`` and check.sh).
    """
    import jax

    from benchmarks.common import emit, scene_and_camera
    from repro.autotune import (
        DEFAULT_CAPACITIES,
        DEFAULT_GROUP_FACTORS,
        DEFAULT_TILES,
    )
    from repro.core.pipeline import RenderConfig

    tiles = tuple(tiles or DEFAULT_TILES)
    factors = tuple(factors or DEFAULT_GROUP_FACTORS)
    capacities = tuple(capacities or DEFAULT_CAPACITIES)
    if min_points is None:
        min_points = len(tiles) * len(factors)

    base_cfg = RenderConfig(mode="gstg", backend=backend, span=6)
    doc = {
        "schema": SCHEMA,
        "host": _host(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "jax_backend": jax.default_backend(),
        "backend": backend,
        "config": {
            "n_gaussians": n_gaussians,
            "tiles": list(tiles),
            "group_factors": list(factors),
            "capacities": list(capacities),
            "warmup": warmup,
            "reps": reps,
            "mode": base_cfg.mode,
        },
        "scenes": {},
    }
    for name in scenes:
        scene, cam = scene_and_camera(
            name, n_gaussians, width=width, height=height
        )
        t0 = time.time()
        sc = _scene_report(
            scene, cam, base_cfg, tiles, factors, capacities, warmup, reps
        )
        doc["scenes"][name] = sc
        sel = sc["selected"]
        emit(
            f"autotune_{name}",
            sel["measured_ms"] * 1e3,
            f"selected {sel['tile']}+{sel['group']}@{sel['tile_capacity']} "
            f"{sel['measured_ms']:.1f}ms "
            f"est_speedup={sc['headlines']['selected_speedup_est']:.2f}x "
            f"({time.time() - t0:.0f}s sweep)",
        )

    errs = validate_bench(doc, min_points=min_points)
    if errs:
        raise AssertionError("BENCH document invalid: " + "; ".join(errs))
    out = out_path or default_out_path()
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    emit("bench_autotune_written", 0.0, out)
    return doc


def _smoke(args) -> int:
    """CI smoke (scripts/check.sh): 2x2 grid at the default tile on a tiny
    scene — validates the emitted schema and asserts the tuned config
    renders BITWISE-identical to the default config."""
    import jax

    from benchmarks.common import scene_and_camera
    from repro import engine
    from repro.core.pipeline import RenderConfig

    scene, cam = scene_and_camera("train", 500, width=96, height=96)
    base_cfg = RenderConfig(mode="gstg", backend=args.backend, span=6)
    doc = run(
        scenes=("train",),
        n_gaussians=500,
        width=96, height=96,
        backend=args.backend,
        tiles=(base_cfg.tile,),            # tile fixed => bitwise guarantee
        factors=(2, 4),
        capacities=(256, 512),
        warmup=1, reps=1,
        out_path=args.out,
        min_points=2,
    )
    sel = doc["scenes"]["train"]["selected"]
    with engine.open(scene, base_cfg) as rd, engine.open(
        scene, base_cfg,
        tile_params=(sel["tile"], sel["group"], sel["tile_capacity"]),
    ) as rt:
        a = np.asarray(rd.render(cam).image)
        b = np.asarray(rt.render(cam).image)
    if not (a == b).all():
        print("bench_autotune --smoke: FAILED (tuned config not "
              "bitwise-identical to the default config)")
        return 1
    print(f"bench_autotune --smoke: OK (selected {sel['tile']}+"
          f"{sel['group']}@{sel['tile_capacity']}, bitwise == default, "
          f"schema valid, wrote {args.out})")
    return 0


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenes", default=",".join(DEFAULT_SCENES))
    ap.add_argument("--gaussians", type=int, default=DEFAULT_GAUSSIANS)
    ap.add_argument("--width", type=int, default=None)
    ap.add_argument("--height", type=int, default=None)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--tiles", default=None,
                    help="comma-separated tile sizes (default 8,16,32)")
    ap.add_argument("--factors", default=None,
                    help="comma-separated group factors (default 2,4,8)")
    ap.add_argument("--capacities", default=None,
                    help="comma-separated tile capacities (default 256,512)")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output path (default BENCH_autotune_<host>.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny scene, 2x2 group x capacity grid, "
                         "schema validation + bitwise-vs-default assert")
    args = ap.parse_args(argv)

    if args.smoke:
        if args.out is None:
            args.out = os.path.join("results", "BENCH_autotune_smoke.json")
            os.makedirs("results", exist_ok=True)
        return _smoke(args)

    ints = lambda s: tuple(int(x) for x in s.split(",")) if s else None
    run(
        scenes=tuple(s.strip() for s in args.scenes.split(",") if s.strip()),
        n_gaussians=args.gaussians,
        width=args.width, height=args.height,
        backend=args.backend,
        tiles=ints(args.tiles),
        factors=ints(args.factors),
        capacities=ints(args.capacities),
        warmup=args.warmup, reps=args.reps,
        out_path=args.out,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
