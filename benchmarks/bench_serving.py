"""Serving-tier benchmark: naive per-request handle renders vs the bucketed
serving stack vs the handle's own futures front-end, on identical request
streams (DESIGN.md §9/§11).

All three paths run through ONE committed engine handle topology:

  * naive     — ``Renderer.render`` per request, in arrival order (the
                pre-serving idiom: one dispatch per camera);
  * served    — the same backlog through ``RenderServer`` (queue ->
                bucketer -> the server's shared handle, batched dispatch);
  * futures   — ``Renderer.submit`` for every request, then gather (the
                handle's internal queue+bucketing worker, same batching).

Reports p50/p99 end-to-end latency and throughput (fps) for each, verifies
every image against the naive render of the same request (allclose), and
checks the handle's 1-device contract: ``Renderer.render_batch`` over a
1-device mesh is BITWISE-identical to ``render_batch``.

The served path must be >= the naive loop on throughput — both hit
warm compiled renderers, the server just amortizes N python dispatches into
one batched call (DESIGN.md §9), so losing would mean scheduler overhead
exceeds the dispatch overhead it removes. Every path is warmed through the
EXACT call path that is then timed (same handles, same pad shapes).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro import engine
from repro.core.camera import orbit_cameras
from repro.core.gaussians import random_scene
from repro.core.pipeline import RenderConfig, render_batch
from repro.launch.mesh import make_render_mesh
from repro.serving.queue import RenderRequest
from repro.serving.server import RenderServer
from repro.serving.stats import ServingStats, percentile

N_REQUESTS = 32
MAX_BATCH = 8
RES = (128, 96)


def _requests(cfg):
    cams = orbit_cameras(N_REQUESTS, 4.5, *RES)
    return [RenderRequest(i, "bench", cam, cfg) for i, cam in enumerate(cams)]


def _naive(handle, reqs):
    """The pre-serving idiom: one handle.render dispatch per request, in
    arrival order. Latency = completion - start of the backlog (closed
    loop)."""
    t0 = time.perf_counter()
    lat, images = [], []
    for r in reqs:
        out = handle.render(r.camera)
        images.append(np.asarray(out.image))  # host copy = completion
        lat.append(time.perf_counter() - t0)
    return time.perf_counter() - t0, lat, images


def _served(server, reqs):
    """Same backlog through queue -> bucketer -> the server's committed
    handle (throughput mode: buckets fill to MAX_BATCH)."""
    results = server.run([(0.0, r) for r in reqs], realtime=False)
    wall = server.stats.wall_s
    lat = [results[r.request_id].latency_s for r in reqs]
    images = [results[r.request_id].image for r in reqs]
    assert len(results) == len(reqs), "serving lost requests"
    stats = server.stats
    server.results.clear()
    server.stats = ServingStats()          # fresh counters for the next rep
    return wall, lat, images, stats


def _futures(handle, reqs):
    """Same backlog through the handle's submit() worker (the async
    front-end): fire everything, then gather."""
    t0 = time.perf_counter()
    futs = [handle.submit(r.camera) for r in reqs]
    lat, images = [], []
    for f in futs:
        res = f.result(timeout=600)
        images.append(res.image)
        lat.append(time.perf_counter() - t0)
    return time.perf_counter() - t0, lat, images


def run() -> dict:
    scene = random_scene(jax.random.key(7), 900, extent=3.0)
    cfg = RenderConfig(
        mode="gstg", tile=16, group=64,
        tile_capacity=256, group_capacity=256, span=6,
    )
    reqs = _requests(cfg)
    mesh = make_render_mesh()

    # --- contract check: handle batch over 1 device == render_batch --------
    handle1 = engine.open(scene, cfg, mesh=make_render_mesh(1))
    plain = render_batch(scene, [r.camera for r in reqs[:5]], cfg)
    shard1 = handle1.render_batch([r.camera for r in reqs[:5]])
    assert (np.asarray(shard1.image) == np.asarray(plain.image)).all(), (
        "Renderer.render_batch(1-device) must be bitwise render_batch"
    )
    handle1.close()

    # ONE handle per path so each is warmed through the exact timed call
    # path: the naive handle's single-camera executable, the server's
    # committed batch executables (full buckets + the ragged tail), and the
    # futures worker's padded dispatch shape.
    naive_handle = engine.open(scene, cfg, mesh=mesh)
    futures_handle = engine.open(
        scene, cfg, mesh=mesh, max_batch=MAX_BATCH, max_wait=0.0,
        queue_depth=2 * N_REQUESTS,
    )
    server = RenderServer(
        {"bench": scene}, mesh=mesh,
        max_batch=MAX_BATCH, max_wait=0.0, queue_depth=2 * N_REQUESTS,
    )
    _naive(naive_handle, reqs[:1])
    _served(server, reqs)
    _futures(futures_handle, reqs)

    # Best-of-2 per path: the compute is identical warmed executables either
    # way, so the honest comparison is the less-noisy rep of each (this CPU
    # is shared; a single rep can swing by more than the dispatch overhead
    # the server amortizes).
    naive_wall, naive_lat, naive_imgs = min(
        (_naive(naive_handle, reqs) for _ in range(2)), key=lambda r: r[0]
    )
    served_wall, served_lat, served_imgs, stats = min(
        (_served(server, reqs) for _ in range(2)), key=lambda r: r[0]
    )
    fut_wall, fut_lat, fut_imgs = min(
        (_futures(futures_handle, reqs) for _ in range(2)), key=lambda r: r[0]
    )

    # Identical images for every request on every path.
    for i, (a, b, c) in enumerate(zip(served_imgs, naive_imgs, fut_imgs)):
        np.testing.assert_allclose(
            a, b, atol=1e-6, rtol=1e-6,
            err_msg=f"served image diverges from naive render (request {i})",
        )
        np.testing.assert_allclose(
            c, b, atol=1e-6, rtol=1e-6,
            err_msg=f"futures image diverges from naive render (request {i})",
        )

    naive_fps = N_REQUESTS / naive_wall
    served_fps = N_REQUESTS / served_wall
    fut_fps = N_REQUESTS / fut_wall
    out = {
        "requests": N_REQUESTS,
        "max_batch": MAX_BATCH,
        "devices": len(jax.devices()),
        "naive": {
            "wall_s": naive_wall, "fps": naive_fps,
            "p50_ms": percentile(naive_lat, 50) * 1e3,
            "p99_ms": percentile(naive_lat, 99) * 1e3,
        },
        "served": {
            "wall_s": served_wall, "fps": served_fps,
            "p50_ms": percentile(served_lat, 50) * 1e3,
            "p99_ms": percentile(served_lat, 99) * 1e3,
            "batches": stats.summary()["batches"],
            "cache_hits": stats.summary()["cache_hits"],
        },
        "futures": {
            "wall_s": fut_wall, "fps": fut_fps,
            "p50_ms": percentile(fut_lat, 50) * 1e3,
            "p99_ms": percentile(fut_lat, 99) * 1e3,
        },
        "speedup": served_fps / naive_fps,
    }
    emit(
        "serving_naive_loop", naive_wall / N_REQUESTS * 1e6,
        f"fps={naive_fps:.1f} p50={out['naive']['p50_ms']:.0f}ms "
        f"p99={out['naive']['p99_ms']:.0f}ms",
    )
    emit(
        "serving_bucketed", served_wall / N_REQUESTS * 1e6,
        f"fps={served_fps:.1f} p50={out['served']['p50_ms']:.0f}ms "
        f"p99={out['served']['p99_ms']:.0f}ms speedup={out['speedup']:.2f}x",
    )
    emit(
        "serving_futures", fut_wall / N_REQUESTS * 1e6,
        f"fps={fut_fps:.1f} p50={out['futures']['p50_ms']:.0f}ms "
        f"p99={out['futures']['p99_ms']:.0f}ms",
    )
    assert served_fps >= naive_fps, (
        f"bucketed serving slower than the naive loop: "
        f"{served_fps:.1f} < {naive_fps:.1f} fps"
    )
    server.close()
    naive_handle.close()
    futures_handle.close()
    return out


if __name__ == "__main__":
    run()
