"""Serving-tier benchmark: naive per-request render_jit loop vs the bucketed
(+ sharded) serving stack, on identical request streams.

Reports p50/p99 end-to-end latency and throughput (fps) for both paths,
verifies every served image against the naive render of the same request
(allclose), and checks the sharded entry's 1-device contract:
``render_batch_sharded`` over a 1-device mesh is BITWISE-identical to
``render_batch``.

The served path must be >= the naive loop on throughput — both hit the same
cached executables, the server just amortizes N python dispatches into one
batched call (DESIGN.md §9), so losing would mean scheduler overhead exceeds
the dispatch overhead it removes.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.camera import orbit_cameras
from repro.core.gaussians import random_scene
from repro.core.pipeline import (
    CameraBatch,
    RenderConfig,
    render_batch,
    render_cache_clear,
    render_jit,
)
from repro.launch.mesh import make_render_mesh
from repro.serving.queue import RenderRequest
from repro.serving.server import RenderServer
from repro.serving.sharded import render_batch_sharded
from repro.serving.stats import percentile

N_REQUESTS = 32
MAX_BATCH = 8
RES = (128, 96)


def _requests(cfg):
    cams = orbit_cameras(N_REQUESTS, 4.5, *RES)
    return [RenderRequest(i, "bench", cam, cfg) for i, cam in enumerate(cams)]


def _naive(scene, reqs):
    """The pre-serving idiom: one render_jit dispatch per request, in arrival
    order. Latency = completion - start of the backlog (closed loop)."""
    t0 = time.perf_counter()
    lat, images = [], []
    for r in reqs:
        out = render_jit(scene, r.camera, r.cfg)
        images.append(np.asarray(out.image))  # host copy = completion
        lat.append(time.perf_counter() - t0)
    return time.perf_counter() - t0, lat, images


def _served(scene, reqs, mesh):
    """Same backlog through queue -> bucketer -> sharded dispatch
    (throughput mode: buckets fill to MAX_BATCH)."""
    server = RenderServer(
        {"bench": scene}, mesh=mesh,
        max_batch=MAX_BATCH, max_wait=0.0, queue_depth=2 * N_REQUESTS,
    )
    results = server.run([(0.0, r) for r in reqs], realtime=False)
    wall = server.stats.wall_s
    lat = [results[r.request_id].latency_s for r in reqs]
    images = [results[r.request_id].image for r in reqs]
    assert len(results) == len(reqs), "serving lost requests"
    return wall, lat, images, server.stats


def run() -> dict:
    scene = random_scene(jax.random.key(7), 900, extent=3.0)
    cfg = RenderConfig(
        mode="gstg", tile=16, group=64,
        tile_capacity=256, group_capacity=256, span=6,
    )
    reqs = _requests(cfg)
    mesh = make_render_mesh()

    # --- contract check: sharded over 1 device == render_batch, bitwise ----
    batch = CameraBatch.from_cameras([r.camera for r in reqs[:5]])
    plain = render_batch(scene, batch, cfg)
    shard1 = render_batch_sharded(scene, batch, cfg, mesh=make_render_mesh(1))
    assert (np.asarray(shard1.image) == np.asarray(plain.image)).all(), (
        "render_batch_sharded(1-device) must be bitwise render_batch"
    )

    # Warm both paths so neither pays compilation inside the timed region:
    # the naive loop's single-camera executable, and the serving path's
    # sharded batch executables (full buckets + the ragged tail) — the
    # sharded call sees committed inputs, which XLA specializes separately
    # from the uncommitted render_batch call above.
    render_cache_clear()
    render_jit(scene, reqs[0].camera, cfg)
    for n in {MAX_BATCH, N_REQUESTS % MAX_BATCH} - {0}:
        render_batch_sharded(
            scene, CameraBatch.from_cameras([r.camera for r in reqs[:n]]),
            cfg, mesh=mesh,
        )

    # Best-of-2 per path: the compute is identical warmed executables either
    # way, so the honest comparison is the less-noisy rep of each (this CPU
    # is shared; a single rep can swing by more than the dispatch overhead
    # the server amortizes).
    naive_wall, naive_lat, naive_imgs = min(
        (_naive(scene, reqs) for _ in range(2)), key=lambda r: r[0]
    )
    served_wall, served_lat, served_imgs, stats = min(
        (_served(scene, reqs, mesh) for _ in range(2)), key=lambda r: r[0]
    )

    # Identical images for every served request.
    for i, (a, b) in enumerate(zip(served_imgs, naive_imgs)):
        np.testing.assert_allclose(
            a, b, atol=1e-6, rtol=1e-6,
            err_msg=f"served image diverges from naive render (request {i})",
        )

    naive_fps = N_REQUESTS / naive_wall
    served_fps = N_REQUESTS / served_wall
    out = {
        "requests": N_REQUESTS,
        "max_batch": MAX_BATCH,
        "devices": len(jax.devices()),
        "naive": {
            "wall_s": naive_wall, "fps": naive_fps,
            "p50_ms": percentile(naive_lat, 50) * 1e3,
            "p99_ms": percentile(naive_lat, 99) * 1e3,
        },
        "served": {
            "wall_s": served_wall, "fps": served_fps,
            "p50_ms": percentile(served_lat, 50) * 1e3,
            "p99_ms": percentile(served_lat, 99) * 1e3,
            "batches": stats.summary()["batches"],
            "cache_hits": stats.summary()["cache_hits"],
        },
        "speedup": served_fps / naive_fps,
    }
    emit(
        "serving_naive_loop", naive_wall / N_REQUESTS * 1e6,
        f"fps={naive_fps:.1f} p50={out['naive']['p50_ms']:.0f}ms "
        f"p99={out['naive']['p99_ms']:.0f}ms",
    )
    emit(
        "serving_bucketed", served_wall / N_REQUESTS * 1e6,
        f"fps={served_fps:.1f} p50={out['served']['p50_ms']:.0f}ms "
        f"p99={out['served']['p99_ms']:.0f}ms speedup={out['speedup']:.2f}x",
    )
    assert served_fps >= naive_fps, (
        f"bucketed serving slower than the naive loop: "
        f"{served_fps:.1f} < {naive_fps:.1f} fps"
    )
    return out


if __name__ == "__main__":
    run()
