"""Fig 11: tile+group size combinations (8+16 ... 32+64), cost-model speedup
normalized to the 16-tile baseline, accounting for BGM||GSM overlap."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import PROFILE_SCENES, emit, scene_and_camera
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render

COMBOS = [(8, 16), (8, 32), (16, 32), (16, 64), (32, 64)]


def _crop(cam, px):
    return dataclasses.replace(
        cam, width=(cam.width // px) * px, height=(cam.height // px) * px
    )


def run() -> dict:
    results = {}
    for name in PROFILE_SCENES:
        scene, cam = scene_and_camera(name)
        base_cfg = RenderConfig(
            mode="tile_baseline", tile=16, group=64,
            tile_capacity=1024, group_capacity=1024, span=6,
        )
        base = render(scene, _crop(cam, 64), base_cfg).stats
        t_base = estimate(base, GSTG_ASIC, mode="tile_baseline").total_s
        row = {}
        for tile, group in COMBOS:
            cfg = RenderConfig(
                mode="gstg", tile=tile, group=group,
                tile_capacity=1024, group_capacity=1024, span=6,
            )
            s = render(scene, _crop(cam, group), cfg).stats
            c = estimate(s, GSTG_ASIC, mode="gstg", execution="asic")
            row[f"{tile}+{group}"] = t_base / c.total_s
        results[name] = row
    avg = {
        k: float(np.mean([results[s][k] for s in PROFILE_SCENES]))
        for k in results[PROFILE_SCENES[0]]
    }
    results["average"] = avg
    best = max(avg, key=avg.get)
    emit("fig11_group_size_sweep", 0.0,
         f"best={best} speedup={avg[best]:.2f}x vs 16px baseline")
    return results


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
