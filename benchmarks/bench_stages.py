"""Fig 13: stage-wise runtime breakdown (train scene): ellipse baseline at
16/32/64 px tiles vs GS-TG (16+64), on the GPU execution model — showing
GS-TG's sort time matches the 64px baseline while raster time matches 16px;
plus the ASIC model where bitmask gen overlaps sorting.

Two lanes (DESIGN.md §14):

  * ``run()`` — the original COST-MODEL breakdown (analytic seconds from the
    accelerator model), still what ``benchmarks/run.py`` drives as
    ``fig13_stages``;
  * ``run_measured()`` / the CLI — MEASURED per-stage device milliseconds
    from the observability layer: ``RenderConfig(timing=True)`` runs every
    backend stage as its own fenced jit program and the tracer's
    ``category == "stage"`` spans are aggregated per rep (median across
    reps).  Emits a schema-versioned ``BENCH_stages_<host>.json`` at the
    repo root — the committed measured-stage trajectory, sibling to
    ``BENCH_autotune_<host>.json``.

  PYTHONPATH=src:. python benchmarks/bench_stages.py            # full bench
  PYTHONPATH=src:. python benchmarks/bench_stages.py --smoke    # CI smoke
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import re
import statistics
import time
from collections import defaultdict

from benchmarks.common import emit, scene_and_camera
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render

SCHEMA = "repro.bench_stages/v1"

#: Per-stage spans a gstg-mode timed render must produce (plus the enclosing
#: ``stage/render``); the measured lane refuses to emit a document missing
#: any of them — a silent instrumentation regression would otherwise read as
#: "stage got free".  ``stage/merge`` only exists on the gaussian-sharded
#: frontend (the per-shard table merge, DESIGN.md §10), so it is required
#: only of ``*sharded*`` variants.
GSTG_STAGES = (
    "stage/project", "stage/identify", "stage/bin",
    "stage/bitmask", "stage/compact", "stage/rasterize",
)


def _host() -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "-", platform.node() or "unknown")


def default_out_path(host: str | None = None) -> str:
    return f"BENCH_stages_{host or _host()}.json"


# ---------------------------------------------------------------------------
# Cost-model lane (benchmarks/run.py: "fig13_stages")
# ---------------------------------------------------------------------------


def run() -> dict:
    scene, cam = scene_and_camera("train")
    out = {}

    for tile in (16, 32, 64):
        cam2 = dataclasses.replace(
            cam, width=(cam.width // tile) * tile, height=(cam.height // tile) * tile
        )
        cfg = RenderConfig(
            mode="tile_baseline", tile=tile, group=tile * 2,
            boundary_tile="ellipse", tile_capacity=1024, group_capacity=1024,
            span=6,
        )
        s = render(scene, cam2, cfg).stats
        c = estimate(s, GSTG_ASIC, mode="tile_baseline")
        out[f"baseline_{tile}"] = c.as_dict()

    cfg = RenderConfig(
        mode="gstg", tile=16, group=64, tile_capacity=1024,
        group_capacity=1024, span=6,
    )
    s = render(scene, cam, cfg).stats
    out["gstg_gpu"] = estimate(s, GSTG_ASIC, mode="gstg", execution="gpu").as_dict()
    out["gstg_asic"] = estimate(s, GSTG_ASIC, mode="gstg", execution="asic").as_dict()

    sort_vs_64 = out["gstg_gpu"]["sort_s"] / max(out["baseline_64"]["sort_s"], 1e-12)
    raster_vs_16 = out["gstg_gpu"]["raster_s"] / max(
        out["baseline_16"]["raster_s"], 1e-12
    )
    emit(
        "fig13_stage_breakdown",
        0.0,
        f"gstg sort/64px-baseline={sort_vs_64:.2f} "
        f"raster/16px-baseline={raster_vs_16:.2f} "
        f"asic_total/gpu_total="
        f"{out['gstg_asic']['total_s']/out['gstg_gpu']['total_s']:.2f}",
    )
    return out


# ---------------------------------------------------------------------------
# Measured lane (obs layer: fenced per-stage device spans)
# ---------------------------------------------------------------------------


def measure_stages(scene, cam, cfg: RenderConfig, *, warmup: int = 1,
                   reps: int = 3) -> dict:
    """Per-stage device milliseconds for one (scene, camera, config).

    Opens an engine handle with ``timing=True`` (every stage its own fenced
    jit program — bitwise-identical image, DESIGN.md §14), renders
    ``warmup`` times to pay the per-stage compiles, then for each of
    ``reps`` measured renders clears the tracer, renders, and aggregates the
    ``category == "stage"`` spans by name.  Returns::

        {"stages": {name: {"calls", "median_ms", "reps_ms"}},
         "render_ms": {...},          # the enclosing stage/render span
         "stage_sum_median_ms": ...}  # sum of per-stage medians
    """
    from repro import engine
    from repro.obs import get_tracer

    tracer = get_tracer()   # TimedBackend records with force=True: no enable
    per_stage: dict = defaultdict(lambda: {"calls": 0, "reps_ms": []})
    with engine.open(scene, dataclasses.replace(cfg, timing=True)) as r:
        for _ in range(warmup):
            r.render(cam)
        for _ in range(reps):
            tracer.clear()
            r.render(cam)
            tot = defaultdict(float)
            calls = defaultdict(int)
            for e in tracer.events():
                if e.category == "stage":
                    tot[e.name] += e.duration_s
                    calls[e.name] += 1
            for name, s in tot.items():
                per_stage[name]["reps_ms"].append(s * 1e3)
                per_stage[name]["calls"] = calls[name]
    stages = {
        name: {
            "calls": d["calls"],
            "median_ms": statistics.median(d["reps_ms"]),
            "reps_ms": d["reps_ms"],
        }
        for name, d in sorted(per_stage.items())
    }
    render_span = stages.pop("stage/render", None)
    return {
        "stages": stages,
        "render_ms": render_span,
        "stage_sum_median_ms": sum(d["median_ms"] for d in stages.values()),
    }


def validate_bench(doc: dict, require_gstg_stages: bool = True) -> list:
    """Schema check for a BENCH_stages document; returns a list of errors
    (empty == valid)."""
    errs = []
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema != {SCHEMA!r}: {doc.get('schema')!r}")
    for key in ("host", "timestamp", "jax_backend", "backend", "config"):
        if key not in doc:
            errs.append(f"missing top-level key {key!r}")
    variants = doc.get("measured", {})
    if not variants:
        errs.append("no measured variants")
    for vname, v in variants.items():
        stages = v.get("stages", {})
        if not stages:
            errs.append(f"{vname}: no stages")
        for sname, d in stages.items():
            if not d.get("reps_ms"):
                errs.append(f"{vname}/{sname}: empty reps_ms")
            elif any(ms < 0 for ms in d["reps_ms"]):
                errs.append(f"{vname}/{sname}: negative duration")
            if d.get("calls", 0) < 1:
                errs.append(f"{vname}/{sname}: calls < 1")
        if require_gstg_stages and vname.startswith("gstg"):
            need = GSTG_STAGES + (("stage/merge",) if "sharded" in vname
                                  else ())
            missing = [s for s in need if s not in stages]
            if missing:
                errs.append(f"{vname}: missing stage spans {missing}")
    return errs


def run_measured(
    scene_name: str = "train",
    n_gaussians: int | None = 6000,
    width: int | None = None,
    height: int | None = None,
    backend: str = "reference",
    tile: int = 16,
    group: int = 64,
    capacity: int = 1024,
    warmup: int = 1,
    reps: int = 3,
    out_path: str | None = None,
) -> dict:
    """Measured per-stage breakdown: gstg vs the 16px tile baseline, same
    scene/camera.  Writes the BENCH json (default repo root) and returns the
    doc; raises if the document fails :func:`validate_bench`."""
    import jax

    scene, cam = scene_and_camera(scene_name, n_gaussians,
                                  width=width, height=height)
    base = RenderConfig(
        mode="gstg", tile=tile, group=group, tile_capacity=capacity,
        group_capacity=capacity, span=6, backend=backend,
    )
    doc = {
        "schema": SCHEMA,
        "host": _host(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "jax_backend": jax.default_backend(),
        "backend": backend,
        "config": {
            "scene": scene_name,
            "n_gaussians": n_gaussians,
            "width": cam.width, "height": cam.height,
            "tile": tile, "group": group, "capacity": capacity,
            "warmup": warmup, "reps": reps,
        },
        "measured": {},
    }
    for vname, cfg in (
        ("gstg", base),
        ("gstg_sharded2", dataclasses.replace(base, scene_shards=2)),
        ("tile_baseline_16", dataclasses.replace(base, mode="tile_baseline")),
    ):
        t0 = time.time()
        m = measure_stages(scene, cam, cfg, warmup=warmup, reps=reps)
        doc["measured"][vname] = m
        top = max(m["stages"].items(), key=lambda kv: kv[1]["median_ms"])
        emit(
            f"stages_{vname}",
            m["stage_sum_median_ms"] * 1e3,
            f"{len(m['stages'])} stages, top {top[0]}="
            f"{top[1]['median_ms']:.2f}ms ({time.time() - t0:.0f}s bench)",
        )
    errs = validate_bench(doc)
    if errs:
        raise AssertionError("BENCH document invalid: " + "; ".join(errs))
    out = out_path or default_out_path()
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    emit("bench_stages_written", 0.0, out)
    return doc


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scene", default="train")
    ap.add_argument("--gaussians", type=int, default=6000)
    ap.add_argument("--width", type=int, default=None)
    ap.add_argument("--height", type=int, default=None)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--tile", type=int, default=16)
    ap.add_argument("--group", type=int, default=64)
    ap.add_argument("--capacity", type=int, default=1024)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output path (default BENCH_stages_<host>.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny scene/resolution, 1 rep, writes "
                         "results/BENCH_stages_smoke.json")
    ap.add_argument("--cost-model", action="store_true",
                    help="run the original fig13 cost-model lane instead")
    args = ap.parse_args(argv)

    if args.cost_model:
        print(json.dumps(run(), indent=1))
        return 0

    if args.smoke:
        out = args.out or os.path.join("results", "BENCH_stages_smoke.json")
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        doc = run_measured(
            scene_name=args.scene, n_gaussians=500, width=96, height=96,
            backend=args.backend, capacity=256,
            warmup=1, reps=1, out_path=out,
        )
        n = len(doc["measured"]["gstg"]["stages"])
        print(f"bench_stages --smoke: OK ({n} gstg stage spans, schema "
              f"valid, wrote {out})")
        return 0

    run_measured(
        scene_name=args.scene, n_gaussians=args.gaussians,
        width=args.width, height=args.height, backend=args.backend,
        tile=args.tile, group=args.group, capacity=args.capacity,
        warmup=args.warmup, reps=args.reps, out_path=args.out,
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
