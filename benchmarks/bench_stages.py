"""Fig 13: stage-wise runtime breakdown (train scene): ellipse baseline at
16/32/64 px tiles vs GS-TG (16+64), on the GPU execution model — showing
GS-TG's sort time matches the 64px baseline while raster time matches 16px;
plus the ASIC model where bitmask gen overlaps sorting."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, scene_and_camera
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render


def run() -> dict:
    scene, cam = scene_and_camera("train")
    out = {}

    for tile in (16, 32, 64):
        cam2 = dataclasses.replace(
            cam, width=(cam.width // tile) * tile, height=(cam.height // tile) * tile
        )
        cfg = RenderConfig(
            mode="tile_baseline", tile=tile, group=tile * 2,
            boundary_tile="ellipse", tile_capacity=1024, group_capacity=1024,
            span=6,
        )
        s = render(scene, cam2, cfg).stats
        c = estimate(s, GSTG_ASIC, mode="tile_baseline")
        out[f"baseline_{tile}"] = c.as_dict()

    cfg = RenderConfig(
        mode="gstg", tile=16, group=64, tile_capacity=1024,
        group_capacity=1024, span=6,
    )
    s = render(scene, cam, cfg).stats
    out["gstg_gpu"] = estimate(s, GSTG_ASIC, mode="gstg", execution="gpu").as_dict()
    out["gstg_asic"] = estimate(s, GSTG_ASIC, mode="gstg", execution="asic").as_dict()

    sort_vs_64 = out["gstg_gpu"]["sort_s"] / max(out["baseline_64"]["sort_s"], 1e-12)
    raster_vs_16 = out["gstg_gpu"]["raster_s"] / max(
        out["baseline_16"]["raster_s"], 1e-12
    )
    emit(
        "fig13_stage_breakdown",
        0.0,
        f"gstg sort/64px-baseline={sort_vs_64:.2f} "
        f"raster/16px-baseline={raster_vs_16:.2f} "
        f"asic_total/gpu_total="
        f"{out['gstg_asic']['total_s']/out['gstg_gpu']['total_s']:.2f}",
    )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
