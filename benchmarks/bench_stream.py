"""Stream-session throughput: exact-reuse frontend cache vs stateless.

The tentpole measurement of the frontend/backend split (DESIGN.md §15): a
smooth orbit trajectory lapped several times through one
``Renderer.open_stream()`` session vs the same frame sequence rendered
statelessly (``Renderer.render``, the fused path). Lap 1 misses and fills
the per-stream cache; every later lap replays the exact float32 poses, so
each frame skips the frontend (project/identify/bin/sort) entirely and
dispatches only the backend program. The headline is the whole-sequence
frame-throughput speedup — cold lap INCLUDED — plus the steady-state
(hot-lap) speedup and the stream hit rate.

Config follows the measured stage split: at 96x96 with 8k gaussians the
frontend is ~84% of the frame (sorting dominates, rasterization is cheap),
which is the regime the paper's tile-grouping targets; the acceptance
floor is ``speedup >= 1.3`` on the default config (validate_bench enforces
it, so a perf regression fails the bench instead of drifting).

Writes the schema-versioned ``BENCH_stream_<host>.json`` trajectory at the
repo root (committed, like BENCH_autotune/BENCH_stages). ``--smoke`` runs
a tiny scene and validates the schema without the speedup floor.
"""
from __future__ import annotations

import json
import os
import platform
import re
import time

import numpy as np

SCHEMA = "repro.bench_stream/v1"

DEFAULT_SCENES = ("train", "truck")
DEFAULT_GAUSSIANS = 8000
DEFAULT_POSES = 16
DEFAULT_LAPS = 4
MIN_SPEEDUP = 1.3


def _host() -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "-", platform.node() or "unknown")


def default_out_path(host: str | None = None) -> str:
    return f"BENCH_stream_{host or _host()}.json"


def validate_bench(doc: dict, min_speedup: float | None = None) -> list:
    """Schema check for a BENCH_stream document; returns problems (empty =
    valid). ``min_speedup`` additionally enforces the acceptance floor on
    every scene's whole-sequence speedup."""
    errs = []
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, expected {SCHEMA!r}")
    for key in ("host", "timestamp", "backend", "config", "scenes"):
        if key not in doc:
            errs.append(f"missing top-level key {key!r}")
    scenes = doc.get("scenes") or {}
    if not scenes:
        errs.append("no scenes")
    for name, sc in scenes.items():
        for k in ("stateless_ms_per_frame", "stream_ms_per_frame",
                  "steady_ms_per_frame", "speedup", "steady_speedup",
                  "hit_rate"):
            if not isinstance(sc.get(k), (int, float)):
                errs.append(f"scene {name}: non-numeric {k!r}")
        for k in ("frames", "poses", "laps"):
            if not isinstance(sc.get(k), int):
                errs.append(f"scene {name}: non-int {k!r}")
        if not isinstance(sc.get("stream_stats"), dict):
            errs.append(f"scene {name}: missing stream_stats")
        hr = sc.get("hit_rate")
        if isinstance(hr, (int, float)) and not 0.0 <= hr <= 1.0:
            errs.append(f"scene {name}: hit_rate {hr} outside [0, 1]")
        laps, poses = sc.get("laps"), sc.get("poses")
        if (isinstance(hr, (int, float)) and isinstance(laps, int)
                and isinstance(poses, int) and laps > 1):
            expect = (laps - 1) / laps   # lap 1 misses, later laps hit
            if abs(hr - expect) > 1e-6:
                errs.append(
                    f"scene {name}: hit_rate {hr} != (laps-1)/laps "
                    f"{expect} — exact reuse broke on the orbit replay")
        if min_speedup is not None:
            sp = sc.get("speedup")
            if isinstance(sp, (int, float)) and sp < min_speedup:
                errs.append(
                    f"scene {name}: speedup {sp:.2f}x below the "
                    f"{min_speedup}x acceptance floor")
    return errs


def _bench_scene(scene, cams, cfg, laps: int):
    """One scene: stateless vs stream over the identical frame sequence."""
    import jax

    from repro import engine

    frames = [cams[i % len(cams)] for i in range(laps * len(cams))]
    with engine.open(scene, cfg) as r:
        # Warm both compiled paths (fused single + frontend/backend split)
        # so neither sequence pays tracing/compile time.
        jax.block_until_ready(r.render(cams[0]).image)
        f0 = r.render_frontend(cams[0])
        jax.block_until_ready(r.render_backend(f0, cams[0]).image)

        t0 = time.perf_counter()
        for cam in frames:
            jax.block_until_ready(r.render(cam).image)
        stateless_s = time.perf_counter() - t0

        with r.open_stream(cache_frames=max(len(cams), 32)) as s:
            t0 = time.perf_counter()
            for cam in frames:
                jax.block_until_ready(s.render(cam).image)
            s.wait_spec_idle(timeout=600.0)   # spec device time is ours too
            stream_s = time.perf_counter() - t0
            seq_stats = s.stats()             # hit rate of the timed sequence

            # Steady state: one extra hot lap, every pose an exact hit.
            t0 = time.perf_counter()
            for cam in cams:
                jax.block_until_ready(s.render(cam).image)
            steady_s = time.perf_counter() - t0

            # Bitwise spot check — the invariant the test suite pins,
            # asserted here too so a bench run can never report a speedup
            # on wrong frames.
            spot = np.asarray(s.render(cams[0]).image)
            ref = np.asarray(r.render(cams[0]).image)
            if not (spot == ref).all():
                raise AssertionError(
                    "stream frame diverged from stateless render — "
                    "refusing to report a speedup on wrong pixels")
            out_stream = s.stats()
    n = len(frames)
    return {
        "frames": n,
        "poses": len(cams),
        "laps": laps,
        "stateless_ms_per_frame": stateless_s / n * 1e3,
        "stream_ms_per_frame": stream_s / n * 1e3,
        "steady_ms_per_frame": steady_s / len(cams) * 1e3,
        "speedup": stateless_s / stream_s,
        "steady_speedup": (stateless_s / n) / (steady_s / len(cams)),
        "hit_rate": seq_stats["hit_rate"],
        "stream_stats": out_stream,
    }


def run(
    scenes=DEFAULT_SCENES,
    n_gaussians: int = DEFAULT_GAUSSIANS,
    width: int = 96,
    height: int = 96,
    backend: str = "reference",
    poses: int = DEFAULT_POSES,
    laps: int = DEFAULT_LAPS,
    out_path: str | None = None,
    min_speedup: float | None = MIN_SPEEDUP,
) -> dict:
    """The orbit-replay bench over ``scenes``; writes the BENCH json and
    returns the doc. ``out_path=None`` writes ``BENCH_stream_<host>.json``
    in the current directory."""
    import jax

    from benchmarks.common import emit
    from repro.configs.gs_scenes import PAPER_SCENES
    from repro.core import orbit_cameras
    from repro.core.gaussians import scene_like_paper
    from repro.core.pipeline import RenderConfig
    import zlib

    cfg = RenderConfig(mode="gstg", backend=backend, span=6)
    doc = {
        "schema": SCHEMA,
        "host": _host(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "jax_backend": jax.default_backend(),
        "backend": backend,
        "config": {
            "n_gaussians": n_gaussians,
            "width": width,
            "height": height,
            "poses": poses,
            "laps": laps,
            "mode": cfg.mode,
        },
        "scenes": {},
    }
    for name in scenes:
        spec = PAPER_SCENES[name]
        seed = zlib.crc32(name.encode()) % 2**31
        scene = scene_like_paper(jax.random.key(seed), name, n_gaussians)
        cams = orbit_cameras(poses, spec.extent * 1.5, width, height)
        t0 = time.time()
        sc = _bench_scene(scene, cams, cfg, laps)
        doc["scenes"][name] = sc
        emit(
            f"stream_{name}",
            sc["stream_ms_per_frame"] * 1e3,
            f"{sc['speedup']:.2f}x vs stateless "
            f"(steady {sc['steady_speedup']:.2f}x, "
            f"hit_rate={sc['hit_rate']:.2f}, "
            f"{sc['stateless_ms_per_frame']:.1f}->"
            f"{sc['stream_ms_per_frame']:.1f}ms/frame, "
            f"{time.time() - t0:.0f}s)",
        )

    errs = validate_bench(doc, min_speedup=min_speedup)
    if errs:
        raise AssertionError("BENCH document invalid: " + "; ".join(errs))
    out = out_path or default_out_path()
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    emit("bench_stream_written", 0.0, out)
    return doc


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenes", default=",".join(DEFAULT_SCENES))
    ap.add_argument("--gaussians", type=int, default=DEFAULT_GAUSSIANS)
    ap.add_argument("--width", type=int, default=96)
    ap.add_argument("--height", type=int, default=96)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--poses", type=int, default=DEFAULT_POSES)
    ap.add_argument("--laps", type=int, default=DEFAULT_LAPS)
    ap.add_argument("--out", default=None,
                    help="output path (default BENCH_stream_<host>.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scene, schema validation only (no speedup "
                         "floor — CI boxes are noisy)")
    args = ap.parse_args(argv)

    if args.smoke:
        if args.out is None:
            args.out = os.path.join("results", "BENCH_stream_smoke.json")
            os.makedirs("results", exist_ok=True)
        run(
            scenes=("train",), n_gaussians=500, width=96, height=96,
            backend=args.backend, poses=4, laps=2,
            out_path=args.out, min_speedup=None,
        )
        print(f"bench_stream --smoke: OK (schema valid, wrote {args.out})")
        return 0

    run(
        scenes=tuple(s.strip() for s in args.scenes.split(",") if s.strip()),
        n_gaussians=args.gaussians,
        width=args.width, height=args.height,
        backend=args.backend,
        poses=args.poses, laps=args.laps,
        out_path=args.out,
        min_speedup=MIN_SPEEDUP,
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
