import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_camera, random_scene
from repro.core.projection import project


def test_projection_shapes_and_finiteness(small_scene, cam128):
    proj = project(small_scene, cam128)
    n = small_scene.num_gaussians
    assert proj.mean2d.shape == (n, 2)
    assert proj.conic.shape == (n, 3)
    assert proj.depth.shape == (n,)
    for field in ("mean2d", "cov2d", "conic", "radius", "rgb", "alpha"):
        v = getattr(proj, field)
        assert bool(jnp.isfinite(v[proj.valid]).all()), field


def test_culling_behind_camera(cam128):
    scene = random_scene(jax.random.key(2), 100, extent=2.0)
    # Move all gaussians behind the camera -> all culled.
    far_behind = scene.means3d + jnp.array([0.0, 0.0, 100.0])
    scene = dataclasses.replace(scene, means3d=far_behind)
    proj = project(scene, cam128)
    assert int(proj.valid.sum()) == 0


def test_cov2d_positive_definite(small_scene, cam128):
    proj = project(small_scene, cam128)
    a, b, c = proj.cov2d[:, 0], proj.cov2d[:, 1], proj.cov2d[:, 2]
    det = a * c - b * b
    valid = proj.valid
    assert bool((a[valid] > 0).all())
    assert bool((det[valid] > 0).all())


def test_eigval_order_and_radius(small_scene, cam128):
    proj = project(small_scene, cam128)
    v = proj.valid
    lam1, lam2 = proj.eigval[:, 0], proj.eigval[:, 1]
    assert bool((lam1[v] >= lam2[v] - 1e-5).all())
    np.testing.assert_allclose(
        np.asarray(proj.radius[v]),
        3.0 * np.sqrt(np.asarray(lam1[v])),
        rtol=1e-5,
    )
    # circumscribed radius bounds both axis extents
    assert bool((proj.radius[v] >= proj.axis_radius[v].max(-1) - 1e-4).all())


def test_rgb_in_range(small_scene, cam128):
    proj = project(small_scene, cam128)
    assert bool((proj.rgb >= 0).all()) and bool((proj.rgb <= 1).all())
    assert bool((proj.alpha >= 0).all()) and bool((proj.alpha <= 1).all())
