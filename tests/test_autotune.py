"""Autotuned tile-grouping (DESIGN.md §13): signature/cache semantics, the
two-phase search, and the engine-handle 'auto' path.

The load-bearing guarantee: ``engine.open(..., tile_params='auto')`` renders
BITWISE-identically to a fixed-config open of the same resolved params —
the handle commits the tuned knobs before any compiled renderer exists, so
both handles run the identical program.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro import engine
from repro.autotune import (
    DEFAULT_CAPACITIES,
    DEFAULT_GROUP_FACTORS,
    DEFAULT_TILES,
    Candidate,
    autotune,
    autotune_signature,
    candidate_grid,
    config_for,
    cost_phase,
    sweep,
)
from repro.autotune import cache as at_cache
from repro.core.pipeline import RenderConfig, render_cache_info

# A small grid keeps the e2e searches to a couple of stats passes + one
# measured candidate (~seconds, fast lane).
TINY_OPTS = dict(
    tiles=(16,), group_factors=(2, 4), capacities=(256,),
    top_k=1, warmup=1, reps=1,
)


@pytest.fixture(autouse=True)
def isolated_autotune_cache(tmp_path, monkeypatch):
    """Point the persisted layer at a per-test file and reset the in-memory
    layer on both sides, so tests neither see nor pollute a real cache."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    at_cache._clear()
    yield
    at_cache._clear()


def _cfg(**kw):
    kw.setdefault("mode", "gstg")
    kw.setdefault("span", 6)
    return RenderConfig(
        tile=16, group=64, group_capacity=256, tile_capacity=256, **kw
    )


# -- signature ----------------------------------------------------------------


def test_signature_excludes_swept_knobs(tiny_scene):
    a = autotune_signature(tiny_scene, 128, 128, _cfg())
    b = autotune_signature(
        tiny_scene, 128, 128,
        dataclasses.replace(
            _cfg(), tile=8, group=32, tile_capacity=512, group_capacity=512
        ),
    )
    assert a == b  # tile/group/capacities are the RESULT, not the key


def test_signature_keys_on_geometry_resolution_backend(tiny_scene,
                                                       small_scene):
    base = autotune_signature(tiny_scene, 128, 128, _cfg())
    assert autotune_signature(tiny_scene, 128, 96, _cfg()) != base
    assert autotune_signature(
        tiny_scene, 128, 128, _cfg(backend="pallas")
    ) != base
    assert autotune_signature(
        tiny_scene, 128, 128, _cfg(mode="tile_baseline")
    ) != base
    assert autotune_signature(small_scene, 128, 128, _cfg()) != base
    # same geometry, different parameter values -> SAME key (a retrained
    # checkpoint reuses the tune)
    clone = dataclasses.replace(
        tiny_scene, means3d=tiny_scene.means3d + 0.1
    )
    assert autotune_signature(clone, 128, 128, _cfg()) == base


# -- grid / config derivation -------------------------------------------------


def test_candidate_grid_is_legal_and_covers_the_floor():
    grid = candidate_grid()
    assert len(grid) == (
        len(DEFAULT_TILES) * len(DEFAULT_GROUP_FACTORS)
        * len(DEFAULT_CAPACITIES)
    )
    # >= 9 distinct (tile, group) points — the BENCH trajectory floor
    assert len({(c.tile, c.group) for c in grid}) >= 9
    for c in grid:
        assert c.group % c.tile == 0  # legal GridSpec

    cfg = config_for(_cfg(), Candidate(8, 64, 512))
    assert (cfg.tile, cfg.group, cfg.tile_capacity) == (8, 64, 512)
    assert cfg.group_capacity >= cfg.tile_capacity


# -- cache layers -------------------------------------------------------------


def test_cache_store_lookup_and_disk_round_trip(tiny_scene):
    sig = autotune_signature(tiny_scene, 128, 128, _cfg())
    assert at_cache.lookup(sig) is None
    at_cache.store(sig, {"tile": 16, "group": 32, "tile_capacity": 256,
                         "measured_ms": 1.5}, scene=tiny_scene)
    hit = at_cache.lookup(sig, scene=tiny_scene)
    assert hit["tile"] == 16 and hit["measured_ms"] == 1.5
    # survive a "process restart": clear memory, reload from the file
    at_cache._clear()
    hit = at_cache.lookup(sig)
    assert hit is not None and hit["source"] == "disk"
    assert hit["group"] == 32
    # the persisted file is valid schema'd JSON
    with open(at_cache.cache_path()) as f:
        doc = json.load(f)
    assert doc["schema"] == "repro.autotune_cache/v1"
    assert len(doc["entries"]) == 1


def test_eviction_drops_memory_keeps_disk(tiny_scene):
    sig = autotune_signature(tiny_scene, 128, 128, _cfg())
    at_cache.store(sig, {"tile": 16, "group": 64, "tile_capacity": 256},
                   scene=tiny_scene)
    assert at_cache.evict_autotune_entries(tiny_scene) == 1
    assert at_cache._info()["currsize"] == 0   # memory gone...
    at_cache._clear()
    assert at_cache.lookup(sig)["source"] == "disk"  # ...disk survives
    # registered with the engine-wide cache registry
    assert "autotune" in render_cache_info()


# -- the search ---------------------------------------------------------------


def test_cost_phase_counts_and_feasibility(tiny_scene, cam128):
    cands = candidate_grid(tiles=(16,), group_factors=(2, 4),
                           capacities=(8, 256))
    entries = cost_phase(tiny_scene, cam128, _cfg(), cands)
    assert len(entries) == len(cands)
    by_knobs = {(e["tile"], e["group"], e["tile_capacity"]): e
                for e in entries}
    # capacity 8 overflows a 200-gaussian scene at 128px -> infeasible;
    # capacity 256 does not
    assert not by_knobs[(16, 32, 8)]["feasible"]
    assert by_knobs[(16, 32, 256)]["feasible"]
    for e in entries:
        assert e["est_total_s"] > 0
        assert e["measured_ms"] is None  # phase 1 never times anything


def test_autotune_search_caches_and_rehits(tiny_scene, cam128):
    cfg = _cfg()
    res = autotune(tiny_scene, cam128, cfg, **TINY_OPTS)
    assert res.source == "search"
    assert res.measured_ms is not None and res.measured_ms > 0
    assert len(res.trajectory) == 2  # full grid recorded, pruned or not
    again = autotune(tiny_scene, cam128, cfg, **TINY_OPTS)
    assert again.source in ("cache", "disk")
    assert again.candidate == res.candidate


@pytest.mark.slow
def test_sweep_winner_is_measured_minimum(tiny_scene, cam128):
    res = sweep(tiny_scene, cam128, _cfg(),
                tiles=(16,), group_factors=(2, 4), capacities=(256,),
                warmup=1, reps=1)
    measured = [e for e in res.trajectory if e["measured_ms"] is not None]
    assert len(measured) == 2  # top_k=None measures EVERY feasible point
    assert res.measured_ms <= min(e["measured_ms"] for e in measured)
    # a sweep must not have written the cache (benchmarks re-measure)
    assert at_cache._info()["currsize"] == 0


# -- the engine-handle 'auto' path --------------------------------------------


def test_open_auto_bitwise_matches_fixed(tiny_scene, cam128):
    cfg = _cfg()
    with engine.open(tiny_scene, cfg, tile_params="auto",
                     autotune_opts=TINY_OPTS) as ra:
        assert ra.tile_params == "auto (pending)"
        img_a = np.asarray(ra.render(cam128).image)
        tuned = ra.tile_params
        assert isinstance(tuned, tuple)
        assert ra.stats()["tile_params"] == tuned
    with engine.open(tiny_scene, cfg, tile_params=tuned) as rf:
        img_f = np.asarray(rf.render(cam128).image)
    assert (img_a == img_f).all()   # acceptance criterion 4: BITWISE


@pytest.mark.slow
def test_open_auto_bitwise_matches_fixed_pallas(tiny_scene, cam128):
    cfg = _cfg(backend="pallas")
    with engine.open(tiny_scene, cfg, tile_params="auto",
                     autotune_opts=TINY_OPTS) as ra:
        img_a = np.asarray(ra.render(cam128).image)
        tuned = ra.tile_params
    with engine.open(tiny_scene, cfg, tile_params=tuned) as rf:
        img_f = np.asarray(rf.render(cam128).image)
    assert (img_a == img_f).all()


def test_open_explicit_triple_and_validation(tiny_scene, cam128):
    cfg = _cfg()
    with engine.open(tiny_scene, cfg, tile_params=(16, 32, 512)) as r:
        assert r.tile_params == (16, 32, 512)
        assert r.stats()["config"].group == 32
        r.render(cam128)
    with pytest.raises(ValueError):
        engine.open(tiny_scene, cfg, tile_params=(16, 32))
    with pytest.raises(ValueError):
        engine.open(tiny_scene, cfg, tile_params="fastest")


@pytest.mark.slow
def test_close_evicts_autotune_entries_disk_survives(tiny_scene, cam128):
    cfg = _cfg()
    with engine.open(tiny_scene, cfg, tile_params="auto",
                     autotune_opts=TINY_OPTS) as r:
        r.render(cam128)
        assert at_cache._info()["currsize"] == 1
    assert at_cache._info()["currsize"] == 0   # close() evicted (memory)
    # a re-open skips the search: the persisted file answers the lookup
    with engine.open(tiny_scene, cfg, tile_params="auto",
                     autotune_opts=TINY_OPTS) as r2:
        r2.render(cam128)
        assert isinstance(r2.tile_params, tuple)
    info = render_cache_info()["autotune"]
    assert info["hits"] >= 1


@pytest.mark.slow
def test_render_server_autotune_path(tiny_scene, cam128):
    """RenderServer(autotune=True): the first dispatch tunes, the handle
    serves the committed triple afterwards."""
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer

    cfg = _cfg()
    with RenderServer({"s": tiny_scene}, autotune=True,
                      autotune_opts=TINY_OPTS,
                      max_batch=2, max_wait=0.01) as srv:
        for i in range(2):
            assert srv.submit(RenderRequest(i, "s", cam128, cfg))
        srv.drain()
        assert len(srv.results) == 2
        assert isinstance(srv.commit("s", cfg).tile_params, tuple)


@pytest.mark.slow
def test_auto_render_batch_resolves_from_lane0(tiny_scene, cam128):
    cfg = _cfg()
    with engine.open(tiny_scene, cfg, tile_params="auto",
                     autotune_opts=TINY_OPTS) as r:
        out = r.render_batch([cam128, cam128], pad_to=2)
        assert isinstance(r.tile_params, tuple)
        assert np.asarray(out.image).shape[0] == 2
