"""Boundary-test properties that the losslessness proof relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import make_camera, random_scene
from repro.core.boundary import (
    aabb_test,
    boundary_test,
    ellipse_min_q,
    ellipse_test,
    obb_test,
)
from repro.core.projection import project, QMAX_3SIGMA


def _proj(seed=0, n=300):
    scene = random_scene(jax.random.key(seed), n, extent=3.0)
    cam = make_camera((0, 1, 4.5), (0, 0, 0), 128, 128)
    return project(scene, cam)


rects = st.tuples(
    st.floats(-40, 130), st.floats(-40, 130), st.floats(4, 80), st.floats(4, 80)
)


@settings(max_examples=25, deadline=None)
@given(rects)
def test_conservativeness_chain(r):
    """ellipse hit => obb hit, and ellipse hit => aabb hit (on any rect).

    This ordering is what makes every boundary method a superset of the true
    q<=9 support, hence lossless (DESIGN.md §7)."""
    proj = _proj()
    x0, y0, w, h = r
    rect = (x0, y0, x0 + w, y0 + h)
    e = ellipse_test(proj.mean2d, proj.conic, rect)
    o = obb_test(proj.mean2d, proj.eigvec, proj.eigval, rect)
    a = aabb_test(proj.mean2d, proj.radius, rect)
    assert bool(jnp.all(~e | o)), "ellipse hit without obb hit"
    assert bool(jnp.all(~e | a)), "ellipse hit without aabb hit"


@settings(max_examples=25, deadline=None)
@given(rects)
def test_monotonicity_under_containment(r):
    """tile ⊂ group => test(tile) => test(group), for every method."""
    proj = _proj(1)
    x0, y0, w, h = r
    tile = (x0, y0, x0 + w, y0 + h)
    group = (x0 - 8.0, y0 - 8.0, x0 + w + 8.0, y0 + h + 8.0)
    for method in ("aabb", "obb", "ellipse"):
        t = boundary_test(method, proj, tile)
        g = boundary_test(method, proj, group)
        assert bool(jnp.all(~t | g)), method


def test_ellipse_min_q_exact_vs_grid():
    """Closed-form rect minimum of the conic form matches dense sampling."""
    proj = _proj(2, n=50)
    rect = (30.0, 30.0, 60.0, 55.0)
    qmin = ellipse_min_q(proj.mean2d, proj.conic, rect)
    xs = jnp.linspace(rect[0], rect[2], 120)
    ys = jnp.linspace(rect[1], rect[3], 120)
    gx, gy = jnp.meshgrid(xs, ys)
    pts = jnp.stack([gx.reshape(-1), gy.reshape(-1)], -1)  # (P, 2)
    d = pts[None, :, :] - proj.mean2d[:, None, :]
    q = (
        proj.conic[:, None, 0] * d[..., 0] ** 2
        + 2 * proj.conic[:, None, 1] * d[..., 0] * d[..., 1]
        + proj.conic[:, None, 2] * d[..., 1] ** 2
    )
    q_grid = jnp.min(q, axis=1)
    # closed form is a true minimum: <= grid min (+tol), and close when the
    # grid is fine
    assert bool(jnp.all(qmin <= q_grid + 1e-3))
    np.testing.assert_allclose(
        np.asarray(qmin), np.asarray(q_grid), rtol=0.15, atol=0.3
    )


def test_ellipse_inside_center_zero():
    proj = _proj(3, n=20)
    mx, my = proj.mean2d[:, 0], proj.mean2d[:, 1]
    rect = (mx - 1.0, my - 1.0, mx + 1.0, my + 1.0)
    q = ellipse_min_q(proj.mean2d, proj.conic, rect)
    assert bool(jnp.all(q == 0.0))
