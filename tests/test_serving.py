"""Serving subsystem: queue/bucketing (pure Python), sharded dispatch, and
the end-to-end server loop (DESIGN.md §9).

The queue/bucketing/stats tests run the scheduling layer with stub cameras
and injected clocks — no jax, no devices, deterministic time — because that
layer is pure by design (enforced by test_pure_layer_imports_without_jax).
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path
from types import SimpleNamespace

import pytest

from repro.serving.bucketing import (
    BucketingScheduler,
    pad_indices,
    pad_indices_to,
    padded_size,
)
from repro.serving.queue import QueueFull, RenderRequest, RequestQueue
from repro.serving.stats import ServingStats, cache_delta, percentile

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _cam(w=128, h=128):
    return SimpleNamespace(width=w, height=h, znear=0.2, zfar=1000.0)


def _req(i, w=128, h=128, cfg="cfg-a", scene="scene-a"):
    return RenderRequest(i, scene, _cam(w, h), cfg)


# ---------------------------------------------------------------------------
# pure layer: queue
# ---------------------------------------------------------------------------


def test_pure_layer_imports_without_jax():
    """queue/bucketing/stats must not pull jax (admission layer runs
    anywhere; importing repro.serving must not init devices)."""
    code = (
        "import sys; import repro.serving; "
        "import repro.serving.queue, repro.serving.bucketing, "
        "repro.serving.stats; "
        "assert 'jax' not in sys.modules, 'pure serving layer imported jax'"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stderr


def test_queue_fifo_depth_and_backpressure():
    q = RequestQueue(maxsize=2, clock=lambda: 0.0)
    q.put_nowait(_req(0))
    q.put_nowait(_req(1))
    with pytest.raises(QueueFull):
        q.put_nowait(_req(2))
    assert not q.try_put(_req(2))
    assert not q.put(_req(2), timeout=0.0)       # bounded put times out
    assert (q.accepted, q.rejected) == (2, 3)
    assert [r.request_id for r in q.drain()] == [0, 1]   # FIFO
    assert len(q) == 0 and q.try_put(_req(3))            # space freed


def test_queue_rejections_count_into_registry():
    """Backpressure is a first-class metrics signal: every failed put
    attempt increments queue.rejected_total alongside the local field
    (delta-based — the registry is process-global across tests)."""
    from repro.obs import get_registry

    before = get_registry().counter("queue.rejected_total").value
    q = RequestQueue(maxsize=1, clock=lambda: 0.0)
    q.put_nowait(_req(0))
    with pytest.raises(QueueFull):
        q.put_nowait(_req(1))
    assert not q.try_put(_req(1))
    assert not q.put(_req(1), timeout=0.0)
    assert q.rejected == 3
    assert get_registry().counter("queue.rejected_total").value == before + 3


def test_queue_enqueue_time_stamped():
    q = RequestQueue(maxsize=4, clock=lambda: 42.0)
    q.put_nowait(_req(0))
    (r,) = q.drain()
    assert r.enqueue_time == 42.0


def test_queue_get_batch_bounds_and_timeout():
    q = RequestQueue(maxsize=8, clock=lambda: 0.0)
    for i in range(5):
        q.put_nowait(_req(i))
    got = q.get_batch(max_n=3)
    assert [r.request_id for r in got] == [0, 1, 2]
    assert q.get_batch() and q.get_batch(timeout=0.0) == []


# ---------------------------------------------------------------------------
# pure layer: bucketing scheduler
# ---------------------------------------------------------------------------


def test_bucketing_flush_on_max_batch():
    sched = BucketingScheduler(max_batch=3, max_wait=10.0, clock=lambda: 0.0)
    assert sched.add(_req(0)) == []
    assert sched.add(_req(1)) == []
    (bucket,) = sched.add(_req(2))               # third request fills it
    assert [r.request_id for r in bucket.requests] == [0, 1, 2]
    assert sched.pending == 0                    # flushed buckets leave


def test_bucketing_flush_on_max_wait():
    sched = BucketingScheduler(max_batch=100, max_wait=0.05)
    sched.add(_req(0), now=1.0)
    sched.add(_req(1), now=1.03)
    assert sched.poll(now=1.04) == []            # oldest only 40ms old
    (bucket,) = sched.poll(now=1.05)             # 50ms: due
    assert len(bucket) == 2 and bucket.age(1.05) == pytest.approx(0.05)
    assert sched.poll(now=9.9) == []             # nothing left


def test_bucketing_signature_isolation():
    """Requests mix only within one executable signature: resolution, cfg,
    and scene each split buckets."""
    sched = BucketingScheduler(max_batch=2, max_wait=10.0, clock=lambda: 0.0)
    sched.add(_req(0, w=128))
    sched.add(_req(1, w=256))                    # other resolution
    sched.add(_req(2, cfg="cfg-b"))              # other config
    sched.add(_req(3, scene="scene-b"))          # other scene
    assert sched.pending == 4                    # four singleton buckets
    (bucket,) = sched.add(_req(4, w=256))        # completes the 256 bucket
    assert {r.request_id for r in bucket.requests} == {1, 4}
    buckets = sched.flush_all()
    assert sorted(len(b) for b in buckets) == [1, 1, 1]
    assert sched.pending == 0


def test_padding_round_trip():
    assert padded_size(1, 4) == 4
    assert padded_size(4, 4) == 4
    assert padded_size(5, 4) == 8
    assert padded_size(7, 1) == 7
    for n, m in [(1, 1), (3, 2), (5, 4), (8, 8), (9, 8)]:
        idx = pad_indices(n, m)
        assert len(idx) == padded_size(n, m) and len(idx) % m == 0
        assert idx[:n] == list(range(n))         # slicing off the pad is exact
        assert all(i == n - 1 for i in idx[n:])  # pad replicates the last lane
    # The absolute-target variant (the fixed-dispatch-shape policy the
    # server's pad_to uses) obeys the same round trip.
    for n, target in [(1, 4), (3, 3), (3, 8)]:
        idx = pad_indices_to(n, target)
        assert len(idx) == target and idx[:n] == list(range(n))
        assert all(i == n - 1 for i in idx[n:])
    with pytest.raises(ValueError):
        padded_size(0, 4)
    with pytest.raises(ValueError):
        pad_indices_to(5, 3)


# ---------------------------------------------------------------------------
# pure layer: stats
# ---------------------------------------------------------------------------


def test_stats_percentiles_and_aggregation():
    assert percentile([], 50) != percentile([], 50)      # nan
    assert percentile([5.0], 99) == 5.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)

    stats = ServingStats()
    stats.record_dispatch(("sig-a",), 3, 4, 0.1, [0.01, 0.02, 0.03])
    stats.record_dispatch(("sig-a",), 2, 2, 0.1, [0.02, 0.04])
    stats.record_dispatch(("sig-b",), 1, 1, 0.1, [0.05])
    stats.wall_s = 0.5
    s = stats.summary()
    assert s["completed"] == 6 and s["batches"] == 3 and s["padded"] == 1
    assert s["fps"] == pytest.approx(12.0)
    assert stats.bucket(("sig-a",)).mean_batch == pytest.approx(2.5)
    assert s["p99_ms"] <= 50.0 + 1e-6
    assert "sig-a" in stats.format()


def test_stats_cache_delta():
    before = {"single": dict(hits=1, misses=2), "batch": dict(hits=0, misses=1)}
    after = {"single": dict(hits=1, misses=2), "batch": dict(hits=3, misses=2)}
    assert cache_delta(before, after) == {"hits": 3, "misses": 1}


def test_stats_latency_memory_is_bounded():
    """A long-lived server must not grow one float per request: latencies
    live in reservoir histograms (DESIGN.md §14) — bounded storage, exact
    counts, percentiles from a uniform sample once past the cap."""
    from repro.obs import MetricsRegistry
    from repro.serving.stats import LATENCY_RESERVOIR

    stats = ServingStats(registry=MetricsRegistry())
    n = LATENCY_RESERVOIR + 500
    for i in range(n // 10):
        stats.record_dispatch(("sig",), 10, 10, 0.01,
                              [0.01 * (j + 1) for j in range(10)])
    b = stats.bucket(("sig",))
    assert b.requests == (n // 10) * 10
    assert b.latency.count == b.requests          # exact count survives
    assert len(b.latencies_s) == LATENCY_RESERVOIR   # bounded storage
    assert len(stats.all_latencies()) == LATENCY_RESERVOIR
    assert b.latency.sampled
    d = b.to_dict()
    assert d["latency_count"] == b.requests and d["latency_sampled"]
    # percentiles still come out of the sampled window
    assert 0.01 <= d["p99_ms"] / 1e3 <= 0.1


def test_stats_record_dispatch_thread_safe():
    """Dispatch folds race in production (driver loop + futures worker);
    every counter must survive N threads folding concurrently."""
    import threading

    from repro.obs import MetricsRegistry

    reg = MetricsRegistry()
    stats = ServingStats(registry=reg)
    threads, per_thread, batch = 8, 50, 4
    barrier = threading.Barrier(threads)

    def fold(k):
        barrier.wait(timeout=10)
        for _ in range(per_thread):
            stats.record_dispatch((f"sig-{k % 2}",), batch, batch + 1,
                                  0.001, [0.01] * batch)
            stats.count_rejected()
            stats.count_deadline_miss()

    ts = [threading.Thread(target=fold, args=(k,)) for k in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    total = threads * per_thread
    s = stats.summary()
    assert s["completed"] == total * batch
    assert s["batches"] == total
    assert s["padded"] == total
    assert s["rejected"] == total
    assert s["deadline_misses"] == total
    assert stats.latency.count == total * batch
    snap = reg.snapshot()
    assert snap["counters"]["serving.requests_total"] == total * batch
    assert snap["counters"]["serving.batches_total"] == total
    assert snap["counters"]["serving.rejected_total"] == total
    assert snap["histograms"]["serving.latency_s"]["count"] == total * batch


# ---------------------------------------------------------------------------
# jax layer: sharded dispatch + server loop
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serving_cfg():
    from repro.core.pipeline import RenderConfig

    return RenderConfig(
        tile=16, group=64, group_capacity=256, tile_capacity=256
    )


def test_sharded_one_device_bitwise(small_scene, serving_cfg):
    """render_batch_sharded over a 1-device mesh IS render_batch."""
    import numpy as np

    from repro.core import orbit_cameras
    from repro.core.pipeline import render_batch
    from repro.launch.mesh import make_render_mesh
    from repro.serving.sharded import render_batch_sharded

    cams = orbit_cameras(3, 4.5, 128, 128)
    plain = render_batch(small_scene, cams, serving_cfg)
    shard = render_batch_sharded(
        small_scene, cams, serving_cfg, mesh=make_render_mesh(1)
    )
    assert (np.asarray(shard.image) == np.asarray(plain.image)).all()
    for name in vars(plain.stats):
        a = np.asarray(getattr(plain.stats, name))
        b = np.asarray(getattr(shard.stats, name))
        assert (a == b).all(), f"sharded stats counter {name} diverges"


def test_pad_camera_batch_mask_correct(small_scene, serving_cfg):
    """Rendering the padded batch and slicing the pad off reproduces the
    unpadded render exactly — padding only appends replicated lanes."""
    import numpy as np

    from repro.core import orbit_cameras
    from repro.core.pipeline import CameraBatch, render_batch
    from repro.serving.sharded import pad_camera_batch

    batch = CameraBatch.from_cameras(orbit_cameras(3, 4.5, 128, 128))
    padded = pad_camera_batch(batch, 4)
    assert len(padded) == 4 and len(pad_camera_batch(batch, 3)) == 3
    out_pad = render_batch(small_scene, padded, serving_cfg)
    out = render_batch(small_scene, batch, serving_cfg)
    assert (np.asarray(out_pad.image[:3]) == np.asarray(out.image)).all()
    assert (np.asarray(out_pad.image[3]) == np.asarray(out.image[2])).all()


def test_server_end_to_end(tiny_scene, serving_cfg):
    """Mixed resolutions through queue -> bucket -> dispatch: every request
    completes with the image render() produces, buckets never mix
    signatures, cache counters see the executable reuse."""
    import numpy as np

    from conftest import jit_render

    from repro.core import make_camera
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer

    resolutions = [(96, 64), (64, 64)]
    reqs = []
    for i in range(9):
        w, h = resolutions[i % 2]
        cam = make_camera((1.5 - 0.2 * i, 1.0, 4.0), (0, 0, 0), w, h)
        reqs.append(RenderRequest(i, "scene", cam, serving_cfg))

    server = RenderServer(
        {"scene": tiny_scene}, max_batch=3, max_wait=0.0, queue_depth=16
    )
    results = server.run([(0.0, r) for r in reqs], realtime=False)

    assert sorted(results) == list(range(9))
    assert server.stats.rejected == 0
    for r in reqs:
        got = results[r.request_id]
        assert got.signature == r.signature()
        # jit'd oracle (conftest session cache): the dispatch path is jit
        # too, and the 1e-6 tolerance absorbs batched-vs-single fusion.
        expect = jit_render(tiny_scene, r.camera, serving_cfg)
        np.testing.assert_allclose(
            got.image, np.asarray(expect.image), atol=1e-6, rtol=1e-6
        )
    s = server.stats.summary()
    assert s["completed"] == 9
    assert len(server.stats.buckets) == 2        # one bucket per signature
    assert s["cache_hits"] > 0                   # repeated signatures reused
    assert np.isfinite(s["p99_ms"]) and s["fps"] > 0


def test_render_cache_covers_scene_layout(tiny_scene):
    """render_cache_clear()/render_cache_info() must cover ALL renderer
    caches, including the sharded scene-LAYOUT cache serving/sharded.py
    keeps — otherwise the server's cache-hit stats (deltas of
    render_cache_info) would lie about sharded dispatches."""
    from repro.core.pipeline import render_cache_clear, render_cache_info
    from repro.serving.sharded import shard_scene_cached

    render_cache_clear()
    info = render_cache_info()
    assert "scene_layout" in info
    assert (info["scene_layout"]["hits"], info["scene_layout"]["misses"]) == (0, 0)

    a = shard_scene_cached(tiny_scene, 2)
    b = shard_scene_cached(tiny_scene, 2)    # hit: same scene, same layout
    shard_scene_cached(tiny_scene, 4)        # miss: different shard count
    assert a is b
    info = render_cache_info()["scene_layout"]
    assert info["hits"] == 1 and info["misses"] == 2 and info["currsize"] == 2

    render_cache_clear()                     # must drop the layout cache too
    info = render_cache_info()["scene_layout"]
    assert (info["hits"], info["misses"], info["currsize"]) == (0, 0, 0)


def test_server_scene_sharded_end_to_end(tiny_scene, serving_cfg):
    """Scene-sharded requests through the full queue -> bucket -> dispatch
    path: bitwise-identical to the replicated batched render, and the
    replicated/sharded layouts of one scene never share a bucket."""
    import dataclasses

    import numpy as np

    from repro.core import make_camera
    from repro.core.pipeline import render_batch
    from repro.launch.mesh import make_render_mesh
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer

    cfg_sh = dataclasses.replace(serving_cfg, scene_shards=2)
    cams = [
        make_camera((1.5 - 0.3 * i, 1.0, 4.0), (0, 0, 0), 64, 64)
        for i in range(4)
    ]
    reqs = [
        RenderRequest(i, "scene", cam, cfg_sh if i % 2 else serving_cfg)
        for i, cam in enumerate(cams)
    ]
    server = RenderServer(
        {"scene": tiny_scene}, mesh=make_render_mesh(1),
        max_batch=2, max_wait=0.0, queue_depth=16, scene_shards=2,
    )
    results = server.run([(0.0, r) for r in reqs], realtime=False)
    assert sorted(results) == [0, 1, 2, 3]
    assert len(server.stats.buckets) == 2    # replicated vs sharded split
    for r in reqs:
        expect = render_batch(tiny_scene, [r.camera], serving_cfg)
        assert (
            results[r.request_id].image == np.asarray(expect.image[0])
        ).all(), f"request {r.request_id} diverges from replicated batch"


def test_server_shares_committed_scene_across_configs(tiny_scene, serving_cfg):
    """Two configs over one scene open two handles (different compiled
    programs) but ONE committed device scene: the second handle commits on
    the first's device copy, so per-scene HBM does not scale with the
    config count."""
    import dataclasses

    from repro.serving.server import RenderServer

    with RenderServer({"scene": tiny_scene}) as server:
        a = server.commit("scene", serving_cfg)
        b = server.commit(
            "scene", dataclasses.replace(serving_cfg, mode="tile_baseline")
        )
        assert a is not b
        assert a.committed_scene.means3d is b.committed_scene.means3d


def test_server_backpressure_and_unknown_scene(tiny_scene, serving_cfg):
    from repro.core import make_camera
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer

    cam = make_camera((0, 1, 4), (0, 0, 0), 64, 64)
    server = RenderServer({"scene": tiny_scene}, queue_depth=1)
    assert server.submit(RenderRequest(0, "scene", cam, serving_cfg))
    assert not server.submit(RenderRequest(1, "scene", cam, serving_cfg))
    assert server.stats.rejected == 1
    with pytest.raises(KeyError):
        server.submit(RenderRequest(2, "nope", cam, serving_cfg))


def test_server_rejects_unservable_scene_shards(tiny_scene, serving_cfg):
    """A request whose cfg.scene_shards neither is 1 nor matches the server
    must be screened at ADMISSION (submit raises; run skips + rejects) —
    letting it reach the dispatch would kill the loop for every queued
    request behind it."""
    import dataclasses

    from repro.core import make_camera
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer

    cam = make_camera((0, 1, 4), (0, 0, 0), 64, 64)
    bad_cfg = dataclasses.replace(serving_cfg, scene_shards=4)
    server = RenderServer({"scene": tiny_scene}, scene_shards=2)
    with pytest.raises(ValueError, match="scene_shards"):
        server.submit(RenderRequest(0, "scene", cam, bad_cfg))
    # run(): the bad request is rejected, the good one still completes.
    load = [
        (0.0, RenderRequest(1, "scene", cam, bad_cfg)),
        (0.0, RenderRequest(2, "scene", cam, serving_cfg)),
    ]
    results = server.run(load, realtime=False)
    assert sorted(results) == [2]
    assert server.stats.rejected == 1


def test_render_batch_sharded_default_mesh_logical_fallback(
    tiny_scene, serving_cfg
):
    """mesh=None with a shard count that does not divide the device count
    must fall back to the logical shard axis (the docstring's single-device
    contract), not crash in make_render_mesh."""
    import dataclasses

    import numpy as np

    from repro.core import orbit_cameras
    from repro.core.pipeline import render_batch
    from repro.serving.sharded import render_batch_sharded

    cams = orbit_cameras(2, 4.5, 64, 64)
    cfg = dataclasses.replace(serving_cfg, scene_shards=3)
    out = render_batch_sharded(tiny_scene, cams, cfg)   # 3 shards, 1 device
    rep = render_batch(tiny_scene, cams, serving_cfg)
    assert (np.asarray(out.image) == np.asarray(rep.image)).all()


@pytest.mark.slow
def test_render_serve_cli_multi_device(tmp_path):
    """The CLI end-to-end on 2 virtual host devices (fresh process so the
    XLA flag lands before jax init): all requests complete, a Chrome trace
    (DESIGN.md §14) is written with the stats summary riding along, and the
    metrics snapshot agrees with it."""
    import json

    from repro.obs import validate_chrome_trace

    trace = tmp_path / "trace.json"
    metrics = tmp_path / "metrics.json"
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.render_serve",
            "--requests", "6", "--rate", "500", "--devices", "2",
            "--gaussians", "400", "--resolutions", "64x64",
            "--scenes", "train", "--max-batch", "3", "--max-wait", "0.02",
            "--no-realtime", "--trace-json", str(trace),
            "--metrics-json", str(metrics),
        ],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": str(tmp_path)},
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(trace.read_text())
    assert validate_chrome_trace(doc) == []
    data = doc["summary"]   # the pre-§14 stats document rides along here
    assert data["completed"] == 6 and data["devices"] == 2
    assert len(data["requests"]) == 6
    # 2 batches of 3 on 2 devices -> each padded to 4: 2 wasted lanes total
    assert data["padded"] == 2
    # request-lifecycle spans: one `request` span per completed request
    reqs = [e for e in doc["traceEvents"]
            if e.get("ph") == "X" and e.get("name") == "request"]
    assert len(reqs) == 6
    snap = json.loads(metrics.read_text())
    assert snap["schema"] == "repro.metrics/v1"
    assert snap["counters"]["serving.requests_total"] == 6
    assert snap["histograms"]["serving.latency_s"]["count"] == 6


def test_server_close_vs_commit_race_leaves_registry_empty(
    tiny_scene, serving_cfg
):
    """close() racing commit() must never leak a handle: the server lock
    orders them — a commit that wins the lock opens a handle close() then
    tears down; one that loses raises RuntimeError. Either way the handle
    registry is empty after close and every handle handed out is closed."""
    import threading

    from repro.serving.server import RenderServer

    for _attempt in range(3):
        server = RenderServer({"scene": tiny_scene})
        handles, barrier = [], threading.Barrier(3)

        def committer():
            barrier.wait()
            try:
                handles.append(server.commit("scene", serving_cfg))
            except RuntimeError:
                pass                     # lost the race: commit after close

        threads = [threading.Thread(target=committer) for _ in range(2)]
        for t in threads:
            t.start()
        barrier.wait()
        server.close()
        for t in threads:
            t.join()
        assert server._renderers == {}, "close() left a handle registered"
        assert all(h.closed for h in handles), "a raced commit leaked"
        with pytest.raises(RuntimeError, match="closed"):
            server.commit("scene", serving_cfg)
