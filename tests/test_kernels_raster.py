"""Pallas raster kernels vs pure-jnp oracle: shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_camera, random_scene
from repro.core.bitmask import compact_tiles, generate_bitmasks
from repro.core.grouping import GridSpec, bin_pairs, identify
from repro.core.pipeline import RenderConfig, render
from repro.core.projection import project
from repro.kernels import ops, ref as kref
from repro.kernels.layout import pack_features
from repro.kernels.raster_tile import raster_group_fused_kernel, raster_tile_kernel


def _tables(seed=1, w=96, h=96, tile=16, group=64, gcap=256, tcap=128):
    # Smallest scene/grid that still exercises the kernels in interpret
    # mode (multiple tiles AND groups, K > one chunk): interpret-mode cost
    # scales with pixels x entries, and these oracle comparisons dominated
    # the fast lane at 128x128/700.
    scene = random_scene(jax.random.key(seed), 400, extent=3.0)
    cam = make_camera((0, 1.0, 4.5), (0, 0, 0), w, h)
    proj = project(scene, cam)
    grid = GridSpec(w, h, tile, group, span=4)
    pairs = identify(proj, grid, "group", "ellipse")
    gtable = bin_pairs(pairs, grid.num_groups, gcap)
    masks = generate_bitmasks(proj, gtable, grid, "ellipse")
    ttable = compact_tiles(gtable, masks, grid, tcap)
    return proj, grid, gtable, masks, ttable


@pytest.mark.parametrize(
    "tile,chunk",
    [
        # Fast lane keeps the default tile=16 layout; the other tile/chunk
        # layouts cover lane/packing variants and ride the slow lane.
        (16, 64),
        pytest.param(8, 64, marks=pytest.mark.slow),
        pytest.param(16, 128, marks=pytest.mark.slow),
        pytest.param(32, 128, marks=pytest.mark.slow),
    ],
)
def test_raster_tile_kernel_vs_oracle(tile, chunk):
    group = tile * 4
    proj, grid, _, _, ttable = _tables(tile=tile, group=group, tcap=128)
    feat = pack_features(proj, ttable.gauss_idx, ttable.entry_valid)
    origins = ops.tile_origins(grid)
    out_k = raster_tile_kernel(feat, origins, tile, chunk=chunk, interpret=True)
    out_r = kref.ref_raster_tiles(feat, origins, tile)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), atol=3e-6, rtol=1e-5
    )


@pytest.mark.parametrize("gf", [2, 4])
def test_fused_kernel_vs_oracle(gf):
    tile = 16
    proj, grid, gtable, masks, _ = _tables(tile=tile, group=tile * gf)
    feat = pack_features(proj, gtable.gauss_idx, gtable.entry_valid)
    origins = ops.group_origins(grid)
    out_k = raster_group_fused_kernel(
        feat, masks.masks, origins, tile, gf, chunk=128, interpret=True
    )
    out_r = kref.ref_raster_group_fused(feat, masks.masks, origins, tile, gf)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), atol=3e-6, rtol=1e-5
    )


def test_kernel_pipeline_matches_core():
    """End-to-end: pallas backend == reference backend through render()."""
    import dataclasses

    # Smallest shape that still exercises the kernels end-to-end: 2x2
    # groups (gf=4 bitmask lanes in play), multi-chunk K, non-trivial
    # occupancy. The big-scene kernel coverage lives in the slow-lane
    # oracle matrix below.
    scene = random_scene(jax.random.key(5), 400, extent=3.0)
    cam = make_camera((0, 1.0, 4.5), (0, 0, 0), 96, 96)
    cfg = RenderConfig(group_capacity=256, tile_capacity=256)
    ref_img = render(scene, cam, cfg).image
    img = render(scene, cam, dataclasses.replace(cfg, backend="pallas")).image
    np.testing.assert_allclose(
        np.asarray(img), np.asarray(ref_img), atol=5e-6, rtol=1e-5
    )


def test_raster_kernel_empty_tiles():
    """Tiles with zero entries produce pure transmittance=1 output."""
    proj, grid, _, _, ttable = _tables(seed=9)
    import dataclasses

    empty = dataclasses.replace(
        ttable,
        entry_valid=jnp.zeros_like(ttable.entry_valid),
        lengths=jnp.zeros_like(ttable.lengths),
    )
    feat = pack_features(proj, empty.gauss_idx, empty.entry_valid)
    out = raster_tile_kernel(feat, ops.tile_origins(grid), 16, chunk=128,
                             interpret=True)
    out = np.asarray(out)
    assert np.allclose(out[:, :3, :], 0.0)
    assert np.allclose(out[:, 3, :], 1.0)
