import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_camera, random_scene
from repro.core.pipeline import RenderConfig, render
from repro.core.metrics import psnr, ssim


def test_background_fills_empty(tiny_scene, cam128):
    # point the camera away from the scene: pure background. jit'd render
    # (conftest session cache) — the property is tolerance-based.
    from conftest import jit_render

    cam = make_camera((0, 0, 50.0), (0, 0, 100.0), 128, 128)
    bg = jnp.array([0.2, 0.4, 0.6])
    out = jit_render(tiny_scene, cam, RenderConfig(), background=bg)
    img = np.asarray(out.image)
    assert np.allclose(img, np.array([0.2, 0.4, 0.6]), atol=1e-5)


def test_early_exit_close_to_exact(small_scene, cam128):
    from conftest import jit_render

    cfg_on = RenderConfig(early_exit=True)
    cfg_off = RenderConfig(early_exit=False)
    a = np.asarray(jit_render(small_scene, cam128, cfg_on).image)
    b = np.asarray(jit_render(small_scene, cam128, cfg_off).image)
    # early exit discards contributions behind T<1e-4: tiny difference
    assert np.abs(a - b).max() < 5e-3


def test_gradients_flow(tiny_scene):
    # 64x64 with small capacities: gradient flow is a structural property —
    # the full-size differentiable path is covered by the training tests.
    from repro.core import make_camera

    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    cfg = RenderConfig(group_capacity=128, tile_capacity=128)

    def loss(s):
        return jnp.mean((render(s, cam, cfg).image - 0.25) ** 2)

    g = jax.jit(jax.grad(loss))(tiny_scene)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.isfinite(x).all()) for x in leaves)
    total = sum(float(jnp.abs(x).sum()) for x in leaves)
    assert total > 0.0


def test_chunk_size_invariance(small_scene, cam128):
    from conftest import jit_render

    imgs = []
    for chunk in (16, 32, 64):
        cfg = RenderConfig(chunk=chunk)
        imgs.append(np.asarray(jit_render(small_scene, cam128, cfg).image))
    np.testing.assert_allclose(imgs[0], imgs[1], atol=2e-6)
    np.testing.assert_allclose(imgs[1], imgs[2], atol=2e-6)


def test_metrics_sanity(small_scene, cam128):
    from conftest import jit_render

    img = jit_render(small_scene, cam128, RenderConfig()).image
    assert float(psnr(img, img)) > 80.0
    assert float(ssim(img, img)) > 0.999
    noisy = img + 0.1 * jax.random.normal(jax.random.key(0), img.shape)
    assert float(psnr(img, noisy)) < 25.0
