from repro.ft import HeartbeatMonitor, plan_elastic_mesh


def test_straggler_detection():
    mon = HeartbeatMonitor(n_hosts=8, window=4, min_factor=1.5)
    for step in range(4):
        for h in range(8):
            lat = 1.0 if h != 3 else 3.5
            mon.report(h, step, lat, now_s=step * 1.0)
    rep = mon.check(3)
    assert rep is not None
    assert rep.stragglers == [3]
    assert rep.slow_factor[3] > 2.0


def test_no_false_positives_on_uniform():
    mon = HeartbeatMonitor(n_hosts=8, window=4)
    for h in range(8):
        mon.report(h, 0, 1.0 + 0.01 * h, now_s=0.0)
    assert mon.check(0) is None


def test_dead_host_detection():
    mon = HeartbeatMonitor(n_hosts=4, miss_timeout_s=30.0)
    for h in range(3):
        mon.report(h, 0, 1.0, now_s=100.0)
    dead = mon.dead_hosts(now_s=120.0)
    assert dead == [3]


def test_elastic_plan_shrinks_data_axis():
    # lost 3 of 32 hosts (8 chips each): 232 chips left, model=16
    plan = plan_elastic_mesh(232, model_parallel=16, global_batch=256)
    assert plan is not None
    assert plan.mesh_shape[-1] == 16
    data = plan.mesh_shape[-2] if len(plan.mesh_shape) == 2 else plan.mesh_shape[1]
    assert 256 % data == 0


def test_elastic_plan_multi_pod():
    plan = plan_elastic_mesh(512, model_parallel=16, global_batch=256)
    assert plan.mesh_shape == (2, 16, 16)
    assert plan.mesh_axes == ("pod", "data", "model")


def test_elastic_plan_infeasible():
    assert plan_elastic_mesh(8, model_parallel=16, global_batch=256) is None
