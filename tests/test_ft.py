from repro.ft import HeartbeatMonitor, plan_elastic_mesh


def test_straggler_detection():
    mon = HeartbeatMonitor(n_hosts=8, window=4, min_factor=1.5)
    for step in range(4):
        for h in range(8):
            lat = 1.0 if h != 3 else 3.5
            mon.report(h, step, lat, now_s=step * 1.0)
    rep = mon.check(3)
    assert rep is not None
    assert rep.stragglers == [3]
    assert rep.slow_factor[3] > 2.0


def test_no_false_positives_on_uniform():
    mon = HeartbeatMonitor(n_hosts=8, window=4)
    for h in range(8):
        mon.report(h, 0, 1.0 + 0.01 * h, now_s=0.0)
    assert mon.check(0) is None


def test_dead_host_detection():
    mon = HeartbeatMonitor(n_hosts=4, miss_timeout_s=30.0)
    for h in range(3):
        mon.report(h, 0, 1.0, now_s=100.0)
    dead = mon.dead_hosts(now_s=120.0)
    assert dead == [3]


def test_elastic_plan_shrinks_data_axis():
    # lost 3 of 32 hosts (8 chips each): 232 chips left, model=16
    plan = plan_elastic_mesh(232, model_parallel=16, global_batch=256)
    assert plan is not None
    assert plan.mesh_shape[-1] == 16
    data = plan.mesh_shape[-2] if len(plan.mesh_shape) == 2 else plan.mesh_shape[1]
    assert 256 % data == 0


def test_elastic_plan_multi_pod():
    plan = plan_elastic_mesh(512, model_parallel=16, global_batch=256)
    assert plan.mesh_shape == (2, 16, 16)
    assert plan.mesh_axes == ("pod", "data", "model")


def test_elastic_plan_infeasible():
    assert plan_elastic_mesh(8, model_parallel=16, global_batch=256) is None


# -- edge cases brought live by the gateway tier (DESIGN.md §16) --------------


def test_miss_timeout_boundary_is_strict():
    # dead_hosts uses a STRICT > comparison: exactly at the timeout a host
    # is still alive — the gateway polices this every router step, so an
    # off-by-one here would flap workers at the boundary.
    mon = HeartbeatMonitor(n_hosts=2, miss_timeout_s=10.0)
    mon.report(0, 0, 1.0, now_s=100.0)
    mon.report(1, 0, 1.0, now_s=100.0)
    assert mon.dead_hosts(now_s=110.0) == []
    assert mon.dead_hosts(now_s=110.0 + 1e-6) == [0, 1]


def test_never_seen_hosts_are_dead():
    # A host that never reported is dead from the start: liveness must be
    # proven, not presumed (the gateway seeds a ping at dispatcher start
    # precisely because of this).
    mon = HeartbeatMonitor(n_hosts=3, miss_timeout_s=10.0)
    mon.report(1, 0, 1.0, now_s=0.0)
    assert mon.dead_hosts(now_s=5.0) == [0, 2]


def test_straggler_quorum_suppresses_report():
    # Below quorum (max(2, n//2) reporters) check() must stay silent — a
    # mostly-idle fleet cannot out-vote itself into straggler flags.
    mon = HeartbeatMonitor(n_hosts=8, window=4, min_factor=1.5)
    for h in range(3):                       # 3 < 8 // 2
        mon.report(h, 0, 5.0 if h == 0 else 1.0, now_s=0.0)
    assert mon.check(0) is None
    for h in range(3, 8):                    # full fleet reporting
        mon.report(h, 0, 1.0, now_s=0.0)
    assert mon.check(0).stragglers == [0]


def test_straggler_window_eviction_forgives():
    # A recovered host ages its slow samples out of the bounded window:
    # only the LATEST latency is judged, so one fast report clears the flag.
    mon = HeartbeatMonitor(n_hosts=8, window=4, min_factor=1.5)
    for h in range(8):
        mon.report(h, 0, 4.0 if h == 2 else 1.0, now_s=0.0)
    assert mon.check(0).stragglers == [2]
    mon.report(2, 1, 1.0, now_s=1.0)
    assert mon.check(1) is None


def test_dead_host_revives_on_report():
    mon = HeartbeatMonitor(n_hosts=2, miss_timeout_s=10.0)
    mon.report(0, 0, 1.0, now_s=0.0)
    mon.report(1, 0, 1.0, now_s=0.0)
    assert mon.dead_hosts(now_s=50.0) == [0, 1]
    mon.report(0, 1, 1.0, now_s=50.0)
    assert mon.dead_hosts(now_s=50.0) == [1]


def test_elastic_prime_batch_collapses_data_axis():
    # A prime global batch only divides by itself: with 6 surviving groups
    # the largest divisor of 7 that fits is 1 — the plan degrades to a
    # single data replica (correct, never a non-divisor) and reports the
    # idle devices honestly.
    plan = plan_elastic_mesh(6 * 4, model_parallel=4, global_batch=7)
    assert plan.mesh_shape == (1, 4)
    assert "(20 idle)" in plan.note
    # with 7 groups the prime fits exactly
    plan = plan_elastic_mesh(7 * 4, model_parallel=4, global_batch=7)
    assert plan.mesh_shape == (7, 4)


def test_elastic_exact_fit_uses_everything():
    plan = plan_elastic_mesh(32, model_parallel=8, global_batch=4)
    assert plan.mesh_shape == (4, 8)
    assert plan.mesh_axes == ("data", "model")
    assert "(0 idle)" in plan.note


def test_elastic_prefer_pods_false_stays_2d():
    plan = plan_elastic_mesh(512, model_parallel=16, global_batch=256,
                             prefer_pods=False)
    assert plan.mesh_axes == ("data", "model")
    assert plan.mesh_shape == (32, 16)


def test_elastic_pod_axis_requires_divisibility():
    # devices_per_pod not divisible by model_parallel: pod grouping is
    # skipped even with prefer_pods=True.
    plan = plan_elastic_mesh(512, model_parallel=16, global_batch=256,
                             devices_per_pod=100)
    assert plan.mesh_axes == ("data", "model")


def test_elastic_model_parallel_exact_boundary():
    # Exactly one surviving group is feasible (data=1); one fewer device
    # is not.
    assert plan_elastic_mesh(16, model_parallel=16, global_batch=8) is not None
    assert plan_elastic_mesh(15, model_parallel=16, global_batch=8) is None


def test_gateway_plan_fleet_maps_workers_to_groups():
    # The gateway treats each worker as one fixed per-host mesh: survivors
    # land on the data axis 1:1 (no divisibility constraint — the gateway
    # pads per-worker dispatches, encoded by global_batch == group count).
    from repro.gateway import plan_fleet

    plan = plan_fleet(["w0", "w1", "w2"], devices_per_worker=2)
    assert plan.routable == ("w0", "w1", "w2")
    assert plan.mesh_shape == (3, 2)
    assert plan.mesh_axes == ("data", "model")
    shrunk = plan_fleet(["w2"], devices_per_worker=2)
    assert shrunk.mesh_shape == (1, 2)
    assert plan_fleet([], devices_per_worker=2) is None
