import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_camera, random_scene
from repro.core.bitmask import generate_bitmasks
from repro.core.grouping import GridSpec, bin_pairs, identify
from repro.core.projection import project
from repro.kernels import ops, ref as kref
from repro.kernels.bitmask_gen import bitmask_kernel
from repro.kernels.layout import pack_features


def _setup(method, gf, seed=0):
    # Smallest interpret-mode shapes that still cover the kernel's lane
    # logic: >1 group on each axis at both gf values, K > one block.
    tile = 16
    w = h = 96
    scene = random_scene(jax.random.key(seed), 400, extent=3.0)
    cam = make_camera((0, 1.0, 4.5), (0, 0, 0), w, h)
    proj = project(scene, cam)
    grid = GridSpec(w, h, tile, tile * gf, span=4)
    pairs = identify(proj, grid, "group", method)
    gtable = bin_pairs(pairs, grid.num_groups, 256)
    feat = pack_features(proj, gtable.gauss_idx, gtable.entry_valid)
    return proj, grid, gtable, feat


@pytest.mark.parametrize("method", ["aabb", "obb", "ellipse"])
@pytest.mark.parametrize("gf", [2, 4])
def test_bitmask_kernel_vs_oracle(method, gf):
    proj, grid, gtable, feat = _setup(method, gf)
    origins = ops.group_origins(grid)
    in_img = ops.tiles_in_image(grid)
    got = bitmask_kernel(
        feat, origins, in_img, grid.tile, gf, method=method, interpret=True
    )
    want = kref.ref_bitmask(feat, origins, in_img, grid.tile, gf, method)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("method", ["aabb", "obb", "ellipse"])
def test_bitmask_kernel_vs_core(method):
    """Kernel masks == core generate_bitmasks (the XLA substrate path)."""
    proj, grid, gtable, feat = _setup(method, 4, seed=3)
    core = generate_bitmasks(proj, gtable, grid, method)
    got = bitmask_kernel(
        feat,
        ops.group_origins(grid),
        ops.tiles_in_image(grid),
        grid.tile,
        4,
        method=method,
        interpret=True,
    )
    assert (np.asarray(got) == np.asarray(core.masks)).all()
