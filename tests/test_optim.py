import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    linear_warmup_cosine,
)


def _quadratic(dim=8):
    target = jnp.arange(1.0, dim + 1)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)
    params = {"w": jnp.zeros((dim,)), "b": jnp.zeros((2, dim))}
    return loss, params


def test_adamw_converges_quadratic():
    loss, params = _quadratic()
    state = adamw_init(params)
    for i in range(600):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, i, lr=5e-2)
    # Adam's per-coordinate steps are ~lr-sized: 600 steps at 5e-2 must pull
    # a target of magnitude 8 to well under 1e-2 residual loss.
    assert float(loss(params)) < 1e-2


def test_adamw_per_leaf_lr_tree():
    loss, params = _quadratic()
    state = adamw_init(params)
    lrs = {"w": 5e-2, "b": 0.0}  # frozen b
    for i in range(50):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, i, lr=lrs)
    assert float(jnp.abs(params["b"]).max()) == 0.0
    assert float(jnp.abs(params["w"]).max()) > 0.1


def test_adafactor_converges_quadratic():
    loss, params = _quadratic()
    state = adafactor_init(params)
    l0 = float(loss(params))
    for i in range(200):
        g = jax.grad(loss)(params)
        params, state = adafactor_update(params, g, state, i, lr=0.3)
    assert float(loss(params)) < 0.5 * l0


def test_adafactor_stacked_leaf_matches_mapped():
    """lax.map chunked path == direct per-slice updates."""
    key = jax.random.key(0)
    p = {"w": jax.random.normal(key, (4, 8, 6))}
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 6))}
    s = adafactor_init(p)
    new_p, _ = adafactor_update(p, g, s, 0, lr=0.1)

    outs = []
    for i in range(4):
        pi = {"w": p["w"][i]}
        gi = {"w": g["w"][i]}
        si = adafactor_init(pi)
        npi, _ = adafactor_update(pi, gi, si, 0, lr=0.1)
        outs.append(npi["w"])
    np.testing.assert_allclose(
        np.asarray(new_p["w"]), np.stack(outs), rtol=2e-5, atol=1e-6
    )


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((5,), -4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0
    # under the limit: unchanged
    small = {"a": jnp.full((4,), 1e-3)}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 1e-3, rtol=1e-5)


def test_global_norm_bf16_accumulation():
    x = {"w": jnp.full((4096,), 0.1, jnp.bfloat16)}
    n = float(global_norm(x))
    assert abs(n - 0.1 * 64.0) / (0.1 * 64) < 0.02


def test_schedules():
    sched = linear_warmup_cosine(1.0, 10, 100)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1.0) < 1e-5
    assert float(sched(200)) <= float(sched(50))
    cos = cosine_schedule(2.0, 100, final_frac=0.25)
    assert abs(float(cos(0)) - 2.0) < 1e-6
    assert abs(float(cos(100)) - 0.5) < 1e-5
