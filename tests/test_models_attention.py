import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention, _repeat_kv
from repro.models.layers import apply_rope, rope_angles


def _naive(q, k, v, causal):
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (hd ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 64)])
def test_chunked_vs_naive_fwd(causal, S, chunk):
    key = jax.random.key(0)
    q, k, v = (
        jax.random.normal(jax.random.fold_in(key, i), (2, S, 4, 16))
        for i in range(3)
    )
    got = chunked_attention(q, k, v, causal, chunk, 0)
    want = _naive(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_vjp_vs_naive(causal):
    key = jax.random.key(1)
    q, k, v = (
        jax.random.normal(jax.random.fold_in(key, i), (2, 64, 2, 16))
        for i in range(3)
    )
    f1 = lambda *a: jnp.sum(jnp.tanh(chunked_attention(*a, causal, 16, 0)))
    f2 = lambda *a: jnp.sum(jnp.tanh(_naive(*a, causal)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_repeat_kv():
    x = jnp.arange(2 * 3 * 2 * 4, dtype=jnp.float32).reshape(2, 3, 2, 4)
    y = _repeat_kv(x, 3)
    assert y.shape == (2, 3, 6, 4)
    # groups of 3 heads share each kv head
    assert (np.asarray(y[:, :, 0]) == np.asarray(y[:, :, 2])).all()
    assert (np.asarray(y[:, :, 3]) == np.asarray(y[:, :, 5])).all()


def test_rope_preserves_norm_and_relative():
    pos = jnp.arange(16)
    cos, sin = rope_angles(pos, 32, 10000.0)
    x = jax.random.normal(jax.random.key(2), (1, 16, 2, 32))
    xr = apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(xr), axis=-1),
        rtol=1e-5,
    )
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.key(3), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.key(4), (1, 1, 1, 32))
    def dot_at(p, d):
        c1, s1 = rope_angles(jnp.array([p]), 32, 10000.0)
        c2, s2 = rope_angles(jnp.array([p + d]), 32, 10000.0)
        return float(jnp.sum(apply_rope(q, c1, s1) * apply_rope(k, c2, s2)))
    assert abs(dot_at(0, 5) - dot_at(7, 5)) < 1e-4


@pytest.mark.parametrize(
    "arch",
    [
        "granite-3-2b",
        # ssm and hybrid decode parity stay covered in the slow lane; the
        # attention family is the fast-lane representative (the 12-step
        # python decode loop dominates this test's walltime).
        pytest.param("mamba2-370m", marks=pytest.mark.slow),
        pytest.param("jamba-1.5-large-398b", marks=pytest.mark.slow),
    ],
)
def test_decode_matches_teacher_forced_forward(arch):
    """Greedy decode cache correctness: logits from decode_step at position t
    equal full-forward logits at position t (same tokens)."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import build_param_spec, build_cache_spec, decode_step, forward
    from repro.models.spec import init_from_spec

    cfg = get_smoke_config(arch)
    params = init_from_spec(build_param_spec(cfg), jax.random.key(5))
    ident = lambda x, a: x
    T = 12
    tokens = jax.random.randint(jax.random.key(6), (2, T), 0, cfg.vocab)
    logits_full, _ = forward(cfg, params, {"tokens": tokens}, ident)

    cache = jax.tree.map(
        jnp.zeros_like,
        init_from_spec(build_cache_spec(cfg, 2, T), jax.random.key(0)),
    )
    errs = []
    for t in range(T):
        _, logits_t, cache = decode_step(
            cfg, params, cache, tokens[:, t], jnp.int32(t), ident
        )
        errs.append(
            float(jnp.abs(logits_t - logits_full[:, t, :]).max())
        )
    assert max(errs) < 2e-3, (arch, errs)
