"""End-to-end 3D-GS scene optimization through the GS-TG renderer."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import make_camera, random_scene
from repro.core.pipeline import RenderConfig, render
from repro.core.train import SceneTrainConfig, fit_scene


@pytest.mark.slow
def test_fit_scene_improves_psnr():
    key = jax.random.key(0)
    target_scene = random_scene(key, 150, extent=2.0)
    cams = [
        make_camera((0.0, 0.8, 3.5), (0, 0, 0), 64, 64),
        make_camera((2.5, 0.8, 2.5), (0, 0, 0), 64, 64),
    ]
    cfg = RenderConfig(
        tile=16, group=32, group_capacity=256, tile_capacity=256, span=4
    )
    targets = [render(target_scene, c, cfg).image for c in cams]

    # perturb the scene and recover
    k2 = jax.random.key(1)
    init = dataclasses.replace(
        target_scene,
        means3d=target_scene.means3d
        + 0.05 * jax.random.normal(k2, target_scene.means3d.shape),
        opacity=target_scene.opacity - 0.5,
    )
    tcfg = SceneTrainConfig(steps=40)
    fitted, history = fit_scene(init, cams, targets, cfg, tcfg, log_every=10)
    assert history[-1]["psnr"] > history[0]["psnr"] + 1.0
    assert history[-1]["loss"] < history[0]["loss"]
