"""Observability layer (DESIGN.md §14): tracer + Chrome export, metrics
registry, timed per-stage rendering, and the serving lifecycle spans.

The tracer/metrics unit tests run pure Python (the obs package must not pull
jax — enforced by a subprocess guard, same pattern as the serving layer).
The timed-render tests assert the ONE property the whole layer hangs off:
``RenderConfig(timing=True)`` (per-stage jit + fences) renders
BITWISE-identical images to the default whole-program jit, on both backends.
"""
from __future__ import annotations

import json
import subprocess
import sys
import threading
from pathlib import Path
from types import SimpleNamespace

import pytest

from repro.obs import (
    REQUEST_PHASES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Tracer,
    emit_request_spans,
    percentile,
    trace_span,
    validate_chrome_trace,
)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# pure layer: imports
# ---------------------------------------------------------------------------


def test_obs_imports_without_jax():
    """repro.obs must not pull jax: the serving admission layer and the
    pure-Python stats surfaces import it, and they run anywhere."""
    code = (
        "import sys; import repro.obs; "
        "import repro.obs.trace, repro.obs.metrics; "
        "assert 'jax' not in sys.modules, 'obs layer imported jax'"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stderr


# ---------------------------------------------------------------------------
# pure layer: tracer
# ---------------------------------------------------------------------------


def _manual_clock(start=0.0):
    state = {"t": start}

    def clock():
        return state["t"]

    def advance(dt):
        state["t"] += dt

    clock.advance = advance
    return clock


def test_tracer_records_and_exports_chrome():
    clock = _manual_clock()
    tr = Tracer(clock=clock, enabled=True)
    with tr.span("outer", category="test", args={"k": 1}):
        clock.advance(0.010)
        with tr.span("inner", category="test"):
            clock.advance(0.005)
        clock.advance(0.001)
    evs = tr.events()
    assert [e.name for e in evs] == ["inner", "outer"]   # completion order
    assert evs[1].duration_s == pytest.approx(0.016)
    doc = tr.chrome_trace()
    assert doc["schema"] == obs_trace.SCHEMA
    assert doc["dropped"] == 0
    assert validate_chrome_trace(doc) == []
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"outer", "inner"}
    outer = next(e for e in xs if e["name"] == "outer")
    assert outer["dur"] == pytest.approx(16000.0)        # us
    assert outer["args"] == {"k": 1}
    # metadata names the process and the recording thread
    mnames = {e["name"] for e in doc["traceEvents"] if e["ph"] == "M"}
    assert {"process_name", "thread_name"} <= mnames


def test_tracer_ring_is_bounded():
    clock = _manual_clock()
    tr = Tracer(capacity=4, clock=clock, enabled=True)
    for i in range(10):
        tr.complete(f"s{i}", 0.0, 1.0)
    assert len(tr.events()) == 4
    assert tr.dropped == 6
    assert [e.name for e in tr.events()] == ["s6", "s7", "s8", "s9"]
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0


def test_tracer_disabled_records_nothing_unless_forced():
    tr = Tracer(clock=_manual_clock(), enabled=False)
    with tr.span("ambient"):
        pass
    tr.complete("plain", 0.0, 1.0)
    assert tr.events() == []
    tr.complete("forced", 0.0, 1.0, force=True)   # the timed-stage opt-in
    assert [e.name for e in tr.events()] == ["forced"]


def test_trace_span_decorator_resolves_tracer_at_call_time():
    from repro.obs import get_tracer, set_tracer

    @trace_span("decorated", category="test")
    def f(x):
        return x + 1

    prev = set_tracer(Tracer(clock=_manual_clock(), enabled=True))
    try:
        assert f(1) == 2
        assert [e.name for e in get_tracer().events()] == ["decorated"]
    finally:
        set_tracer(prev)


def test_tracer_thread_lanes():
    """Spans from different threads land on different tids (no false
    nesting violations across real concurrency)."""
    tr = Tracer(enabled=True)   # real clock: threads overlap in time
    barrier = threading.Barrier(4)   # all alive at once => distinct idents

    def work():
        barrier.wait(timeout=10)
        with tr.span("t-span"):
            pass

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    with tr.span("main-span"):
        pass
    assert len({e.tid for e in tr.events()}) == 5
    assert validate_chrome_trace(tr.chrome_trace()) == []


def test_validate_chrome_trace_catches_bad_documents():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": "nope"}) != []
    assert validate_chrome_trace(
        {"traceEvents": [{"ph": "X", "pid": 1, "tid": 1, "ts": 0.0}]}
    )  # missing name + dur
    # partial overlap on one lane is the nesting violation
    bad = {
        "traceEvents": [
            {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 10.0},
            {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5.0, "dur": 10.0},
        ]
    }
    assert any("partially overlaps" in e for e in validate_chrome_trace(bad))
    # same spans on DIFFERENT lanes are fine
    ok = {
        "traceEvents": [
            {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 10.0},
            {"name": "b", "ph": "X", "pid": 1, "tid": 2, "ts": 5.0, "dur": 10.0},
        ]
    }
    assert validate_chrome_trace(ok) == []


def test_emit_request_spans_tiles_the_lifecycle():
    tr = Tracer(clock=_manual_clock(), enabled=True)
    stamps = {"enqueue": 1.0, "batch_form": 1.2, "dispatch": 1.5,
              "device_done": 2.5, "resolve": 2.6}
    emit_request_spans(tr, 7, stamps, args={"scene_id": "train"})
    by_name = {e.name: e for e in tr.events()}
    assert set(by_name) == {"request"} | {n for _, _, n in REQUEST_PHASES}
    assert by_name["request"].duration_s == pytest.approx(1.6)
    assert by_name["request/device"].duration_s == pytest.approx(1.0)
    assert by_name["request"].args["request_id"] == 7
    # all on one synthetic lane, nested under the enclosing request span
    assert len({e.tid for e in tr.events()}) == 1
    assert validate_chrome_trace(tr.chrome_trace()) == []
    # missing stamps skip their phase; disabled tracer records nothing
    tr.clear()
    emit_request_spans(tr, 8, {"dispatch": 1.0, "device_done": 2.0})
    assert [e.name for e in tr.events()] == ["request/device"]
    tr2 = Tracer(clock=_manual_clock(), enabled=False)
    emit_request_spans(tr2, 9, stamps)
    assert tr2.events() == []


# ---------------------------------------------------------------------------
# pure layer: metrics
# ---------------------------------------------------------------------------


def test_counter_and_gauge():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge()
    g.set(2.5)
    g.set(1.5)
    assert g.value == 1.5


def test_histogram_exact_below_cap():
    h = Histogram(cap=100)
    h.observe_many(float(i) for i in range(1, 11))
    assert h.count == 10 and h.sum == 55.0
    assert (h.min, h.max) == (1.0, 10.0)
    assert not h.sampled
    assert h.percentile(50) == pytest.approx(5.5)
    snap = h.snapshot()
    assert snap["mean"] == pytest.approx(5.5)
    assert snap["reservoir"] == 10 and not snap["sampled"]


def test_histogram_reservoir_bounds_memory():
    h = Histogram(cap=64, seed=0)
    for i in range(10_000):
        h.observe(float(i))
    assert len(h.values()) == 64          # bounded
    assert h.count == 10_000              # exact count survives sampling
    assert h.sum == pytest.approx(sum(range(10_000)))
    assert (h.min, h.max) == (0.0, 9999.0)
    assert h.sampled and h.snapshot()["sampled"]
    # deterministic seed: same stream -> same reservoir
    h2 = Histogram(cap=64, seed=0)
    for i in range(10_000):
        h2.observe(float(i))
    assert h.values() == h2.values()


def test_percentile_contracts_differ_on_empty():
    """obs.percentile -> 0.0 (JSON-plain snapshots); serving keeps nan so
    the render_serve CI exit check fails an empty run."""
    from repro.serving.stats import percentile as serving_percentile

    assert percentile([], 99) == 0.0
    assert serving_percentile([], 99) != serving_percentile([], 99)   # nan
    assert percentile([1.0, 2.0, 3.0], 50) == serving_percentile(
        [1.0, 2.0, 3.0], 50)


def test_registry_instruments_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("a.total").inc(3)
    reg.gauge("b.level").set(0.5)
    reg.histogram("c.lat").observe_many([0.1, 0.2])
    with pytest.raises(TypeError):
        reg.gauge("a.total")             # kind mismatch
    assert reg.counter("a.total").value == 3   # get-or-create returns same
    snap = reg.snapshot()
    assert snap["schema"] == obs_metrics.SCHEMA
    assert snap["counters"] == {"a.total": 3}
    assert snap["gauges"] == {"b.level": 0.5}
    assert snap["histograms"]["c.lat"]["count"] == 2
    json.dumps(snap)                      # JSON-plain throughout
    assert reg.drop("b.") == 1
    assert "b.level" not in reg.snapshot()["gauges"]


def test_registry_collectors_run_at_snapshot():
    reg = MetricsRegistry()
    state = {"v": 1.0}
    reg.register_collector("t", lambda r: r.gauge("scraped.v").set(state["v"]))
    assert reg.snapshot()["gauges"]["scraped.v"] == 1.0
    state["v"] = 2.0
    assert reg.snapshot()["gauges"]["scraped.v"] == 2.0
    reg.unregister_collector("t")
    state["v"] = 3.0
    assert reg.snapshot()["gauges"]["scraped.v"] == 2.0   # stale, not rerun


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("serving.requests_total").inc(2)
    reg.histogram("serving.latency_s").observe(0.5)
    text = reg.prometheus_text()
    assert "# TYPE serving_requests_total counter" in text
    assert "serving_requests_total 2" in text
    assert 'serving_latency_s{quantile="0.99"} 0.5' in text
    assert "serving_latency_s_count 1" in text


# ---------------------------------------------------------------------------
# jax layer: timed per-stage rendering (the bitwise guarantee)
# ---------------------------------------------------------------------------


@pytest.fixture()
def fresh_tracer():
    from repro.obs import set_tracer

    prev = set_tracer(Tracer(enabled=True))
    try:
        yield
    finally:
        set_tracer(prev)


def _render_pair(scene, cam, cfg):
    import dataclasses

    import numpy as np

    from repro import engine

    with engine.open(scene, cfg) as r:
        plain = np.asarray(r.render(cam).image)
    with engine.open(scene, dataclasses.replace(cfg, timing=True)) as r:
        timed = np.asarray(r.render(cam).image)
    return plain, timed


def test_timed_render_bitwise_reference(small_scene, cam128, base_cfg,
                                        fresh_tracer):
    import dataclasses

    from repro.obs import get_tracer

    plain, timed = _render_pair(
        small_scene, cam128, dataclasses.replace(base_cfg, backend="reference")
    )
    assert (plain == timed).all()
    names = {e.name for e in get_tracer().events() if e.category == "stage"}
    assert {"stage/project", "stage/identify", "stage/bin", "stage/bitmask",
            "stage/compact", "stage/rasterize", "stage/render"} <= names
    assert validate_chrome_trace(get_tracer().chrome_trace()) == []


@pytest.mark.slow
def test_timed_render_bitwise_pallas(small_scene, cam128, base_cfg,
                                     fresh_tracer):
    import dataclasses

    plain, timed = _render_pair(
        small_scene, cam128, dataclasses.replace(base_cfg, backend="pallas")
    )
    assert (plain == timed).all()


def test_timed_render_bitwise_sharded(small_scene, cam128, base_cfg,
                                      fresh_tracer):
    """Sharded frontend under timing: the per-stage jit(vmap) programs (incl.
    the merge stage) must match the whole-program sharded render bitwise."""
    import dataclasses

    from repro.obs import get_tracer

    plain, timed = _render_pair(
        small_scene, cam128, dataclasses.replace(base_cfg, scene_shards=2)
    )
    assert (plain == timed).all()
    names = {e.name for e in get_tracer().events() if e.category == "stage"}
    assert "stage/merge" in names


def test_timed_batch_bitwise(small_scene, base_cfg, fresh_tracer):
    """Timed batch path (per-lane loop + stack) == jit(vmap) batch path."""
    import dataclasses

    import numpy as np

    from repro import engine
    from repro.core import orbit_cameras

    cams = orbit_cameras(3, 4.5, 128, 128)
    with engine.open(small_scene, base_cfg) as r:
        plain = np.asarray(r.render_batch(cams).image)
    with engine.open(
        small_scene, dataclasses.replace(base_cfg, timing=True)
    ) as r:
        timed = np.asarray(r.render_batch(cams).image)
    assert (plain == timed).all()


def test_timed_stage_cache_registered():
    from repro.core.pipeline import render_cache_info

    assert "timed_stage" in render_cache_info()


# ---------------------------------------------------------------------------
# jax layer: serving lifecycle spans + metrics end to end
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_server_emits_lifecycle_spans_and_metrics(small_scene, base_cfg):
    """One small serve: every completed request gets a nested lifecycle on
    its own lane, serve/dispatch spans match batches, and the serving.*
    counters in a fresh registry agree with the stats summary."""
    import numpy as np

    from repro.core import orbit_cameras
    from repro.obs import get_tracer, set_tracer
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer
    from repro.serving.stats import ServingStats

    reg = MetricsRegistry()
    prev = set_tracer(Tracer(enabled=True))
    try:
        server = RenderServer(
            {"s": small_scene}, max_batch=2, max_wait=0.01
        )
        server.stats = ServingStats(registry=reg)
        cams = orbit_cameras(4, 4.5, 96, 96)
        load = [
            (0.0, RenderRequest(i, "s", cams[i], base_cfg))
            for i in range(4)
        ]
        results = server.run(load, realtime=False)
        summary = server.stats.summary()
        server.close()

        assert len(results) == 4
        tracer = get_tracer()
        evs = tracer.events()
        req_spans = [e for e in evs if e.name == "request"]
        assert len(req_spans) == 4
        assert len({e.tid for e in req_spans}) == 4       # one lane each
        dispatches = [e for e in evs if e.name == "serve/dispatch"]
        assert len(dispatches) == summary["batches"]
        assert validate_chrome_trace(tracer.chrome_trace()) == []

        snap = reg.snapshot()
        assert snap["counters"]["serving.requests_total"] == 4
        assert snap["counters"]["serving.batches_total"] == summary["batches"]
        assert snap["histograms"]["serving.latency_s"]["count"] == 4
        # request/device span duration matches the recorded render walltime
        # order of magnitude (both bracket the same device work)
        dev = [e for e in evs if e.name == "request/device"]
        assert all(e.duration_s > 0 for e in dev)
        for img in (np.asarray(r.image) for r in results.values()):
            assert img.shape == (96, 96, 3)
    finally:
        set_tracer(prev)


def test_engine_submit_emits_request_spans(small_scene, base_cfg):
    """The engine futures path stamps + emits the same lifecycle spans."""
    from repro import engine
    from repro.core import make_camera
    from repro.obs import get_tracer, set_tracer

    prev = set_tracer(Tracer(enabled=True))
    try:
        cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 96, 96)
        with engine.open(small_scene, base_cfg) as r:
            futs = [r.submit(cam) for _ in range(3)]
            for f in futs:
                f.result(timeout=120)
        evs = get_tracer().events()
        req = [e for e in evs if e.name == "request"]
        assert len(req) == 3
        ids = {e.args["request_id"] for e in req}
        assert len(ids) == 3
        assert all("#" in rid for rid in ids)
        assert any(e.name == "engine/dispatch" for e in evs)
        assert validate_chrome_trace(get_tracer().chrome_trace()) == []
    finally:
        set_tracer(prev)
