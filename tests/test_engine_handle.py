"""The session-style engine handle (repro.engine, DESIGN.md §11).

Covers the handle-semantics contract of the API redesign:
  * bitwise parity of Renderer.render / .render_batch / .submit against the
    legacy free entry points for every mode x backend x shard count;
  * per-handle jit caches: hits across repeated calls, registration with the
    render-cache registry, and close() leaving the registry empty;
  * the layout-cache lifecycle fix (close() evicts every layout of the
    handle's scene, at any shard count);
  * deprecation shims emitting exactly one DeprecationWarning per call and
    the console-script entry points resolving to importable callables.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

from repro import engine
from repro.core import make_camera, orbit_cameras
from repro.core.pipeline import (
    RenderConfig,
    render_batch,
    render_cache_clear,
    render_cache_info,
)

REPO = Path(__file__).resolve().parents[1]

INT_COUNTERS = (
    "n_visible", "n_candidate_tests", "n_pairs_sort", "sort_ops",
    "n_bit_tests", "fifo_ops", "alpha_ops", "blend_ops", "tile_entries",
    "overflow", "span_overflow",
)


def _assert_bitwise(a, b, what):
    assert (np.asarray(a.image) == np.asarray(b.image)).all(), (
        f"{what}: image diverges"
    )
    for name in INT_COUNTERS:
        va = np.asarray(getattr(a.stats, name))
        vb = np.asarray(getattr(b.stats, name))
        assert (va == vb).all(), f"{what}: counter {name} diverges"


def _legacy(scene, cam, cams, cfg):
    """The deprecated free-function outputs the handle must match bitwise."""
    from repro.core.pipeline import render_jit
    from repro.serving.sharded import render_batch_sharded

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        single = render_jit(scene, cam, cfg)
        batch = render_batch_sharded(scene, cams, cfg, pad_to=len(cams))
    return single, batch


# Fast lane: the gstg-reference pair (the paper's mode, both shard counts).
# The other modes and the pallas interpret runs ride the slow lane — the
# fast lane still pins those paths through tests/test_sharding.py (engine-
# level parity, all modes) and tests/test_golden.py (both backends).
PARITY_CASES = [
    pytest.param(
        mode, backend, shards,
        marks=(
            [] if (backend, mode) == ("reference", "gstg")
            else [pytest.mark.slow]
        ),
        id=f"{mode}-{backend}-D{shards}",
    )
    for mode in ("gstg", "tile_baseline", "group_baseline")
    for backend in ("reference", "pallas")
    for shards in (1, 2)
]


@pytest.mark.parametrize("mode,backend,shards", PARITY_CASES)
def test_handle_bitwise_parity_vs_legacy(tiny_scene, mode, backend, shards):
    """Renderer.render / .render_batch / .submit are bitwise-identical to
    the legacy render_jit / render_batch(_sharded) paths for every mode x
    backend x D — the acceptance contract of the handle redesign."""
    cfg = RenderConfig(
        tile=16, group=64, group_capacity=256, tile_capacity=256,
        mode=mode, backend=backend, scene_shards=shards,
    )
    cams = orbit_cameras(2, 4.5, 64, 64)
    legacy_single, legacy_batch = _legacy(tiny_scene, cams[0], cams, cfg)

    with engine.open(tiny_scene, cfg, max_batch=2, max_wait=30.0) as r:
        _assert_bitwise(r.render(cams[0]), legacy_single, "render vs render_jit")
        out_b = r.render_batch(cams, pad_to=2)
        _assert_bitwise(out_b, legacy_batch, "render_batch vs legacy sharded")
        if shards == 1:
            plain = render_batch(tiny_scene, cams, cfg)
            assert (np.asarray(out_b.image) == np.asarray(plain.image)).all()

        # submit(): max_batch=2 fills one bucket -> ONE dispatch through the
        # same padded shape as the render_batch above (a cache hit, not a
        # recompile), so the futures must come back bitwise-identical.
        before = r.cache_info()
        futs = [r.submit(c) for c in cams]
        results = [f.result(timeout=600) for f in futs]
        for i, res in enumerate(results):
            assert (res.image == np.asarray(out_b.image[i])).all(), (
                f"submit result {i} diverges from render_batch"
            )
        after = r.cache_info()
        assert after["misses"] == before["misses"], "submit recompiled"
    engine.close_default_renderers()


def test_handle_cache_hits_across_calls(tiny_scene, base_cfg):
    """Repeated handle calls reuse the per-handle compiled renderers: one
    miss per (kind, geometry), hits afterwards — including across distinct
    cameras of the same resolution."""
    cam_a = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    cam_b = make_camera((1.5, 0.8, 4.0), (0, 0, 0), 64, 64)
    with engine.open(tiny_scene, base_cfg) as r:
        r.render(cam_a)
        assert r.cache_info()["misses"] == 1
        r.render(cam_b)                        # same geometry: hit
        assert r.cache_info() == {
            "hits": 1, "misses": 1, "currsize": 1, "maxsize": 64,
        }
        r.render_batch([cam_a, cam_b])         # batch kind: new miss
        r.render_batch([cam_b, cam_a])
        info = r.cache_info()
        assert (info["hits"], info["misses"], info["currsize"]) == (2, 2, 2)
        # the handle cache is visible through the engine-wide registry
        assert render_cache_info()[r.cache_name] == info


def test_handle_close_empties_registry(tiny_scene, base_cfg):
    """close() unregisters the handle cache, drops its executables, and
    evicts the handle's scene layouts — render_cache_info() shows an empty
    registry afterwards."""
    render_cache_clear()
    engine.close_default_renderers()
    cfg = dataclasses.replace(base_cfg, scene_shards=2)
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)

    r = engine.open(tiny_scene, cfg)
    r.render(cam)
    name = r.cache_name
    info = render_cache_info()
    assert info[name]["currsize"] == 1
    assert info["scene_layout"]["currsize"] == 1   # host-staged layout

    r.close()
    info = render_cache_info()
    assert name not in info, "closed handle left its cache registered"
    assert sum(k["currsize"] for k in info.values()) == 0, (
        f"registry not empty after close: {info}"
    )
    with pytest.raises(RuntimeError, match="closed"):
        r.render(cam)
    with pytest.raises(RuntimeError, match="closed"):
        r.submit(cam)
    r.close()                                       # idempotent


def test_close_releases_only_own_layout(tiny_scene, base_cfg):
    """close() releases exactly this handle's own (scene, D) layout
    reference. Other layouts of the scene are NOT nuked implicitly any
    more (the shared-eviction fix) — they stay until explicit
    evict_scene_layouts()/capacity eviction/scene GC."""
    from repro.serving.sharded import evict_scene_layouts, shard_scene_cached

    render_cache_clear()
    r = engine.open(tiny_scene, base_cfg, scene_shards=2)
    shard_scene_cached(tiny_scene, 3)   # a second, UNREFERENCED layout
    assert render_cache_info()["scene_layout"]["currsize"] == 2
    r.close()
    # The handle's own (scene, 2) entry is gone; the unreferenced bare
    # layout survives until explicitly evicted.
    assert render_cache_info()["scene_layout"]["currsize"] == 1
    assert evict_scene_layouts(tiny_scene) == 1
    assert render_cache_info()["scene_layout"]["currsize"] == 0


def test_close_keeps_layout_shared_with_other_open_handle(
    tiny_scene, base_cfg
):
    """Regression (two handles, one scene): closing one handle must not
    evict the host layout the OTHER open handle still references —
    close() used to call evict_scene_layouts(scene) unconditionally,
    nuking every layout of the scene."""
    render_cache_clear()
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    a = engine.open(tiny_scene, base_cfg, scene_shards=2)
    b = engine.open(tiny_scene, base_cfg, scene_shards=2)
    assert render_cache_info()["scene_layout"]["currsize"] == 1
    a.close()
    assert render_cache_info()["scene_layout"]["currsize"] == 1, (
        "closing one handle evicted a layout another open handle references"
    )
    b.render(cam)                       # the survivor still renders
    b.close()
    assert render_cache_info()["scene_layout"]["currsize"] == 0


def test_open_accepts_presharded_scene(tiny_scene, base_cfg):
    """A host-staged ShardedScene commits as-is (its shard count wins) and
    renders bitwise-identically to the replicated handle."""
    from repro.sharding.scene import shard_scene_host

    cams = orbit_cameras(2, 4.5, 64, 64)
    staged = shard_scene_host(tiny_scene, 2)
    with engine.open(staged, base_cfg) as sharded, \
            engine.open(tiny_scene, base_cfg) as repl:
        assert sharded.scene_shards == 2
        a = sharded.render_batch(cams)
        b = repl.render_batch(cams)
        assert (np.asarray(a.image) == np.asarray(b.image)).all()
    with pytest.raises(ValueError, match="pre-sharded"):
        engine.open(shard_scene_host(tiny_scene, 2), base_cfg, scene_shards=3)


def test_open_enforces_device_budget(tiny_scene, base_cfg):
    """An over-budget commit refuses loudly (the simulated HBM cap moved
    into the handle); a generous budget commits fine and reports the
    per-device footprint."""
    with pytest.raises(ValueError, match="budget"):
        engine.open(tiny_scene, base_cfg, device_budget_mb=1e-6)
    with engine.open(tiny_scene, base_cfg, device_budget_mb=64.0) as r:
        assert 0 < r.stats()["scene_mb_per_device"] <= 64.0


def test_budget_counts_logical_shards_as_replicated(tiny_scene, base_cfg):
    """A shard axis the mesh cannot realize (no 'model' axis) leaves the
    full scene on every device, so the budget must count it as replicated —
    a half-size budget that only a PHYSICAL 2-way shard could meet refuses."""
    from repro.launch.mesh import make_render_mesh
    from repro.sharding.scene import shard_scene_host
    from repro.utils import pytree_bytes

    half_mb = pytree_bytes(tiny_scene) / 2 / 2**20
    mesh = make_render_mesh(1)                     # 1-D ('data',): no 'model'
    with pytest.raises(ValueError, match="replicated"):
        engine.open(
            tiny_scene, base_cfg, mesh=mesh, scene_shards=2,
            device_budget_mb=half_mb * 1.2,
        )
    # The budget applies to pre-sharded scenes too (their layout is fixed:
    # no escalation, just enforcement).
    with pytest.raises(ValueError, match="budget"):
        engine.open(
            shard_scene_host(tiny_scene, 2), base_cfg, mesh=mesh,
            device_budget_mb=1e-6,
        )


def test_submit_failure_resolves_future_exception(tiny_scene, base_cfg):
    """A request the dispatch cannot render resolves ITS future with the
    exception instead of killing the worker for everyone behind it."""
    bad_cam = SimpleNamespace(
        width=64, height=64, znear=0.2, zfar=1000.0,
        R=np.zeros((2, 2), np.float32), t=np.zeros((3,), np.float32),
        fx=60.0, fy=60.0, cx=32.0, cy=32.0,
    )
    good_cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    with engine.open(tiny_scene, base_cfg, max_batch=1, max_wait=0.0) as r:
        bad = r.submit(bad_cam)
        with pytest.raises(Exception):
            bad.result(timeout=600)
        good = r.submit(good_cam)          # worker survived the bad request
        expect = r.render(good_cam)
        assert (good.result(timeout=600).image == np.asarray(expect.image)).all()


def test_cancelled_future_does_not_kill_worker(tiny_scene, base_cfg):
    """Cancelling a pending submit() must not crash the worker or lose the
    other requests sharing its bucket — cancelled futures are skipped at
    resolve time (Future.set_* on a cancelled future raises)."""
    cams = orbit_cameras(3, 4.5, 64, 64)
    with engine.open(tiny_scene, base_cfg, max_batch=3, max_wait=30.0) as r:
        futs = [r.submit(c) for c in cams[:2]]
        cancelled = futs[0].cancel()     # still PENDING in the scheduler
        r.submit(cams[2])                # fills the bucket -> dispatch
        sibling = futs[1].result(timeout=600)
        assert cancelled and futs[0].cancelled()
        expect = r.render(cams[1])
        assert (sibling.image == np.asarray(expect.image)).all()
        # worker survived: a fresh submit still completes. flush() forces
        # the partial bucket out instead of waiting max_wait (30s) out.
        fut = r.submit(cams[0])
        r.flush(timeout=600)
        assert fut.result(timeout=60) is not None


def test_worker_crash_fails_outstanding_futures(tiny_scene, base_cfg):
    """A crash OUTSIDE the dispatch handler (scheduler bug) must terminate
    every outstanding future with the exception — callers blocked on
    .result() (and the gateway's failover accounting above them) depend on
    futures always terminating."""
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    with engine.open(tiny_scene, base_cfg, max_batch=2, max_wait=30.0) as r:
        def bad_add(req):
            raise RuntimeError("scheduler exploded")

        r._scheduler.add = bad_add
        fut = r.submit(cam)
        with pytest.raises(RuntimeError, match="scheduler exploded"):
            fut.result(timeout=600)


def test_close_fails_futures_the_worker_never_resolved(tiny_scene, base_cfg):
    """close() on a handle whose worker never got to a pending submit must
    fail that future, not strand it PENDING forever."""
    r = engine.open(tiny_scene, base_cfg, max_batch=2, max_wait=30.0)
    r._ensure_worker = lambda: None        # a worker that never runs
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    fut = r.submit(cam)
    r.close()
    with pytest.raises(RuntimeError, match="closed before the request"):
        fut.result(timeout=60)


def test_dropped_handle_is_not_pinned_by_registry(tiny_scene, base_cfg):
    """A handle dropped WITHOUT close() must still be collectable (the
    registry holds only weak references) and its registry entry must
    disappear — the leak-safety net behind the close() contract."""
    import gc

    r = engine.open(tiny_scene, base_cfg)
    name = r.cache_name
    ref = __import__("weakref").ref(r)
    assert name in render_cache_info()
    del r
    gc.collect()
    assert ref() is None, "registry pinned a dropped handle"
    assert name not in render_cache_info()


@pytest.mark.filterwarnings("always::DeprecationWarning")
def test_deprecated_shims_warn_exactly_once_per_call(tiny_scene, base_cfg):
    """Each legacy free function emits exactly ONE DeprecationWarning per
    call (no cascades through the handle they delegate to) and returns the
    handle-backed result.

    Explicitly whitelisted from the suite-wide ``error::DeprecationWarning``
    filter for repro.* (pyproject.toml): this test MUST observe the shim
    warnings as warnings to count them."""
    from repro.core.pipeline import render_image, render_jit
    from repro.serving.sharded import render_batch_sharded

    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    calls = [
        lambda: render_jit(tiny_scene, cam, base_cfg),
        lambda: render_image(tiny_scene, cam, base_cfg),
        lambda: render_batch_sharded(tiny_scene, [cam], base_cfg),
    ]
    for call in calls:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            call()
        deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
        assert len(deps) == 1, (
            f"{call}: expected exactly 1 DeprecationWarning, got "
            f"{[str(w.message) for w in deps]}"
        )
    engine.close_default_renderers()


def test_internal_shim_callers_error_under_suite_filters(tiny_scene, base_cfg):
    """The pyproject ``error::DeprecationWarning:repro`` contract: a shim
    call ATTRIBUTED to a repro.* module (an internal caller — the shims warn
    with stacklevel=2) raises under the suite's warning filters, so internal
    code can never silently regress onto the deprecated entry points. The
    simulated caller lives in a module named ``repro._filter_selftest``;
    test-module callers (like every other test here) only warn."""
    import textwrap
    import types

    mod = types.ModuleType("repro._filter_selftest")
    exec(
        compile(
            textwrap.dedent(
                """
                def call(scene, cam, cfg):
                    from repro.core.pipeline import render_jit
                    return render_jit(scene, cam, cfg)
                """
            ),
            "repro/_filter_selftest.py",
            "exec",
        ),
        mod.__dict__,
    )
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    with pytest.raises(DeprecationWarning, match="render_jit"):
        mod.call(tiny_scene, cam, base_cfg)
    engine.close_default_renderers()


@pytest.mark.filterwarnings("always::DeprecationWarning")
def test_shims_share_one_default_handle(tiny_scene, base_cfg):
    """Repeated legacy calls with one (scene, cfg) ride ONE module-default
    handle — the legacy executable-reuse behavior, now handle-owned.
    Whitelisted from the repro.* DeprecationWarning error filter like the
    once-per-call test above."""
    from repro.core.pipeline import render_jit

    engine.close_default_renderers()
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        render_jit(tiny_scene, cam, base_cfg)
        handle = engine.default_renderer(tiny_scene, base_cfg)
        before = handle.cache_info()
        render_jit(tiny_scene, cam, base_cfg)
        assert engine.default_renderer(tiny_scene, base_cfg) is handle
    after = handle.cache_info()
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]
    engine.close_default_renderers()
    assert handle.closed


def test_console_script_entry_points_import():
    """pyproject's [project.scripts] targets must import and be callable —
    the console-script smoke (the package is used from PYTHONPATH here, so
    the metadata is parsed straight from pyproject.toml)."""
    import importlib

    text = (REPO / "pyproject.toml").read_text()
    block = re.search(r"\[project\.scripts\](.*?)(?:\n\[|\Z)", text, re.S)
    assert block, "pyproject.toml lost its [project.scripts] table"
    entries = dict(
        re.findall(r'^([\w-]+)\s*=\s*"([^"]+)"', block.group(1), re.M)
    )
    assert set(entries) == {"repro-render", "repro-serve", "repro-gateway"}
    for name, target in entries.items():
        module, _, attr = target.partition(":")
        fn = getattr(importlib.import_module(module), attr)
        assert callable(fn), f"{name} -> {target} is not callable"
