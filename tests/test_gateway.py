"""Gateway tier tests (DESIGN.md §16): admission, routing, health, failover.

Two lanes:

  * pure-Python stub workers (fast lane): the routing policy, backpressure
    accounting, heartbeat policing, bounded retries, and the no-silent-drop
    invariant — RenderGateway never imports a worker implementation, so the
    duck-typed contract in repro.gateway.worker is testable without jax;
  * in-process InprocWorker fleets (slow lane): the end-to-end failover
    story — kill one of two workers mid-load, every request completes,
    retried requests are BITWISE-identical to a direct single-worker run,
    and the gateway/* span counts agree with the gateway counters;
  * one SubprocessWorker transport test (slow): the line-JSON protocol over
    a real child process, including the SIGKILL -> WorkerDied path.
"""
import dataclasses
import threading
import time

import pytest

from repro.gateway import (
    NoWorkerAvailable,
    RenderGateway,
    WorkerDied,
    plan_fleet,
)
from repro.obs import get_registry
from repro.serving.queue import RenderRequest


class _Res:
    def __init__(self, image, batch_size=1):
        self.image = image
        self.batch_size = batch_size


class StubWorker:
    """Pure-Python fleet member implementing the repro.gateway.worker
    contract; ``fail_dispatches=n`` makes the first n dispatches raise and
    kill the worker (the induced-death chaos knob)."""

    def __init__(self, worker_id, scene_ids, *, max_batch=4, committed=(),
                 fail_dispatches=0, dispatch_sleep=0.0):
        self.worker_id = worker_id
        self.scene_ids = frozenset(scene_ids)
        self.max_batch = max_batch
        self._committed = set(committed)
        self._alive = True
        self._fail_left = fail_dispatches
        self._sleep = dispatch_sleep
        self.dispatched = []

    def alive(self):
        return self._alive

    def ping(self):
        if not self._alive:
            raise WorkerDied(f"{self.worker_id} is dead")

    def committed_scene_ids(self):
        return set(self._committed)

    def commit(self, scene_id, cfg=None):
        self.ping()
        self._committed.add(scene_id)

    def dispatch(self, requests):
        self.ping()
        if self._fail_left > 0:
            self._fail_left -= 1
            self._alive = False
            raise WorkerDied(f"{self.worker_id} died mid-dispatch")
        if self._sleep:
            time.sleep(self._sleep)
        self.dispatched.append([r.request_id for r in requests])
        out = {}
        for r in requests:
            self._committed.add(r.scene_id)     # lazy commit, like the server
            out[r.request_id] = _Res(("img", self.worker_id, r.request_id))
        return out

    def kill(self):
        self._alive = False

    def shutdown(self):
        self._alive = False


def _req(rid, scene="a", stream_id=None):
    return RenderRequest(rid, scene, object(), "cfg", stream_id=stream_id)


def _load(n, scenes=("a",), base=0):
    return [(0.0, _req(base + i, scenes[i % len(scenes)])) for i in range(n)]


# -- admission ----------------------------------------------------------------


def test_admission_unknown_scene_raises():
    gw = RenderGateway([StubWorker("w0", ["a"])])
    with pytest.raises(KeyError):
        gw.submit(_req(1, scene="nope"))
    gw.close()


def test_admission_backpressure_counts_rejected():
    # Dispatchers never started: the queue fills and the third submit is
    # backpressure, mirrored into the registry counter.
    before = get_registry().counter("gateway.rejected_total").value
    gw = RenderGateway([StubWorker("w0", ["a"])], queue_depth=2)
    assert gw.submit(_req(1)) and gw.submit(_req(2))
    assert not gw.submit(_req(3))
    assert gw.counts["rejected"] == 1
    assert gw.counts["submitted"] == 3
    assert get_registry().counter("gateway.rejected_total").value == before + 1
    gw.close()


def test_close_fails_pending_requests():
    gw = RenderGateway([StubWorker("w0", ["a"])], queue_depth=8)
    gw.submit(_req(1))
    gw.submit(_req(2))
    gw.close()
    assert set(gw.failed) == {1, 2}
    assert all(isinstance(e, RuntimeError) for e in gw.failed.values())
    gw.close()                               # idempotent


# -- routing policy (no dispatcher threads: pick/route inspected directly) ----


def test_route_prefers_affine_worker():
    w0 = StubWorker("w0", ["a", "b"])
    w1 = StubWorker("w1", ["a", "b"], committed=["a"])
    gw = RenderGateway([w0, w1])
    assert gw._pick_worker(_req(1, "a")) == "w1"
    # no worker committed "b": least-loaded (both idle) -> first index
    assert gw._pick_worker(_req(2, "b")) == "w0"
    gw.close()


def test_route_least_loaded_among_affine():
    w0 = StubWorker("w0", ["a"], committed=["a"])
    w1 = StubWorker("w1", ["a"], committed=["a"])
    gw = RenderGateway([w0, w1])
    gw._inbox["w0"].append(_req(99))
    assert gw._pick_worker(_req(1)) == "w1"
    gw.close()


def test_route_spills_past_load_threshold():
    # Affinity is a preference, not a pin: an affine worker at spill depth
    # loses to an idle non-affine one.
    w0 = StubWorker("w0", ["a"], committed=["a"])
    w1 = StubWorker("w1", ["a"])
    gw = RenderGateway([w0, w1], spill_load=2)
    assert gw._pick_worker(_req(1)) == "w0"
    gw._inbox["w0"].extend([_req(98), _req(99)])
    assert gw._pick_worker(_req(2)) == "w1"
    gw.close()


def test_route_straggler_deprioritized_not_excluded():
    w0 = StubWorker("w0", ["a"], committed=["a"])
    w1 = StubWorker("w1", ["a"])
    gw = RenderGateway([w0, w1])
    gw._stragglers = {"w0"}
    # straggler loses even with affinity on its side...
    assert gw._pick_worker(_req(1)) == "w1"
    # ...but a drained straggler still beats no worker at all
    gw._routable = {"w0"}
    assert gw._pick_worker(_req(2)) == "w0"
    gw.close()


def test_stream_sticky_routing_and_repin_after_death():
    w0 = StubWorker("w0", ["a"], committed=["a"])
    w1 = StubWorker("w1", ["a"], committed=["a"])
    gw = RenderGateway([w0, w1])
    first = _req(1, stream_id="s0")
    gw._route(first, 0.0)
    assert gw._stream_route["s0"] == "w0"
    # load would favor w1 now, but the stream stays pinned
    gw._inbox["w0"].append(_req(99))
    assert gw._pick_worker(_req(2, stream_id="s0")) == "w0"
    # death unpins; the next frame re-pins to the survivor
    gw._handle_death("w0", [], WorkerDied("chaos"), 0.0)
    assert "s0" not in gw._stream_route
    assert gw._pick_worker(_req(3, stream_id="s0")) == "w1"
    gw.close()


def test_route_counts_lazy_recommit():
    w0 = StubWorker("w0", ["a"])
    gw = RenderGateway([w0])
    gw._route(_req(1), 0.0)
    assert gw.counts["recommits"] == 1
    assert gw.counts["routed"] == 1
    gw.close()


# -- health -------------------------------------------------------------------


def test_heartbeat_timeout_declares_worker_dead():
    w0 = StubWorker("w0", ["a"])
    w1 = StubWorker("w1", ["a"])
    gw = RenderGateway([w0, w1], heartbeat_timeout_s=5.0)
    gw._started = True                       # police without real dispatchers
    now = gw._clock()
    gw._started_at = now - 6.0
    gw.monitor.report(1, 0, 0.0, now)        # w1 reported; w0 never seen
    gw.step(now)
    assert gw.healthy_workers == ["w1"]
    assert gw.counts["failovers"] == 1
    assert gw.plan.mesh_shape == (1, 1)
    gw.close()


def test_failover_replans_fleet_and_empty_fleet_has_no_plan():
    ws = [StubWorker(f"w{i}", ["a"]) for i in range(3)]
    gw = RenderGateway(ws, devices_per_worker=2)
    assert gw.plan.mesh_shape == (3, 2)
    gw._handle_death("w1", [], WorkerDied("x"), 0.0)
    assert gw.plan.mesh_shape == (2, 2)
    assert gw.plan.routable == ("w0", "w2")
    gw._handle_death("w0", [], WorkerDied("x"), 0.0)
    gw._handle_death("w2", [], WorkerDied("x"), 0.0)
    assert gw.plan is None and plan_fleet([]) is None
    gw.close()


def test_duplicate_result_is_dropped():
    gw = RenderGateway([StubWorker("w0", ["a"])])
    req = _req(5)
    gw._attempts[5] = 1
    gw._resolve("w0", req, _Res("first"), 0.0, 0.0)
    gw._resolve("w0", req, _Res("late-duplicate"), 0.0, 0.0)
    assert gw.results[5].image == "first"
    assert gw.counts["duplicates"] == 1
    assert gw.counts["completed"] == 1
    gw.close()


# -- end-to-end over stubs (real dispatcher threads) --------------------------


def test_run_completes_all_requests_healthy():
    ws = [StubWorker("w0", ["a", "b"]), StubWorker("w1", ["a", "b"])]
    gw = RenderGateway(ws, retry_backoff_s=0.001)
    res = gw.run(_load(16, scenes=("a", "b")))
    assert len(res) == 16 and not gw.failed
    assert all(r.attempts == 1 for r in res.values())
    s = gw.summary()
    assert s["gateway"] is True and s["completed"] == 16
    assert "gateway: 16/16 completed" in gw.format()
    gw.close()


def test_failover_retries_complete_on_survivor():
    # w0 holds the affinity (pre-committed) and dies on its first dispatch;
    # every request must terminate on w1, with the scene re-committed there.
    w0 = StubWorker("w0", ["a"], committed=["a"], fail_dispatches=1)
    w1 = StubWorker("w1", ["a"])
    gw = RenderGateway([w0, w1], retry_backoff_s=0.001)
    res = gw.run(_load(6))
    assert len(res) == 6 and not gw.failed
    assert all(r.worker_id == "w1" for r in res.values())
    assert any(r.attempts > 1 for r in res.values())
    assert gw.counts["failovers"] == 1
    assert gw.counts["retries"] >= 1
    assert gw.counts["recommits"] >= 1
    assert gw.healthy_workers == ["w1"]
    gw.close()


def test_total_fleet_death_fails_requests_without_hanging():
    # Both workers die on first dispatch: bounded retries must terminate
    # every request in ``failed`` (no silent drop, no infinite loop).
    ws = [StubWorker("w0", ["a"], fail_dispatches=1),
          StubWorker("w1", ["a"], fail_dispatches=1)]
    gw = RenderGateway(ws, retry_backoff_s=0.001, max_retries=2)
    res = gw.run(_load(4))
    assert res == {} and set(gw.failed) == {0, 1, 2, 3}
    assert all(
        isinstance(e, (NoWorkerAvailable, WorkerDied))
        for e in gw.failed.values()
    )
    assert gw.counts["failovers"] == 2
    assert gw.outstanding() == 0
    assert gw.healthy_workers == []
    gw.close()


def test_kill_hook_induces_failover_on_next_dispatch():
    # An unobserved kill is lazy by design: the death only surfaces when the
    # gateway next touches the worker. Kill between two runs — the first
    # request of the second run routes to the (still-routable) corpse, the
    # dispatch raises, and failover re-runs it on the survivor.
    ws = [StubWorker("w0", ["a"]), StubWorker("w1", ["a"])]
    gw = RenderGateway(ws, retry_backoff_s=0.001)
    assert len(gw.run(_load(4))) == 4
    gw.kill_worker("w0")
    assert not ws[0].alive() and ws[1].alive()
    res = gw.run(_load(16, base=100))
    assert len(res) == 20 and not gw.failed
    assert gw.counts["failovers"] == 1
    assert all(
        res[rid].worker_id == "w1" for rid in range(100, 116)
    )
    gw.close()


def test_submit_step_drive_from_producer_thread():
    # The documented thread model: producers submit from another thread,
    # one driver loops step() until everything terminates.
    gw = RenderGateway([StubWorker("w0", ["a"])], retry_backoff_s=0.001)

    def produce():
        for i in range(8):
            while not gw.submit(_req(i)):
                time.sleep(0.001)

    t = threading.Thread(target=produce)
    t.start()
    deadline = time.monotonic() + 30
    while len(gw.results) < 8:
        gw.step()
        assert time.monotonic() < deadline, "gateway stalled"
        time.sleep(0.001)
    t.join()
    assert len(gw.results) == 8 and not gw.failed
    gw.close()


# -- in-process jax fleet: the failover e2e (DESIGN.md §16 acceptance) --------


@pytest.mark.slow
def test_inproc_failover_bitwise_and_span_parity():
    """Kill 1 of 2 in-process workers mid-load: every request completes,
    every image (retried ones included) is bitwise-identical to a direct
    single-worker run, and the gateway/* spans match the counters."""
    import jax
    import numpy as np

    from repro.core import orbit_cameras
    from repro.core.gaussians import scene_like_paper
    from repro.core.pipeline import RenderConfig
    from repro.gateway.worker import InprocWorker
    from repro.obs import Tracer, get_tracer, set_tracer

    scene_ids = ["train", "truck"]
    built = {
        sid: scene_like_paper(jax.random.key(i), sid, 300)
        for i, sid in enumerate(scene_ids)
    }
    cams = orbit_cameras(6, 4.5, 64, 64)
    cfg = RenderConfig(mode="gstg", backend="reference", span=6)
    warm_ids = iter(range(-1, -100, -1))

    def warm(w):
        # Compile every (scene, resolution) program up front so the first
        # timed dispatch is not a multi-second jit that trips heartbeats.
        for sid in scene_ids:
            w.dispatch([RenderRequest(next(warm_ids), sid, cams[0], cfg)])
        return w

    w0 = warm(InprocWorker("w0", built, max_batch=4))
    w1 = warm(InprocWorker("w1", built, max_batch=4))
    load = [
        (0.0, RenderRequest(i, scene_ids[i % 2], cams[i % len(cams)], cfg))
        for i in range(12)
    ]
    prev = set_tracer(Tracer(enabled=True))
    try:
        gw = RenderGateway([w0, w1], retry_backoff_s=0.005)
        res = gw.run(load, kill_worker="w0", kill_after=2)
        assert len(res) == 12, f"failed: {gw.failed}"
        assert not gw.failed
        assert gw.counts["failovers"] == 1
        retried = [r for r in res.values() if r.attempts > 1]
        assert retried, "the kill should have forced at least one retry"
        assert all(r.worker_id == "w1" for r in retried)

        # bitwise parity vs a direct single-worker run (same settings)
        ref = warm(InprocWorker("ref", built, max_batch=4))
        for i, (_, req) in enumerate(load):
            direct = ref.dispatch(
                [dataclasses.replace(req, request_id=1000 + i)]
            )[1000 + i]
            assert np.array_equal(
                np.asarray(direct.image), np.asarray(res[req.request_id].image)
            ), f"request {req.request_id} diverged from the direct run"
        ref.shutdown()

        # span <-> counter agreement (the validate_trace.py contract)
        names = [e.name for e in get_tracer().events()]
        assert names.count("gateway/failover") == gw.counts["failovers"]
        assert names.count("gateway/retry") == gw.counts["retries"]
        assert names.count("gateway/route") == gw.counts["routed"]
        assert names.count("request") == len(res)
        gw.close()
    finally:
        set_tracer(prev)


# -- subprocess transport -----------------------------------------------------


@pytest.mark.slow
def test_subprocess_worker_transport_roundtrip_and_sigkill():
    import os

    import numpy as np

    import repro
    from repro.core import make_camera
    from repro.gateway.transport import SubprocessWorker, worker_argv

    # pytest's pythonpath does not propagate to children: ship src/ along.
    env = dict(os.environ)
    src = os.path.dirname(list(repro.__path__)[0])
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")

    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    w = SubprocessWorker(
        "sub0", ["train"],
        worker_argv(
            "sub0", ["train:0"],
            devices=1,
            extra=["--gaussians", "300", "--max-batch", "2"],
        ),
        max_batch=2,
        env=env,
    )
    try:
        w.ping()
        assert w.alive()
        w.commit("train")
        assert "train" in w.committed_scene_ids()
        reqs = [RenderRequest(i, "train", cam, None) for i in (1, 2)]
        out = w.dispatch(reqs)
        img1 = np.asarray(out[1].image)
        assert img1.shape == (64, 64, 3) and img1.dtype == np.float32
        # same camera -> bitwise-identical lanes, and a re-dispatch of the
        # same request is deterministic (the retry-parity invariant on the
        # wire: base64 round-trip is byte-exact)
        assert np.array_equal(img1, np.asarray(out[2].image))
        again = w.dispatch([RenderRequest(3, "train", cam, None)])
        assert np.array_equal(img1, np.asarray(again[3].image))
        w.kill()                              # real SIGKILL
        assert not w.alive()
        with pytest.raises(WorkerDied):
            w.dispatch([RenderRequest(4, "train", cam, None)])
    finally:
        w.shutdown()
        w.shutdown()                          # idempotent


def test_route_prefers_resident_over_merely_committed():
    # Residency-aware placement (DESIGN.md §17): both workers committed the
    # scene, but only one still holds it paged IN — that one serves without
    # paying a page-in, so it wins even from a higher index. Residency is a
    # preference with the same spill rule as affinity, not a pin.
    class ResidencyStub(StubWorker):
        def __init__(self, worker_id, scene_ids, *, committed=(),
                     resident=()):
            super().__init__(worker_id, scene_ids, committed=committed)
            self._resident = set(resident)

        def resident_scene_ids(self):
            return set(self._resident)

    w0 = ResidencyStub("w0", ["a"], committed=["a"], resident=[])
    w1 = ResidencyStub("w1", ["a"], committed=["a"], resident=["a"])
    gw = RenderGateway([w0, w1], spill_load=2)
    assert gw._pick_worker(_req(1, "a")) == "w1"
    # at spill depth residency is demoted along with affinity
    gw._inbox["w1"].extend([_req(98), _req(99)])
    assert gw._pick_worker(_req(2, "a")) == "w0"
    gw.close()


def test_route_without_resident_signal_falls_back_to_affinity():
    # Plain StubWorker has no resident_scene_ids(): the router must treat
    # resident == committed (the optional-contract fallback), keeping the
    # pre-residency ordering bit-for-bit.
    w0 = StubWorker("w0", ["a"])
    w1 = StubWorker("w1", ["a"], committed=["a"])
    gw = RenderGateway([w0, w1])
    assert gw._pick_worker(_req(1, "a")) == "w1"
    gw.close()


@pytest.mark.slow
def test_dead_worker_paged_out_scene_repages_on_survivor():
    """A scene committed-but-paged-OUT on a worker that dies must complete
    on the survivor: failover re-routes, the survivor pages the scene in
    under ITS OWN budget (evicting its cold scene), and the pixels are
    bitwise-identical to an unbudgeted direct run."""
    import jax
    import numpy as np

    from repro import engine
    from repro.core import orbit_cameras
    from repro.core.gaussians import scene_like_paper
    from repro.core.pipeline import RenderConfig
    from repro.gateway.worker import InprocWorker

    scene_ids = ["train", "truck"]
    built = {
        sid: scene_like_paper(jax.random.key(i), sid, 300)
        for i, sid in enumerate(scene_ids)
    }
    cams = orbit_cameras(2, 4.5, 64, 64)
    cfg = RenderConfig(mode="gstg", backend="reference", span=6)

    probe = engine.open(built["train"], cfg)
    st = probe.stats()
    cost = st["scene_mb_per_device"] + st["feature_mb_per_device"]
    probe.close()
    budget = 1.5 * cost                     # fits ONE of the two scenes

    warm_ids = iter(range(-1, -100, -1))

    def warm(w):
        # Warming train then truck leaves truck resident and train paged
        # out on a budget this tight (and pre-compiles both programs).
        for sid in scene_ids:
            w.dispatch([RenderRequest(next(warm_ids), sid, cams[0], cfg)])
        return w

    w0 = warm(InprocWorker("w0", built, max_batch=4,
                           device_budget_mb=budget))
    w1 = warm(InprocWorker("w1", built, max_batch=4,
                           device_budget_mb=budget))
    assert "train" in w0.committed_scene_ids()
    assert "train" not in w0.resident_scene_ids()

    gw = RenderGateway([w0, w1], retry_backoff_s=0.005)
    gw.kill_worker("w0")
    res = gw.run([(0.0, RenderRequest(1, "train", cams[1], cfg))])
    assert len(res) == 1 and not gw.failed, f"failed: {gw.failed}"
    assert res[1].worker_id == "w1"
    assert gw.counts["failovers"] == 1
    assert "train" in w1.resident_scene_ids(), (
        "survivor served the failover without paging the scene in"
    )

    ref = InprocWorker("ref", built, max_batch=4)   # no budget: never pages
    direct = ref.dispatch([RenderRequest(99, "train", cams[1], cfg)])[99]
    assert np.array_equal(
        np.asarray(direct.image), np.asarray(res[1].image)
    ), "re-paged failover render diverged from the unbudgeted direct run"
    assert ref.server.residency.stats()["page_outs"] == 0
    ref.shutdown()
    gw.close()
