"""Paged scene residency under the device budget (DESIGN.md §17).

Manager-level unit tests (LRU paging, refcounts, budget eviction) plus the
engine/serving integration invariants: paging is bitwise-invisible (a
thrash workload at 2x the budget renders identically to an unbudgeted
run), ``residency.*`` counters match the ``residency/*`` trace spans, the
stream frontend caches are charged against the budget (the undercount
fix), and an over-budget server commit evicts cold scenes instead of
failing fast.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro import engine
from repro.core import make_camera, orbit_cameras, random_scene
from repro.core.pipeline import RenderConfig
from repro.obs import get_registry
from repro.residency import ResidencyManager


@pytest.fixture()
def res_cfg():
    return RenderConfig(
        tile=16, group=64, group_capacity=256, tile_capacity=256
    )


def _counter(name: str) -> int:
    return get_registry().snapshot()["counters"].get(name, 0)


# ---------------------------------------------------------------------------
# manager unit tests (plain pytrees — no engine involvement)
# ---------------------------------------------------------------------------


def _tree(seed: int):
    rng = np.random.default_rng(seed)
    return {"x": rng.standard_normal((64, 3)).astype(np.float32)}


def test_manager_register_acquire_release():
    mgr = ResidencyManager(budget_mb=None)
    entry = mgr.register("k", _tree(0), None, static_mb=1.0)
    assert not entry.resident
    dev = mgr.acquire(entry)
    assert entry.resident
    assert np.array_equal(np.asarray(dev["x"]), _tree(0)["x"])
    # A resident acquire is a hit (same object, no new transfer).
    assert mgr.acquire(entry) is dev
    s = mgr.stats()
    assert s["page_ins"] == 1 and s["hits"] == 1 and s["page_outs"] == 0
    mgr.release(entry)
    assert mgr.stats()["entries"] == 0
    assert mgr.stats()["page_outs"] == 1       # release pages out


def test_manager_shared_entry_refcount():
    """Two registrants of one key share ONE entry (and device copy); the
    entry survives until the LAST release."""
    mgr = ResidencyManager()
    a = mgr.register("k", _tree(1), None, static_mb=1.0)
    b = mgr.register("k", _tree(1), None, static_mb=2.0)
    assert a is b
    assert a.static_mb == 2.0                  # conservative max
    assert mgr.acquire(a) is mgr.acquire(b)
    mgr.release(a)
    assert mgr.stats()["entries"] == 1         # still referenced
    assert a.resident
    mgr.release(b)
    assert mgr.stats()["entries"] == 0


def test_manager_lru_eviction_against_budget():
    """Page-in past the budget evicts the least-recently-acquired resident;
    a re-acquire of the victim pages it back in (evicting in turn)."""
    mgr = ResidencyManager(budget_mb=2.5)
    ea = mgr.register("a", _tree(2), None, static_mb=1.0)
    eb = mgr.register("b", _tree(3), None, static_mb=1.0)
    ec = mgr.register("c", _tree(4), None, static_mb=1.0)
    mgr.acquire(ea)
    mgr.acquire(eb)
    assert ea.resident and eb.resident
    mgr.acquire(ec)                            # over budget: evict LRU = a
    assert not ea.resident and eb.resident and ec.resident
    assert mgr.stats()["evictions"] == 1
    mgr.acquire(eb)                            # touch b: c becomes LRU
    mgr.acquire(ea)                            # page a back: evicts c
    assert ea.resident and eb.resident and not ec.resident
    assert mgr.stats()["page_ins"] == 4 and mgr.stats()["evictions"] == 2
    for e in (ea, eb, ec):
        mgr.release(e)


def test_manager_single_entry_over_budget_still_serves():
    """With nothing left to evict, the active entry pages in anyway (the
    dispatch must proceed) and the violation is counted."""
    mgr = ResidencyManager(budget_mb=0.5)
    e = mgr.register("big", _tree(5), None, static_mb=1.0)
    assert mgr.acquire(e) is not None
    assert e.resident
    assert mgr.stats()["over_budget"] == 1
    mgr.release(e)


# ---------------------------------------------------------------------------
# engine integration: bitwise-invisible paging + counters == spans
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_thrash_round_robin_bitwise_and_counters_match_spans(
    res_cfg, jit_render_fn
):
    """Commit 4 scenes at 2x the budget and render round-robin for two
    laps: every image is bitwise-identical to an unbudgeted (stateless)
    render, eviction actually happened, and the residency counters match
    the residency/* trace spans exactly."""
    from repro.obs import Tracer, get_tracer, set_tracer

    scenes = [random_scene(__import__("jax").random.key(10 + i), 200,
                           extent=2.5) for i in range(4)]
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)

    # Size the budget off the real committed cost: fits 2 of 4 scenes.
    probe = engine.open(scenes[0], res_cfg)
    st = probe.stats()
    cost = st["scene_mb_per_device"] + st["feature_mb_per_device"]
    probe.close()
    budget = 2.2 * cost

    refs = [np.asarray(jit_render_fn(s, cam, res_cfg).image) for s in scenes]

    c0 = {k: _counter(f"residency.{k}_total")
          for k in ("page_ins", "page_outs")}
    prev = set_tracer(Tracer(enabled=True))
    try:
        mgr = ResidencyManager(budget_mb=budget, name="thrash")
        handles = [
            engine.open(s, res_cfg, residency=mgr) for s in scenes
        ]
        assert mgr.stats()["resident_entries"] <= 2
        for lap in range(2):
            for i, h in enumerate(handles):
                img = np.asarray(h.render(cam).image)
                assert np.array_equal(img, refs[i]), (
                    f"scene {i} lap {lap} diverged after paging"
                )
        s = mgr.stats()
        assert s["page_outs"] > 0, "thrash at 2x budget never evicted"
        assert s["page_ins"] > len(handles), "no scene ever paged back in"
        assert s["resident_mb"] <= budget + 1e-9

        # counters == spans (the validate_trace.py residency contract)
        names = [e.name for e in get_tracer().events()]
        assert names.count("residency/page_in") == (
            _counter("residency.page_ins_total") - c0["page_ins"]
        )
        assert names.count("residency/page_out") == (
            _counter("residency.page_outs_total") - c0["page_outs"]
        )
        for h in handles:
            h.close()
        assert mgr.stats()["entries"] == 0
    finally:
        set_tracer(prev)


def test_open_via_manager_single_scene_over_budget_raises(res_cfg):
    """The per-scene fail-fast is preserved under a shared manager: a
    scene that cannot fit the budget even ALONE still refuses to commit
    (paging cannot help — there would be nothing to evict)."""
    scene = random_scene(__import__("jax").random.key(3), 200, extent=2.5)
    mgr = ResidencyManager(budget_mb=1e-4)
    with pytest.raises(ValueError, match="over the"):
        engine.open(scene, res_cfg, residency=mgr)


# ---------------------------------------------------------------------------
# the budget-undercount fix: stream frontend caches are charged
# ---------------------------------------------------------------------------


def test_frontend_cache_counted_against_budget(tiny_scene, res_cfg):
    """Regression: stream sessions' frontend caches hold device memory the
    budget model used to ignore — they now surface in
    Renderer.stats()['frontend_cache_mb'] and in the residency entry's
    dynamic cost (what eviction decisions see)."""
    with engine.open(tiny_scene, res_cfg) as h:
        assert h.stats()["frontend_cache_mb"] == 0.0
        stream = h.open_stream(cache_frames=4, speculate=False)
        for cam in orbit_cameras(3, 4.5, 64, 64):
            stream.render(cam)
        mb = h.stats()["frontend_cache_mb"]
        assert mb > 0.0, "cached FrontendResults invisible to the budget"
        assert mb == pytest.approx(stream.cache_bytes() / 2**20)
        assert stream.stats()["cache_bytes"] == stream.cache_bytes()
        # The entry's dynamic cost — the number eviction compares against
        # the budget — includes the cache on top of the static model.
        entry = h._res_entry
        assert entry.cost_mb() == pytest.approx(entry.static_mb + mb)
        stream.close()
        assert h.stats()["frontend_cache_mb"] == 0.0


# ---------------------------------------------------------------------------
# serving integration: evict-instead-of-fail + admission prefetch
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_server_overbudget_commit_evicts_instead_of_failing(
    tiny_scene, res_cfg
):
    """A server budgeted for ~1 scene commits and serves 3: commits evict
    cold scenes (never ValueError), every request completes bitwise-equal
    to an unbudgeted run, and the eviction counters are nonzero."""
    import jax

    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer

    scenes = {
        f"s{i}": random_scene(jax.random.key(20 + i), 200, extent=2.5)
        for i in range(3)
    }
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    probe = engine.open(scenes["s0"], res_cfg)
    st = probe.stats()
    cost = st["scene_mb_per_device"] + st["feature_mb_per_device"]
    probe.close()

    load = [
        (0.0, RenderRequest(i, f"s{i % 3}", cam, res_cfg))
        for i in range(6)
    ]
    with RenderServer(scenes, device_budget_mb=1.5 * cost,
                      max_batch=2, max_wait=0.0) as budgeted:
        res = budgeted.run(load, realtime=False)
        assert sorted(res) == list(range(6))
        s = budgeted.residency.stats()
        assert s["evictions"] > 0 and s["page_outs"] > 0
        assert len(budgeted.resident_scene_ids) <= len(
            budgeted.committed_scene_ids
        )
        images = {i: res[i].image for i in res}

    load2 = [
        (0.0, RenderRequest(i, f"s{i % 3}", cam, res_cfg))
        for i in range(6)
    ]
    with RenderServer(scenes, max_batch=2, max_wait=0.0) as unbudgeted:
        ref = unbudgeted.run(load2, realtime=False)
        assert unbudgeted.residency.stats()["page_outs"] == 0
        for i in ref:
            assert np.array_equal(images[i], ref[i].image), (
                f"request {i}: paged serving diverged from unbudgeted"
            )


def test_server_admission_prefetch_pages_in(tiny_scene, res_cfg):
    """An admitted request for a committed-but-paged-out scene pages it
    back in at admission (before its dispatch), counted as a prefetch."""
    import jax

    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer

    scenes = {
        "a": tiny_scene,
        "b": random_scene(jax.random.key(30), 200, extent=2.5),
    }
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    probe = engine.open(scenes["a"], res_cfg)
    st = probe.stats()
    cost = st["scene_mb_per_device"] + st["feature_mb_per_device"]
    probe.close()

    server = RenderServer(scenes, device_budget_mb=1.5 * cost)
    try:
        server.commit("a", res_cfg)
        server.commit("b", res_cfg)            # evicts a (budget fits one)
        assert server.resident_scene_ids == frozenset({"b"})
        pre = server.residency.stats()["prefetches"]
        assert server.submit(RenderRequest(0, "a", cam, res_cfg))
        assert "a" in server.resident_scene_ids, (
            "admission did not prefetch the paged-out scene"
        )
        assert server.residency.stats()["prefetches"] == pre + 1
    finally:
        server.close()


def test_server_close_is_terminal(tiny_scene, res_cfg):
    from repro.serving.server import RenderServer

    server = RenderServer({"scene": tiny_scene})
    server.commit("scene", res_cfg)
    server.close()
    assert server._renderers == {}
    with pytest.raises(RuntimeError, match="closed"):
        server.commit("scene", res_cfg)
    server.close()                             # idempotent
