"""The unified engine: backend dispatch parity + batched multi-camera entry.

Backend parity is the cross-backend losslessness contract (DESIGN.md §6):
the pallas stage implementations must produce the same images (to fp
reassociation of chunk boundaries) and IDENTICAL integer counters as the
reference stages, through the same render() entry.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_camera, orbit_cameras
from repro.core.pipeline import (
    CameraBatch,
    RenderConfig,
    batch_signature,
    render,
    render_batch,
    render_cache_info,
    render_jit,
)
from repro.core.stages import get_backend

INT_COUNTERS = (
    "n_visible",
    "n_candidate_tests",
    "n_pairs_sort",
    "sort_ops",
    "n_bit_tests",
    "fifo_ops",
    "alpha_ops",
    "blend_ops",
    "tile_entries",
    "overflow",
    "span_overflow",
)


def _assert_stats_identical(a, b):
    for name in INT_COUNTERS:
        va, vb = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert (va == vb).all(), f"counter {name}: reference={va} pallas={vb}"


@pytest.mark.parametrize("mode", ["gstg", "tile_baseline", "group_baseline"])
def test_backend_parity(small_scene, cam128, base_cfg, mode):
    """reference vs pallas through the SAME jit'd closure (conftest session
    cache): allclose images, identical counters (incl.
    tile_entries/overflow). The contract is tolerance/integer-based, so the
    jit path is valid — and what production runs."""
    from conftest import jit_render

    cfg = dataclasses.replace(base_cfg, mode=mode)
    ref = jit_render(small_scene, cam128, cfg)
    pal = jit_render(
        small_scene, cam128, dataclasses.replace(cfg, backend="pallas")
    )
    np.testing.assert_allclose(
        np.asarray(pal.image), np.asarray(ref.image), atol=5e-6, rtol=1e-5
    )
    _assert_stats_identical(ref.stats, pal.stats)
    assert int(pal.stats.alpha_ops) > 0  # stats actually populated


@pytest.mark.slow
@pytest.mark.parametrize("bg", ["aabb", "obb", "ellipse"])
@pytest.mark.parametrize("bt", ["aabb", "obb", "ellipse"])
def test_backend_parity_boundary_matrix(tiny_scene, cam128, base_cfg, bg, bt):
    """The full 9-combo boundary-method matrix (ROADMAP): reference vs pallas
    must agree — allclose images, IDENTICAL counters — for every
    (group-identification, tile-bitmask) method pairing, not just the
    defaults; the bitmask/compaction kernels take method-dependent paths."""
    cfg = dataclasses.replace(
        base_cfg, mode="gstg", boundary_group=bg, boundary_tile=bt
    )
    ref = render(tiny_scene, cam128, cfg)
    pal = render(tiny_scene, cam128, dataclasses.replace(cfg, backend="pallas"))
    np.testing.assert_allclose(
        np.asarray(pal.image), np.asarray(ref.image), atol=5e-6, rtol=1e-5
    )
    _assert_stats_identical(ref.stats, pal.stats)
    assert int(ref.stats.overflow) == 0  # parity claim needs no drops


def test_backend_parity_options(small_scene, cam128, base_cfg):
    """pallas honors background, early_exit=False, odd chunk, tight capacity."""
    from conftest import jit_render

    bg = jnp.array([0.25, 0.1, 0.4], jnp.float32)
    cfg = dataclasses.replace(
        base_cfg, early_exit=False, chunk=48, tile_capacity=64
    )
    ref = jit_render(small_scene, cam128, cfg, background=bg)
    pal = jit_render(
        small_scene, cam128, dataclasses.replace(cfg, backend="pallas"),
        background=bg,
    )
    np.testing.assert_allclose(
        np.asarray(pal.image), np.asarray(ref.image), atol=5e-6, rtol=1e-5
    )
    _assert_stats_identical(ref.stats, pal.stats)


def test_unknown_backend_raises(small_scene, cam128, base_cfg):
    with pytest.raises(ValueError, match="unknown backend"):
        render(small_scene, cam128, dataclasses.replace(base_cfg, backend="cuda"))
    assert get_backend("pallas").name == "pallas"


def test_render_batch_matches_loop(small_scene, base_cfg):
    from conftest import jit_render

    cams = orbit_cameras(3, 4.5, 128, 128)
    out = render_batch(small_scene, cams, base_cfg)
    assert out.image.shape == (3, 128, 128, 3)
    for i, cam in enumerate(cams):
        one = jit_render(small_scene, cam, base_cfg)
        np.testing.assert_allclose(
            np.asarray(out.image[i]), np.asarray(one.image), atol=1e-6, rtol=1e-6
        )
        for name in INT_COUNTERS:
            assert int(np.asarray(getattr(out.stats, name))[i]) == int(
                getattr(one.stats, name)
            ), f"batched counter {name} diverges for camera {i}"


def test_render_batch_rejects_mixed_geometry(small_scene):
    cams = [
        make_camera((0, 1, 4.5), (0, 0, 0), 128, 128),
        make_camera((0, 1, 4.5), (0, 0, 0), 256, 128),
    ]
    with pytest.raises(ValueError, match="batch"):
        CameraBatch.from_cameras(cams)


def test_render_batch_jit_cache(small_scene, base_cfg):
    """Second call with an equal (distinct-instance) config and same geometry
    reuses the compiled renderer."""
    cams = CameraBatch.from_cameras(orbit_cameras(2, 4.5, 128, 128))
    render_batch(small_scene, cams, base_cfg)
    before = render_cache_info()["batch"]
    cfg_again = dataclasses.replace(base_cfg)  # equal by value, new instance
    assert cfg_again is not base_cfg
    render_batch(small_scene, cams, cfg_again)
    after = render_cache_info()["batch"]
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]


def test_render_jit_single_camera_cache(small_scene, base_cfg):
    """render_jit shares one executable across cameras of equal resolution
    — now through its module-default engine handle (DESIGN.md §11): the
    second call must be a per-handle cache hit, not a recompile."""
    from repro import engine

    cam_a = make_camera((0, 1.0, 4.5), (0, 0, 0), 128, 128)
    cam_b = make_camera((1.5, 0.8, 4.0), (0, 0, 0), 128, 128)
    render_jit(small_scene, cam_a, base_cfg)
    handle = engine.default_renderer(small_scene, base_cfg)
    before = handle.cache_info()
    out = render_jit(small_scene, cam_b, base_cfg)
    after = handle.cache_info()
    assert engine.default_renderer(small_scene, base_cfg) is handle
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]
    from conftest import jit_render

    oracle = jit_render(small_scene, cam_b, base_cfg)
    np.testing.assert_allclose(
        np.asarray(out.image), np.asarray(oracle.image), atol=1e-6, rtol=1e-6
    )


def test_cache_info_is_plain_dict(small_scene, base_cfg):
    """render_cache_info returns plain dicts (the serving stats and the CLI
    --stats output consume them without lru internals). Registered auxiliary
    caches (engine handles, the scene-layout cache) ride alongside the two
    built-in executable caches."""
    info = render_cache_info()
    assert "batch" in info
    for kind in info.values():
        assert {"hits", "misses", "currsize", "maxsize"} <= set(kind)
        assert all(isinstance(v, int) for v in kind.values())


def test_batch_signature_keys_the_cache(base_cfg):
    """batch_signature is the executable-cache key: equal for any camera of
    the same geometry under an equal config, different across resolutions,
    configs, and backends — the serving bucketer relies on exactly this."""
    cam_a = make_camera((0, 1.0, 4.5), (0, 0, 0), 128, 128)
    cam_b = make_camera((2.0, 0.5, 3.0), (1, 0, 0), 128, 128)
    batch = CameraBatch.from_cameras([cam_a, cam_b])
    assert batch_signature(base_cfg, cam_a) == batch_signature(base_cfg, cam_b)
    assert batch_signature(base_cfg, cam_a) == batch_signature(
        dataclasses.replace(base_cfg), batch
    )
    other_res = make_camera((0, 1.0, 4.5), (0, 0, 0), 256, 128)
    assert batch_signature(base_cfg, cam_a) != batch_signature(base_cfg, other_res)
    assert batch_signature(base_cfg, cam_a) != batch_signature(
        dataclasses.replace(base_cfg, backend="pallas"), cam_a
    )
