import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(key):
    return {
        "w": jax.random.normal(key, (32, 16)),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32)},
    }


def test_roundtrip_bit_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = _tree(jax.random.key(0))
    mgr.save(10, tree, extra={"rng": 123})
    leaves, manifest = mgr.restore()
    orig = jax.tree.leaves(tree)
    assert manifest["step"] == 10
    assert manifest["extra"]["rng"] == 123
    for a, b in zip(orig, leaves):
        assert (np.asarray(a) == b).all()
        assert np.asarray(a).dtype == b.dtype


def test_async_write_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    for s in (1, 2):
        mgr.save(s, _tree(jax.random.key(s)))
    mgr.wait()
    assert mgr.all_steps() == [1, 2]


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in range(5):
        mgr.save(s, _tree(jax.random.key(s)))
    assert mgr.all_steps() == [3, 4]


def test_corruption_detected(tmp_path):
    import zipfile

    try:
        import zstandard
    except ImportError:
        zstandard = None

    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _tree(jax.random.key(0)))
    path = mgr.latest().path
    raw = bytearray(open(path, "rb").read())
    if zstandard is not None:  # flip a byte of the DECOMPRESSED payload
        raw = bytearray(zstandard.ZstdDecompressor().decompress(bytes(raw)))
    raw[len(raw) // 2] ^= 0xFF  # flip a payload byte
    blob = bytes(raw)
    if zstandard is not None:
        blob = zstandard.ZstdCompressor(level=3).compress(blob)
    open(path, "wb").write(blob)
    # Either the container CRC or our per-leaf sha256 must refuse the load —
    # both are integrity failures surfaced before any tensor is used.
    with pytest.raises((IOError, zipfile.BadZipFile)):
        mgr.restore()


def test_resume_reproduces_training(tmp_path):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    from repro.configs import get_smoke_config
    from repro.models import build_param_spec, loss_fn
    from repro.models.spec import init_from_spec
    from repro.optim import adamw_init, adamw_update
    from repro.data import TokenStream, make_batch

    cfg = get_smoke_config("granite-3-2b")
    stream = TokenStream(cfg.vocab, 2, 32, seed=7)
    ident = lambda x, a: x

    @jax.jit
    def _update(params, opt, batch, i):
        g = jax.grad(lambda p: loss_fn(cfg, p, batch, ident)[0])(params)
        return adamw_update(params, g, opt, i, lr=1e-3)

    def step(params, opt, i):
        # jit'd update: an unjitted jax.grad re-traces on EVERY call; both
        # the straight and resumed runs use this same compiled step, so the
        # bitwise resume comparison is unaffected.
        batch = {k: jnp.asarray(v) for k, v in make_batch(stream, i).items()}
        return _update(params, opt, batch, jnp.int32(i))

    p0 = init_from_spec(build_param_spec(cfg), jax.random.key(1))
    o0 = adamw_init(p0)

    # straight
    p, o = p0, o0
    for i in range(4):
        p, o = step(p, o, i)
    straight = jax.tree.leaves(p)

    # interrupted at step 2
    p, o = p0, o0
    for i in range(2):
        p, o = step(p, o, i)
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(2, {"params": p, "opt": o})
    leaves, manifest = mgr.restore()
    restored = jax.tree.unflatten(
        jax.tree.structure({"params": p, "opt": o}), [jnp.asarray(x) for x in leaves]
    )
    p, o = restored["params"], restored["opt"]
    for i in range(2, 4):
        p, o = step(p, o, i)
    resumed = jax.tree.leaves(p)
    for a, b in zip(straight, resumed):
        assert (np.asarray(a) == np.asarray(b)).all()
