import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grouping import (
    GridSpec,
    PairSet,
    bin_pairs,
    identify,
    merge_bin_tables,
    sort_op_count,
)
from repro.core.projection import project
from repro.core import make_camera, random_scene
from repro.utils import wide_count_dtype, wide_count_sum

# Jitted stage wrappers for the full-scene tests (GridSpec is hashable):
# one compile per (shape, statics) instead of per-op eager tracing. The
# synthetic merge tests below stay eager — their many tiny shapes would
# each recompile.
identify_j = jax.jit(identify, static_argnames=("grid", "level", "method"))
bin_pairs_j = jax.jit(bin_pairs, static_argnames=("num_bins", "capacity"))


def _setup(seed=0, n=400, w=192, h=128):
    scene = random_scene(jax.random.key(seed), n, extent=3.0)
    cam = make_camera((0, 1.2, 5.0), (0, 0, 0), w, h)
    proj = project(scene, cam)
    grid = GridSpec(w, h, 16, 64, span=4)
    return proj, grid


def test_pairs_group_leq_tile():
    """The paper's core quantity: group-level sorting keys are a strict
    subset of tile-level ones (Table I / Fig 5)."""
    proj, grid = _setup()
    pt = identify_j(proj, grid, "tile", "ellipse")
    pg = identify_j(proj, grid, "group", "ellipse")
    assert int(pg.n_pairs) <= int(pt.n_pairs)
    assert int(pg.n_pairs) > 0
    # every tile hit implies its group hit => tile pairs >= group pairs and
    # per gaussian, #tiles >= #groups; globally strict for clustered scenes
    assert int(pt.n_pairs) > int(pg.n_pairs)


def test_no_overflow_small_scene():
    proj, grid = _setup()
    pg = identify_j(proj, grid, "group", "ellipse")
    assert int(pg.n_span_overflow) == 0
    table = bin_pairs_j(pg, grid.num_groups, 512)
    assert int(table.overflow) == 0


def test_bin_table_depth_sorted():
    proj, grid = _setup(1)
    pg = identify_j(proj, grid, "group", "ellipse")
    table = bin_pairs_j(pg, grid.num_groups, 512)
    depth = np.asarray(proj.depth)
    gidx = np.asarray(table.gauss_idx)
    valid = np.asarray(table.entry_valid)
    for g in range(table.num_bins):
        d = depth[gidx[g][valid[g]]]
        assert (np.diff(d) >= -1e-6).all(), f"group {g} not depth sorted"


def test_bin_lengths_match_pairs():
    proj, grid = _setup(2)
    pg = identify_j(proj, grid, "group", "ellipse")
    table = bin_pairs_j(pg, grid.num_groups, 512)
    assert int(jnp.sum(table.lengths)) == int(pg.n_pairs)


def test_sort_op_count_model():
    lengths = jnp.array([0, 1, 2, 8, 100])
    ops = int(sort_op_count(lengths))
    expected = 0 + 1 * 1 + 2 * 1 + 8 * 3 + 100 * 7
    assert ops == expected


def test_grid_spec_validation():
    import pytest

    with pytest.raises(ValueError):
        GridSpec(100, 100, 16, 64)  # not tile-divisible
    with pytest.raises(ValueError):
        GridSpec(128, 128, 16, 40)  # group not multiple of tile


# ---------------------------------------------------------------------------
# Cross-shard merge stage (DESIGN.md §10)
# ---------------------------------------------------------------------------


def _synthetic_pairs(rng, n_gauss, span, num_bins):
    """Gaussian-major synthetic pair list with FORCED depth ties (depths drawn
    from 4 values) so the merge's insertion-order tie-break is exercised."""
    S = span * span
    bin_id = rng.integers(0, num_bins + 1, size=(n_gauss, S)).astype(np.int32)
    hit = bin_id < num_bins
    depth = rng.choice([1.0, 2.0, 3.0, 5.0], size=(n_gauss, S)).astype(np.float32)
    depth = np.where(hit, depth, np.inf)
    bin_id = np.where(hit, bin_id, num_bins).astype(np.int32)
    gauss = np.broadcast_to(
        np.arange(n_gauss, dtype=np.int32)[:, None], (n_gauss, S)
    )
    flat = lambda a: jnp.asarray(a.reshape(-1))
    zero = jnp.zeros((), jnp.int32)
    return PairSet(
        bin_id=flat(bin_id), gauss_idx=flat(gauss), depth=flat(depth),
        valid=flat(hit), n_candidate_tests=zero, n_pairs=zero,
        n_span_overflow=zero,
    )


def _shard_pairs(pairs, n_gauss, shards, span):
    """Slice the gaussian-major pair list into contiguous gaussian shards
    (what the sharded frontend's per-shard identify produces)."""
    S = span * span
    size = -(-n_gauss // shards)
    out = []
    for d in range(shards):
        lo, hi = d * size, min((d + 1) * size, n_gauss)
        sl = slice(lo * S, hi * S)
        out.append(
            dataclasses.replace(
                pairs,
                bin_id=pairs.bin_id[sl],
                gauss_idx=pairs.gauss_idx[sl] - lo,
                depth=pairs.depth[sl],
                valid=pairs.valid[sl],
            )
        )
    return out, size


def test_merge_bin_tables_bitwise_vs_global():
    """D per-shard tables + stable merge == binning the global pair set,
    field for field — including under depth ties (insertion-order tie-break)
    and per-bin capacity overflow (merged top-K == global top-K)."""
    rng = np.random.default_rng(0)
    n_gauss, span, num_bins = 60, 3, 7
    pairs = _synthetic_pairs(rng, n_gauss, span, num_bins)
    depth_by_gauss = rng.uniform(1.0, 9.0, size=n_gauss).astype(np.float32)
    # Per-gaussian depths (as projection produces): rebuild pair depths so the
    # merge's depth gather (a per-gaussian lookup) matches the pair keys.
    depth_flat = jnp.where(
        pairs.valid, jnp.asarray(depth_by_gauss)[pairs.gauss_idx], jnp.inf
    )
    # Quantize to force cross-gaussian ties.
    depth_flat = jnp.where(
        jnp.isfinite(depth_flat), jnp.round(depth_flat), jnp.inf
    )
    pairs = dataclasses.replace(pairs, depth=depth_flat)
    gauss_depth = jnp.round(jnp.asarray(depth_by_gauss))

    for capacity in (64, 6):   # no-overflow and overflow regimes
        for shards in (1, 2, 3):
            ref = bin_pairs(pairs, num_bins, capacity)
            shard_pairs, size = _shard_pairs(pairs, n_gauss, shards, span)
            tables = [bin_pairs(p, num_bins, capacity) for p in shard_pairs]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *tables)
            offs = (jnp.arange(shards, dtype=jnp.int32) * size)[:, None, None]
            gidx = jnp.where(
                stacked.entry_valid, stacked.gauss_idx + offs, 0
            )
            pad_depth = jnp.concatenate(
                [gauss_depth,
                 jnp.full((shards * size - n_gauss,), jnp.inf, jnp.float32)]
            )
            depth = jnp.where(
                stacked.entry_valid, pad_depth[gidx], jnp.inf
            )
            merged = merge_bin_tables(
                dataclasses.replace(stacked, gauss_idx=gidx), depth
            )
            for field in ("gauss_idx", "entry_valid", "lengths", "overflow"):
                a = np.asarray(getattr(ref, field))
                b = np.asarray(getattr(merged, field))
                assert (a == b).all(), (capacity, shards, field)


# ---------------------------------------------------------------------------
# Wide op counters (int32-overflow regression, multi-million-Gaussian scenes)
# ---------------------------------------------------------------------------


def test_sort_op_count_no_int32_wraparound():
    """Synthetic lengths whose true op count exceeds 2**31: the old int32
    accumulator wrapped negative; the wide counter must stay positive and
    within fp rounding of the exact total."""
    lengths = jnp.full((64,), 10_000_000, jnp.int32)
    ops = float(sort_op_count(lengths))
    exact = 64 * 10_000_000 * 24          # ceil(log2 1e7) == 24
    assert ops > 2**31
    assert abs(ops - exact) / exact < 1e-5


def test_wide_count_sum_no_int32_wraparound():
    """The fifo_ops-style accumulation (sum of lengths x tiles_per_group)
    stays positive past 2**31."""
    lengths = jnp.full((2048,), 2**20, jnp.int32)
    total = float(wide_count_sum(lengths)) * 16
    assert total == float(2**31) * 16 > 2**31


def test_identify_counter_dtype_is_wide():
    proj, grid = _setup()
    pg = identify(proj, grid, "tile", "ellipse")
    assert pg.n_candidate_tests.dtype == wide_count_dtype()
    # small-regime exactness: the wide counter agrees with an int count
    exact = int(np.asarray(pg.valid).sum())
    assert int(pg.n_pairs) == exact
    assert int(pg.n_candidate_tests) >= exact


# ---------------------------------------------------------------------------
# merge_bin_tables property test (hypothesis): the merge invariant holds for
# ANY gaussian-major pair population — forced depth ties, per-bin capacity
# overflow, all-padding shards, D in {1..4} — not just the scenes the render
# parity suite happens to produce.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests degrade gracefully without hypothesis
    HAVE_HYPOTHESIS = False


def _merge_case(n_gauss, shards, capacity, num_bins, depth_levels,
                dead_tail, seed):
    """One merge-vs-global comparison on a synthetic pair population.

    Mirrors the canonical sharded layout (sharding/scene.py): the gaussian
    axis is padded to a multiple of D and every padding gaussian's pairs
    are invalid (culled rows still occupy pair slots) — so EVERY shard has
    the same size, and a shard can be entirely padding.
    """
    rng = np.random.default_rng(seed)
    span = 2
    size = -(-n_gauss // shards)
    n_pad = size * shards
    pairs = _synthetic_pairs(rng, n_pad, span, num_bins)
    # Per-gaussian depths from a tiny pool => heavy cross-gaussian ties, so
    # the stable tie-break (insertion order == global gaussian order) is the
    # only thing that can make the comparison pass.
    gauss_depth = np.full((n_pad,), np.inf, np.float32)
    gauss_depth[:n_gauss] = rng.choice(
        np.arange(1.0, depth_levels + 1.0, dtype=np.float32), size=n_gauss
    )
    gauss_depth = jnp.asarray(gauss_depth)
    # Cull padding rows; dead_tail additionally kills the whole LAST shard
    # (an all-padding shard must contribute nothing and not disturb the
    # tie-break).
    cut = (shards - 1) * size if dead_tail and shards > 1 else n_gauss
    alive = np.asarray(pairs.gauss_idx) < min(cut, n_gauss)
    valid = pairs.valid & jnp.asarray(alive)
    depth_flat = jnp.where(valid, gauss_depth[pairs.gauss_idx], jnp.inf)
    pairs = dataclasses.replace(
        pairs,
        depth=depth_flat,
        valid=valid,
        bin_id=jnp.where(valid, pairs.bin_id, num_bins).astype(jnp.int32),
    )

    ref = bin_pairs(pairs, num_bins, capacity)
    shard_pairs, size = _shard_pairs(pairs, n_pad, shards, span)
    tables = [bin_pairs(p, num_bins, capacity) for p in shard_pairs]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *tables)
    offs = (jnp.arange(shards, dtype=jnp.int32) * size)[:, None, None]
    gidx = jnp.where(stacked.entry_valid, stacked.gauss_idx + offs, 0)
    depth = jnp.where(stacked.entry_valid, gauss_depth[gidx], jnp.inf)
    merged = merge_bin_tables(
        dataclasses.replace(stacked, gauss_idx=gidx), depth
    )
    for field in ("gauss_idx", "entry_valid", "lengths", "overflow"):
        a = np.asarray(getattr(ref, field))
        b = np.asarray(getattr(merged, field))
        assert (a == b).all(), (
            f"{field} diverges (n={n_gauss}, D={shards}, K={capacity}, "
            f"bins={num_bins}, levels={depth_levels}, dead_tail={dead_tail})"
        )


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        n_gauss=st.integers(1, 24),
        shards=st.integers(1, 4),
        capacity=st.sampled_from([3, 8, 64]),   # overflow and no-overflow
        num_bins=st.integers(1, 6),
        depth_levels=st.integers(1, 3),          # 1 => EVERY depth ties
        dead_tail=st.booleans(),                 # all-padding last shard
        seed=st.integers(0, 2**20),
    )
    def test_merge_bin_tables_property(
        n_gauss, shards, capacity, num_bins, depth_levels, dead_tail, seed
    ):
        """merge_bin_tables == bin_pairs on the global pair set, field for
        field, for arbitrary pair populations — the standalone contract the
        render parity suite only exercises end-to-end."""
        _merge_case(
            n_gauss, shards, capacity, num_bins, depth_levels, dead_tail,
            seed,
        )

else:

    import pytest as _pytest

    @_pytest.mark.parametrize("shards", [1, 2, 3, 4])
    @_pytest.mark.parametrize(
        "n_gauss,capacity,depth_levels,dead_tail",
        [
            (1, 3, 1, False),     # single gaussian, everything ties
            (5, 3, 1, True),      # overflow + all-padding last shard
            (17, 8, 2, False),    # ragged shard sizes + ties
            (24, 64, 3, True),    # no overflow, dead tail
        ],
    )
    def test_merge_bin_tables_property(
        n_gauss, shards, capacity, depth_levels, dead_tail
    ):
        """Deterministic fallback sweep of the same merge property when
        hypothesis is unavailable (the property test proper randomizes the
        pair population; this pins the named edge cases)."""
        for seed in (0, 1):
            _merge_case(
                n_gauss, shards, capacity, 5, depth_levels, dead_tail, seed
            )
