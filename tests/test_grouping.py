import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grouping import (
    GridSpec,
    PairSet,
    bin_pairs,
    identify,
    merge_bin_tables,
    sort_op_count,
)
from repro.core.projection import project
from repro.core import make_camera, random_scene
from repro.utils import wide_count_dtype, wide_count_sum


def _setup(seed=0, n=600, w=256, h=192):
    scene = random_scene(jax.random.key(seed), n, extent=3.0)
    cam = make_camera((0, 1.2, 5.0), (0, 0, 0), w, h)
    proj = project(scene, cam)
    grid = GridSpec(w, h, 16, 64, span=4)
    return proj, grid


def test_pairs_group_leq_tile():
    """The paper's core quantity: group-level sorting keys are a strict
    subset of tile-level ones (Table I / Fig 5)."""
    proj, grid = _setup()
    pt = identify(proj, grid, "tile", "ellipse")
    pg = identify(proj, grid, "group", "ellipse")
    assert int(pg.n_pairs) <= int(pt.n_pairs)
    assert int(pg.n_pairs) > 0
    # every tile hit implies its group hit => tile pairs >= group pairs and
    # per gaussian, #tiles >= #groups; globally strict for clustered scenes
    assert int(pt.n_pairs) > int(pg.n_pairs)


def test_no_overflow_small_scene():
    proj, grid = _setup()
    pg = identify(proj, grid, "group", "ellipse")
    assert int(pg.n_span_overflow) == 0
    table = bin_pairs(pg, grid.num_groups, 512)
    assert int(table.overflow) == 0


def test_bin_table_depth_sorted():
    proj, grid = _setup(1)
    pg = identify(proj, grid, "group", "ellipse")
    table = bin_pairs(pg, grid.num_groups, 512)
    depth = np.asarray(proj.depth)
    gidx = np.asarray(table.gauss_idx)
    valid = np.asarray(table.entry_valid)
    for g in range(table.num_bins):
        d = depth[gidx[g][valid[g]]]
        assert (np.diff(d) >= -1e-6).all(), f"group {g} not depth sorted"


def test_bin_lengths_match_pairs():
    proj, grid = _setup(2)
    pg = identify(proj, grid, "group", "ellipse")
    table = bin_pairs(pg, grid.num_groups, 512)
    assert int(jnp.sum(table.lengths)) == int(pg.n_pairs)


def test_sort_op_count_model():
    lengths = jnp.array([0, 1, 2, 8, 100])
    ops = int(sort_op_count(lengths))
    expected = 0 + 1 * 1 + 2 * 1 + 8 * 3 + 100 * 7
    assert ops == expected


def test_grid_spec_validation():
    import pytest

    with pytest.raises(ValueError):
        GridSpec(100, 100, 16, 64)  # not tile-divisible
    with pytest.raises(ValueError):
        GridSpec(128, 128, 16, 40)  # group not multiple of tile


# ---------------------------------------------------------------------------
# Cross-shard merge stage (DESIGN.md §10)
# ---------------------------------------------------------------------------


def _synthetic_pairs(rng, n_gauss, span, num_bins):
    """Gaussian-major synthetic pair list with FORCED depth ties (depths drawn
    from 4 values) so the merge's insertion-order tie-break is exercised."""
    S = span * span
    bin_id = rng.integers(0, num_bins + 1, size=(n_gauss, S)).astype(np.int32)
    hit = bin_id < num_bins
    depth = rng.choice([1.0, 2.0, 3.0, 5.0], size=(n_gauss, S)).astype(np.float32)
    depth = np.where(hit, depth, np.inf)
    bin_id = np.where(hit, bin_id, num_bins).astype(np.int32)
    gauss = np.broadcast_to(
        np.arange(n_gauss, dtype=np.int32)[:, None], (n_gauss, S)
    )
    flat = lambda a: jnp.asarray(a.reshape(-1))
    zero = jnp.zeros((), jnp.int32)
    return PairSet(
        bin_id=flat(bin_id), gauss_idx=flat(gauss), depth=flat(depth),
        valid=flat(hit), n_candidate_tests=zero, n_pairs=zero,
        n_span_overflow=zero,
    )


def _shard_pairs(pairs, n_gauss, shards, span):
    """Slice the gaussian-major pair list into contiguous gaussian shards
    (what the sharded frontend's per-shard identify produces)."""
    S = span * span
    size = -(-n_gauss // shards)
    out = []
    for d in range(shards):
        lo, hi = d * size, min((d + 1) * size, n_gauss)
        sl = slice(lo * S, hi * S)
        out.append(
            dataclasses.replace(
                pairs,
                bin_id=pairs.bin_id[sl],
                gauss_idx=pairs.gauss_idx[sl] - lo,
                depth=pairs.depth[sl],
                valid=pairs.valid[sl],
            )
        )
    return out, size


def test_merge_bin_tables_bitwise_vs_global():
    """D per-shard tables + stable merge == binning the global pair set,
    field for field — including under depth ties (insertion-order tie-break)
    and per-bin capacity overflow (merged top-K == global top-K)."""
    rng = np.random.default_rng(0)
    n_gauss, span, num_bins = 60, 3, 7
    pairs = _synthetic_pairs(rng, n_gauss, span, num_bins)
    depth_by_gauss = rng.uniform(1.0, 9.0, size=n_gauss).astype(np.float32)
    # Per-gaussian depths (as projection produces): rebuild pair depths so the
    # merge's depth gather (a per-gaussian lookup) matches the pair keys.
    depth_flat = jnp.where(
        pairs.valid, jnp.asarray(depth_by_gauss)[pairs.gauss_idx], jnp.inf
    )
    # Quantize to force cross-gaussian ties.
    depth_flat = jnp.where(
        jnp.isfinite(depth_flat), jnp.round(depth_flat), jnp.inf
    )
    pairs = dataclasses.replace(pairs, depth=depth_flat)
    gauss_depth = jnp.round(jnp.asarray(depth_by_gauss))

    for capacity in (64, 6):   # no-overflow and overflow regimes
        for shards in (1, 2, 3):
            ref = bin_pairs(pairs, num_bins, capacity)
            shard_pairs, size = _shard_pairs(pairs, n_gauss, shards, span)
            tables = [bin_pairs(p, num_bins, capacity) for p in shard_pairs]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *tables)
            offs = (jnp.arange(shards, dtype=jnp.int32) * size)[:, None, None]
            gidx = jnp.where(
                stacked.entry_valid, stacked.gauss_idx + offs, 0
            )
            pad_depth = jnp.concatenate(
                [gauss_depth,
                 jnp.full((shards * size - n_gauss,), jnp.inf, jnp.float32)]
            )
            depth = jnp.where(
                stacked.entry_valid, pad_depth[gidx], jnp.inf
            )
            merged = merge_bin_tables(
                dataclasses.replace(stacked, gauss_idx=gidx), depth
            )
            for field in ("gauss_idx", "entry_valid", "lengths", "overflow"):
                a = np.asarray(getattr(ref, field))
                b = np.asarray(getattr(merged, field))
                assert (a == b).all(), (capacity, shards, field)


# ---------------------------------------------------------------------------
# Wide op counters (int32-overflow regression, multi-million-Gaussian scenes)
# ---------------------------------------------------------------------------


def test_sort_op_count_no_int32_wraparound():
    """Synthetic lengths whose true op count exceeds 2**31: the old int32
    accumulator wrapped negative; the wide counter must stay positive and
    within fp rounding of the exact total."""
    lengths = jnp.full((64,), 10_000_000, jnp.int32)
    ops = float(sort_op_count(lengths))
    exact = 64 * 10_000_000 * 24          # ceil(log2 1e7) == 24
    assert ops > 2**31
    assert abs(ops - exact) / exact < 1e-5


def test_wide_count_sum_no_int32_wraparound():
    """The fifo_ops-style accumulation (sum of lengths x tiles_per_group)
    stays positive past 2**31."""
    lengths = jnp.full((2048,), 2**20, jnp.int32)
    total = float(wide_count_sum(lengths)) * 16
    assert total == float(2**31) * 16 > 2**31


def test_identify_counter_dtype_is_wide():
    proj, grid = _setup()
    pg = identify(proj, grid, "tile", "ellipse")
    assert pg.n_candidate_tests.dtype == wide_count_dtype()
    # small-regime exactness: the wide counter agrees with an int count
    exact = int(np.asarray(pg.valid).sum())
    assert int(pg.n_pairs) == exact
    assert int(pg.n_candidate_tests) >= exact
