import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grouping import GridSpec, bin_pairs, identify, sort_op_count
from repro.core.projection import project
from repro.core import make_camera, random_scene


def _setup(seed=0, n=600, w=256, h=192):
    scene = random_scene(jax.random.key(seed), n, extent=3.0)
    cam = make_camera((0, 1.2, 5.0), (0, 0, 0), w, h)
    proj = project(scene, cam)
    grid = GridSpec(w, h, 16, 64, span=4)
    return proj, grid


def test_pairs_group_leq_tile():
    """The paper's core quantity: group-level sorting keys are a strict
    subset of tile-level ones (Table I / Fig 5)."""
    proj, grid = _setup()
    pt = identify(proj, grid, "tile", "ellipse")
    pg = identify(proj, grid, "group", "ellipse")
    assert int(pg.n_pairs) <= int(pt.n_pairs)
    assert int(pg.n_pairs) > 0
    # every tile hit implies its group hit => tile pairs >= group pairs and
    # per gaussian, #tiles >= #groups; globally strict for clustered scenes
    assert int(pt.n_pairs) > int(pg.n_pairs)


def test_no_overflow_small_scene():
    proj, grid = _setup()
    pg = identify(proj, grid, "group", "ellipse")
    assert int(pg.n_span_overflow) == 0
    table = bin_pairs(pg, grid.num_groups, 512)
    assert int(table.overflow) == 0


def test_bin_table_depth_sorted():
    proj, grid = _setup(1)
    pg = identify(proj, grid, "group", "ellipse")
    table = bin_pairs(pg, grid.num_groups, 512)
    depth = np.asarray(proj.depth)
    gidx = np.asarray(table.gauss_idx)
    valid = np.asarray(table.entry_valid)
    for g in range(table.num_bins):
        d = depth[gidx[g][valid[g]]]
        assert (np.diff(d) >= -1e-6).all(), f"group {g} not depth sorted"


def test_bin_lengths_match_pairs():
    proj, grid = _setup(2)
    pg = identify(proj, grid, "group", "ellipse")
    table = bin_pairs(pg, grid.num_groups, 512)
    assert int(jnp.sum(table.lengths)) == int(pg.n_pairs)


def test_sort_op_count_model():
    lengths = jnp.array([0, 1, 2, 8, 100])
    ops = int(sort_op_count(lengths))
    expected = 0 + 1 * 1 + 2 * 1 + 8 * 3 + 100 * 7
    assert ops == expected


def test_grid_spec_validation():
    import pytest

    with pytest.raises(ValueError):
        GridSpec(100, 100, 16, 64)  # not tile-divisible
    with pytest.raises(ValueError):
        GridSpec(128, 128, 16, 40)  # group not multiple of tile
