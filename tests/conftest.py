import jax
import jax.numpy as jnp
import pytest

from repro.core import GaussianScene, make_camera, random_scene
from repro.core.pipeline import RenderConfig

# Session-wide compiled-renderer cache for parity-style tests: jitting the
# whole render (the same traced-camera closure the engine handle compiles)
# costs ~1.4s per (config, geometry) vs ~8s for a first EAGER render()
# (which traces/compiles its internal scans piecemeal) — the single biggest
# lever of the `-m "not slow"` fast lane. Tests that specifically assert
# the eager differentiable oracle keep calling render() directly.
_JIT_RENDER_FNS = {}


def jit_render(scene, cam, cfg, background=None):
    from repro.core.pipeline import (
        _background_array,
        _render_with_traced_camera,
    )

    key = (cfg, cam.width, cam.height, cam.znear, cam.zfar)
    fn = _JIT_RENDER_FNS.get(key)
    if fn is None:
        fn = jax.jit(
            _render_with_traced_camera(
                cfg, cam.width, cam.height, cam.znear, cam.zfar
            )
        )
        _JIT_RENDER_FNS[key] = fn
    return fn(
        scene,
        jnp.asarray(cam.R), jnp.asarray(cam.t),
        jnp.float32(cam.fx), jnp.float32(cam.fy),
        jnp.float32(cam.cx), jnp.float32(cam.cy),
        _background_array(background),
    )


@pytest.fixture(scope="session")
def jit_render_fn():
    return jit_render


@pytest.fixture(scope="session")
def small_scene():
    return random_scene(jax.random.key(0), 800, extent=3.0)


@pytest.fixture(scope="session")
def tiny_scene():
    return random_scene(jax.random.key(1), 200, extent=2.5)


@pytest.fixture(scope="session")
def cam128():
    return make_camera((0.0, 1.0, 4.5), (0, 0, 0), 128, 128)


@pytest.fixture(scope="session")
def cam256():
    return make_camera((0.0, 1.2, 5.0), (0, 0, 0), 256, 192)


@pytest.fixture()
def base_cfg():
    return RenderConfig(
        tile=16,
        group=64,
        group_capacity=256,
        tile_capacity=256,
    )
