import jax
import jax.numpy as jnp
import pytest

from repro.core import GaussianScene, make_camera, random_scene
from repro.core.pipeline import RenderConfig


@pytest.fixture(scope="session")
def small_scene():
    return random_scene(jax.random.key(0), 800, extent=3.0)


@pytest.fixture(scope="session")
def tiny_scene():
    return random_scene(jax.random.key(1), 200, extent=2.5)


@pytest.fixture(scope="session")
def cam128():
    return make_camera((0.0, 1.0, 4.5), (0, 0, 0), 128, 128)


@pytest.fixture(scope="session")
def cam256():
    return make_camera((0.0, 1.2, 5.0), (0, 0, 0), 256, 192)


@pytest.fixture()
def base_cfg():
    return RenderConfig(
        tile=16,
        group=64,
        group_capacity=256,
        tile_capacity=256,
    )
