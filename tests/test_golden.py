"""Golden-image regression suite (tests/golden/, DESIGN.md §12 pinning).

The cross-path parity suites (test_sharding / test_engine) prove every path
agrees with the replicated reference WITHIN one checkout — a numerics
regression that moves all paths together would sail through them. These
tests pin the rendered output itself ACROSS PRs: three tiny deterministic
scenes are committed with their rendered images (scene arrays stored, not
seeds, so a jax.random change cannot move the pin) and sha256 checksums.

Covered per fixture: both backends (each against its OWN golden — they
agree only to fp reassociation in some configs, DESIGN.md §6) x scene
shards D in {1, 2} (D=2 runs the feature-sharded gathers, so losslessness
of the sharded path is pinned across PRs too — not just cross-path within
one PR).

If a render intentionally changes numerics, regenerate with
``PYTHONPATH=src python tests/golden/generate.py`` and review the image
diff in the PR.
"""
from __future__ import annotations

import dataclasses
import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

GOLDEN = Path(__file__).resolve().parent / "golden"
FIXTURES = ("mini_gstg", "aabb_lossless", "tile_base")
BACKENDS = ("reference", "pallas")

# The generator module is the single source of truth for HOW a golden is
# rendered (the jit'd traced-camera closure the engine handle compiles);
# the test must render through the identical path.
_spec = importlib.util.spec_from_file_location(
    "golden_generate", GOLDEN / "generate.py"
)
golden_generate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(golden_generate)


# The generator's hash IS the pin definition — reuse it so the two files
# can never hash differently.
_sha256 = golden_generate._sha256


@pytest.fixture(scope="module")
def checksums():
    with open(GOLDEN / "checksums.json") as f:
        return json.load(f)


def _load(name):
    data = np.load(GOLDEN / f"{name}.npz")
    from repro.core import GaussianScene, make_camera

    scene = GaussianScene(
        **{
            f.name: data[f"scene_{f.name}"]
            for f in dataclasses.fields(GaussianScene)
        }
    )
    cam_kw = json.loads(bytes(data["camera_json"]).decode())
    cam_kw["eye"] = tuple(cam_kw.pop("eye"))
    cam_kw["target"] = tuple(cam_kw.pop("target"))
    cfg_kw = json.loads(bytes(data["config_json"]).decode())
    return data, scene, make_camera(**cam_kw), cfg_kw


@pytest.mark.parametrize("name", FIXTURES)
def test_fixture_integrity(name, checksums):
    """Every stored array hashes to its committed checksum — accidental
    fixture regeneration (or a corrupted npz) fails loudly and separately
    from a real numerics regression."""
    data = np.load(GOLDEN / f"{name}.npz")
    assert set(data.files) == set(checksums[name]), (
        f"{name}: fixture/checksum key sets diverge"
    )
    for key in data.files:
        assert _sha256(data[key]) == checksums[name][key], (
            f"{name}/{key}: stored array does not match checksums.json — "
            "was the fixture regenerated without updating the other file?"
        )


GOLDEN_CASES = [
    pytest.param(name, backend, shards, id=f"{name}-{backend}-D{shards}")
    for name in FIXTURES
    for backend in BACKENDS
    for shards in (1, 2)
]


@pytest.mark.parametrize("name,backend,shards", GOLDEN_CASES)
def test_golden_image(name, backend, shards, checksums):
    """Bitwise reproduction of the committed golden image, per backend, at
    D in {1, 2} — D=2 exercises the per-shard frontend + merge + the
    feature-sharded gathers and must land on the SAME image."""
    from repro.core.pipeline import RenderConfig

    data, scene, cam, cfg_kw = _load(name)
    cfg = RenderConfig(backend=backend, scene_shards=shards, **cfg_kw)
    out = golden_generate.render_one_jit(scene, cam, cfg)
    img = np.asarray(out.image)
    golden = data[f"image_{backend}"]
    assert img.shape == golden.shape and img.dtype == golden.dtype
    assert int(np.asarray(out.stats.overflow)) == 0
    if not (img == golden).all():
        diff = np.abs(img - golden)
        pytest.fail(
            f"{name}/{backend}/D{shards}: image diverges from golden "
            f"(max abs diff {diff.max():.3e} over "
            f"{(diff > 0).sum()} channels); if intentional, regenerate via "
            "tests/golden/generate.py and review the diff"
        )
    assert _sha256(img) == checksums[name][f"image_{backend}"]
