import numpy as np

from repro.data import TokenStream, make_batch


def test_determinism():
    s = TokenStream(vocab=1000, global_batch=8, seq_len=32, seed=3)
    a = s.batch_at(5)
    b = s.batch_at(5)
    assert (a == b).all()
    c = s.batch_at(6)
    assert (a != c).any()


def test_shard_slices_match_global():
    """Any host can materialize its own rows — elastic resharding property."""
    s = TokenStream(vocab=1000, global_batch=16, seq_len=16, seed=0)
    full = s.batch_at(3)
    part = s.batch_at(3, lo=4, hi=9)
    assert (full[4:9] == part).all()


def test_vocab_bounds_and_shapes():
    s = TokenStream(vocab=517, global_batch=4, seq_len=64, seed=1)
    b = make_batch(s, 0)
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 517
    # labels are next-token shifted
    full = s.batch_at(0)
    assert (b["labels"] == full[:, 1:]).all()


def test_frontend_batches():
    s = TokenStream(vocab=100, global_batch=2, seq_len=32, seed=2)
    v = make_batch(s, 1, frontend="vision_stub", n_frontend_tokens=8, d_model=16)
    assert v["tokens"].shape == (2, 24)
    assert v["patch_embeds"].shape == (2, 8, 16)
    a = make_batch(s, 1, frontend="audio_stub", d_model=16)
    assert a["frames"].shape == (2, 32, 16)
    assert a["labels"].max() < 504
