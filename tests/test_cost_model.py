import types

import jax
import pytest

from repro.core import make_camera, random_scene
from repro.core.cost_model import GSTG_ASIC, StageCosts, estimate
from repro.core.pipeline import RenderConfig, render


def _stats(mode, scene, cam, **kw):
    # jit'd render (conftest session cache): the four tests below share the
    # same two (mode, geometry) programs, so everything after the first
    # call per config is a cache hit. The cost model consumes integer
    # counters, which are identical on the eager and jit paths.
    from conftest import jit_render

    return jit_render(scene, cam, RenderConfig(mode=mode, **kw)).stats


def test_gstg_faster_than_tile_baseline(small_scene, cam256):
    base = _stats("tile_baseline", small_scene, cam256)
    ours = _stats("gstg", small_scene, cam256)
    cb = estimate(base, GSTG_ASIC, mode="tile_baseline")
    co = estimate(ours, GSTG_ASIC, mode="gstg", execution="asic")
    assert co.total_s < cb.total_s
    # the win comes from sorting, not rasterization
    assert co.sort_s < cb.sort_s
    assert abs(co.raster_s - cb.raster_s) / max(cb.raster_s, 1e-12) < 0.35


def test_asic_overlap_beats_gpu_serialization(small_scene, cam256):
    ours = _stats("gstg", small_scene, cam256)
    asic = estimate(ours, GSTG_ASIC, mode="gstg", execution="asic")
    gpu = estimate(ours, GSTG_ASIC, mode="gstg", execution="gpu")
    assert asic.total_s <= gpu.total_s


def test_energy_positive_and_gstg_wins(small_scene, cam256):
    base = _stats("tile_baseline", small_scene, cam256)
    ours = _stats("gstg", small_scene, cam256)
    eb = estimate(base, GSTG_ASIC, mode="tile_baseline").energy_j
    eo = estimate(ours, GSTG_ASIC, mode="gstg").energy_j
    assert eb > 0 and eo > 0
    assert eo < eb


def test_group_baseline_raster_penalty(small_scene, cam256):
    """Fig 13: large-tile baseline sorts less but rasterizes much more."""
    big = _stats("group_baseline", small_scene, cam256)
    small = _stats("tile_baseline", small_scene, cam256)
    cb = estimate(big, GSTG_ASIC, mode="group_baseline")
    cs = estimate(small, GSTG_ASIC, mode="tile_baseline")
    assert cb.sort_s < cs.sort_s
    assert cb.raster_s > cs.raster_s


# -- estimate() as an autotune pruning oracle (DESIGN.md §13) ----------------
# The phase-1 search ranks candidates by estimate(...).total_s, so the model
# must be monotone in the counters the knobs move: sorting work (sort_ops /
# n_pairs_sort) and bitmask work (n_bit_tests).


def _fake_stats(**kw):
    base = dict(
        n_visible=1_000,
        n_candidate_tests=4_000,
        n_pairs_sort=8_000,
        sort_ops=6.0e5,
        n_bit_tests=16_000,
        fifo_ops=2_000,
        alpha_ops=5.0e5,
        tile_entries=3_000,
    )
    base.update(kw)
    return types.SimpleNamespace(**base)


def test_estimate_monotone_in_sort_ops():
    lo = estimate(_fake_stats(), GSTG_ASIC, mode="gstg", execution="gpu")
    hi = estimate(
        _fake_stats(sort_ops=6.0e8, n_pairs_sort=8.0e5),
        GSTG_ASIC, mode="gstg", execution="gpu",
    )
    assert hi.sort_s > lo.sort_s
    assert hi.total_s > lo.total_s
    assert hi.energy_j > lo.energy_j


def test_estimate_monotone_in_bit_tests():
    lo = estimate(_fake_stats(), GSTG_ASIC, mode="gstg", execution="gpu")
    hi = estimate(
        _fake_stats(n_bit_tests=1.6e8),
        GSTG_ASIC, mode="gstg", execution="gpu",
    )
    assert hi.bitmask_s > lo.bitmask_s
    assert hi.total_s > lo.total_s
    # the ASIC overlaps BGM with GSM, so bitmask growth must never cost MORE
    # there than under GPU serialization
    hi_asic = estimate(
        _fake_stats(n_bit_tests=1.6e8),
        GSTG_ASIC, mode="gstg", execution="asic",
    )
    assert hi_asic.total_s <= hi.total_s


def test_stage_costs_dict_round_trip():
    c = estimate(_fake_stats(), GSTG_ASIC, mode="gstg", execution="asic")
    d = c.as_dict()
    assert StageCosts.from_dict(d) == c
    # serialization drift fails loudly, never zero-fills
    with pytest.raises(ValueError):
        StageCosts.from_dict({**d, "bogus_stage_s": 1.0})
    short = dict(d)
    short.pop("sort_s")
    with pytest.raises(ValueError):
        StageCosts.from_dict(short)
