import jax

from repro.core import make_camera, random_scene
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render


def _stats(mode, scene, cam, **kw):
    # jit'd render (conftest session cache): the four tests below share the
    # same two (mode, geometry) programs, so everything after the first
    # call per config is a cache hit. The cost model consumes integer
    # counters, which are identical on the eager and jit paths.
    from conftest import jit_render

    return jit_render(scene, cam, RenderConfig(mode=mode, **kw)).stats


def test_gstg_faster_than_tile_baseline(small_scene, cam256):
    base = _stats("tile_baseline", small_scene, cam256)
    ours = _stats("gstg", small_scene, cam256)
    cb = estimate(base, GSTG_ASIC, mode="tile_baseline")
    co = estimate(ours, GSTG_ASIC, mode="gstg", execution="asic")
    assert co.total_s < cb.total_s
    # the win comes from sorting, not rasterization
    assert co.sort_s < cb.sort_s
    assert abs(co.raster_s - cb.raster_s) / max(cb.raster_s, 1e-12) < 0.35


def test_asic_overlap_beats_gpu_serialization(small_scene, cam256):
    ours = _stats("gstg", small_scene, cam256)
    asic = estimate(ours, GSTG_ASIC, mode="gstg", execution="asic")
    gpu = estimate(ours, GSTG_ASIC, mode="gstg", execution="gpu")
    assert asic.total_s <= gpu.total_s


def test_energy_positive_and_gstg_wins(small_scene, cam256):
    base = _stats("tile_baseline", small_scene, cam256)
    ours = _stats("gstg", small_scene, cam256)
    eb = estimate(base, GSTG_ASIC, mode="tile_baseline").energy_j
    eo = estimate(ours, GSTG_ASIC, mode="gstg").energy_j
    assert eb > 0 and eo > 0
    assert eo < eb


def test_group_baseline_raster_penalty(small_scene, cam256):
    """Fig 13: large-tile baseline sorts less but rasterizes much more."""
    big = _stats("group_baseline", small_scene, cam256)
    small = _stats("tile_baseline", small_scene, cam256)
    cb = estimate(big, GSTG_ASIC, mode="group_baseline")
    cs = estimate(small, GSTG_ASIC, mode="tile_baseline")
    assert cb.sort_s < cs.sort_s
    assert cb.raster_s > cs.raster_s
