"""Regenerate the golden-image regression fixtures (tests/test_golden.py).

Run from the repo root ONLY when the renderer's output is *supposed* to
change (a numerics-affecting feature with a reviewed diff):

    PYTHONPATH=src python tests/golden/generate.py

Each fixture ``<name>.npz`` is fully self-contained: the scene ARRAYS are
stored (not a PRNG seed — a jax.random implementation change must not be
able to move the pin), together with the camera, the RenderConfig kwargs,
and the rendered image per backend. ``checksums.json`` pins the sha256 of
every stored array so accidental regeneration or fixture drift is loud in
review. The images are tiny (64px-side scenes) to keep the fixtures a few
tens of KB and the renders inside the fast test lane.

Backends are pinned separately: reference and pallas images agree only to
fp reassociation in some configurations (DESIGN.md §6), so each backend is
compared bitwise against ITS OWN golden.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path

import numpy as np

HERE = Path(__file__).resolve().parent

# (name, scene kwargs, camera kwargs, RenderConfig kwargs). Three tiny
# deterministic scenes covering the gstg ellipse path, the aabb lossless
# combo with degree-1 SH, and the per-tile baseline.
FIXTURES = [
    (
        "mini_gstg",
        dict(seed=11, num_gaussians=96, extent=2.2, sh_degree=0),
        dict(eye=(0.0, 0.9, 3.6), target=(0.0, 0.0, 0.0), width=64, height=64),
        dict(tile=16, group=32, mode="gstg", boundary_group="ellipse",
             boundary_tile="ellipse", group_capacity=128, tile_capacity=128,
             span=4, chunk=16),
    ),
    (
        "aabb_lossless",
        dict(seed=23, num_gaussians=120, extent=2.6, sh_degree=1),
        dict(eye=(1.2, 0.7, 3.2), target=(0.0, 0.1, 0.0), width=64, height=64),
        dict(tile=16, group=32, mode="gstg", boundary_group="aabb",
             boundary_tile="aabb", group_capacity=128, tile_capacity=128,
             span=4, chunk=16),
    ),
    (
        "tile_base",
        dict(seed=37, num_gaussians=80, extent=2.0, sh_degree=0),
        dict(eye=(-0.8, 1.1, 3.0), target=(0.0, 0.0, 0.0), width=64,
             height=48),
        dict(tile=16, group=32, mode="tile_baseline", boundary_tile="ellipse",
             group_capacity=128, tile_capacity=128, span=4, chunk=16),
    ),
]

BACKENDS = ("reference", "pallas")


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def render_one_jit(scene, cam, cfg):
    """Render through the SAME jit'd traced-camera closure the engine
    handle compiles (core/pipeline.py::_render_with_traced_camera) — the
    goldens pin the production (jit) numerics, which differ from the eager
    oracle by ~1 ulp of fusion rounding (DESIGN.md §10)."""
    import jax
    import jax.numpy as jnp

    from repro.core.pipeline import (
        _background_array,
        _render_with_traced_camera,
    )

    one = _render_with_traced_camera(
        cfg, cam.width, cam.height, cam.znear, cam.zfar
    )
    return jax.jit(one)(
        scene,
        jnp.asarray(cam.R), jnp.asarray(cam.t),
        jnp.float32(cam.fx), jnp.float32(cam.fy),
        jnp.float32(cam.cx), jnp.float32(cam.cy),
        _background_array(None),
    )


def build_fixture(name, scene_kw, cam_kw, cfg_kw):
    import jax

    from repro.core import make_camera, random_scene
    from repro.core.pipeline import RenderConfig

    scene = random_scene(
        jax.random.key(scene_kw["seed"]),
        scene_kw["num_gaussians"],
        extent=scene_kw["extent"],
        sh_degree=scene_kw["sh_degree"],
    )
    cam = make_camera(**cam_kw)
    payload = {
        f"scene_{f.name}": np.asarray(getattr(scene, f.name))
        for f in dataclasses.fields(scene)
    }
    payload["camera_json"] = np.frombuffer(
        json.dumps(cam_kw).encode(), dtype=np.uint8
    )
    payload["config_json"] = np.frombuffer(
        json.dumps(cfg_kw).encode(), dtype=np.uint8
    )
    for backend in BACKENDS:
        cfg = RenderConfig(backend=backend, **cfg_kw)
        out = render_one_jit(scene, cam, cfg)
        img = np.asarray(out.image)
        assert int(np.asarray(out.stats.overflow)) == 0, (name, backend)
        assert np.isfinite(img).all(), (name, backend)
        payload[f"image_{backend}"] = img
    return payload


def main() -> None:
    checksums = {}
    for name, scene_kw, cam_kw, cfg_kw in FIXTURES:
        payload = build_fixture(name, scene_kw, cam_kw, cfg_kw)
        np.savez(HERE / f"{name}.npz", **payload)
        checksums[name] = {
            key: _sha256(arr) for key, arr in sorted(payload.items())
        }
        print(f"wrote {name}.npz "
              f"({sum(a.nbytes for a in payload.values()) / 1024:.1f} KB)")
    with open(HERE / "checksums.json", "w") as f:
        json.dump(checksums, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote checksums.json ({len(checksums)} fixtures)")


if __name__ == "__main__":
    main()
