"""Per-architecture smoke tests: reduced same-family configs, one forward /
train step on CPU, asserting output shapes and no NaNs (assignment item f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (
    build_cache_spec,
    build_param_spec,
    decode_step,
    forward,
    loss_fn,
)
from repro.models.spec import init_from_spec
from repro.optim import adamw_init, adamw_update

IDENT = lambda x, a: x
B, S = 2, 64


def _batch(cfg):
    if cfg.frontend == "vision_stub":
        s_text = S - cfg.n_frontend_tokens
        return {
            "tokens": jnp.ones((B, s_text), jnp.int32),
            "patch_embeds": jnp.full((B, cfg.n_frontend_tokens, cfg.d_model), 0.01),
            "labels": jnp.ones((B, s_text), jnp.int32),
        }
    if cfg.frontend == "audio_stub":
        return {
            "frames": jnp.full((B, S, cfg.d_model), 0.01),
            "labels": jnp.ones((B, S), jnp.int32),
        }
    return {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_consistency(arch):
    cfg = get_config(arch)
    assert cfg.n_layers % len(cfg.pattern) == 0
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()
    spec = build_param_spec(cfg)  # must build without allocation
    assert spec["embed"].shape[0] % 256 == 0


# Fast-lane representatives: one arch per family (dense/moe/encoder/
# hybrid.../ssm/vlm). The remaining archs exercise the same code paths with
# different dims and ride the slow lane; jamba (hybrid attn+ssm+moe) is the
# single heaviest smoke config and is slow on every heavy test.
_FAST_ARCHS = {
    "granite-3-2b", "granite-moe-1b-a400m", "hubert-xlarge",
    "mamba2-370m", "llava-next-34b", "smollm-360m",
}


def _arch_params(archs):
    return [
        pytest.param(
            a, marks=[] if a in _FAST_ARCHS else [pytest.mark.slow]
        )
        for a in archs
    ]


@pytest.mark.parametrize("arch", _arch_params(sorted(ARCHS)))
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_from_spec(build_param_spec(cfg), jax.random.key(0))
    batch = _batch(cfg)

    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b, IDENT))(params, batch)
    exp_s = S if cfg.frontend != "vision_stub" else S
    assert logits.shape == (B, exp_s, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())

    loss, metrics = loss_fn(cfg, params, batch, IDENT)
    assert bool(jnp.isfinite(loss))

    # one optimizer step reduces nothing catastrophic (finite params)
    opt = adamw_init(params)
    g = jax.grad(lambda p: loss_fn(cfg, p, batch, IDENT)[0])(params)
    new_params, _ = adamw_update(params, g, opt, 0, lr=1e-3)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(new_params))


@pytest.mark.parametrize(
    "arch",
    _arch_params(a for a in sorted(ARCHS) if ARCHS[a].family != "encoder"),
)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_from_spec(build_param_spec(cfg), jax.random.key(0))
    cache = jax.tree.map(
        jnp.zeros_like,
        init_from_spec(build_cache_spec(cfg, B, 16), jax.random.key(1)),
    )
    toks = jnp.ones((B,), jnp.int32)
    nt, logits, new_cache = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t, jnp.int32(0), IDENT)
    )(params, cache, toks)
    assert nt.shape == (B,)
    assert int(nt.max()) < cfg.vocab
    assert bool(jnp.isfinite(logits).all())
    # cache got written somewhere
    total = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(new_cache))
    assert total > 0.0


def test_two_train_steps_reduce_loss():
    """A couple of steps on repeated data should reduce loss (sanity)."""
    cfg = get_smoke_config("smollm-360m")
    params = init_from_spec(build_param_spec(cfg), jax.random.key(2))
    batch = _batch(cfg)
    opt = adamw_init(params)
    losses = []
    params2, opt2 = params, opt
    for i in range(3):
        l, g = jax.value_and_grad(lambda q: loss_fn(cfg, q, batch, IDENT)[0])(params2)
        losses.append(float(l))
        params2, opt2 = adamw_update(params2, g, opt2, i, lr=5e-3)
    assert losses[-1] < losses[0]
