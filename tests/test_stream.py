"""Frame-coherent camera streams (repro.engine.stream, DESIGN.md §15).

Covers the acceptance contract of the frontend/backend split + stream
session layer:
  * split parity: render_frontend -> render_backend is bitwise-identical
    to the fused render, at the core level and through the handle;
  * stream-vs-stateless bitwise identity on a lapping orbit (exact-reuse
    hits engaged) for both backends x replicated + scene_shards=2;
  * pose_key: injective across distinct cameras, stable across rebuilt
    bit-identical ones (hypothesis property test, randomized fallback);
  * mid-stream resolution bump invalidates the frontend cache;
  * the speculation queue is bounded (drop-oldest, spec_dropped counted)
    and a float32-exact dolly yields a real speculative hit, bitwise;
  * close() stops the worker and empties the render-cache registry —
    including when the HANDLE is closed first.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro import engine
from repro.core import make_camera, orbit_cameras
from repro.core.pipeline import (
    RenderConfig,
    render,
    render_backend,
    render_cache_clear,
    render_cache_info,
    render_frontend,
)
from repro.engine.stream import pose_key, predict_next_camera
from repro.obs import get_registry

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests degrade gracefully without hypothesis
    HAVE_HYPOTHESIS = False


def _assert_images_bitwise(a, b, what):
    assert (np.asarray(a) == np.asarray(b)).all(), f"{what}: image diverges"


def _dolly_cameras(n, step=0.25, w=64, h=64):
    """A constant-rotation dolly whose translation steps are exactly
    representable in float32 — the trajectory the constant-velocity
    predictor must extrapolate bit-exactly."""
    base = make_camera((0.0, 1.0, 4.5), (0, 0, 0), w, h)
    out = []
    for i in range(n):
        t = (base.t.astype(np.float32)
             + np.float32(i) * np.array([0.0, 0.0, step], np.float32))
        out.append(dataclasses.replace(base, t=t))
    return out


# ---------------------------------------------------------------------------
# Split parity: frontend ∘ backend == fused render.
# ---------------------------------------------------------------------------


def test_core_split_matches_fused_render(tiny_scene, base_cfg, cam128):
    """The public render_frontend/render_backend pair reproduces render()
    bitwise — the fused path is literally backend(frontend(...)), so this
    pins the decomposition itself."""
    fused = render(tiny_scene, cam128, base_cfg)
    front = render_frontend(tiny_scene, cam128, base_cfg)
    split = render_backend(front, cam128, base_cfg)
    _assert_images_bitwise(split.image, fused.image, "split vs fused")
    for name in ("n_visible", "n_pairs_sort", "tile_entries", "overflow"):
        assert (np.asarray(getattr(split.stats, name))
                == np.asarray(getattr(fused.stats, name))).all(), name


# Fast lane: the reference pairs (both shard counts); pallas interpret runs
# ride the slow lane, same as the handle parity suite.
STREAM_CASES = [
    pytest.param(
        backend, shards,
        marks=[] if backend == "reference" else [pytest.mark.slow],
        id=f"{backend}-D{shards}",
    )
    for backend in ("reference", "pallas")
    for shards in (1, 2)
]


@pytest.mark.parametrize("backend,shards", STREAM_CASES)
def test_stream_bitwise_vs_stateless(tiny_scene, backend, shards):
    """A stream lapping a 4-pose orbit twice returns every frame
    bitwise-identical to stateless handle.render — lap 2 is served from
    the exact-reuse frontend cache, so the hits are exercised, and the
    verify-or-discard invariant means reuse can never change a pixel."""
    cfg = RenderConfig(
        tile=16, group=64, group_capacity=256, tile_capacity=256,
        backend=backend, scene_shards=shards,
    )
    cams = orbit_cameras(4, 4.5, 64, 64)
    with engine.open(tiny_scene, cfg) as r, r.open_stream() as s:
        for lap in range(2):
            for i, cam in enumerate(cams):
                out = s.render(cam)
                ref = r.render(cam)
                _assert_images_bitwise(
                    out.image, ref.image,
                    f"lap {lap} frame {i} ({backend}, D={shards})")
        stats = s.stats()
    assert stats["frames"] == 8
    assert stats["hits"] == 4, f"lap 2 should be all hits: {stats}"
    assert stats["misses"] == 4


def test_mid_stream_resolution_bump_invalidates(tiny_scene, base_cfg):
    """Changing the camera geometry mid-stream (a resolution bump) drops
    every cached table — they were binned for another grid — and the
    stream keeps rendering correctly at the new resolution."""
    cam_lo = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    cam_hi = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 96, 96)
    with engine.open(tiny_scene, base_cfg) as r, r.open_stream() as s:
        s.render(cam_lo)
        s.render(cam_lo)
        info = s.cache_info()
        assert (info["hits"], info["misses"], info["currsize"]) == (1, 1, 1)

        out_hi = s.render(cam_hi)
        stats = s.stats()
        assert stats["invalidations"] == 1
        assert s.cache_info()["currsize"] == 1   # only the new-grid entry
        _assert_images_bitwise(
            out_hi.image, r.render(cam_hi).image, "post-bump frame")

        # the old-resolution entry really is gone: re-rendering it misses
        s.render(cam_lo)
        assert s.stats()["invalidations"] == 2
        assert s.cache_info()["misses"] == 3


# ---------------------------------------------------------------------------
# pose_key: injective on distinct poses, stable on bit-identical ones.
# ---------------------------------------------------------------------------


def _cam_from(eye, fx, w):
    cam = make_camera(eye, (0, 0, 0), w, w)
    return dataclasses.replace(cam, fx=float(np.float32(fx)))


if HAVE_HYPOTHESIS:
    finite = st.floats(min_value=-8.0, max_value=8.0,
                       allow_nan=False, width=32)

    @settings(max_examples=50, deadline=None)
    @given(
        eye_a=st.tuples(finite, finite,
                        st.floats(min_value=2.0, max_value=8.0, width=32)),
        eye_b=st.tuples(finite, finite,
                        st.floats(min_value=2.0, max_value=8.0, width=32)),
        fx=st.floats(min_value=10.0, max_value=500.0, width=32),
        w=st.sampled_from([32, 64, 96]),
    )
    def test_pose_key_property(eye_a, eye_b, fx, w):
        a = _cam_from(eye_a, fx, w)
        a2 = _cam_from(eye_a, fx, w)      # rebuilt, bit-identical fields
        b = _cam_from(eye_b, fx, w)
        assert pose_key(a) == pose_key(a2), "stability on identical bits"
        same_bits = (
            np.asarray(a.R, np.float32).tobytes()
            == np.asarray(b.R, np.float32).tobytes()
            and np.asarray(a.t, np.float32).tobytes()
            == np.asarray(b.t, np.float32).tobytes()
        )
        if not same_bits:
            assert pose_key(a) != pose_key(b), "injectivity on distinct poses"


def test_pose_key_randomized_fallback():
    """Deterministic randomized sweep (runs with or without hypothesis):
    500 cameras with distinct float32 poses -> 500 distinct keys, and a
    rebuilt camera always maps to the same key. Also pins the field-
    confusion cases a flat byte-concat would alias: intrinsics swapped
    between fx/fy, and width/height swapped."""
    rng = np.random.default_rng(0)
    keys = set()
    for _ in range(500):
        eye = tuple(float(v) for v in rng.uniform(-5, 5, 3))
        cam = make_camera((eye[0], eye[1], abs(eye[2]) + 2.0), (0, 0, 0),
                          64, 64)
        k = pose_key(cam)
        assert pose_key(dataclasses.replace(cam)) == k
        keys.add(k)
    assert len(keys) == 500, "distinct poses collided"

    base = dataclasses.replace(
        make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 96),
        fx=50.0, fy=70.0,
    )
    swapped_f = dataclasses.replace(base, fx=70.0, fy=50.0)
    assert pose_key(base) != pose_key(swapped_f)
    tall = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 96, 64)
    assert pose_key(base) != pose_key(tall)


def test_predict_next_camera_constant_components():
    """Bitwise-equal components propagate EXACTLY (the short-circuit that
    makes float32-representable dollies speculatable), and a geometry
    change disables prediction."""
    c0, c1, c2 = _dolly_cameras(3)
    pred = predict_next_camera(c0, c1)
    assert pred is not None
    assert pose_key(pred) == pose_key(c2), "dolly extrapolation not exact"
    assert np.asarray(pred.R).tobytes() == np.asarray(c1.R).tobytes()

    resized = dataclasses.replace(c1, width=96, height=96)
    assert predict_next_camera(c0, resized) is None


# ---------------------------------------------------------------------------
# Speculation: bounded queue, real hits, discard accounting.
# ---------------------------------------------------------------------------


def test_spec_queue_bounded_drop_oldest(tiny_scene, base_cfg):
    """With the worker parked, every observed transition enqueues a
    prediction; the queue never grows past spec_depth and each overflow
    counts one spec_dropped (metric included)."""
    cams = _dolly_cameras(8)
    dropped_before = get_registry().counter("spec.dropped_total").value
    with engine.open(tiny_scene, base_cfg) as r:
        with r.open_stream(spec_depth=2) as s:
            s._ensure_spec_worker = lambda: None   # park the worker
            for cam in cams:
                s.render(cam)
                assert len(s._spec_queue) <= s.spec_depth
            stats = s.stats()
    # frames 0-1 prime the predictor; every later frame predicts one pose
    # into a depth-2 queue that is never drained.
    assert stats["spec_dropped"] >= 3, stats
    assert stats["spec_runs"] == 0
    dropped_after = get_registry().counter("spec.dropped_total").value
    assert dropped_after - dropped_before >= stats["spec_dropped"]


def test_dolly_speculative_hit_bitwise(tiny_scene, base_cfg):
    """On a float32-exact dolly the constant-velocity predictor pre-runs
    the frontend for the NEXT pose: later frames arrive as speculative
    hits and stay bitwise-identical to the stateless render."""
    cams = _dolly_cameras(6)
    with engine.open(tiny_scene, base_cfg) as r, r.open_stream() as s:
        for i, cam in enumerate(cams):
            assert s.wait_spec_idle(timeout=120.0)
            out = s.render(cam)
            _assert_images_bitwise(
                out.image, r.render(cam).image, f"dolly frame {i}")
        assert s.wait_spec_idle(timeout=120.0)
        stats = s.stats()
    # frames 0-1 must miss (nothing to extrapolate from); with the worker
    # drained before every frame, frames 2+ are all speculative hits.
    assert stats["spec_hits"] == 4, stats
    assert stats["hits"] == 4 and stats["misses"] == 2, stats
    assert stats["spec_runs"] >= stats["spec_hits"]


def test_speculate_false_runs_nothing(tiny_scene, base_cfg):
    cams = _dolly_cameras(4)
    with engine.open(tiny_scene, base_cfg) as r:
        with r.open_stream(speculate=False) as s:
            for cam in cams:
                s.render(cam)
            stats = s.stats()
    assert stats["spec_runs"] == 0 and stats["spec_hits"] == 0
    assert stats["misses"] == 4


def test_cache_frames_evicts_lru(tiny_scene, base_cfg):
    """cache_frames bounds the per-stream frontend cache: rendering more
    distinct poses than the bound keeps currsize pinned and re-rendering
    the evicted oldest pose misses again."""
    cams = orbit_cameras(6, 4.5, 64, 64)
    with engine.open(tiny_scene, base_cfg) as r:
        with r.open_stream(cache_frames=4, speculate=False) as s:
            for cam in cams:
                s.render(cam)
            assert s.cache_info()["currsize"] == 4
            s.render(cams[0])                       # evicted -> miss
            assert s.stats()["misses"] == 7
            s.render(cams[-1])                      # still resident -> hit
            assert s.stats()["hits"] == 1


# ---------------------------------------------------------------------------
# Lifecycle: registry hygiene on close (stream-first and handle-first).
# ---------------------------------------------------------------------------


def test_stream_close_empties_registry(tiny_scene, base_cfg):
    """The regression pinned by the issue: a closed stream must leave the
    render-cache registry empty (its frontend cache evicted + unregistered),
    same contract as the handle cache."""
    render_cache_clear()
    engine.close_default_renderers()
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)

    r = engine.open(tiny_scene, base_cfg)
    s = r.open_stream(speculate=False)
    s.render(cam)
    assert render_cache_info()[s.name]["currsize"] == 1
    assert render_cache_clear() is None or True    # global clear reaches it
    assert render_cache_info()[s.name]["currsize"] == 0

    s.render(cam)
    s.close()
    info = render_cache_info()
    assert s.name not in info, "closed stream left its cache registered"
    with pytest.raises(RuntimeError, match="closed"):
        s.render(cam)
    s.close()                                       # idempotent

    r.close()
    info = render_cache_info()
    assert r.cache_name not in info
    assert sum(k["currsize"] for k in info.values()) == 0, (
        f"registry not empty after close: {info}"
    )


def test_handle_close_closes_streams(tiny_scene, base_cfg):
    """Closing the HANDLE closes every open stream first — no orphaned
    speculation worker, no stale registry entry."""
    cam = make_camera((0.0, 1.0, 4.5), (0, 0, 0), 64, 64)
    r = engine.open(tiny_scene, base_cfg)
    s1 = r.open_stream(speculate=False)
    s2 = r.open_stream(speculate=False)
    s1.render(cam)
    r.close()
    assert s1.closed and s2.closed
    info = render_cache_info()
    assert s1.name not in info and s2.name not in info
    assert sum(k["currsize"] for k in info.values()) == 0


def test_stream_discard_accounting(tiny_scene, base_cfg):
    """Unused speculative entries count as discarded when dropped — the
    'verify-or-discard' bookkeeping the obs counters expose."""
    cams = _dolly_cameras(3)
    with engine.open(tiny_scene, base_cfg) as r, r.open_stream() as s:
        for cam in cams:
            s.render(cam)
        assert s.wait_spec_idle(timeout=120.0)
        # the worker just pre-ran the frame-3 pose; never render it
        if s.cache_info()["currsize"] > 3:
            s.cache_clear()
            assert s.stats()["spec_discarded"] >= 1


@pytest.mark.parametrize("bad", [
    dict(cache_frames=0), dict(spec_depth=-1),
])
def test_stream_rejects_bad_params(tiny_scene, base_cfg, bad):
    with engine.open(tiny_scene, base_cfg) as r:
        with pytest.raises(ValueError):
            r.open_stream(**bad)


def test_closed_handle_refuses_open_stream(tiny_scene, base_cfg):
    r = engine.open(tiny_scene, base_cfg)
    r.close()
    with pytest.raises(RuntimeError, match="closed"):
        r.open_stream()
