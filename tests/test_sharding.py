"""Distribution transparency: sharded == single-device, for BOTH axes.

Part 1 (LM): sharded train step == single-device step.
Part 2 (render engine): the gaussian-sharded scene pipeline (DESIGN.md §10)
is bitwise-identical — image AND integer counters — to the replicated path,
for every mode, both backends, 1/2/3 logical shards in-process and 2/4
virtual host devices in subprocesses (so the XLA host-platform flag never
leaks into the main test process; smoke tests must see 1 device).
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.models import build_param_spec, loss_fn
from repro.models.spec import init_from_spec
from repro.sharding.policies import make_constrain

cfg = get_smoke_config("granite-3-2b")
cfg = dataclasses.replace(cfg, mlp_sharding="ff", d_ff=128, shard_vocab=True, vocab=512)
params = init_from_spec(build_param_spec(cfg), jax.random.key(0))
batch = {"tokens": jnp.ones((8, 32), jnp.int32),
         "labels": jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (8, 1))}

# single device
l1 = float(loss_fn(cfg, params, batch, lambda x, a: x)[0])

# 2x4 mesh
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 4)
constrain = make_constrain(cfg, mesh)
with mesh:
    l2 = float(jax.jit(lambda p, b: loss_fn(cfg, p, b, constrain)[0])(params, batch))

print(json.dumps({"single": l1, "sharded": l2}))
"""


@pytest.mark.slow
def test_sharded_loss_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["single"] - res["sharded"]) < 5e-3, res


def test_param_rules_divisibility_checks():
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.policies import param_rules

    mesh = make_host_mesh(1, 1)
    # all production configs must build rules against the 16-wide model axis;
    # emulate by checking the declared dims directly
    for name in ("qwen1.5-110b", "kimi-k2-1t-a32b", "jamba-1.5-large-398b"):
        cfg = get_config(name)
        assert cfg.n_heads % 16 == 0
        if cfg.n_experts:
            assert cfg.n_experts % 16 == 0
    rules = param_rules(get_config("qwen1.5-110b"), mesh)
    assert rules["heads"] == "model"


def test_elastic_then_restore_shapes(tmp_path):
    """Checkpoint saved under one mesh restores under another (reshard)."""
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import CheckpointManager

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, tree)
    leaves, _ = mgr.restore()  # host arrays; device_put under new mesh is a
    assert (np.asarray(leaves[0]) == np.asarray(tree["w"])).all()


# ===========================================================================
# Scene sharding: gaussian-axis parity (DESIGN.md §10)
# ===========================================================================


CAM_POS = (0.0, 1.0, 4.0)


def _cfg(**kw):
    from repro.core.pipeline import RenderConfig

    base = dict(tile=16, group=64, group_capacity=256, tile_capacity=256)
    base.update(kw)
    return RenderConfig(**base)


_REP_CACHE = {}


def _replicated(scene, mode):
    """Module-shared replicated reference render per mode (the jit'd
    production path every sharded/strategy variant is compared against —
    rendering it once keeps the parity matrix inside the fast lane)."""
    key = (id(scene), mode)
    if key not in _REP_CACHE:
        from conftest import jit_render

        from repro.core import make_camera

        cam = make_camera(CAM_POS, (0, 0, 0), 128, 128)
        _REP_CACHE[key] = jit_render(scene, cam, _cfg(mode=mode))
    return _REP_CACHE[key]


def _assert_same_result(a, b, ctx=""):
    assert (np.asarray(a.image) == np.asarray(b.image)).all(), (
        f"image diverges {ctx}"
    )
    for name in vars(a.stats):
        va, vb = np.asarray(getattr(a.stats, name)), np.asarray(
            getattr(b.stats, name)
        )
        assert (va == vb).all(), f"counter {name} diverges {ctx}: {va} != {vb}"


def test_shard_scene_canonical_layout(tiny_scene):
    """Pad/shard/flatten round trip: contiguous layout, bitwise real rows,
    cull-guaranteed padding rows."""
    import jax
    from repro.core.projection import project
    from repro.core import make_camera
    from repro.sharding.scene import scene_flat, shard_scene, unshard_scene

    n = tiny_scene.num_gaussians          # 200
    sharded = shard_scene(tiny_scene, 3)  # ragged: 200 -> 3 x 67
    assert sharded.num_shards == 3 and sharded.shard_size == 67
    assert sharded.num_real == n and sharded.padded_size == 201

    flat = scene_flat(sharded)
    for f in dataclasses.fields(tiny_scene):
        a = np.asarray(getattr(tiny_scene, f.name))
        b = np.asarray(getattr(flat, f.name))
        assert (a == b[:n]).all(), f.name

    # padding rows are culled by projection (alpha < 1/255)
    cam = make_camera(CAM_POS, (0, 0, 0), 128, 128)
    proj = project(flat, cam)
    assert not np.asarray(proj.valid)[n:].any()

    back = unshard_scene(sharded)
    assert back.num_gaussians == n
    with pytest.raises(ValueError):
        shard_scene(tiny_scene, 0)

    # The host-side staging path (serving) builds the IDENTICAL layout.
    from repro.sharding.scene import shard_scene_host

    hosted = shard_scene_host(tiny_scene, 3)
    assert hosted.num_real == sharded.num_real
    for f in dataclasses.fields(tiny_scene):
        a = np.asarray(getattr(sharded.shards, f.name))
        b = getattr(hosted.shards, f.name)
        assert isinstance(b, np.ndarray) and (a == b).all(), f.name


@pytest.mark.parametrize("mode", ["gstg", "tile_baseline", "group_baseline"])
@pytest.mark.parametrize("shards", [1, 2, 3])
def test_scene_sharded_render_parity(tiny_scene, jit_render_fn, mode, shards):
    """The tentpole invariant: the sharded engine is bitwise-identical
    (image + every integer counter) to the replicated path, for every mode,
    including the degenerate 1-shard layout and ragged padding (200 % 3).
    Since DESIGN.md §12 this runs WITH feature-sharded gathers on (the
    default 'auto' strategy resolves to the (shard, local) indexed gather):
    the projected features stay per-shard through bitmask/compact/raster.
    Both sides run the jit'd production closure (the eager oracle differs
    from ANY jit path by ~1 ulp of fusion rounding, sharded or not)."""
    from repro.core import make_camera
    from repro.sharding.scene import shard_scene

    cam = make_camera(CAM_POS, (0, 0, 0), 128, 128)
    rep = _replicated(tiny_scene, mode)
    # Pass the canonical layout explicitly — exercises the ShardedScene entry
    # (the serving path) rather than the in-trace shard.
    sh = jit_render_fn(
        shard_scene(tiny_scene, shards), cam,
        _cfg(mode=mode, scene_shards=shards),
    )
    _assert_same_result(rep, sh, f"(mode={mode}, shards={shards})")


@pytest.mark.parametrize(
    "mode,gather",
    [
        ("gstg", "index"),
        ("gstg", "psum"),
        ("gstg", "flat"),
        ("tile_baseline", "psum"),
        ("group_baseline", "psum"),
        ("group_baseline", "flat"),
    ],
)
def test_feature_gather_strategy_parity(tiny_scene, jit_render_fn, mode, gather):
    """Every feature-gather strategy (DESIGN.md §12) lands on the SAME bits
    as the replicated path: the plain (shard, local) indexed gather, the
    owner-masked psum collective (whose cross-shard sum runs on raw bit
    patterns — the partition-friendly form), and the legacy flat concat.
    Gathers commute with concatenation; this is the test of that claim.
    ('index' is the default strategy, so the full mode matrix above already
    covers it; the explicit combos here pin psum/flat on every mode.)"""
    from repro.core import make_camera

    cam = make_camera(CAM_POS, (0, 0, 0), 128, 128)
    rep = _replicated(tiny_scene, mode)
    sh = jit_render_fn(
        tiny_scene, cam,
        _cfg(mode=mode, scene_shards=3, feature_gather=gather),
    )
    _assert_same_result(rep, sh, f"(mode={mode}, gather={gather})")


def test_feature_gather_unknown_strategy_raises(tiny_scene):
    from repro.core import make_camera
    from repro.core.pipeline import render

    cam = make_camera(CAM_POS, (0, 0, 0), 128, 128)
    with pytest.raises(ValueError, match="feature_gather"):
        render(
            tiny_scene, cam,
            _cfg(scene_shards=2, feature_gather="bogus"),
        )


def test_sharded_proj_take_matches_flat_gather(tiny_scene):
    """proj_take unit contract: on a ShardedProjected, both strategies
    reproduce the flat gather bit for bit, for every Projected field —
    including NaN-free specials like signed zeros (the psum path sums raw
    bits, so exactly-one-owner == owner's bits verbatim)."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro.core import make_camera
    from repro.core.projection import (
        ShardedProjected,
        proj_take,
        proj_valid_count,
        project,
    )
    from repro.sharding.scene import shard_scene

    cam = make_camera(CAM_POS, (0, 0, 0), 128, 128)
    sharded = shard_scene(tiny_scene, 3)
    proj_s = jax.vmap(lambda s: project(s, cam))(sharded.shards)
    flat = jax.tree.map(
        lambda x: x.reshape(x.shape[0] * x.shape[1], *x.shape[2:]), proj_s
    )
    rng = np.random.default_rng(0)
    idx = jnp.asarray(
        rng.integers(0, sharded.padded_size, size=(7, 13)).astype(np.int32)
    )
    for gather in ("index", "psum"):
        sp = ShardedProjected(shards=proj_s, gather=gather)
        for f in dc.fields(flat):
            want = np.asarray(getattr(flat, f.name)[idx])
            got = np.asarray(proj_take(sp, f.name, idx))
            assert want.dtype == got.dtype and (
                want.view(np.uint8) == got.view(np.uint8)
            ).all(), f"{gather}/{f.name} diverges from flat gather"
        assert int(proj_valid_count(sp)) == int(proj_valid_count(flat))


def test_feature_budget_model_scales_inverse_d(tiny_scene):
    """The --device-budget-mb model (engine/handle.py): per-camera projected
    feature bytes divide by D exactly when the commit runs the psum gathers
    over a PHYSICAL 'model' axis; logical shard axes and the legacy 'flat'
    strategy count full N (asserted without devices — the model is pure
    arithmetic; the virtual-device suite asserts the committed stats)."""
    import dataclasses as dc

    from repro.core.pipeline import RenderConfig
    from repro.core.projection import projected_bytes_per_gaussian
    from repro.engine import Renderer

    cfg = RenderConfig(scene_shards=4)
    full = Renderer._feature_mb(tiny_scene, 4)
    n_pad = -(-tiny_scene.num_gaussians // 4) * 4
    assert full == n_pad * projected_bytes_per_gaussian() / 2**20
    # physical 4-way shard + auto (-> psum): 1/D
    assert Renderer._feature_div(cfg, 4, 4) == 4
    # logical-only shard axis: full N per device
    assert Renderer._feature_div(cfg, 4, 1) == 1
    # legacy flat concat: full N even when physically sharded
    flat_cfg = dc.replace(cfg, feature_gather="flat")
    assert Renderer._feature_div(flat_cfg, 4, 4) == 1
    # replicated scene: no sharded features at all
    assert Renderer._feature_div(RenderConfig(), 1, 1) == 1


@pytest.mark.parametrize("bg,bt", [("aabb", "aabb"), ("obb", "ellipse")])
def test_scene_sharded_lossless_combos(tiny_scene, jit_render_fn, bg, bt):
    """Sharding composes with the §7 losslessness combos: gstg sharded ==
    gstg replicated (bitwise) == tile_baseline (bitwise, lossless combo) —
    all through the jit'd production closure (the §7 combos hold under jit
    because the per-tile entry TABLES are identical arrays, so the blended
    programs see the same inputs; the eager-oracle combos are
    tests/test_pipeline_lossless.py)."""
    from repro.core import make_camera

    cam = make_camera(CAM_POS, (0, 0, 0), 128, 128)
    cfg = _cfg(mode="gstg", boundary_group=bg, boundary_tile=bt)
    rep = jit_render_fn(tiny_scene, cam, cfg)
    sh = jit_render_fn(
        tiny_scene, cam, dataclasses.replace(cfg, scene_shards=2)
    )
    _assert_same_result(rep, sh, f"({bg},{bt})")
    base = jit_render_fn(
        tiny_scene, cam, _cfg(mode="tile_baseline", boundary_tile=bt)
    )
    assert (np.asarray(sh.image) == np.asarray(base.image)).all()


def test_scene_shards_config_mismatch_raises(tiny_scene):
    from repro.core import make_camera
    from repro.core.pipeline import render
    from repro.sharding.scene import shard_scene

    cam = make_camera(CAM_POS, (0, 0, 0), 128, 128)
    with pytest.raises(ValueError, match="scene_shards"):
        render(shard_scene(tiny_scene, 2), cam, _cfg(scene_shards=3))


def test_scene_sharded_batch_ragged_cameras(tiny_scene):
    """Gaussian sharding x ragged camera padding: a B=3 batch through
    render_batch_sharded with pad_to=4 and scene_shards=2 equals the
    replicated render_batch row for row (both axes' padding is sliced)."""
    from repro.core import orbit_cameras
    from repro.core.pipeline import render_batch
    from repro.launch.mesh import make_render_mesh
    from repro.serving.sharded import render_batch_sharded

    cams = orbit_cameras(3, 4.5, 128, 128)
    cfg = _cfg()
    rep = render_batch(tiny_scene, cams, cfg)
    sh = render_batch_sharded(
        tiny_scene, cams, cfg, mesh=make_render_mesh(1), pad_to=4,
        scene_shards=2,
    )
    _assert_same_result(rep, sh, "(batch ragged)")


_PALLAS_REP = {}


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["gstg", "tile_baseline", "group_baseline"])
@pytest.mark.parametrize("shards", [2, 3])
def test_scene_sharded_pallas_parity(tiny_scene, jit_render_fn, mode, shards):
    """Both backends honor the sharded frontend WITH feature-sharded
    gathers: pallas sharded == pallas replicated bitwise for every mode x
    D (the kernels' feature packer gathers straight from the owning shards
    — kernels/layout.py::pack_features via proj_take). Completes the
    acceptance matrix: all modes x backends x D in {1, 2, 3} (D=1 pallas
    rides tests/test_engine_handle.py and tests/test_golden.py)."""
    from repro.core import make_camera

    cam = make_camera(CAM_POS, (0, 0, 0), 64, 64)
    cfg = _cfg(
        mode=mode, backend="pallas", group_capacity=128, tile_capacity=128
    )
    if mode not in _PALLAS_REP:
        _PALLAS_REP[mode] = jit_render_fn(tiny_scene, cam, cfg)
    rep = _PALLAS_REP[mode]
    sh = jit_render_fn(
        tiny_scene, cam, dataclasses.replace(cfg, scene_shards=shards)
    )
    _assert_same_result(rep, sh, f"(pallas, mode={mode}, D={shards})")
    # The psum collective form too (what a physical mesh commits).
    sh_psum = jit_render_fn(
        tiny_scene, cam,
        dataclasses.replace(cfg, scene_shards=shards, feature_gather="psum"),
    )
    _assert_same_result(
        rep, sh_psum, f"(pallas psum, mode={mode}, D={shards})"
    )


_DEVICE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(devices)d"
import dataclasses, json, warnings
import jax, numpy as np

from repro import engine
from repro.core import orbit_cameras, random_scene
from repro.core.pipeline import RenderConfig, render_batch
from repro.launch.mesh import make_render_mesh
from repro.serving.sharded import render_batch_sharded

scene = random_scene(jax.random.key(3), 300, extent=3.0)
cams = orbit_cameras(3, 4.5, 96, 96)   # ragged over the data axis
failures = []
for mode, backend in %(combos)s:
    cfg = RenderConfig(mode=mode, backend=backend, group_capacity=256,
                       tile_capacity=256, span=6)
    rep = render_batch(scene, cams, cfg)
    mesh = make_render_mesh(%(devices)d, scene_shards=%(shards)d)
    sh = render_batch_sharded(scene, cams, cfg, mesh=mesh,
                              scene_shards=%(shards)d)
    if not (np.asarray(rep.image) == np.asarray(sh.image)).all():
        failures.append((mode, backend, "image"))
    for name in vars(rep.stats):
        if not (np.asarray(getattr(rep.stats, name))
                == np.asarray(getattr(sh.stats, name))).all():
            failures.append((mode, backend, name))

# Commit-time gather decision (DESIGN.md S12): a PHYSICAL 'model' axis must
# commit the psum collective, with the budget model's per-camera feature
# term at N/D per device; and the per-shard features must actually lay over
# 'model' (the feature_shard_pspec layout).
mesh = make_render_mesh(%(devices)d, scene_shards=%(shards)d)
cfg = RenderConfig(group_capacity=256, tile_capacity=256, span=6,
                   scene_shards=%(shards)d)
h = engine.open(scene, cfg, mesh=mesh)
hs = h.stats()
if hs["feature_gather"] != "psum":
    failures.append(("commit", "feature_gather", hs["feature_gather"]))
from repro.core.projection import projected_bytes_per_gaussian
n_pad = -(-scene.num_gaussians // %(shards)d) * %(shards)d
want_mb = n_pad * projected_bytes_per_gaussian() / 2**20 / %(shards)d
if abs(hs["feature_mb_per_device"] - want_mb) > 1e-9:
    failures.append(("commit", "feature_mb", hs["feature_mb_per_device"]))
h.close()

# Budget-driven auto escalation under the full (params + features) model: a
# budget only a physical %(shards)d-way commit can meet must escalate a
# scene_shards=1 'auto' open() to %(shards)d with psum gathers.
from repro.utils import pytree_bytes
full_mb = pytree_bytes(scene) / 2**20 + n_pad * projected_bytes_per_gaussian() / 2**20
h = engine.open(
    scene,
    RenderConfig(group_capacity=256, tile_capacity=256, span=6),
    devices=%(devices)d,
    device_budget_mb=full_mb / %(shards)d * 1.2,
)
hs = h.stats()
if hs["physical_shards"] < 2 or hs["feature_gather"] != "psum":
    failures.append(("escalation", hs["physical_shards"], hs["feature_gather"]))
h.close()

from jax.sharding import NamedSharding
from repro.core.projection import project
from repro.sharding.policies import feature_shard_pspec, scene_shard_pspec
from repro.sharding.scene import shard_scene_host
staged = jax.device_put(
    shard_scene_host(scene, %(shards)d),
    NamedSharding(mesh, scene_shard_pspec(mesh)),
)
proj_s = jax.jit(
    lambda s: jax.vmap(lambda x: project(x, cams[0]))(s.shards),
    out_shardings=NamedSharding(mesh, feature_shard_pspec(mesh)),
)(staged)
spec = proj_s.depth.sharding.spec
if tuple(spec)[:1] != ("model",):
    failures.append(("pspec", "feature_shard", str(spec)))
print(json.dumps({"failures": failures}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("devices,shards", [(2, 2), (4, 4), (4, 2)])
def test_scene_sharded_virtual_devices(devices, shards):
    """Physically sharded over 2/4 virtual host devices (2-D (data, model)
    mesh, subprocess so the XLA flag stays contained): bitwise image +
    identical counters vs the replicated path, gstg and tile_baseline —
    pallas included on the 2-device mesh (interpret mode is slow)."""
    combos = [("gstg", "reference"), ("tile_baseline", "reference")]
    if devices == 2:
        combos.append(("gstg", "pallas"))
    script = _DEVICE_SCRIPT % {
        "devices": devices, "shards": shards, "combos": repr(combos),
    }
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["failures"] == [], res
