"""Distribution transparency: sharded train step == single-device step.

Runs in a subprocess so the 8-device XLA host-platform flag never leaks into
the main test process (smoke tests must see 1 device).
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.models import build_param_spec, loss_fn
from repro.models.spec import init_from_spec
from repro.sharding.policies import make_constrain

cfg = get_smoke_config("granite-3-2b")
cfg = dataclasses.replace(cfg, mlp_sharding="ff", d_ff=128, shard_vocab=True, vocab=512)
params = init_from_spec(build_param_spec(cfg), jax.random.key(0))
batch = {"tokens": jnp.ones((8, 32), jnp.int32),
         "labels": jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (8, 1))}

# single device
l1 = float(loss_fn(cfg, params, batch, lambda x, a: x)[0])

# 2x4 mesh
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 4)
constrain = make_constrain(cfg, mesh)
with mesh:
    l2 = float(jax.jit(lambda p, b: loss_fn(cfg, p, b, constrain)[0])(params, batch))

print(json.dumps({"single": l1, "sharded": l2}))
"""


@pytest.mark.slow
def test_sharded_loss_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["single"] - res["sharded"]) < 5e-3, res


def test_param_rules_divisibility_checks():
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.policies import param_rules

    mesh = make_host_mesh(1, 1)
    # all production configs must build rules against the 16-wide model axis;
    # emulate by checking the declared dims directly
    for name in ("qwen1.5-110b", "kimi-k2-1t-a32b", "jamba-1.5-large-398b"):
        cfg = get_config(name)
        assert cfg.n_heads % 16 == 0
        if cfg.n_experts:
            assert cfg.n_experts % 16 == 0
    rules = param_rules(get_config("qwen1.5-110b"), mesh)
    assert rules["heads"] == "model"


def test_elastic_then_restore_shapes(tmp_path):
    """Checkpoint saved under one mesh restores under another (reshard)."""
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import CheckpointManager

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, tree)
    leaves, _ = mgr.restore()  # host arrays; device_put under new mesh is a
    assert (np.asarray(leaves[0]) == np.asarray(tree["w"])).all()
