import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_chunked, mamba_forward, mamba_decode, _segsum


def _naive_ssd(x, log_a, b, c):
    """Sequential reference recurrence: h_t = a_t h_{t-1} + b_t^T x_t."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    h = np.zeros((B, H, N, P), np.float64)
    ys = []
    xn = np.asarray(x, np.float64)
    an = np.exp(np.asarray(log_a, np.float64))
    bn = np.asarray(b, np.float64)
    cn = np.asarray(c, np.float64)
    for t in range(S):
        h = h * an[:, t][:, :, None, None] + np.einsum(
            "bn,bhp->bhnp", bn[:, t], xn[:, t]
        )
        ys.append(np.einsum("bn,bhnp->bhp", cn[:, t], h))
    return np.stack(ys, axis=1)  # (B,S,H,P)


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (64, 64)])
def test_ssd_chunked_vs_sequential(S, chunk):
    key = jax.random.key(0)
    B, H, P, N = 2, 3, 8, 4
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, P))
    log_a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (B, S, H))) * 0.5
    b = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    c = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N))
    got = np.asarray(ssd_chunked(x, log_a, b, c, chunk))
    want = _naive_ssd(x, log_a, b, c)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=1e-3)


def test_segsum_lower_triangular():
    log_a = jnp.array([[0.1, 0.2, 0.3, 0.4]])
    out = np.asarray(_segsum(log_a))[0]
    assert out[0, 0] == 0.0
    np.testing.assert_allclose(out[2, 0], 0.2 + 0.3, rtol=1e-6)
    assert np.isneginf(out[0, 1])


def test_mamba_decode_matches_forward():
    """Recurrent decode over a sequence == chunked forward at each position."""
    from repro.configs import get_smoke_config
    from repro.models.lm import build_param_spec, _mamba_p
    from repro.models.spec import init_from_spec

    cfg = get_smoke_config("mamba2-370m")
    spec = build_param_spec(cfg)["units"]["pos0"]["mixer"]
    p = init_from_spec(spec, jax.random.key(3))
    p = jax.tree.map(lambda a: a[0], p)  # drop unit axis
    mp = _mamba_p(p)

    B, S, D = 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.key(4), (B, S, D)) * 0.3
    ident = lambda t, a: t
    y_full = mamba_forward(mp, x, cfg, ident)

    din, N = cfg.d_inner, cfg.ssm_state
    H, P, W = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    ssm = jnp.zeros((B, H, N, P))
    conv = jnp.zeros((B, W - 1, din + 2 * N))
    ys = []
    for t in range(S):
        y, ssm, conv = mamba_decode(mp, x[:, t : t + 1], ssm, conv, cfg)
        ys.append(y[:, 0])
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_dec), np.asarray(y_full), atol=5e-4, rtol=1e-2
    )
