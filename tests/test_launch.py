"""Launch-layer units that don't need the 512-device mesh."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.roofline import (
    ICI_BW,
    collective_bytes,
    derive_terms,
    model_flops_for_cell,
)
from repro.launch.shapes import SHAPES, cell_supported


def test_skip_rules_match_assignment():
    skips = []
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            ok, reason = cell_supported(cfg, shape)
            if not ok:
                skips.append((arch, shape.name))
    # encoder: no decode cells; full-attention archs: no long_500k
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    assert ("qwen1.5-110b", "long_500k") in skips
    assert ("mamba2-370m", "long_500k") not in skips
    assert ("jamba-1.5-large-398b", "long_500k") not in skips
    assert len(skips) == 9  # 7 long_500k + 2 hubert decode shapes


def test_collective_parser():
    hlo = """
  %ar = bf16[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag-start = (f32[64]{0}, f32[1024]{0}) all-gather-start(%y)
  %ag-done = f32[1024]{0} all-gather-done(%ag-start)
  %a2a = u32[16,16]{1,0} all-to-all(%z)
  %cp = s8[8]{0} collective-permute(%w)
  %dot = f32[2,2]{1,0} dot(%a, %b)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 256 * 2
    assert got["all-gather"] == 64 * 4 + 1024 * 4  # -start counted, -done not
    assert got["all-to-all"] == 16 * 16 * 4
    assert got["collective-permute"] == 8
    assert "dot" not in got


def test_model_flops():
    cfg = get_config("qwen1.5-110b")
    tr = model_flops_for_cell(cfg, SHAPES["train_4k"])
    pf = model_flops_for_cell(cfg, SHAPES["prefill_32k"])
    dc = model_flops_for_cell(cfg, SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert abs(tr - 6 * n * 256 * 4096) / tr < 1e-6
    assert abs(pf - 2 * n * 32 * 32768) / pf < 1e-6
    assert abs(dc - 2 * n * 128) / dc < 1e-6
    # MoE: active << total
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.active_param_count() < 0.05 * kimi.param_count()
    assert 0.9e12 < kimi.param_count() < 1.3e12  # ~1T
    assert 25e9 < kimi.active_param_count() < 40e9  # ~a32b


def test_derive_terms_dominance():
    t = derive_terms(
        arch="x", shape_name="train_4k", mesh_name="16x16", chips=256,
        cost={"flops": 1e15, "bytes accessed": 1e10},
        hlo_text="%ar = bf16[1024]{0} all-reduce(%x)\n",
        model_flops=6e17,
    )
    assert t.dominant == "compute"
    assert abs(t.compute_s - 1e15 / 197e12) < 1e-9
    assert t.collective_bytes_total == 2048


def test_param_counts_sane():
    expected = {
        "qwen1.5-110b": (100e9, 125e9),
        "jamba-1.5-large-398b": (300e9, 450e9),
        "llava-next-34b": (30e9, 40e9),
        "smollm-360m": (0.3e9, 0.45e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "granite-3-2b": (2e9, 3.5e9),
        "phi4-mini-3.8b": (3e9, 5e9),
        "granite-moe-1b-a400m": (0.8e9, 1.6e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for name, (lo, hi) in expected.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
