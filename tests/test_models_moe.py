import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import load_balance_loss, moe_ffn, router_topk


class _Cfg:
    n_experts = 8
    experts_per_token = 2
    capacity_factor = 8.0  # ample: no drops
    d_ff_expert = 16


def _params(key, D=12, E=8, F=16):
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (D, E)) * 0.3,
        "w1": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
        "w3": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
        "w2": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F),
    }


def _dense_reference(p, x, k):
    """Route every token through its top-k experts WITHOUT capacity."""
    B, S, D = x.shape
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    w, ids = router_topk(logits, k)
    out = jnp.zeros_like(x)
    for e in range(p["router"].shape[1]):
        a = x @ p["w1"][e]
        h = (a * jax.nn.sigmoid(a)) * (x @ p["w3"][e])
        ye = h @ p["w2"][e]
        mask = jnp.sum(jnp.where(ids == e, w, 0.0), axis=-1)  # (B,S)
        out = out + ye * mask[..., None]
    return out


def test_moe_matches_dense_reference_no_drops():
    cfg = _Cfg()
    p = _params(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, 12))
    ident = lambda t, a: t
    got, aux = moe_ffn(p, x, cfg, ident)
    want = _dense_reference(p, x, cfg.experts_per_token)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    assert float(aux) > 0


def test_capacity_drops_tokens_gracefully():
    cfg = _Cfg()
    cfg.capacity_factor = 0.25  # force drops
    p = _params(jax.random.key(2))
    x = jax.random.normal(jax.random.key(3), (2, 32, 12))
    got, _ = moe_ffn(p, x, cfg, lambda t, a: t)
    assert bool(jnp.isfinite(got).all())
    # dropped-token rows produce smaller-magnitude output, not NaN
    want = _dense_reference(p, x, cfg.experts_per_token)
    assert float(jnp.abs(got).sum()) < float(jnp.abs(want).sum()) + 1e-3


def test_router_topk_normalized():
    logits = jax.random.normal(jax.random.key(4), (10, 8))
    w, ids = router_topk(logits, 3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert int(ids.max()) < 8


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss ~= 1 (E * E * (1/E) * (1/E))."""
    T, E, k = 4096, 8, 1
    logits = jnp.zeros((T, E))
    ids = (jnp.arange(T) % E).reshape(T, 1)
    lb = float(load_balance_loss(logits, ids, E))
    assert abs(lb - 1.0) < 0.05


def test_moe_grads_finite():
    cfg = _Cfg()
    p = _params(jax.random.key(5))
    x = jax.random.normal(jax.random.key(6), (2, 16, 12))

    def loss(p):
        y, aux = moe_ffn(p, x, cfg, lambda t, a: t)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
