"""The paper's central claim: tile grouping is LOSSLESS (hypothesis property).

Bitwise for combos where the bitmask method is at least as tight as the group
method; exact-set (same contributing gaussians, fp-equal to reassociation
tolerance) for all nine combos.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import make_camera, random_scene
from repro.core.pipeline import RenderConfig, render

CAM = make_camera((0.0, 1.1, 4.6), (0, 0, 0), 128, 128)

BITWISE_COMBOS = [
    ("aabb", "aabb"),
    ("aabb", "ellipse"),
    ("obb", "ellipse"),
    ("ellipse", "ellipse"),
]
ALL_COMBOS = BITWISE_COMBOS + [
    ("ellipse", "aabb"),
    ("obb", "aabb"),
    ("obb", "obb"),
    ("aabb", "obb"),
    ("ellipse", "obb"),
]


def _cfg(mode, bg="ellipse", bt="ellipse", tile=16, group=64):
    return RenderConfig(
        mode=mode,
        tile=tile,
        group=group,
        boundary_group=bg,
        boundary_tile=bt,
        group_capacity=512,
        tile_capacity=512,
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_bitwise_lossless_primary_combo(seed):
    scene = random_scene(jax.random.key(seed), 400, extent=3.0)
    base = render(scene, CAM, _cfg("tile_baseline"))
    ours = render(scene, CAM, _cfg("gstg"))
    assert int(base.stats.overflow) == 0 and int(ours.stats.overflow) == 0
    assert (np.asarray(base.image) == np.asarray(ours.image)).all()


@pytest.mark.parametrize("bg,bt", BITWISE_COMBOS)
def test_bitwise_lossless_conservative_combos(small_scene, bg, bt):
    base = render(small_scene, CAM, _cfg("tile_baseline", bt=bt))
    ours = render(small_scene, CAM, _cfg("gstg", bg=bg, bt=bt))
    assert (np.asarray(base.image) == np.asarray(ours.image)).all(), (bg, bt)


@pytest.mark.parametrize("bg,bt", ALL_COMBOS)
def test_exact_set_lossless_all_combos(small_scene, bg, bt):
    base = render(small_scene, CAM, _cfg("tile_baseline", bt=bt))
    ours = render(small_scene, CAM, _cfg("gstg", bg=bg, bt=bt))
    np.testing.assert_allclose(
        np.asarray(base.image), np.asarray(ours.image), atol=2e-6, rtol=1e-5
    )


@pytest.mark.parametrize("tile,group", [(8, 16), (8, 32), (16, 32), (16, 64), (32, 64)])
def test_lossless_across_group_sizes(tiny_scene, tile, group):
    cam = make_camera((0.0, 1.0, 4.0), (0, 0, 0), 128, 128)
    base = render(tiny_scene, cam, _cfg("tile_baseline", tile=tile, group=group))
    ours = render(tiny_scene, cam, _cfg("gstg", tile=tile, group=group))
    assert (np.asarray(base.image) == np.asarray(ours.image)).all()


def test_sorting_reduction_and_raster_parity(small_scene):
    """The paper's trade-off resolution: fewer sort keys, same alpha work."""
    base = render(small_scene, CAM, _cfg("tile_baseline"))
    ours = render(small_scene, CAM, _cfg("gstg"))
    big = render(small_scene, CAM, _cfg("group_baseline"))
    # sorting: gstg keys = group keys << tile keys
    assert int(ours.stats.n_pairs_sort) < int(base.stats.n_pairs_sort)
    assert int(ours.stats.n_pairs_sort) == int(big.stats.n_pairs_sort)
    # rasterization: gstg alpha work == small-tile baseline << large-tile
    assert int(ours.stats.alpha_ops) == int(base.stats.alpha_ops)
    assert int(big.stats.alpha_ops) > int(base.stats.alpha_ops)


def test_nonempty_render(small_scene):
    out = render(small_scene, CAM, _cfg("gstg"))
    img = np.asarray(out.image)
    assert img.shape == (128, 128, 3)
    assert img.max() > 0.01
    assert np.isfinite(img).all()
