import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import (
    TopKState,
    int8_dequantize,
    int8_quantize,
    topk_compress,
    topk_decompress,
)


def test_topk_selects_largest_and_residual():
    g = jnp.array([0.1, -5.0, 0.2, 3.0, -0.05, 0.0])
    st = TopKState(residual=jnp.zeros_like(g))
    vals, idx, st2 = topk_compress(g, st, k_frac=2 / 6)
    dec = topk_decompress(vals, idx, g.shape)
    assert set(np.nonzero(np.asarray(dec))[0].tolist()) == {1, 3}
    # residual holds exactly what wasn't sent
    np.testing.assert_allclose(np.asarray(st2.residual + dec), np.asarray(g), atol=1e-7)


def test_topk_error_feedback_catches_up():
    """Untransmitted gradient drains from the residual once the dominant
    coordinate stops arriving (the error-feedback guarantee)."""
    g0 = jnp.array([1.0, 0.01, 0.0, 0.0])
    zero = jnp.zeros_like(g0)
    st = TopKState(residual=jnp.zeros_like(g0))
    sent_total = jnp.zeros_like(g0)
    # round 1: real gradient — only the big coordinate is sent
    vals, idx, st = topk_compress(g0, st, k_frac=0.25)
    sent_total += topk_decompress(vals, idx, g0.shape)
    assert float(sent_total[1]) == 0.0
    # subsequent rounds: residual drains the small coordinate
    for _ in range(2):
        vals, idx, st = topk_compress(zero, st, k_frac=0.25)
        sent_total += topk_decompress(vals, idx, g0.shape)
    assert float(sent_total[1]) > 0.0
    # nothing is ever lost: sent + residual == total gradient mass
    import numpy as np
    np.testing.assert_allclose(
        np.asarray(sent_total + st.residual), np.asarray(g0), atol=1e-6
    )


def test_int8_roundtrip_error_bound():
    key = jax.random.key(0)
    x = jax.random.normal(key, (1024,)) * 3.0
    q, s = int8_quantize(x, jax.random.key(1))
    y = int8_dequantize(q, s, x.shape)
    err = np.abs(np.asarray(x - y))
    scale = np.asarray(s).repeat(256)[: x.size]
    assert (err <= scale + 1e-6).all()  # stochastic rounding: within 1 LSB


def test_int8_stochastic_rounding_unbiased():
    x = jnp.full((4096,), 0.05)
    keys = jax.random.split(jax.random.key(2), 16)
    means = []
    for k in keys:
        q, s = int8_quantize(x, k)
        means.append(float(int8_dequantize(q, s, x.shape).mean()))
    assert abs(np.mean(means) - 0.05) < 1e-3
