import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_camera, random_scene
from repro.core.bitmask import compact_tiles, generate_bitmasks
from repro.core.grouping import (
    GridSpec,
    bin_pairs,
    identify,
)
from repro.core.projection import project

# Jitted stage wrappers (GridSpec is a frozen, hashable dataclass): a first
# EAGER pass through identify/bin/bitmask traces each op separately and
# dominated this file's walltime; one jit compile per (shape, statics) is
# ~5x cheaper and shared across the module's tests.
identify_j = jax.jit(identify, static_argnames=("grid", "level", "method"))
bin_pairs_j = jax.jit(bin_pairs, static_argnames=("num_bins", "capacity"))
bitmasks_j = jax.jit(generate_bitmasks, static_argnames=("grid", "method"))
compact_j = jax.jit(compact_tiles, static_argnames=("grid", "tile_capacity"))


def _pipeline(seed=0, method="ellipse", w=192, h=128):
    scene = random_scene(jax.random.key(seed), 350, extent=3.0)
    cam = make_camera((0, 1.2, 5.0), (0, 0, 0), w, h)
    proj = project(scene, cam)
    grid = GridSpec(w, h, 16, 64, span=4)
    pairs = identify_j(proj, grid, "group", method)
    gtable = bin_pairs_j(pairs, grid.num_groups, 512)
    masks = bitmasks_j(proj, gtable, grid, method)
    return proj, grid, gtable, masks


def test_bitmask_soundness_vs_tile_identify():
    """bit t of gaussian g in group G set <=> tile-level identification
    includes (g, global_tile(G,t)) — computational independence (Fig 8b)."""
    proj, grid, gtable, masks = _pipeline()
    ttable = compact_j(gtable, masks, grid, 256)

    pairs_t = identify_j(proj, grid, "tile", "ellipse")
    ref_table = bin_pairs_j(pairs_t, grid.num_tiles, 256)

    gi = np.asarray(ttable.gauss_idx)
    vi = np.asarray(ttable.entry_valid)
    gr = np.asarray(ref_table.gauss_idx)
    vr = np.asarray(ref_table.entry_valid)
    for t in range(grid.num_tiles):
        got = set(gi[t][vi[t]].tolist())
        ref = set(gr[t][vr[t]].tolist())
        assert got == ref, f"tile {t}: {got ^ ref}"


def test_compaction_preserves_depth_order():
    proj, grid, gtable, masks = _pipeline(1)
    ttable = compact_j(gtable, masks, grid, 256)
    depth = np.asarray(proj.depth)
    gi = np.asarray(ttable.gauss_idx)
    vi = np.asarray(ttable.entry_valid)
    for t in range(grid.num_tiles):
        d = depth[gi[t][vi[t]]]
        assert (np.diff(d) >= -1e-6).all()


def test_masks_zero_for_invalid_entries():
    proj, grid, gtable, masks = _pipeline(2)
    m = np.asarray(masks.masks)
    valid = np.asarray(gtable.entry_valid)
    assert (m[~valid] == 0).all()


def test_out_of_image_tiles_masked():
    # 200x120 image: groups extend past the right/bottom edge
    proj, grid, gtable, masks = _pipeline(3, w=208, h=128)
    ttable = compact_j(gtable, masks, grid, 256)
    assert ttable.num_bins == grid.num_tiles
    assert int(ttable.overflow) == 0
