import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref as kref
from repro.kernels.bitonic_sort import bitonic_sort_kernel
from repro.kernels.ops import sort_groups_bitonic


@pytest.mark.parametrize("K", [64, 128, 256, 1024])
def test_bitonic_sorted_and_permutation(K):
    key = jax.random.key(K)
    G = 6
    keys = jax.random.uniform(key, (G, K))
    n_valid = K - K // 4
    keys = keys.at[:, n_valid:].set(jnp.inf)
    payload = jnp.tile(jnp.arange(K, dtype=jnp.float32)[None], (G, 1))
    sk, sv = bitonic_sort_kernel(keys, payload, interpret=True)
    sk, sv = np.asarray(sk), np.asarray(sv)
    # ascending
    assert (np.diff(sk[:, :n_valid], axis=1) >= 0).all()
    # payload is a permutation
    for g in range(G):
        assert sorted(sv[g].astype(int).tolist()) == list(range(K))
    # keys at payload positions match
    k0 = np.asarray(keys)
    for g in range(G):
        np.testing.assert_allclose(k0[g, sv[g, :n_valid].astype(int)], sk[g, :n_valid])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_bitonic_matches_ref_sort(seed):
    keys = jax.random.uniform(jax.random.key(seed), (3, 128))
    payload = jnp.tile(jnp.arange(128, dtype=jnp.float32)[None], (3, 1))
    sk, _ = bitonic_sort_kernel(keys, payload, interpret=True)
    rk, _ = kref.ref_sort(keys, payload)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(rk), rtol=1e-6)


def test_sort_groups_bitonic_int_payload():
    keys = jnp.array([[3.0, 1.0, 2.0, jnp.inf]])
    payload = jnp.array([[10, 11, 12, 13]], dtype=jnp.int32)
    k, v = sort_groups_bitonic(keys, payload, interpret=True)
    assert v[0, :3].tolist() == [11, 12, 10]


def test_bitonic_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        bitonic_sort_kernel(jnp.ones((1, 100)), jnp.ones((1, 100)), interpret=True)
