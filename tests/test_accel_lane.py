"""The real-hardware Pallas lane (DESIGN.md §13).

Two halves:

  * unit tests for ``kernels.ops.default_interpret`` — the env-var override
    and platform auto-detect that decide whether Pallas kernels interpret
    (CPU, this container) or compile (Mosaic on TPU, Triton on GPU);
  * ``@pytest.mark.accel`` parity tests that only run when jax actually has
    an accelerator backend: the COMPILED pallas lane against the reference
    backend, through the same engine-handle path the interpret-mode parity
    suite uses. On CPU they skip — ``scripts/check.sh --accel`` is the hook
    that selects them the day real hardware appears.
"""
import jax
import numpy as np
import pytest

from repro.kernels.ops import _ACCEL_PLATFORMS, default_interpret

ON_ACCEL = jax.default_backend() in _ACCEL_PLATFORMS


# -- default_interpret resolution --------------------------------------------


@pytest.mark.parametrize("val", ["0", "false", "OFF", " no "])
def test_env_forces_compiled(monkeypatch, val):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", val)
    assert default_interpret() is False


@pytest.mark.parametrize("val", ["1", "true", "on", "yes"])
def test_env_forces_interpreter(monkeypatch, val):
    # explicit ON beats platform detect — debugging on a TPU host
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", val)
    assert default_interpret() is True


@pytest.mark.parametrize("val", [None, "", "  "])
def test_unset_or_blank_falls_back_to_platform(monkeypatch, val):
    if val is None:
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    else:
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", val)
    assert default_interpret() is (jax.default_backend()
                                   not in _ACCEL_PLATFORMS)


def test_explicit_arg_still_overrides(monkeypatch, tiny_scene):
    """Per-call interpret= beats both env and platform (ops docstring)."""
    from repro.kernels.ops import sort_groups_bitonic

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    import jax.numpy as jnp

    keys = jnp.array([[3.0, 1.0, 2.0, jnp.inf]], jnp.float32)
    idx = jnp.array([[0, 1, 2, 3]], jnp.int32)
    # interpret=True must run fine on CPU even with the env forcing compiled
    k, v = sort_groups_bitonic(keys, idx, interpret=True)
    assert np.asarray(k)[0, 0] == 1.0
    assert list(np.asarray(v)[0, :3]) == [1, 2, 0]


# -- compiled-lane parity (auto-skipped off-accelerator) ----------------------


@pytest.mark.accel
@pytest.mark.skipif(
    not ON_ACCEL,
    reason=f"jax backend {jax.default_backend()!r} has no native Pallas "
           f"lowering; compiled-lane parity needs TPU/GPU",
)
def test_compiled_pallas_matches_reference(monkeypatch, tiny_scene, cam128):
    """The whole point of the lane: the COMPILED kernels (not the
    interpreter) must agree with the reference backend on real hardware."""
    from repro import engine
    from repro.core.pipeline import RenderConfig

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret() is False
    kw = dict(tile=16, group=64, group_capacity=256, tile_capacity=256,
              mode="gstg", span=6)
    with engine.open(tiny_scene, RenderConfig(backend="reference", **kw)) as rr, \
            engine.open(tiny_scene, RenderConfig(backend="pallas", **kw)) as rp:
        ref = np.asarray(rr.render(cam128).image)
        pal = np.asarray(rp.render(cam128).image)
    # cross-substrate fp tolerance (same bound as the interpret-mode parity
    # suite); bitwise is not expected across compilers
    assert np.allclose(ref, pal, atol=1e-5, rtol=1e-5)


@pytest.mark.accel
@pytest.mark.skipif(
    not ON_ACCEL,
    reason="bitonic kernel compiled-lane check needs TPU/GPU",
)
def test_compiled_bitonic_sort_matches_xla(monkeypatch):
    import jax.numpy as jnp

    from repro.kernels.ops import sort_groups_bitonic

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    keys = jax.random.uniform(jax.random.key(0), (8, 64))
    keys = jnp.where(keys > 0.9, jnp.inf, keys)
    idx = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (8, 64))
    k, _ = sort_groups_bitonic(keys, idx)  # interpret=None -> compiled here
    assert np.allclose(np.asarray(k), np.sort(np.asarray(keys), axis=-1))
