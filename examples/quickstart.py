"""Quickstart: render a synthetic scene with GS-TG and verify losslessness.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import numpy as np

import jax

from repro.core import make_camera, orbit_cameras, random_scene
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render, render_batch


def main():
    # 1) a synthetic scene + camera
    scene = random_scene(jax.random.key(0), 4000, extent=3.0)
    cam = make_camera(eye=(0, 1.5, 5.0), target=(0, 0, 0), width=512, height=384)

    # 2) the conventional per-tile pipeline (paper Fig 1) ...
    base_cfg = RenderConfig(mode="tile_baseline", tile=16, group=64,
                            tile_capacity=1024, group_capacity=1024)
    base = render(scene, cam, base_cfg)

    # 3) ... and GS-TG (paper Fig 9): group-wise sorting + bitmask raster
    ours_cfg = RenderConfig(mode="gstg", tile=16, group=64,
                            tile_capacity=1024, group_capacity=1024)
    ours = render(scene, cam, ours_cfg)

    # 4) lossless: bitwise-identical images
    identical = bool((np.asarray(base.image) == np.asarray(ours.image)).all())
    print(f"images bitwise identical : {identical}")

    # 5) the trade-off the paper resolves:
    print(f"sorting keys   baseline  : {int(base.stats.n_pairs_sort):8d}")
    print(f"sorting keys   GS-TG     : {int(ours.stats.n_pairs_sort):8d}  "
          f"({int(base.stats.n_pairs_sort)/max(int(ours.stats.n_pairs_sort),1):.2f}x fewer)")
    print(f"alpha ops      baseline  : {int(base.stats.alpha_ops):8d}")
    print(f"alpha ops      GS-TG     : {int(ours.stats.alpha_ops):8d}  (identical)")

    # 6) accelerator cost model (paper Table III config)
    cb = estimate(base.stats, GSTG_ASIC, mode="tile_baseline")
    co = estimate(ours.stats, GSTG_ASIC, mode="gstg", execution="asic")
    print(f"modeled ASIC time        : baseline {cb.total_s*1e3:.3f}ms -> "
          f"GS-TG {co.total_s*1e3:.3f}ms ({cb.total_s/co.total_s:.2f}x)")

    # 7) same entry, Pallas kernels: the BGM + fused RM stages run as TPU
    #    kernels (interpret mode on CPU) and report the SAME counters.
    pallas = render(scene, cam, dataclasses.replace(ours_cfg, backend="pallas"))
    max_diff = float(np.abs(np.asarray(pallas.image) - np.asarray(ours.image)).max())
    same_counters = all(
        int(getattr(pallas.stats, f.name)) == int(getattr(ours.stats, f.name))
        for f in dataclasses.fields(pallas.stats)
    )
    print(f"pallas backend           : image max|diff|={max_diff:.1e}  "
          f"counters identical={same_counters}")

    # 8) batched multi-view rendering: N cameras in ONE jit call; the
    #    compiled renderer is cached by (config, resolution) so the second
    #    call dispatches straight to the executable.
    small = random_scene(jax.random.key(1), 800, extent=3.0)
    cams = orbit_cameras(6, 4.5, 128, 128)
    bcfg = RenderConfig(mode="gstg", tile=16, group=64,
                        tile_capacity=256, group_capacity=256)
    batch = render_batch(small, cams, bcfg)  # compiles
    t0 = time.time()
    batch = render_batch(small, cams, bcfg)  # cached
    jax.block_until_ready(batch.image)
    print(f"render_batch             : {batch.image.shape[0]} views "
          f"{batch.image.shape[1:]} in {time.time()-t0:.3f}s (cached jit)")


if __name__ == "__main__":
    main()
