"""Quickstart: render a synthetic scene with GS-TG and verify losslessness.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax

from repro.core import make_camera, random_scene
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render


def main():
    # 1) a synthetic scene + camera
    scene = random_scene(jax.random.key(0), 4000, extent=3.0)
    cam = make_camera(eye=(0, 1.5, 5.0), target=(0, 0, 0), width=512, height=384)

    # 2) the conventional per-tile pipeline (paper Fig 1) ...
    base_cfg = RenderConfig(mode="tile_baseline", tile=16, group=64,
                            tile_capacity=1024, group_capacity=1024)
    base = render(scene, cam, base_cfg)

    # 3) ... and GS-TG (paper Fig 9): group-wise sorting + bitmask raster
    ours_cfg = RenderConfig(mode="gstg", tile=16, group=64,
                            tile_capacity=1024, group_capacity=1024)
    ours = render(scene, cam, ours_cfg)

    # 4) lossless: bitwise-identical images
    identical = bool((np.asarray(base.image) == np.asarray(ours.image)).all())
    print(f"images bitwise identical : {identical}")

    # 5) the trade-off the paper resolves:
    print(f"sorting keys   baseline  : {int(base.stats.n_pairs_sort):8d}")
    print(f"sorting keys   GS-TG     : {int(ours.stats.n_pairs_sort):8d}  "
          f"({int(base.stats.n_pairs_sort)/max(int(ours.stats.n_pairs_sort),1):.2f}x fewer)")
    print(f"alpha ops      baseline  : {int(base.stats.alpha_ops):8d}")
    print(f"alpha ops      GS-TG     : {int(ours.stats.alpha_ops):8d}  (identical)")

    # 6) accelerator cost model (paper Table III config)
    cb = estimate(base.stats, GSTG_ASIC, mode="tile_baseline")
    co = estimate(ours.stats, GSTG_ASIC, mode="gstg", execution="asic")
    print(f"modeled ASIC time        : baseline {cb.total_s*1e3:.3f}ms -> "
          f"GS-TG {co.total_s*1e3:.3f}ms ({cb.total_s/co.total_s:.2f}x)")


if __name__ == "__main__":
    main()
