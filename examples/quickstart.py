"""Quickstart: render a synthetic scene with GS-TG and verify losslessness.

  PYTHONPATH=src python examples/quickstart.py

Migration note (DESIGN.md §11): repeated rendering now goes through a
session handle — commit the scene ONCE with ``repro.engine.open(scene,
cfg)`` and call the handle. Each deprecated free function maps to:

  render_jit(scene, cam, cfg)            -> engine.open(scene, cfg).render(cam)
  render_image(scene, cam, cfg)          -> render(scene, cam, cfg).image
                                            (differentiable/eager), or
                                            handle.render(cam).image
  render_batch_sharded(scene, cams, cfg) -> engine.open(scene, cfg,
                                            mesh=...).render_batch(cams)

``render()`` (eager single camera, the differentiable oracle) and
``render_batch()`` (one-off batched jit) remain the low-level primitives.
"""
import dataclasses
import time

import numpy as np

import jax

from repro import engine
from repro.core import make_camera, orbit_cameras, random_scene
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render


def main():
    # 1) a synthetic scene + camera
    scene = random_scene(jax.random.key(0), 4000, extent=3.0)
    cam = make_camera(eye=(0, 1.5, 5.0), target=(0, 0, 0), width=512, height=384)

    # 2) the conventional per-tile pipeline (paper Fig 1) ...
    base_cfg = RenderConfig(mode="tile_baseline", tile=16, group=64,
                            tile_capacity=1024, group_capacity=1024)
    base = render(scene, cam, base_cfg)

    # 3) ... and GS-TG (paper Fig 9): group-wise sorting + bitmask raster
    ours_cfg = RenderConfig(mode="gstg", tile=16, group=64,
                            tile_capacity=1024, group_capacity=1024)
    ours = render(scene, cam, ours_cfg)

    # 4) lossless: bitwise-identical images
    identical = bool((np.asarray(base.image) == np.asarray(ours.image)).all())
    print(f"images bitwise identical : {identical}")

    # 5) the trade-off the paper resolves:
    print(f"sorting keys   baseline  : {int(base.stats.n_pairs_sort):8d}")
    print(f"sorting keys   GS-TG     : {int(ours.stats.n_pairs_sort):8d}  "
          f"({int(base.stats.n_pairs_sort)/max(int(ours.stats.n_pairs_sort),1):.2f}x fewer)")
    print(f"alpha ops      baseline  : {int(base.stats.alpha_ops):8d}")
    print(f"alpha ops      GS-TG     : {int(ours.stats.alpha_ops):8d}  (identical)")

    # 6) accelerator cost model (paper Table III config)
    cb = estimate(base.stats, GSTG_ASIC, mode="tile_baseline")
    co = estimate(ours.stats, GSTG_ASIC, mode="gstg", execution="asic")
    print(f"modeled ASIC time        : baseline {cb.total_s*1e3:.3f}ms -> "
          f"GS-TG {co.total_s*1e3:.3f}ms ({cb.total_s/co.total_s:.2f}x)")

    # 7) same entry, Pallas kernels: the BGM + fused RM stages run as TPU
    #    kernels (interpret mode on CPU) and report the SAME counters.
    pallas = render(scene, cam, dataclasses.replace(ours_cfg, backend="pallas"))
    max_diff = float(np.abs(np.asarray(pallas.image) - np.asarray(ours.image)).max())
    same_counters = all(
        int(getattr(pallas.stats, f.name)) == int(getattr(ours.stats, f.name))
        for f in dataclasses.fields(pallas.stats)
    )
    print(f"pallas backend           : image max|diff|={max_diff:.1e}  "
          f"counters identical={same_counters}")

    # 8) the session handle (DESIGN.md §11): commit the scene ONCE, then
    #    render single cameras, whole batches, or submit() futures through
    #    one facade — the compiled renderers are cached per camera geometry
    #    inside the handle, so the second batch dispatches straight to the
    #    executable.
    small = random_scene(jax.random.key(1), 800, extent=3.0)
    cams = orbit_cameras(6, 4.5, 128, 128)
    bcfg = RenderConfig(mode="gstg", tile=16, group=64,
                        tile_capacity=256, group_capacity=256)
    with engine.open(small, bcfg, max_batch=6, max_wait=0.0) as renderer:
        batch = renderer.render_batch(cams)  # compiles
        t0 = time.time()
        batch = renderer.render_batch(cams)  # cached
        jax.block_until_ready(batch.image)
        print(f"renderer.render_batch    : {batch.image.shape[0]} views "
              f"{batch.image.shape[1:]} in {time.time()-t0:.3f}s (cached jit)")

        # 9) the futures front-end: submit() batches concurrent requests
        #    behind the scenes (queue -> bucketing worker) and resolves each
        #    future with a host-side RenderResult.
        futs = [renderer.submit(c) for c in cams]
        imgs = [f.result(timeout=120).image for f in futs]
        same = all(
            (img == np.asarray(batch.image[i])).all()
            for i, img in enumerate(imgs)
        )
        stats = renderer.stats()
        print(f"renderer.submit futures  : {len(imgs)} results in "
              f"{stats['batches']} batch(es), identical to render_batch: "
              f"{same}")

        # 10) camera streams (DESIGN.md §15): open_stream() caches frontend
        #     results under an EXACT pose signature — lap 2 of the orbit
        #     skips project/identify/bin entirely and dispatches only the
        #     backend program, while staying bitwise-identical to the
        #     stateless path by construction.
        with renderer.open_stream() as stream:
            for lap in range(2):
                for cam in cams:
                    frame = stream.render(cam)
            jax.block_until_ready(frame.image)
            sstats = stream.stats()
            bitwise = (np.asarray(frame.image)
                       == np.asarray(renderer.render(cams[-1]).image)).all()
        print(f"renderer.open_stream     : {sstats['frames']} frames, "
              f"hit_rate={sstats['hit_rate']:.2f} (lap 2 all hits), "
              f"bitwise == stateless: {bitwise}")

    # 11) gateway fleet (DESIGN.md §16): two in-process workers behind a
    #     RenderGateway; one is killed mid-load and every request STILL
    #     completes — failover retries are idempotent and the pixels stay
    #     bitwise-identical to a healthy run. (`repro-gateway` runs the
    #     same thing over subprocess workers with their own jax runtimes.)
    from repro.gateway import RenderGateway
    from repro.gateway.worker import InprocWorker
    from repro.serving.queue import RenderRequest

    workers = [
        InprocWorker(f"w{i}", {"quick": small}, max_batch=2)
        for i in range(2)
    ]
    gw = RenderGateway(workers, retry_backoff_s=0.005)
    load = [
        (0.0, RenderRequest(i, "quick", cams[i % len(cams)], bcfg))
        for i in range(6)
    ]
    res = gw.run(load, kill_worker="w0", kill_after=1)
    s = gw.summary()
    print(f"gateway fleet            : {len(res)}/6 completed after killing "
          f"w0 ({s['failovers']} failover, {s['retries']} retries, "
          f"{s['healthy_workers']} worker left)")
    gw.close()


if __name__ == "__main__":
    main()
