"""Train a ~100M-class LM for a few hundred steps on the synthetic token
stream, with async checkpointing + resume (the launch/train.py driver).

  PYTHONPATH=src python examples/train_lm.py [--arch smollm-360m --steps 300]

The default runs the reduced smollm config; pass --full-config on a TPU fleet.
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="results/ckpt_lm")
    args = ap.parse_args()

    _, history = train(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        smoke=True,
    )
    first, last = history[0], history[-1]
    print(
        f"\nloss {first['loss']:.4f} (step {first['step']}) -> "
        f"{last['loss']:.4f} (step {last['step']})"
    )
    assert last["loss"] < first["loss"], "training did not reduce loss"


if __name__ == "__main__":
    main()
