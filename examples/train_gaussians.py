"""End-to-end 3D-GS training: optimize Gaussian parameters against target
renders, differentiating THROUGH the GS-TG pipeline (lossless => training
through either pipeline is identical).

  PYTHONPATH=src python examples/train_gaussians.py [--steps 200]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import make_camera, random_scene
from repro.core.pipeline import RenderConfig, render
from repro.core.train import SceneTrainConfig, fit_scene


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--gaussians", type=int, default=400)
    args = ap.parse_args()

    key = jax.random.key(0)
    target_scene = random_scene(key, args.gaussians, extent=2.5)
    cams = [
        make_camera((0.0, 1.0, 4.0), (0, 0, 0), 96, 96),
        make_camera((3.0, 1.0, 2.5), (0, 0, 0), 96, 96),
        make_camera((-3.0, 1.2, 2.5), (0, 0, 0), 96, 96),
    ]
    cfg = RenderConfig(tile=16, group=32, group_capacity=512, tile_capacity=512)
    targets = [render(target_scene, c, cfg).image for c in cams]

    # start from a perturbed copy and recover the target scene
    init = dataclasses.replace(
        target_scene,
        means3d=target_scene.means3d
        + 0.08 * jax.random.normal(jax.random.key(1), target_scene.means3d.shape),
        opacity=target_scene.opacity - 1.0,
        sh=target_scene.sh + 0.1 * jax.random.normal(
            jax.random.key(2), target_scene.sh.shape
        ),
    )
    tcfg = SceneTrainConfig(steps=args.steps)
    fitted, history = fit_scene(init, cams, targets, cfg, tcfg, log_every=25)
    for h in history:
        print(f"step {h['step']:4d}  loss={h['loss']:.5f}  psnr={h['psnr']:.2f}dB")
    print(f"\nPSNR {history[0]['psnr']:.2f} -> {history[-1]['psnr']:.2f} dB")


if __name__ == "__main__":
    main()
