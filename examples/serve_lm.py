"""Serve a small LM with batched greedy decoding (KV-cache decode path — the
same serve_step the decode_32k/long_500k dry-run cells lower).

  PYTHONPATH=src python examples/serve_lm.py [--arch granite-3-2b]
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()
    gen = serve(
        args.arch,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen_len=args.gen_len,
        smoke=True,
    )
    print("sample continuation token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
