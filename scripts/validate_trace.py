#!/usr/bin/env python
"""CI validator for the traced serving smoke (scripts/check.sh).

  PYTHONPATH=src python scripts/validate_trace.py TRACE.json METRICS.json

Cross-checks the three observability surfaces one ``repro.launch
.render_serve --trace-json --metrics-json`` run emits (DESIGN.md §14):

  * the Chrome trace itself: ``repro.trace/v1`` schema, well-formed events,
    per-(pid, tid) span nesting (``repro.obs.validate_chrome_trace``);
  * stage coverage: with REPRO_TRACE=1 the timed renders must record >= 7
    distinct ``cat == "stage"`` span names (project/identify/bin/bitmask/
    compact/rasterize + the enclosing render; merge rides along when the
    scene is gaussian-sharded);
  * trace <-> metrics <-> summary consistency: completed requests and
    dispatched batches must agree between the request/serve spans, the
    ``serving.*`` counters + latency histogram, and the stats summary
    embedded under the trace's ``"summary"`` key;
  * residency paging (DESIGN.md §17): ``residency/page_in|page_out``
    span counts must equal the ``residency.page_ins_total|page_outs_total``
    counters (span + counter are recorded in the same critical section),
    in both serve and gateway modes.

Exits non-zero listing every drift — the point is that a broken stamp,
a lost span, or a double-counted metric fails CI instead of silently
skewing the next perf investigation.
"""
from __future__ import annotations

import json
import sys

from repro.obs import validate_chrome_trace

MIN_STAGE_NAMES = 7


def validate_residency(xs: list, counters: dict) -> list:
    """Residency-mode checks (DESIGN.md §17): every scene page-in/-out
    records its ``residency/*`` span and bumps its ``residency.*`` counter
    in the same critical section, so the two surfaces must agree exactly.
    Enforced whenever the run paged at all (any residency counter or span
    present) — which includes every serve run, since commits page scenes
    in even with no budget set."""
    errs = []
    if "residency.page_ins_total" not in counters and not any(
        e.get("cat") == "residency" for e in xs
    ):
        return errs
    for name, counter in (
        ("residency/page_in", "residency.page_ins_total"),
        ("residency/page_out", "residency.page_outs_total"),
    ):
        n_span = sum(1 for e in xs if e.get("name") == name)
        n_counter = counters.get(counter, 0)
        if n_span != n_counter:
            errs.append(
                f"{name} spans = {n_span} but counters[{counter!r}] = "
                f"{n_counter} — a page transition lost its span or "
                f"double-counted")
    evictions = counters.get("residency.evictions_total", 0)
    page_outs = counters.get("residency.page_outs_total", 0)
    if evictions > page_outs:
        errs.append(
            f"counters['residency.evictions_total'] = {evictions} exceeds "
            f"page_outs = {page_outs} — an eviction that never paged out")
    return errs


def validate_gateway(trace_doc: dict, metrics_doc: dict) -> list:
    """Gateway-mode checks (``repro.launch.render_gateway --trace-json``):
    the rendering happens inside worker subprocesses, so there are no
    stage/serving spans in the parent trace — instead the ``gateway/*``
    span family must match the ``gateway.*`` counters and the embedded
    summary one-to-one (route spans == routed, retry spans == retries,
    failover spans == failovers, request spans == completed)."""
    errs = list(validate_chrome_trace(trace_doc))
    xs = [e for e in trace_doc.get("traceEvents", [])
          if isinstance(e, dict) and e.get("ph") == "X"]
    summary = trace_doc.get("summary", {})
    if metrics_doc.get("schema") != "repro.metrics/v1":
        errs.append(f"metrics schema != 'repro.metrics/v1': "
                    f"{metrics_doc.get('schema')!r}")
    counters = metrics_doc.get("counters", {})

    spans = {}
    for e in xs:
        if e.get("cat") == "gateway":
            spans[e["name"]] = spans.get(e["name"], 0) + 1
    for name, counter, key in (
        ("gateway/route", "gateway.routed_total", "routed"),
        ("gateway/retry", "gateway.retries_total", "retries"),
        ("gateway/failover", "gateway.failovers_total", "failovers"),
    ):
        n_span = spans.get(name, 0)
        n_counter = counters.get(counter, 0)
        n_summary = summary.get(key)
        if not (n_span == n_counter == n_summary):
            errs.append(
                f"{name} spans = {n_span}, counters[{counter!r}] = "
                f"{n_counter}, summary.{key} = {n_summary} — must agree")

    req_ids = {e["args"]["request_id"] for e in xs
               if e.get("cat") == "request" and e.get("name") == "request"}
    completed = summary.get("completed")
    done_counter = counters.get("gateway.completed_total")
    for label, got in (
        ("request spans in trace", len(req_ids)),
        ("counters['gateway.completed_total']", done_counter),
    ):
        if got != completed:
            errs.append(f"{label} = {got} but summary.completed = {completed}")

    # An induced kill must leave a consistent failure record: a failover
    # implies a worker-death counter and at least one retry span.
    if summary.get("failovers", 0) > 0:
        if counters.get("gateway.worker_deaths_total", 0) < 1:
            errs.append("summary.failovers > 0 but "
                        "counters['gateway.worker_deaths_total'] < 1")
        if spans.get("gateway/retry", 0) < 1:
            errs.append("summary.failovers > 0 but no gateway/retry spans")
    # Inproc fleets page in the parent process (subprocess workers page in
    # their own registries — both sides absent here, trivially consistent).
    errs.extend(validate_residency(xs, counters))
    return errs


def validate(trace_doc: dict, metrics_doc: dict) -> list:
    if trace_doc.get("summary", {}).get("gateway"):
        return validate_gateway(trace_doc, metrics_doc)
    errs = list(validate_chrome_trace(trace_doc))

    xs = [e for e in trace_doc.get("traceEvents", [])
          if isinstance(e, dict) and e.get("ph") == "X"]
    stage_names = {e["name"] for e in xs if e.get("cat") == "stage"}
    if len(stage_names) < MIN_STAGE_NAMES:
        errs.append(
            f"only {len(stage_names)} distinct stage span names "
            f"{sorted(stage_names)}; need >= {MIN_STAGE_NAMES} "
            f"(was the run traced with REPRO_TRACE=1?)")

    summary = trace_doc.get("summary")
    if not isinstance(summary, dict):
        errs.append("trace is missing the embedded 'summary' object")
        summary = {}

    if metrics_doc.get("schema") != "repro.metrics/v1":
        errs.append(f"metrics schema != 'repro.metrics/v1': "
                    f"{metrics_doc.get('schema')!r}")
    counters = metrics_doc.get("counters", {})
    hists = metrics_doc.get("histograms", {})

    # Completed requests: request spans == serving.requests_total ==
    # summary.completed == latency histogram count.
    req_ids = {e["args"]["request_id"] for e in xs
               if e.get("cat") == "request" and e.get("name") == "request"}
    completed = summary.get("completed")
    req_counter = counters.get("serving.requests_total")
    lat_count = hists.get("serving.latency_s", {}).get("count")
    for label, got in (
        ("request spans in trace", len(req_ids)),
        ("counters['serving.requests_total']", req_counter),
        ("latency histogram count", lat_count),
    ):
        if got != completed:
            errs.append(f"{label} = {got} but summary.completed = {completed}")

    # Dispatched batches: serve/dispatch spans == serving.batches_total ==
    # summary.batches.
    dispatches = sum(1 for e in xs if e.get("name") == "serve/dispatch")
    batches = summary.get("batches")
    batch_counter = counters.get("serving.batches_total")
    for label, got in (
        ("serve/dispatch spans in trace", dispatches),
        ("counters['serving.batches_total']", batch_counter),
    ):
        if got != batches:
            errs.append(f"{label} = {got} but summary.batches = {batches}")

    # Stream/speculation consistency (DESIGN.md §15): every stream frame
    # records exactly one spec/verify span (the exact-reuse cache decision),
    # so the span count must equal the stream hit+miss counter totals; every
    # speculative frontend records one spec/run span matching spec.runs_total.
    # Only enforced when the run actually served streams — stateless smokes
    # carry no stream counters or spec spans.
    stream_frames = counters.get("stream.frames_total")
    if stream_frames is not None or any(e.get("cat") == "spec" for e in xs):
        hits = counters.get("stream.hits_total", 0)
        misses = counters.get("stream.misses_total", 0)
        verifies = sum(1 for e in xs if e.get("name") == "spec/verify")
        if verifies != hits + misses:
            errs.append(
                f"spec/verify spans = {verifies} but stream hit+miss "
                f"counters total {hits + misses} "
                f"(hits={hits}, misses={misses})")
        if stream_frames != hits + misses:
            errs.append(
                f"counters['stream.frames_total'] = {stream_frames} but "
                f"hit+miss counters total {hits + misses}")
        spec_runs = counters.get("spec.runs_total", 0)
        run_spans = sum(1 for e in xs if e.get("name") == "spec/run")
        if run_spans != spec_runs:
            errs.append(
                f"spec/run spans = {run_spans} but "
                f"counters['spec.runs_total'] = {spec_runs}")

    # Every request span must carry its device phase — a request that
    # completed without a dispatch/device_done stamp pair means a lifecycle
    # stamp went missing.
    device_ids = {e["args"]["request_id"] for e in xs
                  if e.get("cat") == "request"
                  and e.get("name") == "request/device"}
    missing = req_ids - device_ids
    if missing:
        errs.append(f"{len(missing)} request(s) have no request/device span: "
                    f"{sorted(missing)[:5]}")

    errs.extend(validate_residency(xs, counters))
    return errs


def main(argv) -> int:
    if len(argv) != 3:
        print(__doc__.strip().splitlines()[2].strip())
        return 2
    with open(argv[1]) as f:
        trace_doc = json.load(f)
    with open(argv[2]) as f:
        metrics_doc = json.load(f)
    errs = validate(trace_doc, metrics_doc)
    if errs:
        for e in errs:
            print(f"validate_trace: DRIFT: {e}")
        print(f"validate_trace: FAILED ({len(errs)} problems)")
        return 1
    n_events = len(trace_doc.get("traceEvents", []))
    summary = trace_doc.get("summary", {})
    tail = (f"failovers={summary.get('failovers')}" if summary.get("gateway")
            else f"batches={summary.get('batches')}")
    print(f"validate_trace: OK ({n_events} events, "
          f"{trace_doc.get('dropped', 0)} dropped, "
          f"completed={summary.get('completed')}, {tail})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
