#!/usr/bin/env bash
# Single CI gate: tier-1 tests + a 1-frame smoke render on both backends.
#
#   scripts/check.sh          # full tier-1 (includes slow tests)
#   scripts/check.sh --fast   # deselect slow tests
set -euo pipefail
cd "$(dirname "$0")/.."

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-m "not slow")
fi

echo "== tier-1 tests =="
python -m pytest "${PYTEST_ARGS[@]}"

# module runs (benchmarks/, repro.*) need both roots on the path; pytest gets
# them from pyproject's pythonpath, plain `python -m` does not.
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

# Engine-handle smokes (DESIGN.md §11): both drivers run on the committed
# Renderer handle (engine.open), so these exercise commit -> per-handle jit
# cache -> render on each backend end to end.
SMOKE="--scene train --gaussians 1200 --width 256 --height 192 --capacity 256"
echo "== engine-handle smoke render: reference backend =="
python -m repro.launch.render $SMOKE --backend reference --stats
echo "== engine-handle smoke render: pallas backend =="
python -m repro.launch.render $SMOKE --backend pallas --stats

# Serving smoke: a small synthetic load through queue -> bucketing -> the
# server's shared handles; render_serve exits non-zero unless every request
# completes and p99 latency is finite.
echo "== smoke serve: reference backend =="
python -m repro.launch.render_serve --backend reference \
    --requests 8 --rate 200 --gaussians 600 --scenes train \
    --resolutions 96x96,128x96 --max-batch 4 --max-wait 0.05

# Scene-sharded handle smoke: 2 virtual host devices, gaussian axis over the
# mesh 'model' axis (DESIGN.md §10), committed through engine.open with the
# handle-enforced --device-budget-mb gate (proves the per-device footprint
# halves). --parity-check re-renders every request on a replicated handle
# and requires BITWISE-identical images (exit non-zero otherwise).
echo "== smoke serve: scene-sharded handle (2 virtual devices, bitwise parity) =="
python -m repro.launch.render_serve --backend reference --devices 2 \
    --scene-shards 2 --parity-check --device-budget-mb 0.02 \
    --requests 6 --rate 200 --gaussians 500 --scenes train \
    --resolutions 96x96 --max-batch 2 --max-wait 0.05 --no-realtime

echo "check.sh: OK"
