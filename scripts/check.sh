#!/usr/bin/env bash
# Single CI gate: tier-1 tests + a 1-frame smoke render on both backends.
#
#   scripts/check.sh          # full tier-1 (includes slow tests)
#   scripts/check.sh --fast   # deselect slow tests
set -euo pipefail
cd "$(dirname "$0")/.."

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-m "not slow")
fi

echo "== tier-1 tests =="
python -m pytest "${PYTEST_ARGS[@]}"

# module runs (benchmarks/, repro.*) need both roots on the path; pytest gets
# them from pyproject's pythonpath, plain `python -m` does not.
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

SMOKE="--scene train --gaussians 1200 --width 256 --height 192 --capacity 256"
echo "== smoke render: reference backend =="
python -m repro.launch.render $SMOKE --backend reference --stats
echo "== smoke render: pallas backend =="
python -m repro.launch.render $SMOKE --backend pallas --stats

# Serving smoke: a small synthetic load through queue -> bucketing -> sharded
# dispatch; render_serve exits non-zero unless every request completes and
# p99 latency is finite.
echo "== smoke serve: reference backend =="
python -m repro.launch.render_serve --backend reference \
    --requests 8 --rate 200 --gaussians 600 --scenes train \
    --resolutions 96x96,128x96 --max-batch 4 --max-wait 0.05

echo "check.sh: OK"
