#!/usr/bin/env bash
# Single CI gate: tier-1 tests (fast lane + slow remainder) + smoke renders.
#
#   scripts/check.sh          # fast lane, then the slow remainder = full tier-1
#   scripts/check.sh --fast   # fast lane only (-m "not slow", target < 5 min)
#   scripts/check.sh --accel  # ONLY the compiled-Pallas lane (-m accel):
#                             # REPRO_PALLAS_INTERPRET=0 parity on real
#                             # TPU/GPU hardware (tests skip on CPU) —
#                             # DESIGN.md §13
#
# The fast lane is the quick signal: golden-image checksums (both backends),
# every non-slow parity/unit suite, with per-test timings reported so creep
# is visible. The slow remainder (-m slow) holds the pallas-interpret
# heavyweights and the subprocess/virtual-device suites; running it second
# keeps the default invocation equal to the full tier-1 set without running
# anything twice.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--accel" ]]; then
    echo "== compiled-Pallas lane (-m accel, REPRO_PALLAS_INTERPRET=0) =="
    REPRO_PALLAS_INTERPRET=0 python -m pytest -x -q -m "accel" \
        --durations=15 -rs
    echo "check.sh --accel: OK"
    exit 0
fi

echo "== tier-1 tests: fast lane (-m 'not slow') =="
python -m pytest -x -q -m "not slow" --durations=15

if [[ "${1:-}" != "--fast" ]]; then
    echo "== tier-1 tests: slow remainder (-m slow) =="
    python -m pytest -x -q -m "slow" --durations=15
fi

# module runs (benchmarks/, repro.*) need both roots on the path; pytest gets
# them from pyproject's pythonpath, plain `python -m` does not.
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

# Engine-handle smokes (DESIGN.md §11): both drivers run on the committed
# Renderer handle (engine.open), so these exercise commit -> per-handle jit
# cache -> render on each backend end to end.
SMOKE="--scene train --gaussians 1200 --width 256 --height 192 --capacity 256"
echo "== engine-handle smoke render: reference backend =="
python -m repro.launch.render $SMOKE --backend reference --stats
echo "== engine-handle smoke render: pallas backend =="
python -m repro.launch.render $SMOKE --backend pallas --stats

# Serving smoke: a small synthetic load through queue -> bucketing -> the
# server's shared handles; render_serve exits non-zero unless every request
# completes and p99 latency is finite.
echo "== smoke serve: reference backend =="
python -m repro.launch.render_serve --backend reference \
    --requests 8 --rate 200 --gaussians 600 --scenes train \
    --resolutions 96x96,128x96 --max-batch 4 --max-wait 0.05

# Scene-sharded handle smoke: 2 virtual host devices, gaussian axis over the
# mesh 'model' axis (DESIGN.md §10) with the FEATURE-SHARDED gathers the
# handle commits for a physical 'model' axis (feature_gather=psum, DESIGN.md
# §12). The handle-enforced --device-budget-mb now counts the per-camera
# projected features too: 0.04 MB admits the sharded layout (params + N/2
# features ~ 0.033 MB/device) but would REFUSE the replicated one (~0.065
# MB), so passing proves the full per-device footprint halves.
# --parity-check re-renders every request on a replicated handle and
# requires BITWISE-identical images (exit non-zero otherwise).
echo "== smoke serve: feature-sharded handle (2 virtual devices, bitwise parity) =="
python -m repro.launch.render_serve --backend reference --devices 2 \
    --scene-shards 2 --parity-check --device-budget-mb 0.04 \
    --requests 6 --rate 200 --gaussians 500 --scenes train \
    --resolutions 96x96 --max-batch 2 --max-wait 0.05 --no-realtime

# Autotune smoke (DESIGN.md §13): a 2x2 (group x capacity) grid at the
# default tile on a tiny scene through the full sweep -> BENCH emission
# path. Validates the schema-versioned document AND asserts the tuned
# config renders BITWISE-identical to the default config (group/capacity
# are the lossless axes; the smoke pins the tile so the guarantee is exact).
# Exits non-zero on any failure; writes under results/ so the committed
# BENCH_autotune_<host>.json trajectory is never clobbered by CI.
echo "== autotune smoke: 2x2 sweep, schema + bitwise tuned-vs-default =="
python benchmarks/bench_autotune.py --smoke

# Traced serving smoke (DESIGN.md §14): the same 2-virtual-device serve with
# REPRO_TRACE=1 (fenced per-stage device spans) writing a Chrome trace +
# metrics snapshot, then cross-validated — span nesting, >= 7 distinct stage
# span names, and request/batch counts agreeing across trace, metrics
# registry, and stats summary. Exits non-zero on any drift.
echo "== traced smoke serve: chrome trace + metrics registry cross-check =="
REPRO_TRACE=1 python -m repro.launch.render_serve --backend reference \
    --devices 2 --requests 6 --rate 200 --gaussians 500 --scenes train \
    --resolutions 96x96 --max-batch 2 --max-wait 0.05 --no-realtime \
    --trace-json results/trace_smoke.json \
    --metrics-json results/metrics_smoke.json
python scripts/validate_trace.py \
    results/trace_smoke.json results/metrics_smoke.json

# Stream smoke (DESIGN.md §15): 2 interactive camera streams on the
# 2-virtual-device server, frames lapping a 16-pose orbit so the exact-reuse
# frontend cache and the speculation worker both engage. --parity-check
# exits non-zero on ANY frame that is not BITWISE-identical to the stateless
# path (the verify-or-discard invariant), and validate_trace.py cross-checks
# the spec/* span counts against the stream/spec metrics counters.
echo "== stream smoke serve: exact-reuse + speculation, bitwise parity =="
REPRO_TRACE=1 python -m repro.launch.render_serve --backend reference \
    --devices 2 --scene-shards 2 --streams 2 --stream-frames 20 \
    --spec-depth 2 \
    --rate 200 --gaussians 500 --scenes train --resolutions 96x96 \
    --max-batch 4 --max-wait 0.05 --no-realtime --parity-check \
    --trace-json results/trace_stream_smoke.json \
    --metrics-json results/metrics_stream_smoke.json
python scripts/validate_trace.py \
    results/trace_stream_smoke.json results/metrics_stream_smoke.json

# Residency smoke (DESIGN.md §17): 3 scenes on the 2-virtual-device server
# under a budget that holds only ONE of them — commits succeed anyway
# (over-budget commits evict cold scenes instead of failing fast), the
# round-robin load thrashes the LRU, and --parity-check exits non-zero on
# ANY image that is not BITWISE-identical to the replicated unbudgeted
# path (paging must be invisible in the pixels). validate_trace.py
# (residency mode) cross-checks the residency/page_in|page_out span
# counts against the residency.* counters.
echo "== residency smoke serve: 3 scenes in a 1-scene budget, bitwise parity =="
REPRO_TRACE=1 python -m repro.launch.render_serve --backend reference \
    --devices 2 --requests 12 --rate 200 --gaussians 500 \
    --scenes train,truck,drjohnson --resolutions 96x96 \
    --max-batch 2 --max-wait 0.05 --no-realtime --parity-check \
    --device-budget-mb 0.1 \
    --trace-json results/trace_residency_smoke.json \
    --metrics-json results/metrics_residency_smoke.json
python scripts/validate_trace.py \
    results/trace_residency_smoke.json results/metrics_residency_smoke.json

# Measured per-stage bench smoke (DESIGN.md §14): tiny scene through the
# timing=True engine path -> BENCH_stages schema validation.
echo "== bench_stages smoke: measured per-stage spans, schema valid =="
python benchmarks/bench_stages.py --smoke

# Gateway chaos smoke (DESIGN.md §16): 2 subprocess workers (2 virtual
# devices EACH, in their own jax runtimes) behind the gateway; w0 is
# SIGKILLed after 3 completions mid-load. render_gateway exits non-zero
# unless 100% of requests complete with finite p99, zero failures, and at
# least one failover; validate_trace.py (gateway mode) then cross-checks
# the gateway/route|retry|failover span counts against the gateway.*
# counters and the embedded summary.
echo "== gateway chaos smoke: 2 workers, induced kill, failover cross-check =="
python -m repro.launch.render_gateway --workers 2 --devices-per-worker 2 \
    --requests 16 --rate 200 --gaussians 400 --scenes train,truck \
    --resolutions 96x96 --max-batch 4 --kill-worker auto --kill-after 3 \
    --no-realtime \
    --trace-json results/trace_gateway_smoke.json \
    --metrics-json results/metrics_gateway_smoke.json
python scripts/validate_trace.py \
    results/trace_gateway_smoke.json results/metrics_gateway_smoke.json

echo "check.sh: OK"
