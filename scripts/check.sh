#!/usr/bin/env bash
# Single CI gate: tier-1 tests + a 1-frame smoke render on both backends.
#
#   scripts/check.sh          # full tier-1 (includes slow tests)
#   scripts/check.sh --fast   # deselect slow tests
set -euo pipefail
cd "$(dirname "$0")/.."

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-m "not slow")
fi

echo "== tier-1 tests =="
python -m pytest "${PYTEST_ARGS[@]}"

# module runs (benchmarks/, repro.*) need both roots on the path; pytest gets
# them from pyproject's pythonpath, plain `python -m` does not.
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

SMOKE="--scene train --gaussians 1200 --width 256 --height 192 --capacity 256"
echo "== smoke render: reference backend =="
python -m repro.launch.render $SMOKE --backend reference
echo "== smoke render: pallas backend =="
python -m repro.launch.render $SMOKE --backend pallas

echo "check.sh: OK"
