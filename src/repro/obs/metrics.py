"""Metrics registry: named counters / gauges / reservoir histograms
(DESIGN.md §14).

One process-wide :class:`MetricsRegistry` sits behind every stats surface
in the repo — ``ServingStats``, ``Renderer.stats()``, the render-cache
registry (via a collector), the autotune cache — so FPS, p50/p99, cache
hit rates, and overflow counters coexist in ONE schema-versioned snapshot
(``registry.snapshot()``, ``--metrics-json``) instead of three ad-hoc
dicts.

Instruments are cheap and individually locked, safe to update from the
serving driver loop, the futures worker thread, and test threads at once.

:class:`Histogram` is a bounded reservoir (algorithm R, deterministic
seed): exact count/sum/min/max always; percentiles exact while the sample
count is within the reservoir capacity, and an unbiased uniform sample
above it (``sampled`` flags the switch). This is what bounds
``BucketStats`` latency memory on a long-lived server.
"""
from __future__ import annotations

import math
import random
import re
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

SCHEMA = "repro.metrics/v1"

#: Default reservoir capacity: exact percentiles for any bucket that has
#: seen up to this many observations.
DEFAULT_RESERVOIR = 4096


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank-with-interpolation percentile; 0.0 on empty input.

    (``serving.stats.percentile`` is the same interpolation with a
    DIFFERENT empty-input contract — nan — because the serving CI exit
    check keys on a finite p99; this one feeds :class:`Histogram`
    snapshots, which must stay JSON-plain.)
    """
    if not values:
        return 0.0
    xs = sorted(values)
    if len(xs) == 1:
        return float(xs[0])
    pos = (len(xs) - 1) * (q / 100.0)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


class Counter:
    """Monotonic counter."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("Counter.inc is monotonic; got n=%r" % (n,))
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins scalar."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value: float = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Bounded-reservoir value distribution (algorithm R).

    ``count``/``sum``/``min``/``max`` are exact over every observation.
    Percentiles come from the reservoir: exact while ``count <= cap``,
    a uniform random sample of the stream beyond that (deterministic
    seeded RNG so snapshots are reproducible under a fixed arrival
    order). ``sampled`` in the snapshot says which regime you're in.
    """

    def __init__(self, cap: int = DEFAULT_RESERVOIR, seed: int = 0) -> None:
        if cap < 1:
            raise ValueError("Histogram cap must be >= 1")
        self.cap = int(cap)
        self._lock = threading.Lock()
        self._rng = random.Random(seed)
        self._values: List[float] = []
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            if len(self._values) < self.cap:
                self._values.append(v)
            else:
                j = self._rng.randrange(self.count)
                if j < self.cap:
                    self._values[j] = v

    def observe_many(self, values: Sequence[float]) -> None:
        for v in values:
            self.observe(v)

    @property
    def sampled(self) -> bool:
        """True once percentiles are reservoir-sampled rather than exact."""
        with self._lock:
            return self.count > self.cap

    def values(self) -> List[float]:
        """A copy of the reservoir (NOT the full stream once sampled)."""
        with self._lock:
            return list(self._values)

    def percentile(self, q: float) -> float:
        return percentile(self.values(), q)

    def snapshot(self) -> dict:
        with self._lock:
            vals = list(self._values)
            count, total = self.count, self.sum
            vmin, vmax = self.min, self.max
        return {
            "count": count,
            "sum": total,
            "min": vmin,
            "max": vmax,
            "mean": (total / count) if count else 0.0,
            "p50": percentile(vals, 50),
            "p90": percentile(vals, 90),
            "p99": percentile(vals, 99),
            "reservoir": len(vals),
            "cap": self.cap,
            "sampled": count > self.cap,
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create registry of named instruments + lazy collectors.

    Collectors run at :meth:`snapshot` time and publish derived state
    (e.g. the render-cache registry's hit/miss tables) into the registry,
    so surfaces that already keep their own counters don't need a write
    on every event — they're scraped, Prometheus-style.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, Tuple[str, Any]] = {}
        self._collectors: Dict[str, Callable[["MetricsRegistry"], None]] = {}

    def _get(self, kind: str, name: str, factory: Callable[[], Any]):
        with self._lock:
            entry = self._instruments.get(name)
            if entry is None:
                entry = (kind, factory())
                self._instruments[name] = entry
            elif entry[0] != kind:
                raise TypeError(
                    f"metric {name!r} already registered as {entry[0]}, "
                    f"requested {kind}")
            return entry[1]

    def counter(self, name: str) -> Counter:
        return self._get("counter", name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get("gauge", name, Gauge)

    def histogram(self, name: str, cap: int = DEFAULT_RESERVOIR) -> Histogram:
        return self._get("histogram", name, lambda: Histogram(cap=cap))

    def drop(self, prefix: str) -> int:
        """Remove every instrument whose name starts with ``prefix`` —
        lifecycle hygiene for per-handle gauges (``Renderer.close()``)."""
        with self._lock:
            stale = [n for n in self._instruments if n.startswith(prefix)]
            for n in stale:
                del self._instruments[n]
            return len(stale)

    # -- collectors -----------------------------------------------------------

    def register_collector(self, name: str,
                           fn: Callable[["MetricsRegistry"], None]) -> None:
        with self._lock:
            self._collectors[name] = fn

    def unregister_collector(self, name: str) -> None:
        with self._lock:
            self._collectors.pop(name, None)

    def _run_collectors(self) -> None:
        with self._lock:
            collectors = list(self._collectors.values())
        for fn in collectors:
            fn(self)

    # -- export ---------------------------------------------------------------

    def snapshot(self) -> dict:
        """Schema-versioned dump: ``{schema, time_s, counters, gauges,
        histograms}`` with plain-JSON values throughout."""
        self._run_collectors()
        with self._lock:
            items = sorted(self._instruments.items())
        counters: Dict[str, int] = {}
        gauges: Dict[str, float] = {}
        histograms: Dict[str, dict] = {}
        for name, (kind, inst) in items:
            if kind == "counter":
                counters[name] = inst.value
            elif kind == "gauge":
                gauges[name] = inst.value
            else:
                histograms[name] = inst.snapshot()
        return {
            "schema": SCHEMA,
            "time_s": time.time(),
            "counters": counters,
            "gauges": gauges,
            "histograms": histograms,
        }

    def prometheus_text(self) -> str:
        """Prometheus exposition-format dump of the same snapshot."""
        snap = self.snapshot()
        lines: List[str] = []
        for name, v in snap["counters"].items():
            n = _prom_name(name)
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {v}")
        for name, v in snap["gauges"].items():
            n = _prom_name(name)
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {_prom_value(v)}")
        for name, h in snap["histograms"].items():
            n = _prom_name(name)
            lines.append(f"# TYPE {n} summary")
            for q in (50, 90, 99):
                lines.append(
                    f'{n}{{quantile="0.{q}"}} {_prom_value(h[f"p{q}"])}')
            lines.append(f"{n}_sum {_prom_value(h['sum'])}")
            lines.append(f"{n}_count {h['count']}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Drop every instrument and collector (tests)."""
        with self._lock:
            self._instruments.clear()
            self._collectors.clear()


def _prom_name(name: str) -> str:
    n = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    return n if not n[:1].isdigit() else "_" + n


def _prom_value(v: float) -> str:
    return repr(float(v))


# -- process-wide registry ----------------------------------------------------

_global_lock = threading.Lock()
_global: Optional[MetricsRegistry] = None


def get_registry() -> MetricsRegistry:
    """The process-wide registry every stats surface publishes into."""
    global _global
    with _global_lock:
        if _global is None:
            _global = MetricsRegistry()
        return _global
