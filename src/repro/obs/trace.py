"""Thread-safe span tracer with Chrome trace-event export (DESIGN.md §14).

Spans are COMPLETE events: the caller measures ``[t0, t1]`` on the shared
monotonic clock and hands the finished interval to :meth:`Tracer.complete`
(or lets the :meth:`Tracer.span` context manager / :func:`trace_span`
decorator do it). Events land in a bounded ring buffer — a long-lived
server never grows; the oldest spans fall off and ``dropped`` counts them.

The export format is the Chrome trace-event JSON object form
(``{"traceEvents": [...]}``), loadable in Perfetto / chrome://tracing:

  * ``"ph": "X"`` complete events with ``ts``/``dur`` in microseconds
    relative to the tracer's origin, ``pid`` = this process,
    ``tid`` = the recording thread (or a synthetic lane such as one row
    per serving request);
  * ``"ph": "M"`` metadata events naming the process and every tid.

Clock: ``time.monotonic`` — the SAME clock the serving tier stamps
requests with (``RequestQueue``/``RenderServer`` defaults), so request
lifecycle stamps and stage spans line up on one timeline without any
cross-clock alignment.

:func:`validate_chrome_trace` is the single schema checker shared by the
test suite and the CI validator (``scripts/validate_trace.py``): every
event carries name/ph/ts/dur/pid/tid, and within each (pid, tid) lane the
X events must nest like a call stack (touching siblings allowed, partial
overlap is a violation).
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

SCHEMA = "repro.trace/v1"
_ENV = "REPRO_TRACE"

# Partial-overlap tolerance for the nesting check, in microseconds. Spans on
# one lane come from sequential code on one clock, so true siblings share
# boundary timestamps exactly; the epsilon only absorbs float64->float
# round-trips through JSON.
_NEST_EPS_US = 0.01


def trace_env_enabled() -> bool:
    """True when ``REPRO_TRACE`` is set to anything but ''/0/false/off."""
    return os.environ.get(_ENV, "").strip().lower() not in ("", "0", "false", "off")


@dataclass(frozen=True)
class SpanEvent:
    """One finished span. Times are raw clock readings (seconds); the
    Chrome export rebases them onto the tracer origin."""

    name: str
    t0: float
    t1: float
    tid: int
    category: str = ""
    args: Optional[Dict[str, Any]] = field(default=None)

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Bounded, thread-safe recorder of :class:`SpanEvent`.

    ``enabled`` gates the ambient helpers (:meth:`span`, the decorator,
    serving lifecycle spans): when off they cost one predicate and record
    nothing. :meth:`complete` with ``force=True`` records regardless —
    the timed-stage engine path uses it because ``RenderConfig.timing``
    IS the opt-in there; asking twice would drop spans on the floor.
    """

    def __init__(self, capacity: int = 65536,
                 clock: Callable[[], float] = time.monotonic,
                 enabled: Optional[bool] = None):
        self.clock = clock
        self.capacity = int(capacity)
        self._events: "deque[SpanEvent]" = deque(maxlen=self.capacity)
        self._dropped = 0
        self._lock = threading.Lock()
        self._enabled = trace_env_enabled() if enabled is None else bool(enabled)
        self._origin = clock()
        # tid registry: stable small ints per thread / synthetic lane, plus
        # display names for the metadata events.
        self._tids: Dict[Any, int] = {}
        self._tid_names: Dict[int, str] = {}

    # -- enable/disable -------------------------------------------------------

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    # -- tid registry ---------------------------------------------------------

    def _tid_for(self, key: Any, name: str) -> int:
        with self._lock:
            tid = self._tids.get(key)
            if tid is None:
                tid = len(self._tids)
                self._tids[key] = tid
                self._tid_names[tid] = name
            return tid

    def current_tid(self) -> int:
        """tid of the calling thread (registered with its thread name)."""
        t = threading.current_thread()
        return self._tid_for(("thread", t.ident), t.name)

    def lane_tid(self, key: Any, name: Optional[str] = None) -> int:
        """A synthetic lane — e.g. one trace row per serving request — so
        concurrent lifecycles don't interleave on a real thread's row."""
        return self._tid_for(("lane", key), name if name is not None else str(key))

    # -- recording ------------------------------------------------------------

    def complete(self, name: str, t0: float, t1: float, *,
                 category: str = "", args: Optional[Dict[str, Any]] = None,
                 tid: Optional[int] = None, force: bool = False) -> None:
        """Record a finished ``[t0, t1]`` span (clock readings in seconds)."""
        if not (self._enabled or force):
            return
        ev = SpanEvent(name=name, t0=float(t0), t1=float(t1),
                       tid=self.current_tid() if tid is None else tid,
                       category=category, args=args)
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self._dropped += 1
            self._events.append(ev)

    @contextmanager
    def span(self, name: str, *, category: str = "",
             args: Optional[Dict[str, Any]] = None, tid: Optional[int] = None):
        """Context manager recording the enclosed wall interval."""
        if not self._enabled:
            yield
            return
        t0 = self.clock()
        try:
            yield
        finally:
            self.complete(name, t0, self.clock(), category=category,
                          args=args, tid=tid)

    # -- introspection --------------------------------------------------------

    def events(self) -> List[SpanEvent]:
        with self._lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0

    # -- export ---------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """The ring as a Chrome trace-event JSON document (object form)."""
        pid = os.getpid()
        with self._lock:
            events = list(self._events)
            tid_names = dict(self._tid_names)
        out: List[dict] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "repro"},
        }]
        for tid, name in sorted(tid_names.items()):
            out.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "args": {"name": name}})
        for ev in events:
            rec = {
                "name": ev.name,
                "ph": "X",
                "cat": ev.category or "span",
                "ts": (ev.t0 - self._origin) * 1e6,
                "dur": max(0.0, ev.t1 - ev.t0) * 1e6,
                "pid": pid,
                "tid": ev.tid,
            }
            if ev.args:
                rec["args"] = dict(ev.args)
            out.append(rec)
        return {
            "schema": SCHEMA,
            "displayTimeUnit": "ms",
            "traceEvents": out,
            "dropped": self._dropped,
        }

    def write_chrome_trace(self, path: str) -> None:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)


# Stamp-pair -> span-name table for the serving request lifecycle
# (serving/server.py, engine/handle.py): consecutive phases share boundary
# stamps, so the spans tile the request lane without overlap.
REQUEST_PHASES = (
    ("enqueue", "batch_form", "request/queue"),
    ("batch_form", "dispatch", "request/batch_wait"),
    ("dispatch", "device_done", "request/device"),
    ("device_done", "resolve", "request/resolve"),
)


def emit_request_spans(tracer: Tracer, request_id, stamps: Dict[str, float],
                       *, args: Optional[Dict[str, Any]] = None) -> None:
    """Emit the standard request-lifecycle spans onto a per-request lane.

    Each request gets its OWN synthetic tid: concurrent lifecycles on a
    shared lane would partially overlap and break the per-tid nesting
    contract the validator enforces. Missing stamps (e.g. a request that
    skipped the queue) just skip their phase span; an enclosing
    ``request`` span covers enqueue -> resolve when both exist.
    """
    if not tracer.enabled:
        return
    tid = tracer.lane_tid(("request", request_id), f"request {request_id}")
    ev_args = dict(args or {})
    ev_args["request_id"] = request_id
    t0, t_end = stamps.get("enqueue"), stamps.get("resolve")
    if t0 is not None and t_end is not None and t_end >= t0:
        tracer.complete("request", t0, t_end, tid=tid, category="request",
                        args=ev_args)
    for a, b, name in REQUEST_PHASES:
        ta, tb = stamps.get(a), stamps.get(b)
        if ta is not None and tb is not None and tb >= ta:
            tracer.complete(name, ta, tb, tid=tid, category="request",
                            args=ev_args)


# -- validation (shared by tests + scripts/validate_trace.py) -----------------


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema + nesting check; returns a list of violations (empty = valid).

    Checks: the document is the object form with a ``traceEvents`` list;
    every event has name/ph/pid/tid; every ``"X"`` event has numeric
    ``ts``/``dur >= 0``; and per (pid, tid) lane the X events nest like a
    call stack — a span may share boundaries with a sibling but must not
    PARTIALLY overlap an enclosing span.
    """
    errs: List[str] = []
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        return ["document is not an object with a traceEvents list"]
    lanes: Dict[Tuple[Any, Any], List[Tuple[float, float, str]]] = {}
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                errs.append(f"event {i}: missing {key!r}")
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            errs.append(f"event {i}: unsupported ph {ph!r}")
            continue
        if ph == "M":
            continue
        ts, dur = ev.get("ts"), ev.get("dur")
        if not isinstance(ts, (int, float)) or not isinstance(dur, (int, float)):
            errs.append(f"event {i}: X event needs numeric ts/dur")
            continue
        if dur < 0:
            errs.append(f"event {i} ({ev.get('name')}): negative dur")
            continue
        lanes.setdefault((ev.get("pid"), ev.get("tid")), []).append(
            (float(ts), float(dur), str(ev.get("name"))))
    for (pid, tid), spans in lanes.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: List[Tuple[float, str]] = []  # (end, name)
        for ts, dur, name in spans:
            end = ts + dur
            while stack and ts >= stack[-1][0] - _NEST_EPS_US:
                stack.pop()
            if stack and end > stack[-1][0] + _NEST_EPS_US:
                errs.append(
                    f"tid {tid}: span {name!r} [{ts:.1f}, {end:.1f}]us "
                    f"partially overlaps enclosing {stack[-1][1]!r} "
                    f"(ends {stack[-1][0]:.1f}us)")
            stack.append((end, name))
    return errs


# -- process-wide tracer + ambient helpers ------------------------------------

_global_lock = threading.Lock()
_global: Optional[Tracer] = None


def get_tracer() -> Tracer:
    """The process-wide tracer (created lazily; enabled iff ``REPRO_TRACE``
    is set, until someone calls ``.enable()``/``.disable()``)."""
    global _global
    with _global_lock:
        if _global is None:
            _global = Tracer()
        return _global


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Swap the process-wide tracer (tests); returns the previous one."""
    global _global
    with _global_lock:
        prev, _global = _global, tracer
        return prev


@contextmanager
def span(name: str, *, category: str = "",
         args: Optional[Dict[str, Any]] = None, tid: Optional[int] = None):
    """``with obs.span("phase"):`` on the process-wide tracer."""
    with get_tracer().span(name, category=category, args=args, tid=tid):
        yield


def trace_span(name: Optional[str] = None, *, category: str = ""):
    """Decorator recording one span per call on the process-wide tracer.

    The tracer is resolved at CALL time, so decorating at import does not
    freeze an early (possibly disabled) tracer instance.
    """
    def deco(fn):
        label = name if name is not None else fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            tracer = get_tracer()
            if not tracer.enabled:
                return fn(*a, **kw)
            with tracer.span(label, category=category):
                return fn(*a, **kw)
        return wrapper
    return deco
