"""Observability layer: tracing spans + metrics registry (DESIGN.md §14).

Pure Python — importing ``repro.obs`` (or any submodule) must NOT import
jax, mirroring the serving-scheduler guarantee (tests/test_obs.py keeps
this honest with a subprocess guard). The jax-facing integration lives in
the layers that already import jax (``core.stages.TimedBackend``,
``engine.handle``, ``serving.server``); this package only records what
they report.

Two halves:

  * ``repro.obs.trace`` — a thread-safe :class:`Tracer` ring buffer of
    complete spans with Chrome trace-event JSON export
    (Perfetto-loadable) and a shared :func:`validate_chrome_trace` used
    by both the test suite and ``scripts/validate_trace.py``.
  * ``repro.obs.metrics`` — a :class:`MetricsRegistry` of named
    counters/gauges/reservoir histograms behind every stats surface
    (``ServingStats``, ``Renderer.stats()``, the render-cache registry,
    the autotune cache), exported as a schema-versioned snapshot dict or
    Prometheus text.
"""
from repro.obs.trace import (
    REQUEST_PHASES,
    SpanEvent,
    emit_request_spans,
    Tracer,
    get_tracer,
    set_tracer,
    span,
    trace_env_enabled,
    trace_span,
    validate_chrome_trace,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    percentile,
)

__all__ = [
    "REQUEST_PHASES",
    "SpanEvent",
    "emit_request_spans",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "span",
    "trace_env_enabled",
    "trace_span",
    "validate_chrome_trace",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "percentile",
]
