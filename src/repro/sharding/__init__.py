"""Sharding policies (logical-axis -> mesh-axis rules) and the canonical
scene-sharded layout.

Lazy re-exports: ``policies`` pulls in the LM model configs and ``scene``
pulls in the render core — importing ``repro.sharding`` must stay free of
both so either side can depend on this package without importing the other.
"""

_LAZY = {
    "activation_rules": "repro.sharding.policies",
    "make_constrain": "repro.sharding.policies",
    "param_rules": "repro.sharding.policies",
    "camera_batch_pspec": "repro.sharding.policies",
    "data_extent": "repro.sharding.policies",
    "render_replicated_pspec": "repro.sharding.policies",
    "scene_shard_pspec": "repro.sharding.policies",
    "ShardedScene": "repro.sharding.scene",
    "shard_scene": "repro.sharding.scene",
    "shard_scene_host": "repro.sharding.scene",
    "scene_flat": "repro.sharding.scene",
    "unshard_scene": "repro.sharding.scene",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
