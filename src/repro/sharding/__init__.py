from repro.sharding.policies import (
    activation_rules,
    make_constrain,
    param_rules,
)

__all__ = ["activation_rules", "make_constrain", "param_rules"]
