"""Canonical padded/sharded layout of the Gaussian axis (DESIGN.md §10).

``ShardedScene`` is THE layout every scene-sharded entry point agrees on:
the Gaussian axis is padded up to a multiple of the shard count and reshaped
to a leading ``(D, N_pad // D)`` shard axis, gaussian-contiguous (shard ``d``
holds global gaussians ``[d * shard_size, (d + 1) * shard_size)``). Contiguity
is load-bearing: the engine's stable cross-shard merge
(``core/grouping.py::merge_bin_tables``) reconstructs the replicated
(depth, insertion-order) tie-break from *shard-major* concatenation order,
which equals global gaussian order only for this layout.

Padding rows are real (finite, NaN-free) gaussians that the projection stage
culls: opacity logit ``PAD_OPACITY`` puts their alpha far below the 1/255
visibility cutoff, so ``Projected.valid`` is False and every counter
(n_visible, candidate tests, pairs) sees exactly the unpadded scene.

The partition spec that lays the shard axis over a mesh lives with the other
policies (``sharding/policies.py::scene_shard_pspec``); this module is pure
layout so ``core/pipeline.py`` can depend on it without touching mesh or
model code.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gaussians import GaussianScene
from repro.utils import cdiv

# Opacity logit for padding rows: sigmoid(-30) ~ 9e-14 << 1/255, so padded
# gaussians fail the visible_alpha cull and never reach identification.
PAD_OPACITY = -30.0


@dataclasses.dataclass
class ShardedScene:
    """A GaussianScene in the canonical padded/sharded layout.

    ``shards`` holds the ordinary scene arrays with a leading ``(D, Ns)``
    shard axis; ``num_real`` is the unpadded gaussian count (static pytree
    metadata, so it survives jit/vmap). Constructed by ``shard_scene``.
    """

    shards: GaussianScene   # every field with leading (D, Ns) axes
    num_real: int           # static: gaussians before padding

    @property
    def num_shards(self) -> int:
        return self.shards.means3d.shape[0]

    @property
    def shard_size(self) -> int:
        return self.shards.means3d.shape[1]

    @property
    def num_gaussians(self) -> int:
        """Unpadded count (mirrors GaussianScene.num_gaussians)."""
        return self.num_real

    @property
    def padded_size(self) -> int:
        return self.num_shards * self.shard_size


jax.tree_util.register_dataclass(
    ShardedScene, data_fields=["shards"], meta_fields=["num_real"]
)

SceneLike = Union[GaussianScene, ShardedScene]

# Per-field padding fill. Everything but opacity pads with zeros (quat zero
# normalizes to the identity rotation under the norm guard; zero scales/means
# are finite) — the opacity logit alone guarantees the cull.
_PAD_FILL = {"opacity": PAD_OPACITY}


def shard_scene(scene: GaussianScene, num_shards: int) -> ShardedScene:
    """Pad + reshape a scene into the canonical gaussian-contiguous layout.

    Traceable (pure jnp), so ``render()`` can shard in-trace when handed a
    plain scene with ``cfg.scene_shards > 1``; callers that want the device
    placement to happen once (serving) use ``shard_scene_host`` ahead of
    time — it builds the same layout on the host, so the full padded scene
    never has to fit one device — and ``device_put`` the result with
    ``scene_shard_pspec``.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    n = scene.num_gaussians
    if n < 1:
        raise ValueError("cannot shard an empty scene")
    size = cdiv(n, num_shards)
    pad = size * num_shards - n

    def prep(name: str, x: jnp.ndarray) -> jnp.ndarray:
        if pad:
            fill = jnp.full((pad,) + x.shape[1:], _PAD_FILL.get(name, 0.0), x.dtype)
            x = jnp.concatenate([x, fill], axis=0)
        return x.reshape(num_shards, size, *x.shape[1:])

    shards = GaussianScene(
        **{
            f.name: prep(f.name, getattr(scene, f.name))
            for f in dataclasses.fields(scene)
        }
    )
    return ShardedScene(shards=shards, num_real=n)


def shard_scene_host(scene: GaussianScene, num_shards: int) -> ShardedScene:
    """``shard_scene`` on the HOST (numpy): the staging step for serving.

    Builds the identical canonical layout (pad + reshape are pure layout
    ops — bitwise-equal to the traced version) without ever allocating the
    full padded scene on a device: the returned leaves are host arrays, and
    ``device_put`` with ``scene_shard_pspec`` then transfers each shard to
    its own device. Use this ahead-of-time path for scenes near the
    per-device HBM budget; the jnp ``shard_scene`` is for in-trace use.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    n = scene.num_gaussians
    if n < 1:
        raise ValueError("cannot shard an empty scene")
    size = cdiv(n, num_shards)
    pad = size * num_shards - n

    def prep(name: str, x) -> np.ndarray:
        x = np.asarray(x)
        if pad:
            fill = np.full(
                (pad,) + x.shape[1:], _PAD_FILL.get(name, 0.0), x.dtype
            )
            x = np.concatenate([x, fill], axis=0)
        return x.reshape(num_shards, size, *x.shape[1:])

    shards = GaussianScene(
        **{
            f.name: prep(f.name, getattr(scene, f.name))
            for f in dataclasses.fields(scene)
        }
    )
    return ShardedScene(shards=shards, num_real=n)


def scene_flat(scene: ShardedScene) -> GaussianScene:
    """The padded flat ``(D * Ns, ...)`` view of a sharded scene.

    ``scene_flat(shard_scene(s, d))`` equals ``s`` on the first
    ``s.num_gaussians`` rows bitwise; the tail is cull-guaranteed padding.
    """
    return jax.tree.map(
        lambda x: x.reshape(x.shape[0] * x.shape[1], *x.shape[2:]), scene.shards
    )


def unshard_scene(scene: ShardedScene) -> GaussianScene:
    """Invert ``shard_scene``: flatten and drop the padding rows."""
    flat = scene_flat(scene)
    return jax.tree.map(lambda x: x[: scene.num_real], flat)
