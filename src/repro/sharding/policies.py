"""Logical-axis -> mesh-axis sharding policies (DP/TP/EP/SP per arch).

The mesh is fixed by the deployment ((data, model) single pod, or
(pod, data, model) multi-pod; the pod axis always joins data parallelism).
What varies per architecture is WHICH logical axes map onto 'model':

  * attn_sharding='heads'     — Megatron column-parallel attention (requires
                                n_heads % model_size == 0); kv heads are
                                replicated when n_kv_heads < model_size.
  * attn_sharding='row'       — weights sharded on the input d_model axis
                                ('attn_embed'); activations replicated, XLA
                                reduces partial sums. For archs whose head
                                count does not divide the model axis.
  * attn_sharding='head_dim'  — shard inside each head (interleaved-RoPE safe);
                                beyond-paper option used in §Perf hillclimbs.
  * attn_sharding='replicated'— tiny models; attention fully replicated.
  * mlp_sharding='ff'         — column+row parallel MLP on the hidden axis.
  * experts                   — expert-parallel over 'model' (MoE archs).
  * cache_seq                 — decode KV caches shard their sequence axis on
                                'model' (sequence-parallel decode): partial
                                softmax reductions become all-reduces.

Divisibility is validated at policy-build time so misconfigurations fail
loudly before lowering.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

MeshAxes = Union[None, str, Tuple[str, ...]]


def _data_axes(mesh: Mesh) -> MeshAxes:
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def _check(cond: bool, msg: str):
    if not cond:
        raise ValueError(f"sharding policy error: {msg}")


def param_rules(cfg: ModelConfig, mesh: Mesh) -> Dict[str, MeshAxes]:
    """Rules applied to parameter logical axes."""
    m = mesh.shape["model"]
    rules: Dict[str, MeshAxes] = {
        "vocab": "model" if cfg.shard_vocab else None,
        "embed_tbl": "model",
        "attn_embed": None,
        "heads": None,
        "kv_heads": None,
        "head_dim": None,
        "ffn": None,
        "experts": None,
        "expert_ffn": None,
        "ssm_inner": None,
        "ssm_heads": None,
    }
    if cfg.shard_vocab:
        _check(cfg.vocab_padded % m == 0, f"vocab_padded {cfg.vocab_padded} % {m}")

    if cfg.attn_sharding == "heads":
        _check(cfg.n_heads % m == 0, f"{cfg.name}: n_heads {cfg.n_heads} % {m}")
        rules["heads"] = "model"
        if cfg.n_kv_heads % m == 0:
            rules["kv_heads"] = "model"
        # else: kv replicated (GQA with few kv heads) — standard Megatron GQA.
    elif cfg.attn_sharding == "row":
        _check(cfg.d_model % m == 0, f"{cfg.name}: d_model % {m}")
        rules["attn_embed"] = "model"
    elif cfg.attn_sharding == "head_dim":
        _check(cfg.head_dim % m == 0, f"{cfg.name}: head_dim {cfg.head_dim} % {m}")
        rules["head_dim"] = "model"
    elif cfg.attn_sharding != "replicated":
        raise ValueError(cfg.attn_sharding)

    if cfg.mlp_sharding == "ff" and cfg.d_ff:
        _check(cfg.d_ff % m == 0, f"{cfg.name}: d_ff {cfg.d_ff} % {m}")
        rules["ffn"] = "model"

    if cfg.n_experts:
        _check(cfg.n_experts % m == 0, f"{cfg.name}: experts {cfg.n_experts} % {m}")
        rules["experts"] = "model"

    if cfg.family in ("ssm", "hybrid"):
        _check(cfg.d_inner % m == 0, f"{cfg.name}: d_inner % {m}")
        _check(cfg.n_ssm_heads % m == 0, f"{cfg.name}: ssm heads % {m}")
        rules["ssm_inner"] = "model"
        rules["ssm_heads"] = "model"
    return rules


def activation_rules(cfg: ModelConfig, mesh: Mesh) -> Dict[str, MeshAxes]:
    """Rules applied by the in-model with_sharding_constraint calls."""
    rules = dict(param_rules(cfg, mesh))
    rules["batch"] = _data_axes(mesh)
    rules["cache_batch"] = _data_axes(mesh)
    rules["cache_seq"] = "model"   # sequence-parallel decode cache
    # Sequence parallelism on the residual stream: activations between
    # attention/MLP segments are sharded over 'model' on the seq axis, which
    # divides the remat-saved per-layer stack (the dominant training-memory
    # term) by the model-axis size. Attention/SSD blocks re-gather the seq
    # axis via their own head-sharded constraints.
    rules["act_seq"] = "model"
    return rules


def make_constrain(cfg: ModelConfig, mesh: Optional[Mesh], batch_shardable: bool = True):
    """Returns constrain(x, logical_axes) -> x with a sharding constraint.

    With mesh=None (single-device smoke tests) it is the identity.
    batch_shardable=False replicates the batch axis (e.g. long_500k decode
    with global_batch=1, which cannot be split over the data axes).
    """
    if mesh is None:
        return lambda x, axes: x
    rules = activation_rules(cfg, mesh)
    if not batch_shardable:
        rules["batch"] = None
        rules["cache_batch"] = None

    def constrain(x, axes):
        spec = P(*[rules.get(a) if a is not None else None for a in axes])
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def camera_batch_pspec(mesh: Mesh) -> P:
    """PartitionSpec for the camera-batch axis of the render serving tier.

    The batch axis lays over the mesh's data axes (camera renders are
    independent); the background is replicated via ``render_replicated_pspec``
    and the scene is either replicated or gaussian-sharded over 'model'
    (``scene_shard_pspec``). Batch sizes must be padded to the DATA-axis
    extent first (``data_extent``; serving/bucketing.py pad helpers) — on a
    2-D (data, model) render mesh the camera axis splits over 'data' only.
    """
    return P(_data_axes(mesh))


def data_extent(mesh: Mesh) -> int:
    """Number of camera lanes a render mesh provides: the product of its
    data-axis sizes (== mesh.size on a pure-DP 1-D render mesh)."""
    axes = _data_axes(mesh)
    axes = (axes,) if isinstance(axes, str) else axes
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def render_replicated_pspec() -> P:
    """Fully-replicated spec for the background (and for scenes small enough
    to replicate): every device rasterizes its camera shard against the whole
    operand."""
    return P()


def scene_shard_pspec(mesh: Mesh) -> P:
    """Spec for a ``ShardedScene`` (sharding/scene.py): the leading shard
    axis D lays over the mesh's 'model' axis, every other axis replicated —
    each device holds 1/D of the Gaussian set (DESIGN.md §10). On a mesh
    without a 'model' axis the shard axis stays logical (unpartitioned),
    which is how single-device tests exercise the sharded engine."""
    if "model" in mesh.axis_names:
        return P("model")
    return P()


def feature_shard_pspec(mesh: Mesh) -> P:
    """Spec for per-camera projected features in the per-shard layout
    (``core/projection.py::ShardedProjected``, DESIGN.md §12): the leading
    shard axis lays over 'model' exactly like the persistent scene
    parameters, so each device materializes only its own N/D feature rows.
    GSPMD propagates this from the scene's input sharding through the
    per-shard frontend; the explicit spec exists for pinning it at jit
    boundaries (out_shardings in tests/benchmarks) and for the budget
    model's 1/D per-camera feature term. Without a 'model' axis the shard
    axis stays logical, mirroring ``scene_shard_pspec``."""
    return scene_shard_pspec(mesh)


def batch_specs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, P]:
    """PartitionSpecs for input batches."""
    dp = _data_axes(mesh)
    return {
        "tokens": P(dp, None),
        "labels": P(dp, None),
        "loss_mask": P(dp, None),
        "mask": P(dp, None),
        "patch_embeds": P(dp, None, None),
        "frames": P(dp, None, None),
    }
