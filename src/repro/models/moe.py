"""Top-k mixture-of-experts with GShard-style grouped dispatch (EP-sharded).

Dispatch is per GROUP = batch row: the position-in-expert cumsum runs over
each row's S*k assignments locally (no cross-shard scan), and the dispatched
block (B, E, C, D) shards as batch->data, experts->model — the expert
all-to-all happens exactly once, at the (B, E) resharding boundary. Capacity
overflow drops tokens per group (standard GShard semantics); the combine
re-weights with the surviving assignments' router probabilities.

This mirrors the GS-TG binning idiom (DESIGN.md §5): static-capacity bins
built from cumsum positions instead of dynamic lists.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def router_topk(
    logits: jnp.ndarray,   # (..., E) float32
    k: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (weights (..., k), ids (..., k)); renormalized over top-k."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9
    )
    return weights, ids


def load_balance_loss(logits: jnp.ndarray, ids: jnp.ndarray, n_experts: int):
    """Switch-style aux loss: E * sum_e f_e * p_e. Expert counts use a
    scatter-add (O(T*k)), never a (T, E) one-hot — at 1M tokens x 384
    experts that one-hot is terabytes."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p_mean = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    counts = jnp.zeros((n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(jnp.sum(counts), 1.0)
    return n_experts * jnp.sum(f * p_mean)


def moe_ffn(
    p: dict,            # {'router' (D,E), 'w1' (E,D,F), 'w3' (E,D,F), 'w2' (E,F,D)}
    x: jnp.ndarray,     # (B, S, D)
    cfg,
    constrain,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux_loss ())."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    A = S * k  # assignments per group (= per batch row)

    logits = jnp.einsum(
        "bsd,de->bse", x, p["router"], preferred_element_type=jnp.float32
    )
    weights, ids = router_topk(logits, k)            # (B, S, k)
    aux = load_balance_loss(logits, ids, E)

    capacity = max(int(S * k / E * cfg.capacity_factor), min(8, S))

    # --- position-in-expert within each group, SORT-based (the same static
    # binning idiom as GS-TG's group identification): never materializes a
    # (A, E) one-hot. Stable argsort by expert id gives contiguous expert
    # segments; position = rank within segment. O(A log A) per group. ---
    eid = ids.reshape(B, A)                          # (B, A)

    def positions_one_group(e):
        order = jnp.argsort(e, stable=True)          # (A,)
        e_sorted = e[order]
        seg_start = jnp.searchsorted(e_sorted, jnp.arange(E, dtype=e.dtype))
        pos_sorted = jnp.arange(A, dtype=jnp.int32) - seg_start[e_sorted]
        return jnp.zeros((A,), jnp.int32).at[order].set(pos_sorted)

    pos = jax.vmap(positions_one_group)(eid)         # (B, A)
    keep = (pos >= 0) & (pos < capacity)
    slot = jnp.where(keep, pos, capacity)            # capacity slot = trash

    # --- dispatch: scatter tokens into (B, E, C+1, D), local per group.
    # vmap over the group axis keeps the scatter's batching dims explicit —
    # GSPMD partitions batched scatters on the batch axis; a flattened-index
    # scatter would be replicated (observed: 280 GiB/device at kimi scale).
    # custom_vjp (§Perf iteration 3): the natural take->scatter backward is
    # gather(dxe)[A, D] pulled across the expert/model axis (the k-amplified
    # pattern again); the custom backward scatter-adds slot gradients to
    # token space per expert shard + one all-reduce, mirroring the combine.
    # NOTE: every jnp constant (tok) is created INSIDE the custom_vjp rule
    # bodies — a constant captured by closure leaks as a tracer when the
    # custom_vjp lives inside a checkpointed scan body.
    xdt = x.dtype  # static: closures below must not capture the tracer x

    def _tok():
        return jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)  # (A,)

    @jax.custom_vjp
    def _dispatch(xx, eidf, slotf):
        tok = _tok()

        def dispatch_one(xg, eidg, slotg):
            return jnp.zeros((E, capacity + 1, D), xdt).at[eidg, slotg].set(
                xg[tok], mode="drop"
            )

        return jax.vmap(dispatch_one)(
            xx, eidf.astype(jnp.int32), slotf.astype(jnp.int32)
        )

    def _dispatch_fwd(xx, eidf, slotf):
        return _dispatch(xx, eidf, slotf), (eidf, slotf)

    def _dispatch_bwd(res, dxe):
        eidf, slotf = res
        tok = _tok()

        def one(dxe_g, eidg, slotg):
            tok_slot = jnp.full((E, capacity + 1), S, jnp.int32).at[
                eidg, slotg
            ].set(tok, mode="drop")
            return (
                jnp.zeros((S + 1, D), dxe_g.dtype)
                .at[tok_slot.reshape(-1)]
                .add(dxe_g.reshape(-1, D), mode="drop")[:S]
            )

        dx = jax.vmap(one)(dxe, eidf.astype(jnp.int32), slotf.astype(jnp.int32))
        return dx, jnp.zeros_like(eidf), jnp.zeros_like(slotf)

    _dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)

    xe = _dispatch(x, eid.astype(jnp.float32), slot.astype(jnp.float32))
    xe = xe[:, :, :capacity]
    # The expert all-to-all: batch stays on data, experts land on model.
    xe = constrain(xe, ("batch", "experts", None, None))

    # --- expert computation (SwiGLU), batched over (B, E) ---
    a = jnp.einsum("becd,edf->becf", xe, p["w1"])
    silu = a * jax.nn.sigmoid(a.astype(jnp.float32)).astype(a.dtype)
    h = silu * jnp.einsum("becd,edf->becf", xe, p["w3"])
    ye = jnp.einsum("becf,efd->becd", h, p["w2"])
    ye = constrain(ye, ("batch", "experts", None, None))

    # --- combine: weight slots IN EXPERT SPACE and scatter-add back to
    # (B, S, D). A gather-based combine materializes (B, S*k, D) pulled
    # across the expert/model axis — k-times the token bytes (measured 42
    # GiB/device of all-gathers at kimi scale, §Perf iteration 2). Here each
    # model shard scatter-adds only its own experts' contributions, and the
    # cross-expert sum becomes ONE all-reduce of the (B, S, D) output. ---
    wts = jnp.where(keep, weights.reshape(B, A), 0.0).astype(x.dtype)

    def combine_one(yeg, eidg, slotg, wg):
        # per-slot combine weight + destination token, scattered once
        tok = _tok()
        wslot = jnp.zeros((E, capacity + 1), xdt).at[eidg, slotg].set(
            wg, mode="drop"
        )[:, :capacity]
        tok_slot = jnp.full((E, capacity + 1), S, jnp.int32).at[
            eidg, slotg
        ].set(tok, mode="drop")[:, :capacity]
        contrib = yeg * wslot[:, :, None]            # (E, C, D)
        return (
            jnp.zeros((S + 1, D), xdt)
            .at[tok_slot.reshape(-1)]
            .add(contrib.reshape(-1, D), mode="drop")[:S]
        )

    out = jax.vmap(combine_one)(ye, eid, slot, wts)
    return out, aux
