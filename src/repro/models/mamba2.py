"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), chunked form.

The SSD recurrence per head (state N, head dim P):
    h_t = a_t * h_{t-1} + b_t^T (dt_t * x_t)      h in R^{N x P}
    y_t = c_t h_t + D * x_t
with a_t = exp(-dt_t * exp(A_log)) scalar per head, b/c shared across heads
(n_groups=1). Computed chunk-parallel: within a chunk the quadratic
'attention-like' term C_i (prod a) B_j^T masks to lower-triangular; across
chunks a small recurrent scan carries the (H, N, P) state. This is the
standard minimal SSD algorithm, vectorized for the MXU (einsums over chunks).

Decode is the O(1) recurrent update on a persistent (B, H, N, P) state plus a
depthwise-conv ring buffer.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _segsum(log_a: jnp.ndarray):
    """log_a (..., L) -> (..., L, L) lower-tri cumulative segment sums:
    out[i, j] = sum_{k=j+1..i} log_a_k for i >= j, -inf otherwise."""
    L = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]   # sum_{j+1..i} when i>=j
    ii = jnp.arange(L)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,      # (B, S, H, P) inputs (dt applied by caller)
    log_a: jnp.ndarray,  # (B, S, H) per-step log decay (negative)
    b: jnp.ndarray,      # (B, S, N)  input projections (n_groups=1)
    c: jnp.ndarray,      # (B, S, N)  output projections
    chunk: int,
) -> jnp.ndarray:
    """Returns y (B, S, H, P)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    L = chunk
    pad = (-S) % L
    if pad:
        # zero-padded tail: b=0 adds nothing to the state, log_a=0 (decay 1)
        # carries it unchanged; padded outputs are sliced off below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L

    xr = x.reshape(B, nc, L, H, P)
    ar = log_a.reshape(B, nc, L, H)
    br = b.reshape(B, nc, L, N)
    cr = c.reshape(B, nc, L, N)

    # --- intra-chunk (quadratic) term ---
    # bf16 operands + f32 accumulation (preferred_element_type); the decay
    # masks stay f32 (exp of log sums), downcast before the MXU contractions.
    seg = _segsum(ar.transpose(0, 1, 3, 2))               # (B,nc,H,L,L)
    decay = jnp.exp(seg)
    scores = jnp.einsum(
        "bcln,bcmn->bclm", cr, br, preferred_element_type=jnp.float32
    )                                                     # (B,nc,L,L)
    mat = (scores[:, :, None, :, :] * decay).astype(x.dtype)  # (B,nc,H,L,L)
    y_intra = jnp.einsum(
        "bchlm,bcmhp->bclhp", mat, xr, preferred_element_type=jnp.float32
    )

    # --- chunk states: sum_j (prod_{j+1..L} a) b_j x_j ---
    a_cum = jnp.cumsum(ar, axis=2)                        # (B,nc,L,H)
    a_tail = a_cum[:, :, -1:, :] - a_cum                  # decay to chunk end
    w = jnp.exp(a_tail).astype(x.dtype)                   # (B,nc,L,H)
    states = jnp.einsum(
        "bcln,bclh,bclhp->bchnp", br, w, xr,
        preferred_element_type=jnp.float32,
    )                                                     # (B,nc,H,N,P)

    # --- inter-chunk recurrence over nc (small sequential scan) ---
    a_chunk = a_cum[:, :, -1, :]                          # (B,nc,H) total decay

    def scan_fn(h_prev, inp):
        st, ac = inp                                      # (B,H,N,P), (B,H)
        h_new = h_prev * jnp.exp(ac)[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((B, H, N, P), jnp.float32)  # matches f32-accumulated states
    _, h_before = jax.lax.scan(
        scan_fn,
        h0,
        (states.transpose(1, 0, 2, 3, 4), a_chunk.transpose(1, 0, 2)),
    )
    h_before = h_before.transpose(1, 0, 2, 3, 4)          # (B,nc,H,N,P)

    # --- inter-chunk contribution: y += (c_t * decay_to_chunk_start) h_prev
    w_in = jnp.exp(a_cum).astype(x.dtype)                 # decay from chunk start
    y_inter = jnp.einsum(
        "bcln,bclh,bchnp->bclhp", cr, w_in, h_before.astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    y = (y_intra + y_inter).reshape(B, S, H, P)
    if pad:
        y = y[:, : S - pad]
    return y


def mamba_forward(
    p: dict,
    x: jnp.ndarray,     # (B, S, D)
    cfg,
    constrain,
) -> jnp.ndarray:
    """Full Mamba-2 mixer block (in_proj -> conv -> SSD -> gate -> out_proj)."""
    B, S, D = x.shape
    din = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.n_ssm_heads
    P = cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xin, bc, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + 2 * N], axis=-1
    )
    # depthwise causal conv over (x, b, c)
    xbc = jnp.concatenate([xin, bc], axis=-1)             # (B,S,din+2N)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], cfg.ssm_conv)
    xin, b, c = jnp.split(xbc, [din, din + N], axis=-1)

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                     # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))          # (H,)
    log_a = dt * a[None, None, :]                         # (B,S,H)

    xh = xin.reshape(B, S, H, P)
    xh = constrain(xh, ("batch", None, "ssm_heads", None))
    xdt = xh * dt[..., None].astype(x.dtype)
    y = ssd_chunked(xdt, log_a, b, c, cfg.ssm_chunk)
    y = y + (xh * p["D"].astype(x.dtype)[None, None, :, None]).astype(jnp.float32)
    y = y.reshape(B, S, din).astype(x.dtype)

    # gated RMS norm (Mamba-2 norm-before-out_proj)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y32 = y.astype(jnp.float32)
    y = (y32 * jax.lax.rsqrt(
        jnp.mean(jnp.square(y32), axis=-1, keepdims=True) + 1e-6
    )).astype(x.dtype) * p["norm_w"]
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray, width: int):
    """Depthwise causal conv1d. x (B,S,C), w (width,C)."""
    B, S, C = x.shape
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):  # small static width (4)
        out = out + xp[:, i : i + S, :].astype(jnp.float32) * w[i][None, None, :]
    out = out + bias[None, None, :]
    return jax.nn.silu(out).astype(x.dtype)


def mamba_decode(
    p: dict,
    x: jnp.ndarray,        # (B, 1, D)
    ssm_state: jnp.ndarray,   # (B, H, N, P)
    conv_state: jnp.ndarray,  # (B, width-1, din+2N)
    cfg,
):
    """Single-token recurrent step. Returns (y, new_ssm_state, new_conv_state)."""
    B, _, D = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    width = cfg.ssm_conv

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]  # (B, E)
    z, xin, bc, dt = jnp.split(zxbcdt, [din, 2 * din, 2 * din + 2 * N], axis=-1)
    xbc = jnp.concatenate([xin, bc], axis=-1)             # (B, din+2N)

    # conv ring buffer
    hist = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,width,C)
    new_conv_state = hist[:, 1:, :]
    conv = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), w_full(p, width))
    conv = jax.nn.silu(conv + p["conv_b"][None, :].astype(jnp.float32))
    xin, b, c = jnp.split(conv.astype(x.dtype), [din, din + N], axis=-1)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)

    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])  # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt_ * a[None, :])                     # (B,H)

    xh = xin.reshape(B, H, P).astype(jnp.float32) * dt_[..., None]
    upd = jnp.einsum("bn,bhp->bhnp", b, xh)
    new_state = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", c, new_state)
    y = y + xin.reshape(B, H, P).astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, din)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), axis=-1, keepdims=True) + 1e-6)
    y = (y.astype(x.dtype) * p["norm_w"])[:, None, :]
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_state, new_conv_state


def w_full(p, width):
    return p["conv_w"].astype(jnp.float32)
