"""Single source of truth for parameter shapes + logical sharding axes.

A ParamSpec tree (nested dicts of LeafSpec) is built once per model config;
it is consumed three ways:
  * init_from_spec(spec, key)        -> real parameters (smoke tests, examples)
  * abstract_from_spec(spec)         -> ShapeDtypeStruct tree (dry-run)
  * partition_from_spec(spec, rules) -> PartitionSpec tree (pjit shardings)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    dtype: str = "bfloat16"
    init: str = "normal"              # normal | zeros | ones | small_normal
    fan_in: Optional[int] = None      # for scaled normal init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_leaf(x) -> bool:
    return isinstance(x, LeafSpec)


def init_from_spec(spec, key: jax.Array):
    leaves, treedef = jax.tree.flatten(spec, is_leaf=is_leaf)
    keys = jax.random.split(key, len(leaves))

    def mk(leaf: LeafSpec, k):
        dt = jnp.dtype(leaf.dtype)
        if leaf.init == "zeros":
            return jnp.zeros(leaf.shape, dt)
        if leaf.init == "ones":
            return jnp.ones(leaf.shape, dt)
        fan = leaf.fan_in or (leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1])
        scale = 1.0 / max(fan, 1) ** 0.5
        if leaf.init == "small_normal":
            scale *= 0.1
        return (jax.random.normal(k, leaf.shape, jnp.float32) * scale).astype(dt)

    return treedef.unflatten([mk(l, k) for l, k in zip(leaves, keys)])


def abstract_from_spec(spec):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.dtype(l.dtype)),
        spec,
        is_leaf=is_leaf,
    )


def partition_from_spec(spec, rules: Dict[str, Optional[object]]):
    """rules: logical axis name -> mesh axis (str/tuple) or None."""

    def leaf_spec(l: LeafSpec):
        return P(*[rules.get(a) if a is not None else None for a in l.axes])

    return jax.tree.map(leaf_spec, spec, is_leaf=is_leaf)


def spec_bytes(spec) -> int:
    import numpy as np

    total = 0
    for l in jax.tree.leaves(spec, is_leaf=is_leaf):
        total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total
