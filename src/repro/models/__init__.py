from repro.models.config import ModelConfig
from repro.models.lm import (
    build_param_spec,
    build_cache_spec,
    decode_step,
    forward,
    loss_fn,
)

__all__ = [
    "ModelConfig",
    "build_param_spec",
    "build_cache_spec",
    "decode_step",
    "forward",
    "loss_fn",
]
