"""Shared model layers: norms, embeddings, rotary embeddings, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6):
    """RMSNorm with f32 statistics but NO materialized f32 copy of x.

    The variance is an einsum with f32 accumulation (contraction, fuses into
    a reduce); the normalize multiply stays in the activation dtype. A plain
    x.astype(f32) here becomes the first use of every remat-saved layer
    input, and XLA then widens the whole saved activation stack to f32.
    """
    var = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    ) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None]
    return x * inv.astype(x.dtype) * weight.astype(x.dtype)


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim//2), interleaved convention.

    Interleaved (even/odd) pairing keeps each rotation pair inside a
    contiguous half-lane block, so a head_dim-sharded layout never splits a
    pair across devices (used by the 'head_dim' attention sharding policy).
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (..., S, H, hd); cos/sin (..., S, hd//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1 = x[..., :half]
    x2 = x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1).astype(
        x.dtype
    )


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray, one_hot: bool = False):
    """Token embedding lookup.

    one_hot=True uses the one-hot-matmul formulation: with a vocab-sharded
    table, gather/scatter would replicate the full table (and its f32
    gradient) on every device; the matmul contracts the sharded vocab axis
    with partial sums instead, and its transpose keeps dTable vocab-sharded.
    This is the standard TPU big-vocab embedding idiom.
    """
    if not one_hot:
        return jnp.take(table, tokens, axis=0)
    oh = jax.nn.one_hot(tokens, table.shape[0], dtype=table.dtype)
    return jnp.einsum("...v,vd->...d", oh, table)


def softmax_xent(
    logits: jnp.ndarray,      # (B, S, V) possibly vocab-sharded
    labels: jnp.ndarray,      # (B, S) int32
    mask: jnp.ndarray,        # (B, S) 0/1 valid positions
    vocab: int,               # logical (unpadded) vocab size
):
    """Stable mean cross-entropy; padded vocab tail masked out."""
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    if V > vocab:
        pad_mask = jnp.arange(V) >= vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # One-hot contraction instead of take_along_axis: a gather across the
    # vocab-sharded axis would force an all-gather of the full logits; the
    # elementwise product + reduction partitions cleanly (partial sums).
    onehot = jax.nn.one_hot(labels, V, dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
