"""GQA attention: chunked online-softmax prefill/train + KV-cache decode.

Memory discipline: full (S, S) score materialization is never allowed — the
kv axis is processed in attn_chunk-sized blocks with running (max, sum, acc)
online-softmax state (flash-attention recurrence, jax.lax.scan over blocks).
This is what makes prefill_32k / train_4k lowerable at production shapes.

Decode consumes a (B, S_cache, KV, hd) cache laid out for sequence-parallel
sharding (cache seq axis on the 'model' mesh axis): the online softmax over a
sharded kv axis reduces via XLA's partial logsumexp + all-reduce.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rope_angles

NEG_INF = -1e30


class AttnParams(NamedTuple):
    # Packed in lm.py param dicts; listed here for shape documentation only.
    pass


def _repeat_kv(x: jnp.ndarray, n_rep: int):
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd) by head-group broadcast."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd))
    return x.reshape(b, s, kv * n_rep, hd)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def chunked_attention(
    q: jnp.ndarray,             # (B, Sq, H, hd)
    k: jnp.ndarray,             # (B, Skv, H, hd)   (already GQA-expanded)
    v: jnp.ndarray,             # (B, Skv, H, hd)
    causal: bool,
    chunk: int = 512,
    q_offset: int = 0,          # absolute position of q[0] (for causal mask)
    unroll: bool = False,       # unroll kv blocks (roofline costing mode)
) -> jnp.ndarray:
    """Online-softmax attention, scanning kv blocks. O(Sq * chunk) memory.

    custom_vjp (flash-attention backward): the naive scan VJP would stack the
    f32 (m, l, acc) carries for every kv block — O(Skv/chunk) copies of the
    attention output. The flash backward saves only (q, k, v, out, m, l) and
    recomputes each block's score tile.
    """
    out, _, _ = _chunked_attention_fwd_impl(q, k, v, causal, chunk, q_offset,
                                            unroll)
    return out


def _kv_blocks(k, v, chunk):
    B, Skv, H, hd = k.shape
    n_blocks = -(-Skv // chunk)
    pad = n_blocks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blocks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    return kb, vb, n_blocks, pad


def _chunked_attention_fwd_impl(q, k, v, causal, chunk, q_offset, unroll=False):
    """Returns (out (B,Sq,H,hd), m (B,H,Sq), l (B,H,Sq))."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = hd ** -0.5
    # Mixed-precision discipline: operands stay bf16, matmuls accumulate in
    # f32 via preferred_element_type. Upcasting operands (q.astype(f32))
    # would make every backward cotangent f32 all the way into the stacked
    # weight-gradient accumulators — 2x the gradient memory.
    q = (q * scale).astype(q.dtype)

    n_blocks = -(-Skv // chunk)
    pad = n_blocks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blocks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        m, l, acc = carry                      # (B,H,Sq), (B,H,Sq), (B,H,Sq,hd)
        kc, vc, blk = xs                       # (B,chunk,H,hd) x2, ()
        kv_pos = blk * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bqhd,bkhd->bhqk", q, kc, preferred_element_type=jnp.float32
        )                                      # (B,H,Sq,chunk) f32
        mask = jnp.broadcast_to((kv_pos < Skv)[None, :], (Sq, chunk))  # pad mask
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask[None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(n_blocks)),
        unroll=n_blocks if unroll else 1,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # Downcast before leaving the attention segment: keeps the remat-saved
    # residual stream (and everything XLA stores per scan step) in bf16.
    out = out.astype(k.dtype)
    return out.transpose(0, 2, 1, 3), m, l  # out (B, Sq, H, hd)


def _chunked_attention_fwd(q, k, v, causal, chunk, q_offset, unroll):
    out, m, l = _chunked_attention_fwd_impl(q, k, v, causal, chunk, q_offset,
                                            unroll)
    return out, (q, k, v, out, m, l)


def _chunked_attention_bwd(causal, chunk, q_offset, unroll, res, dout):
    """Flash backward: recompute each block's p tile from the saved softmax
    statistics; per-block transients only."""
    q, k, v, out, m, l = res
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = hd ** -0.5
    qs = (q * scale).astype(q.dtype)

    kb, vb, n_blocks, pad = _kv_blocks(k, v, chunk)
    q_pos = q_offset + jnp.arange(Sq)
    l_safe = jnp.maximum(l, 1e-30)

    # D_i = rowsum(dout * out) (B,H,Sq) — the softmax-backward diagonal term.
    dout_t = dout.transpose(0, 2, 1, 3)            # (B,H,Sq,hd)
    out_t = out.transpose(0, 2, 1, 3)
    delta = jnp.einsum(
        "bhqd,bhqd->bhq", dout_t, out_t, preferred_element_type=jnp.float32
    )

    def body(dq_acc, xs):
        kc, vc, blk = xs
        kv_pos = blk * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bqhd,bkhd->bhqk", qs, kc, preferred_element_type=jnp.float32
        )
        mask = jnp.broadcast_to((kv_pos < Skv)[None, :], (Sq, chunk))
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask[None, None, :, :], s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / l_safe[..., None]   # normalized probs
        p16 = p.astype(v.dtype)
        dv_c = jnp.einsum(
            "bhqk,bhqd->bkhd", p16, dout_t.astype(v.dtype),
            preferred_element_type=jnp.float32,
        )
        dp = jnp.einsum(
            "bhqd,bkhd->bhqk", dout_t.astype(v.dtype), vc,
            preferred_element_type=jnp.float32,
        )
        ds = p * (dp - delta[..., None])                    # f32 tile
        ds16 = ds.astype(q.dtype)
        dq_acc = dq_acc + jnp.einsum(
            "bhqk,bkhd->bqhd", ds16, kc, preferred_element_type=jnp.float32
        )
        dk_c = jnp.einsum(
            "bhqk,bqhd->bkhd", ds16, qs, preferred_element_type=jnp.float32
        )
        return dq_acc, (dk_c.astype(k.dtype), dv_c.astype(v.dtype))

    dq0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(
        body, dq0, (kb, vb, jnp.arange(n_blocks)),
        unroll=n_blocks if unroll else 1,
    )
    dq = (dq * scale).astype(q.dtype)
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(B, n_blocks * chunk, H, hd)
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(B, n_blocks * chunk, H, hd)
    if pad:
        dk = dk[:, :Skv]
        dv = dv[:, :Skv]
    return dq, dk, dv


chunked_attention.defvjp(_chunked_attention_fwd, _chunked_attention_bwd)


def attention_forward(
    p: dict,                    # {'wq','wk','wv','wo'[,'bq','bk','bv']}
    x: jnp.ndarray,             # (B, S, D)
    cfg,
    positions: jnp.ndarray,     # (S,) absolute positions
    causal: bool,
    constrain,                  # fn(tensor, logical_axes) -> tensor
) -> jnp.ndarray:
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, KV, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, hd)
        k = k + p["bk"].reshape(KV, hd)
        v = v + p["bv"].reshape(KV, hd)

    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    q = constrain(q, ("batch", None, "heads", "head_dim"))
    k = constrain(k, ("batch", None, "kv_heads", "head_dim"))
    v = constrain(v, ("batch", None, "kv_heads", "head_dim"))

    k = _repeat_kv(k, H // KV)
    v = _repeat_kv(v, H // KV)
    out = chunked_attention(q, k, v, causal, cfg.attn_chunk, 0,
                            cfg.unroll_for_costing)
    out = constrain(out, ("batch", None, "heads", "head_dim"))
    out = out.reshape(B, S, H * hd).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def attention_decode(
    p: dict,
    x: jnp.ndarray,             # (B, 1, D) current token activations
    cache_k: jnp.ndarray,       # (B, S_max, KV, hd)
    cache_v: jnp.ndarray,
    pos,                        # () int32 current position
    cfg,
    constrain,
):
    """One decode step against a (possibly seq-sharded) KV cache.

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B, _, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    S_max = cache_k.shape[1]

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, 1, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, 1, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, 1, KV, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, hd)
        k = k + p["bk"].reshape(KV, hd)
        v = v + p["bv"].reshape(KV, hd)

    posv = jnp.asarray(pos)[None]
    cos, sin = rope_angles(posv, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0)
    )
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0)
    )
    cache_k = constrain(cache_k, ("cache_batch", "cache_seq", "kv_heads", "head_dim"))
    cache_v = constrain(cache_v, ("cache_batch", "cache_seq", "kv_heads", "head_dim"))

    # Grouped-query attention over the whole cache (seq axis may be sharded;
    # the softmax/contraction reductions then become all-reduces).
    qg = q.reshape(B, KV, H // KV, hd).astype(jnp.float32) * hd ** -0.5
    kf = cache_k.astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kf)  # (B, KV, G, S_max)
    valid = jnp.arange(S_max)[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), cache_k, cache_v
