"""Unified model configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.utils import round_up


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True              # False for encoder-only
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    moe_period: int = 1              # MoE ffn every `period` layers (1 = all)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64
    attn_period: int = 0             # hybrid: attention every `period` layers
    attn_offset: int = 0             # position of the attn layer inside period

    # --- frontends (stubs per assignment) ---
    frontend: str = "text"           # text | vision_stub | audio_stub
    n_frontend_tokens: int = 0       # patches / frames prepended to the seq

    # --- numerics / execution ---
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 512            # online-softmax KV chunk
    logical_max_seq: int = 524_288

    # --- sharding policy knobs (see sharding/policies.py) ---
    force_fsdp: Optional[bool] = None  # pin the FSDP decision (calibration)
    unroll_for_costing: bool = False   # unroll scans so cost_analysis counts
                                       # every iteration (roofline calibration)
    attn_sharding: str = "heads"     # heads | row | replicated | head_dim
    mlp_sharding: str = "ff"         # ff | replicated
    shard_vocab: bool = True

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ----- derived -----
    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def pattern(self) -> Tuple[Tuple[str, str], ...]:
        """Layer pattern of one scan unit: ((mixer, ffn), ...).

        mixer in {attn, mamba}; ffn in {dense, moe, none}.
        """
        if self.family == "ssm":
            return (("mamba", "none"),)
        if self.family == "hybrid":
            period = self.attn_period or 8
            out = []
            for j in range(period):
                mixer = "attn" if j == (self.attn_offset % period) else "mamba"
                ffn = (
                    "moe"
                    if (self.n_experts and j % self.moe_period == self.moe_period - 1)
                    else "dense"
                )
                out.append((mixer, ffn))
            return tuple(out)
        ffn = "moe" if self.n_experts else "dense"
        if self.n_experts and self.moe_period > 1:
            out = []
            for j in range(self.moe_period):
                out.append(("attn", "moe" if j == self.moe_period - 1 else "dense"))
            return tuple(out)
        return (("attn", ffn),)

    @property
    def n_units(self) -> int:
        plen = len(self.pattern)
        if self.n_layers % plen:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern length {plen}"
            )
        return self.n_layers // plen

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        D, V = self.d_model, self.vocab_padded
        hd = self.head_dim
        n = V * D  # embed
        if not self.tie_embeddings:
            n += D * V
        for mixer, ffn in self.pattern:
            reps = self.n_units
            if mixer == "attn":
                qkv = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd
                o = self.n_heads * hd * D
                bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
                n += reps * (qkv + o + bias + D)
            else:
                din = self.d_inner
                G = 1  # n_groups
                inproj = D * (2 * din + 2 * G * self.ssm_state + self.n_ssm_heads)
                n += reps * (
                    inproj
                    + self.ssm_conv * (din + 2 * G * self.ssm_state)
                    + 3 * self.n_ssm_heads
                    + din * D
                    + din
                    + D
                )
            if ffn == "dense":
                n += reps * (3 * D * self.d_ff + D)
            elif ffn == "moe":
                fe = self.d_ff_expert or self.d_ff
                n += reps * (D * self.n_experts + 3 * D * fe * self.n_experts + D)
        n += D  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        D = self.d_model
        fe = self.d_ff_expert or self.d_ff
        n_moe_layers = sum(
            1 for _, f in self.pattern if f == "moe"
        ) * self.n_units
        inactive = n_moe_layers * 3 * D * fe * (
            self.n_experts - self.experts_per_token
        )
        return self.param_count() - inactive
