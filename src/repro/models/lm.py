"""Unified language model covering the assigned architecture pool.

One parameterized decoder/encoder stack supporting:
  * dense GQA transformers (qwen, smollm, granite, phi4, llava backbone)
  * MoE FFNs (kimi-k2, granite-moe, jamba's MoE layers)
  * Mamba-2 mixers (mamba2-370m, jamba hybrid 1:7 interleave)
  * encoder-only bidirectional (hubert)
  * frontend stubs (vision patches / audio frames) prepended to the sequence

Layers are stacked per pattern-position and scanned (jax.lax.scan) so the HLO
stays compact at 60-80 layers; remat wraps the unit body.

Weights are stored with explicit head/dim axes — e.g. wq (D, H, hd) — so the
sharding policies (heads / row / head_dim TP) are pure PartitionSpec choices.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention_decode, attention_forward
from repro.models.config import ModelConfig
from repro.models.layers import embed_tokens, rms_norm, softmax_xent
from repro.models.mamba2 import mamba_decode, mamba_forward
from repro.models.moe import moe_ffn
from repro.models.spec import LeafSpec


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------

def build_param_spec(cfg: ModelConfig) -> Dict[str, Any]:
    D = cfg.d_model
    U = cfg.n_units
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype

    def leaf(shape, axes, init="normal", fan_in=None):
        return LeafSpec(tuple(shape), tuple(axes), dt, init, fan_in)

    def attn_spec():
        s = {
            "wq": leaf((U, D, H, hd), (None, "attn_embed", "heads", "head_dim"), fan_in=D),
            "wk": leaf((U, D, KV, hd), (None, "attn_embed", "kv_heads", "head_dim"), fan_in=D),
            "wv": leaf((U, D, KV, hd), (None, "attn_embed", "kv_heads", "head_dim"), fan_in=D),
            "wo": leaf((U, H, hd, D), (None, "heads", "head_dim", "attn_embed"), fan_in=H * hd),
        }
        if cfg.qkv_bias:
            s["bq"] = leaf((U, H, hd), (None, "heads", "head_dim"), init="zeros")
            s["bk"] = leaf((U, KV, hd), (None, "kv_heads", "head_dim"), init="zeros")
            s["bv"] = leaf((U, KV, hd), (None, "kv_heads", "head_dim"), init="zeros")
        return s

    def mamba_spec():
        din, N, SH = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        W = cfg.ssm_conv
        return {
            "wz": leaf((U, D, din), (None, None, "ssm_inner"), fan_in=D),
            "wx": leaf((U, D, din), (None, None, "ssm_inner"), fan_in=D),
            "wbc": leaf((U, D, 2 * N), (None, None, None), fan_in=D),
            "wdt": leaf((U, D, SH), (None, None, "ssm_heads"), fan_in=D),
            "conv_x": leaf((U, W, din), (None, None, "ssm_inner"), init="small_normal"),
            "conv_bc": leaf((U, W, 2 * N), (None, None, None), init="small_normal"),
            "conv_bx": leaf((U, din), (None, "ssm_inner"), init="zeros"),
            "conv_bbc": leaf((U, 2 * N), (None, None), init="zeros"),
            "A_log": leaf((U, SH), (None, "ssm_heads"), init="ones"),
            "D": leaf((U, SH), (None, "ssm_heads"), init="ones"),
            "dt_bias": leaf((U, SH), (None, "ssm_heads"), init="zeros"),
            "norm_w": leaf((U, din), (None, "ssm_inner"), init="ones"),
            "out_proj": leaf((U, din, D), (None, "ssm_inner", None), fan_in=din),
        }

    def dense_ffn_spec():
        F = cfg.d_ff
        s = {
            "w1": leaf((U, D, F), (None, None, "ffn"), fan_in=D),
            "w2": leaf((U, F, D), (None, "ffn", None), fan_in=F),
        }
        s["w3"] = leaf((U, D, F), (None, None, "ffn"), fan_in=D)
        return s

    def moe_spec():
        E = cfg.n_experts
        F = cfg.d_ff_expert or cfg.d_ff
        return {
            "router": leaf((U, D, E), (None, None, "experts"), fan_in=D),
            "w1": leaf((U, E, D, F), (None, "experts", None, "expert_ffn"), fan_in=D),
            "w3": leaf((U, E, D, F), (None, "experts", None, "expert_ffn"), fan_in=D),
            "w2": leaf((U, E, F, D), (None, "experts", "expert_ffn", None), fan_in=F),
        }

    units: Dict[str, Any] = {}
    for j, (mixer, ffn) in enumerate(cfg.pattern):
        pos: Dict[str, Any] = {"norm1": leaf((U, D), (None, None), init="ones")}
        pos["mixer"] = attn_spec() if mixer == "attn" else mamba_spec()
        if ffn != "none":
            pos["norm2"] = leaf((U, D), (None, None), init="ones")
            pos["ffn"] = dense_ffn_spec() if ffn == "dense" else moe_spec()
        units[f"pos{j}"] = pos

    spec: Dict[str, Any] = {
        # Embedding table sharded on the EMBED dim: row gathers are then
        # shard-local (each device holds a D-slice of every row) — no
        # collectives, no scatter in the backward, and no one-hot matmul
        # FLOPs. The (separate) lm_head stays vocab-sharded for the logits
        # matmul + sharded softmax. Tied-embedding archs matmul x @ table.T,
        # contracting the sharded D axis (partial sums).
        "embed": leaf((cfg.vocab_padded, D), (None, "embed_tbl"), fan_in=D),
        "units": units,
        "final_norm": leaf((D,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = leaf((D, cfg.vocab_padded), (None, "vocab"), fan_in=D)
    if cfg.frontend in ("vision_stub", "audio_stub"):
        spec["frontend_proj"] = leaf((D, D), (None, None), fan_in=D)
    return spec


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _mlp(p, x, act: str, constrain):
    h1 = jnp.einsum("bsd,df->bsf", x, p["w1"])
    h1 = constrain(h1, ("batch", None, "ffn"))
    if act == "gelu":
        h = jax.nn.gelu(h1.astype(jnp.float32)).astype(x.dtype)
    else:
        silu = h1 * jax.nn.sigmoid(h1.astype(jnp.float32)).astype(x.dtype)
        h3 = jnp.einsum("bsd,df->bsf", x, p["w3"])
        h3 = constrain(h3, ("batch", None, "ffn"))
        h = silu * h3
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


def _flat_attn(p):
    """(D,H,hd)/(H,hd,D) weights -> flat views for attention.py einsums."""
    U_absent = p["wq"].ndim == 3  # sliced by scan: (D,H,hd)
    assert U_absent
    D, H, hd = p["wq"].shape
    KV = p["wk"].shape[1]
    q = {"wq": p["wq"].reshape(D, H * hd),
         "wk": p["wk"].reshape(D, KV * hd),
         "wv": p["wv"].reshape(D, KV * hd),
         "wo": p["wo"].reshape(H * hd, D)}
    for b in ("bq", "bk", "bv"):
        if b in p:
            q[b] = p[b].reshape(-1)
    return q


def _unit_forward(cfg: ModelConfig, x, unit_params, positions, constrain):
    aux = jnp.zeros((), jnp.float32)
    for j, (mixer, ffn) in enumerate(cfg.pattern):
        pj = unit_params[f"pos{j}"]
        h = rms_norm(x, pj["norm1"])
        if mixer == "attn":
            y = attention_forward(
                _flat_attn(pj["mixer"]), h, cfg, positions,
                causal=cfg.causal, constrain=constrain,
            )
        else:
            y = mamba_forward(_mamba_p(pj["mixer"]), h, cfg, constrain)
        x = x + y
        if ffn != "none":
            h2 = rms_norm(x, pj["norm2"])
            if ffn == "dense":
                act = "gelu" if cfg.family == "encoder" else "swiglu"
                y2 = _mlp(pj["ffn"], h2, act, constrain)
            else:
                y2, a = moe_ffn(pj["ffn"], h2, cfg, constrain)
                aux = aux + a
            x = x + y2
        x = constrain(x, ("batch", "act_seq", None))
    return x, aux


def _mamba_p(p):
    """Assemble the packed views mamba2.py expects from split weights."""
    out = dict(p)
    out["in_proj"] = jnp.concatenate(
        [p["wz"], p["wx"], p["wbc"], p["wdt"]], axis=-1
    )
    out["conv_w"] = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)
    out["conv_b"] = jnp.concatenate([p["conv_bx"], p["conv_bbc"]], axis=-1)
    return out


def _embed_inputs(cfg: ModelConfig, params, batch, constrain):
    """Token (+frontend) embedding. Returns (x (B,S,D), loss_mask (B,S))."""
    if cfg.frontend == "text":
        x = embed_tokens(params["embed"], batch["tokens"], one_hot=False)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones(batch["tokens"].shape, jnp.float32)
        return x, mask
    if cfg.frontend == "vision_stub":
        tok = embed_tokens(params["embed"], batch["tokens"], one_hot=False)
        patches = jnp.einsum(
            "bpd,de->bpe", batch["patch_embeds"].astype(tok.dtype),
            params["frontend_proj"],
        )
        x = jnp.concatenate([patches, tok], axis=1)
        mask = jnp.concatenate(
            [
                jnp.zeros(patches.shape[:2], jnp.float32),
                jnp.ones(tok.shape[:2], jnp.float32),
            ],
            axis=1,
        )
        return x, mask
    if cfg.frontend == "audio_stub":
        x = jnp.einsum(
            "bsd,de->bse",
            batch["frames"].astype(jnp.dtype(cfg.activation_dtype)),
            params["frontend_proj"],
        )
        return x, jnp.ones(x.shape[:2], jnp.float32)
    raise ValueError(cfg.frontend)


def forward(
    cfg: ModelConfig,
    params,
    batch: Dict[str, jnp.ndarray],
    constrain,
    unit_constrain=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss ()).

    unit_constrain: optional fn(unit_params)->unit_params applied INSIDE the
    scan body — constrains each layer's weight slices to the compute sharding
    so FSDP-stored weights are all-gathered one layer at a time, not as the
    whole stack.
    """
    x, _ = _embed_inputs(cfg, params, batch, constrain)
    x = x.astype(jnp.dtype(cfg.activation_dtype))
    x = constrain(x, ("batch", "act_seq", None))
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, unit_params):
        h, aux = carry
        if unit_constrain is not None:
            unit_params = unit_constrain(unit_params)
        h, a = _unit_forward(cfg, h, unit_params, positions, constrain)
        return (h, aux + a), None

    unit_fn = (
        jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    )
    if cfg.unroll_for_costing:
        carry = (x, jnp.zeros((), jnp.float32))
        for i in range(cfg.n_units):
            up = jax.tree.map(lambda a: a[i], params["units"])
            carry, _ = unit_fn(carry, up)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(
            unit_fn, (x, jnp.zeros((), jnp.float32)), params["units"]
        )

    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits, aux


def loss_fn(cfg: ModelConfig, params, batch, constrain, unit_constrain=None):
    logits, aux = forward(cfg, params, batch, constrain, unit_constrain)
    if cfg.frontend == "vision_stub":
        n_front = batch["patch_embeds"].shape[1]
        logits_txt = logits[:, n_front:, :]
    else:
        logits_txt = logits
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    ce = softmax_xent(logits_txt, labels, mask, cfg.vocab)
    return ce + cfg.router_aux_weight * aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# decode (serve_step)
# --------------------------------------------------------------------------

def build_cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    """LeafSpec tree for the decode cache (shapes + logical axes)."""
    U = cfg.n_units
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    din, N = cfg.d_inner, cfg.ssm_state
    SH, P, W = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    cdt = cfg.activation_dtype
    cache: Dict[str, Any] = {}
    for j, (mixer, _) in enumerate(cfg.pattern):
        if mixer == "attn":
            cache[f"pos{j}"] = {
                "k": LeafSpec((U, batch, max_seq, KV, hd),
                              (None, "cache_batch", "cache_seq", "kv_heads", "head_dim"), cdt, "zeros"),
                "v": LeafSpec((U, batch, max_seq, KV, hd),
                              (None, "cache_batch", "cache_seq", "kv_heads", "head_dim"), cdt, "zeros"),
            }
        else:
            cache[f"pos{j}"] = {
                "ssm": LeafSpec((U, batch, SH, N, P),
                                (None, "cache_batch", "ssm_heads", None, None), "float32", "zeros"),
                "conv_x": LeafSpec((U, batch, W - 1, din),
                                   (None, "cache_batch", None, "ssm_inner"), cdt, "zeros"),
                "conv_bc": LeafSpec((U, batch, W - 1, 2 * N),
                                    (None, "cache_batch", None, None), cdt, "zeros"),
            }
    return cache


def decode_step(
    cfg: ModelConfig,
    params,
    cache,
    tokens: jnp.ndarray,   # (B,) current token ids
    pos,                   # () int32 position to write
    constrain,
    unit_constrain=None,
):
    """One greedy decode step. Returns (next_tokens (B,), logits, new_cache)."""
    x = embed_tokens(params["embed"], tokens[:, None], one_hot=False)
    x = x.astype(jnp.dtype(cfg.activation_dtype))

    def body(carry, xs):
        h = carry
        unit_params, unit_cache = xs
        if unit_constrain is not None:
            unit_params = unit_constrain(unit_params)
        new_cache = {}
        for j, (mixer, _ffn) in enumerate(cfg.pattern):
            pj = unit_params[f"pos{j}"]
            cj = unit_cache[f"pos{j}"]
            hin = rms_norm(h, pj["norm1"])
            if mixer == "attn":
                y, nk, nv = attention_decode(
                    _flat_attn(pj["mixer"]), hin, cj["k"], cj["v"], pos, cfg,
                    constrain,
                )
                new_cache[f"pos{j}"] = {"k": nk, "v": nv}
            else:
                y, st_dict = _mamba_decode_split(
                    _mamba_p(pj["mixer"]), hin, cj, cfg
                )
                new_cache[f"pos{j}"] = st_dict
            h = h + y
            ffn = cfg.pattern[j][1]
            if ffn != "none":
                h2 = rms_norm(h, pj["norm2"])
                if ffn == "dense":
                    act = "gelu" if cfg.family == "encoder" else "swiglu"
                    h = h + _mlp(pj["ffn"], h2, act, constrain)
                else:
                    y2, _ = moe_ffn(pj["ffn"], h2, cfg, constrain)
                    h = h + y2
        return h, new_cache

    if cfg.unroll_for_costing:
        outs = []
        h = x
        for i in range(cfg.n_units):
            up = jax.tree.map(lambda a: a[i], params["units"])
            uc_i = jax.tree.map(lambda a: a[i], cache)
            h, nc = body(h, (up, uc_i))
            outs.append(nc)
        x = h
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, new_cache = jax.lax.scan(body, x, (params["units"], cache))
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0, :]
    logits = constrain(logits, ("batch", "vocab"))
    next_tokens = jnp.argmax(
        jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -jnp.inf),
        axis=-1,
    ).astype(jnp.int32)
    return next_tokens, logits, new_cache


def _mamba_decode_split(mp, hin, cj, cfg):
    """Adapter: split conv cache -> packed mamba_decode -> split again."""
    conv_state = jnp.concatenate([cj["conv_x"], cj["conv_bc"]], axis=-1)
    y, new_ssm, new_conv = mamba_decode(mp, hin, cj["ssm"], conv_state, cfg)
    din = cfg.d_inner
    st = {
        "ssm": new_ssm,
        "conv_x": new_conv[..., :din].astype(cj["conv_x"].dtype),
        "conv_bc": new_conv[..., din:].astype(cj["conv_bc"].dtype),
    }
    return y, st
