"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 —
encoder-only, same arch as w2v2 [arXiv:2106.07447; unverified].

Encoder-only: bidirectional attention, no decode step (decode_32k/long_500k
cells are skipped — see DESIGN.md). The CNN waveform frontend is a stub:
input_specs provide precomputed frame embeddings (B, S, d_model); the 504
'vocab' is the masked-unit prediction target space.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    causal=False,
    frontend="audio_stub",
    attn_sharding="heads",
    mlp_sharding="ff",
    shard_vocab=False,       # 504-way output head: too small to shard
)
