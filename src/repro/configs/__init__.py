"""Config registry: one module per assigned architecture (+ GS-TG scenes).

``get_config(name)`` returns the full production ModelConfig;
``get_smoke_config(name)`` returns the reduced same-family variant used by
CPU smoke tests (small layers/width/experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig

from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.mamba2_370m import CONFIG as _mamba2
from repro.configs.qwen1_5_110b import CONFIG as _qwen
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.granite_3_2b import CONFIG as _granite3
from repro.configs.phi4_mini_3_8b import CONFIG as _phi4
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite_moe

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _llava,
        _mamba2,
        _qwen,
        _smollm,
        _granite3,
        _phi4,
        _jamba,
        _hubert,
        _kimi,
        _granite_moe,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: one scan unit, narrow dims, small vocab."""
    cfg = get_config(name)
    plen = len(cfg.pattern)
    hd = 16
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, 2))
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=plen,          # one scan unit
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 8),
        param_dtype="float32",
        activation_dtype="float32",
        attn_chunk=32,
        remat=False,
        attn_sharding="replicated",
        mlp_sharding="replicated",
        shard_vocab=False,
    )
    if cfg.n_experts:
        changes.update(
            n_experts=min(cfg.n_experts, 8),
            experts_per_token=min(cfg.experts_per_token, 2),
            d_ff_expert=64,
            # ample capacity: smoke tests assert decode == batched forward,
            # which only holds when no tokens are capacity-dropped
            capacity_factor=8.0,
        )
    if cfg.family in ("ssm", "hybrid"):
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    return dataclasses.replace(cfg, **changes)
