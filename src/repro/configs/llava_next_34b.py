"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

The transformer BACKBONE only; the anyres vision frontend is a stub per the
assignment: input_specs provide precomputed patch embeddings (anyres 2x2 grid
+ base view of 576 patches each => 2880 frontend tokens).

56 heads do not divide the 16-way model axis -> 'row' attention sharding
(weights sharded on d_model, partial-sum reduce). See §Perf for the head_dim
alternative explored in the hillclimb.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    frontend="vision_stub",
    n_frontend_tokens=2880,   # anyres: 4 tiles + base view, 576 patches each
    attn_sharding="row",
    mlp_sharding="ff",
)
