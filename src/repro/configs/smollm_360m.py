"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

15 heads (kv=5) divide neither 16-way TP nor anything useful — at 360M the
model is replicated on the model axis except the MLP hidden and vocab.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab=49152,
    tie_embeddings=True,
    attn_sharding="replicated",
    mlp_sharding="ff",
)
