"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) vocab=49155,
MoE 32e top-8, expert d_ff=512 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    n_experts=32,
    experts_per_token=8,
    d_ff_expert=512,
    moe_period=1,
    attn_sharding="heads",
    mlp_sharding="replicated",
)
