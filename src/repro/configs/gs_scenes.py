"""The paper's six evaluation scenes (Table II) + synthetic stand-in specs.

Pretrained 3D-GS-30k checkpoints are not available offline; the synthetic
generator reproduces the statistics the paper's effect depends on (Gaussian
count scale, clustering, screen footprint). Resolutions are the paper's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class SceneSpec:
    name: str
    dataset: str
    width: int
    height: int
    kind: str                  # indoor | outdoor
    paper_gaussians: int       # approximate published 3D-GS-30k model size
    synthetic_gaussians: int   # scaled-down stand-in used on CPU
    extent: float              # world extent of the synthetic stand-in


PAPER_SCENES: Dict[str, SceneSpec] = {
    "train": SceneSpec("train", "Tanks&Temples", 1959, 1090, "outdoor",
                       1_026_000, 24_000, 5.0),
    "truck": SceneSpec("truck", "Tanks&Temples", 1957, 1091, "outdoor",
                       2_541_000, 24_000, 5.0),
    "drjohnson": SceneSpec("drjohnson", "DeepBlending", 1332, 876, "indoor",
                           3_278_000, 20_000, 4.0),
    "playroom": SceneSpec("playroom", "DeepBlending", 1264, 832, "indoor",
                          2_343_000, 20_000, 4.0),
    "rubble": SceneSpec("rubble", "Mill-19", 4608, 3456, "outdoor",
                        9_060_000, 32_000, 8.0),
    "residence": SceneSpec("residence", "UrbanScene3D", 5472, 3648, "outdoor",
                           5_950_000, 32_000, 8.0),
}

# Evaluation renders on CPU use tile-aligned reduced resolutions that keep the
# scenes' aspect ratios; the cost model then scales op counts by the pixel and
# Gaussian ratios to project to paper scale.
EVAL_RESOLUTION: Dict[str, tuple] = {
    "train": (512, 288),
    "truck": (512, 288),
    "drjohnson": (384, 256),
    "playroom": (384, 256),
    "rubble": (640, 480),
    "residence": (640, 448),
}
