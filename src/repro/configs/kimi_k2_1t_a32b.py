"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) vocab=163840,
MoE 384e top-8, expert d_ff=2048 — trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified].

Expert parallelism: 384 experts / 16-way model axis = 24 experts per device.
Training uses Adafactor (launch/train.py picks it for >=100B param counts) so
optimizer state fits v5e HBM.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,               # dense-FFN dim unused: every layer is MoE
    vocab=163840,
    n_experts=384,
    experts_per_token=8,
    d_ff_expert=2048,
    moe_period=1,
    attn_sharding="heads",
    mlp_sharding="replicated",
)
