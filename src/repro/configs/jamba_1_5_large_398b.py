"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].

Pattern per 8-layer unit: attention at position 4, Mamba elsewhere (1:7);
MoE FFN on every second layer (16 experts, top-2), dense FFN otherwise.
Runs long_500k: the Mamba layers are O(n); the sparse attention layers see
the full 500k KV cache sequence-sharded across the model axis.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    experts_per_token=2,
    d_ff_expert=24576,
    moe_period=2,
    attn_period=8,
    attn_offset=4,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=64,
    attn_sharding="heads",
    mlp_sharding="ff",
)
