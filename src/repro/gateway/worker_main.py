"""Subprocess worker entrypoint: one RenderServer behind line-JSON stdio.

  python -m repro.gateway.worker_main --worker-id w0 --scenes train:0 \
      --devices 2 --gaussians 1500 --max-batch 8

Counterpart of :class:`repro.gateway.transport.SubprocessWorker`. stdout is
RESERVED for the protocol: the real fd 1 is dup'd away for the JSON channel
and fd 1 is re-pointed at stderr before jax loads, so any library print or
warning lands in the log stream instead of corrupting the wire.

Scene construction mirrors ``repro.launch.render_serve`` exactly —
``scene_like_paper(jax.random.key(i), sid, gaussians)`` with ``i`` the
scene's GLOBAL index (shipped as ``sid:i`` in ``--scenes``) — so a worker
hosting any subset of the fleet's scenes builds each one bit-identically
to a direct single-server run, and renders it through the same padded
dispatch shape. That is the whole parity story: the gateway can hand a
request to any worker (or retry it on another after a death) and the
pixels cannot tell.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker-id", required=True)
    ap.add_argument("--scenes", required=True,
                    help="comma-separated sid:global_index pairs; the index "
                         "keys the synthetic scene RNG (parity with the "
                         "single-server scene enumeration)")
    ap.add_argument("--devices", type=int, default=None,
                    help="virtual host devices for THIS worker (set via "
                         "XLA_FLAGS before jax initializes)")
    ap.add_argument("--gaussians", type=int, default=1500)
    ap.add_argument("--scene-shards", type=int, default=1)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=float, default=0.05)
    ap.add_argument("--queue-depth", type=int, default=128)
    ap.add_argument("--mode", default="gstg",
                    choices=["gstg", "tile_baseline", "group_baseline"])
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--device-budget-mb", type=float, default=None)
    return ap.parse_args(argv)


def _emit(out, doc: dict) -> None:
    out.write(json.dumps(doc) + "\n")
    out.flush()


def main(argv=None) -> int:
    args = parse_args(argv)

    # Claim the protocol channel, then point fd 1 at stderr so stray prints
    # (jax banners, library warnings) cannot corrupt the wire.
    proto = os.fdopen(os.dup(1), "w", buffering=1)
    os.dup2(2, 1)
    sys.stdout = sys.stderr

    if args.devices and args.devices > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} "
                f"--xla_force_host_platform_device_count={args.devices}"
            ).strip()

    import jax
    import numpy as np

    from repro.core.camera import Camera
    from repro.core.gaussians import scene_like_paper
    from repro.core.pipeline import RenderConfig
    from repro.gateway.transport import decode_array, encode_array
    from repro.launch.mesh import make_render_mesh, render_mesh_shards
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer

    n_dev = len(jax.devices())
    use_dev = min(args.devices or n_dev, n_dev)
    shards = max(args.scene_shards, 1)
    phys = render_mesh_shards(use_dev, shards)
    mesh = make_render_mesh(use_dev, scene_shards=phys)

    scene_index = {}
    for spec in args.scenes.split(","):
        sid, _, idx = spec.strip().rpartition(":")
        scene_index[sid] = int(idx)
    scenes = {
        sid: scene_like_paper(jax.random.key(i), sid, args.gaussians)
        for sid, i in scene_index.items()
    }
    cfg = RenderConfig(
        mode=args.mode,
        backend=args.backend,
        group_capacity=args.capacity,
        tile_capacity=args.capacity,
        span=6,
        scene_shards=shards,
    )
    server = RenderServer(
        scenes,
        mesh=mesh,
        max_batch=args.max_batch,
        max_wait=args.max_wait,
        queue_depth=args.queue_depth,
        scene_shards=shards,
        device_budget_mb=args.device_budget_mb,
    )

    def decode_camera(doc: dict) -> Camera:
        return Camera(
            R=np.asarray(decode_array(doc["R"])),
            t=np.asarray(decode_array(doc["t"])),
            fx=doc["fx"], fy=doc["fy"], cx=doc["cx"], cy=doc["cy"],
            width=doc["width"], height=doc["height"],
            znear=doc["znear"], zfar=doc["zfar"],
        )

    def committed() -> list:
        return sorted(server.committed_scene_ids)

    def resident() -> list:
        return sorted(server.resident_scene_ids)

    def do_dispatch(msg: dict) -> dict:
        reqs = [
            RenderRequest(
                request_id=r["request_id"],
                scene_id=r["scene_id"],
                camera=decode_camera(r["camera"]),
                cfg=cfg,
                stream_id=r.get("stream_id"),
            )
            for r in msg["requests"]
        ]
        for req in reqs:
            if not server.submit(req):
                server.drain()
                if not server.submit(req):
                    raise RuntimeError(
                        f"queue jammed at depth {server.queue.maxsize}"
                    )
        server.drain()
        results = []
        for req in reqs:
            res = server.results.pop(req.request_id, None)
            if res is None:
                raise RuntimeError(f"lost request {req.request_id}")
            results.append({
                "request_id": req.request_id,
                "image": encode_array(np.asarray(res.image)),
                "latency_s": res.latency_s,
                "batch_size": res.batch_size,
            })
        return {"results": results}

    _emit(proto, {
        "ready": True,
        "worker_id": args.worker_id,
        "devices": use_dev,
        "scenes": sorted(scenes),
        "pid": os.getpid(),
    })
    print(f"[{args.worker_id}] up: {len(scenes)} scenes, "
          f"{use_dev} devices, backend={args.backend}", file=sys.stderr)

    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        msg = json.loads(line)
        rep = {"id": msg.get("id"), "ok": True}
        try:
            op = msg["op"]
            if op == "ping":
                pass
            elif op == "commit":
                server.commit(msg["scene_id"], cfg)
            elif op == "dispatch":
                rep.update(do_dispatch(msg))
            elif op == "shutdown":
                rep["committed"] = committed()
                rep["resident"] = resident()
                _emit(proto, rep)
                break
            else:
                raise ValueError(f"unknown op {op!r}")
            rep["committed"] = committed()
            # Residency piggybacks on every reply, same as the committed
            # set: the parent's placement data stays fresh with no extra RPC.
            rep["resident"] = resident()
        except Exception as e:            # noqa: BLE001 — report, don't die
            rep = {"id": msg.get("id"), "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
        _emit(proto, rep)

    server.close()
    print(f"[{args.worker_id}] shut down", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
