"""Line-JSON subprocess transport: the out-of-process worker form.

The gateway side (:class:`SubprocessWorker`, this module — pure Python, no
jax) spawns ``python -m repro.gateway.worker_main`` and speaks a one-line-
JSON request/response protocol over the child's stdin/stdout:

  parent -> child   {"op": "ping|commit|dispatch|shutdown", "id": n, ...}
  child  -> parent  {"id": n, "ok": true, ...}          (same order, 1:1)

The child owns a full jax runtime (its own virtual-device set via
``XLA_FLAGS`` in its environment) and a ``RenderServer``; the first line it
emits is a ``{"ready": true}`` banner after scenes are built. Cameras ship
with pose/translation as base64 raw bytes (dtype+shape alongside) so the
child reconstructs BITWISE-identical ``Camera`` values — the parity
invariant must survive the wire. Images come back the same way.

Failure model: any transport fault — EOF (the child died, e.g. our
``kill()``'s SIGKILL), a read timeout, a broken pipe, a protocol error, or
an ``ok: false`` reply — raises :class:`WorkerDied` and the worker is done
(the gateway never routes to it again; ``shutdown()`` reaps the process).
That maps exactly onto the all-or-nothing dispatch contract: a child that
died mid-batch completed none of it.
"""
from __future__ import annotations

import base64
import json
import os
import select
import signal
import subprocess
import sys
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

from repro.gateway.errors import WorkerDied

__all__ = ["SubprocessWorker", "WireResult", "encode_array", "decode_array"]


def encode_array(arr) -> dict:
    """numpy array -> JSON-safe {b64, dtype, shape} (bitwise round-trip)."""
    import numpy as np

    a = np.ascontiguousarray(arr)
    return {
        "b64": base64.b64encode(a.tobytes()).decode("ascii"),
        "dtype": str(a.dtype),
        "shape": list(a.shape),
    }


def decode_array(doc: dict):
    import numpy as np

    return np.frombuffer(
        base64.b64decode(doc["b64"]), dtype=np.dtype(doc["dtype"])
    ).reshape(doc["shape"])


def encode_camera(cam) -> dict:
    # fx/fy/cx/cy/znear/zfar are Python floats: JSON round-trips them
    # exactly (repr-based); only the arrays need the byte-exact path.
    return {
        "R": encode_array(cam.R),
        "t": encode_array(cam.t),
        "fx": float(cam.fx), "fy": float(cam.fy),
        "cx": float(cam.cx), "cy": float(cam.cy),
        "width": int(cam.width), "height": int(cam.height),
        "znear": float(cam.znear), "zfar": float(cam.zfar),
    }


def encode_request(req) -> dict:
    # cfg intentionally does NOT ship: the child renders every request under
    # its OWN RenderConfig (built from the same CLI flags as the parent's),
    # which is what guarantees one compiled program per child signature.
    return {
        "request_id": req.request_id,
        "scene_id": req.scene_id,
        "stream_id": req.stream_id,
        "camera": encode_camera(req.camera),
    }


@dataclass
class WireResult:
    """A completed request as decoded off the wire (duck-types the serving
    tier's ``RequestResult`` where the gateway cares: ``.image``)."""

    request_id: int
    image: Any
    latency_s: float
    batch_size: int


class SubprocessWorker:
    """A fleet member living in a child process.

    ``argv`` is the full child command line (the CLI composes it around
    ``repro.gateway.worker_main``); ``scene_ids`` mirrors what the child was
    told to host. The parent keeps the committed-scene set from the child's
    replies, so affinity routing never pays an RPC.
    """

    def __init__(
        self,
        worker_id: str,
        scene_ids: Sequence[str],
        argv: Sequence[str],
        *,
        max_batch: int = 8,
        read_timeout_s: float = 120.0,
        ready_timeout_s: float = 300.0,
        env: Optional[Dict[str, str]] = None,
    ):
        self.worker_id = worker_id
        self.scene_ids = frozenset(scene_ids)
        self.max_batch = max_batch
        self.read_timeout_s = read_timeout_s
        self._lock = threading.Lock()      # serializes the req/resp pairing
        self._seq = 0
        self._buf = b""
        self._committed: set = set()
        self._resident: Optional[set] = None   # None until first report
        self._killed = False
        self.proc = subprocess.Popen(
            list(argv),
            stdin=subprocess.PIPE,
            stdout=subprocess.PIPE,
            stderr=None,                   # child logs inherit our stderr
            env=env,
            bufsize=0,
        )
        banner = self._read_line(ready_timeout_s)
        if not banner.get("ready"):
            self._reap()
            raise WorkerDied(
                f"worker {worker_id} failed to start: {banner!r}"
            )
        self.devices = int(banner.get("devices", 1))

    # -- wire ----------------------------------------------------------------

    def _read_line(self, timeout_s: float) -> dict:
        """One JSON line off the child's stdout, or WorkerDied on
        EOF/timeout/garbage. select-based so a hung child can't hang us."""
        fd = self.proc.stdout.fileno()
        deadline = time.monotonic() + timeout_s
        while b"\n" not in self._buf:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                self._reap()
                raise WorkerDied(
                    f"worker {self.worker_id} unresponsive for {timeout_s}s"
                )
            ready, _, _ = select.select([fd], [], [], min(remaining, 0.5))
            if not ready:
                continue
            chunk = os.read(fd, 1 << 20)
            if not chunk:                  # EOF: the child is gone
                self._reap()
                raise WorkerDied(
                    f"worker {self.worker_id} exited "
                    f"(code {self.proc.poll()})"
                )
            self._buf += chunk
        line, self._buf = self._buf.split(b"\n", 1)
        try:
            return json.loads(line)
        except ValueError as e:
            self._reap()
            raise WorkerDied(
                f"worker {self.worker_id} wrote a non-protocol line: "
                f"{line[:200]!r}"
            ) from e

    def _rpc(self, msg: dict, timeout_s: Optional[float] = None) -> dict:
        with self._lock:
            if not self.alive():
                raise WorkerDied(f"worker {self.worker_id} is dead")
            self._seq += 1
            msg = dict(msg, id=self._seq)
            try:
                self.proc.stdin.write(
                    (json.dumps(msg) + "\n").encode("ascii")
                )
                self.proc.stdin.flush()
            except (BrokenPipeError, OSError) as e:
                self._reap()
                raise WorkerDied(
                    f"worker {self.worker_id} pipe broke: {e}"
                ) from e
            rep = self._read_line(
                self.read_timeout_s if timeout_s is None else timeout_s
            )
            if rep.get("id") != self._seq or not rep.get("ok"):
                err = rep.get("error", f"bad reply {rep!r}")
                self._reap()
                raise WorkerDied(f"worker {self.worker_id}: {err}")
            if "committed" in rep:
                self._committed = set(rep["committed"])
            if "resident" in rep:
                self._resident = set(rep["resident"])
            return rep

    # -- worker contract -----------------------------------------------------

    def alive(self) -> bool:
        return not self._killed and self.proc.poll() is None

    def ping(self) -> None:
        self._rpc({"op": "ping"}, timeout_s=min(self.read_timeout_s, 10.0))

    def committed_scene_ids(self) -> set:
        return set(self._committed)

    def resident_scene_ids(self) -> set:
        """Scenes the child last reported device-resident (DESIGN.md §17).
        Replies carry the set alongside ``committed``; before any report
        (an old child, or no RPC yet) fall back to the committed set so
        residency routing degrades to plain affinity."""
        if self._resident is None:
            return set(self._committed)
        return set(self._resident)

    def commit(self, scene_id: str, cfg=None) -> None:
        """Pre-commit ``scene_id`` in the child (the child applies its own
        config — ``cfg`` is accepted for contract parity and ignored)."""
        self._rpc({"op": "commit", "scene_id": scene_id})

    def dispatch(self, requests: List[Any]) -> Dict[int, WireResult]:
        rep = self._rpc({
            "op": "dispatch",
            "requests": [encode_request(r) for r in requests],
        })
        out: Dict[int, WireResult] = {}
        for res in rep.get("results", []):
            out[res["request_id"]] = WireResult(
                request_id=res["request_id"],
                image=decode_array(res["image"]),
                latency_s=float(res.get("latency_s", 0.0)),
                batch_size=int(res.get("batch_size", 1)),
            )
        missing = [r.request_id for r in requests if r.request_id not in out]
        if missing:
            self._reap()
            raise WorkerDied(
                f"worker {self.worker_id} lost requests {missing}"
            )
        return out

    def kill(self) -> None:
        """SIGKILL — a real node loss, no goodbye. The in-flight dispatch
        (if any) sees EOF and raises; failover takes it from there."""
        self._killed = True
        try:
            self.proc.send_signal(signal.SIGKILL)
        except (ProcessLookupError, OSError):
            pass

    def shutdown(self) -> None:
        if self.proc.poll() is None and not self._killed:
            try:
                self._rpc({"op": "shutdown"}, timeout_s=10.0)
            except WorkerDied:
                pass
        self._reap()

    def _reap(self) -> None:
        if self.proc.poll() is None:
            self.proc.kill()
        try:
            self.proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            pass
        for pipe in (self.proc.stdin, self.proc.stdout):
            try:
                pipe.close()
            except OSError:
                pass

    def __repr__(self) -> str:
        state = "alive" if self.alive() else "dead"
        return (
            f"<SubprocessWorker {self.worker_id} pid={self.proc.pid} {state} "
            f"scenes={sorted(self.scene_ids)}>"
        )


def worker_argv(
    worker_id: str,
    scene_specs: Sequence[str],
    *,
    devices: Optional[int] = None,
    python: Optional[str] = None,
    extra: Sequence[str] = (),
) -> List[str]:
    """The child command line for ``repro.gateway.worker_main``.

    ``scene_specs`` are ``sid:global_index`` pairs — the GLOBAL index keys
    the synthetic scene's RNG, so a worker hosting a subset of the fleet's
    scenes still builds each one bit-identically to a single-server run
    over the full list (the parity invariant).
    """
    argv = [
        python or sys.executable, "-m", "repro.gateway.worker_main",
        "--worker-id", worker_id,
        "--scenes", ",".join(scene_specs),
    ]
    if devices:
        argv += ["--devices", str(devices)]
    argv += list(extra)
    return argv
