"""Render gateway: admission, routing, health, failover over a worker fleet
(DESIGN.md §16).

The tier above the serving tier: one :class:`RenderGateway` fronts N workers
(:mod:`repro.gateway.worker` in-process, :mod:`repro.gateway.transport`
subprocess), each an owned ``RenderServer`` over its own committed scenes.
The gateway only schedules — all device work happens inside workers — so it
is pure Python on the hot path, reusing the serving tier's primitives:

  submit() --> RequestQueue --> router (step) --> per-worker inbox
   (bounded, backpressure,      scene-affinity +     (one dispatcher thread
    gateway.rejected)           stream-sticky +       per worker; per-dispatch
                                least-loaded spill)   heartbeats)

Health: every worker dispatch (and idle ping) reports into an
``ft.heartbeat.HeartbeatMonitor``; a worker silent past the miss timeout is
declared dead, a worker whose dispatch latency is a robust outlier is
flagged a straggler and drained (deprioritized for new work). Death —
flagged, heartbeat-missed, or a transport error mid-dispatch — triggers
failover: the worker's inbox and in-flight batch are re-routed to healthy
workers (bounded retries with backoff; the new worker re-commits the scene
lazily at dispatch), and the routable fleet is re-planned through
``ft.elastic.plan_elastic_mesh`` (each worker = one fixed per-host mesh, so
the fleet shrinks on the data axis). Request ids make retries idempotent at
resolve time: the first completion of an id wins, a late duplicate (a
worker declared dead that was merely slow) is counted and dropped.

Invariants (tests/test_gateway.py):
  * no request is silently dropped — every admitted request terminates in
    ``results`` or ``failed`` (with the terminal exception);
  * worker responses are bitwise-identical to a direct single-server run
    with the same settings (the worker's server pads each dispatch to the
    same fixed shape, and batch lanes are independent), so failover is
    invisible in the pixels;
  * ``gateway/route|retry|failover`` spans match the ``gateway.*``
    counters one-to-one (cross-checked by scripts/validate_trace.py).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.ft.elastic import plan_elastic_mesh
from repro.ft.heartbeat import HeartbeatMonitor
from repro.obs import emit_request_spans, get_registry, get_tracer
from repro.serving.queue import RenderRequest, RequestQueue
from repro.serving.stats import percentile
from repro.obs.metrics import Histogram


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """The routable fleet after (re)planning — ``ft.elastic`` applied to
    workers: each worker contributes one fixed per-host mesh of
    ``devices_per_worker`` devices (the 'model'-like axis a worker cannot
    split), so elasticity happens on the worker/data axis, exactly the
    ``plan_elastic_mesh`` policy. ``global_batch`` is passed as the group
    count because render serving pads per-worker dispatches — there is no
    cross-worker batch-divisibility constraint to preserve."""

    routable: Tuple[str, ...]
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    note: str


def plan_fleet(
    worker_ids: Iterable[str], devices_per_worker: int = 1
) -> Optional[FleetPlan]:
    """Plan the routable fleet over the surviving workers; None when no
    worker survives (the caller must fail pending requests explicitly)."""
    ids = tuple(sorted(worker_ids))
    if devices_per_worker < 1:
        raise ValueError(
            f"devices_per_worker must be >= 1, got {devices_per_worker}"
        )
    plan = plan_elastic_mesh(
        available_devices=len(ids) * devices_per_worker,
        model_parallel=devices_per_worker,
        global_batch=max(len(ids), 1),
        prefer_pods=False,
    )
    if plan is None:
        return None
    return FleetPlan(
        routable=ids,
        mesh_shape=plan.mesh_shape,
        mesh_axes=plan.mesh_axes,
        note=plan.note,
    )


@dataclasses.dataclass
class GatewayResult:
    """One completed request as the gateway saw it."""

    request_id: int
    image: Any                   # (H, W, 3) host numpy
    latency_s: float             # resolve - gateway enqueue (queue+route+worker)
    worker_id: str
    attempts: int                # 1 = first try; >1 = failover retries
    batch_size: int = 1


class NoWorkerAvailable(RuntimeError):
    """Terminal routing failure: no routable worker hosts the scene (the
    whole fleet died, or every hosting worker did)."""


class RenderGateway:
    """Admission + routing + health + failover over a fleet of workers.

    ``workers`` is a list of objects satisfying the contract documented in
    :mod:`repro.gateway.worker` (``InprocWorker``/``SubprocessWorker``, or
    pure-Python stubs in tests). Thread model: producers call ``submit``
    (bounded queue = the thread-safe boundary), ONE driver thread calls
    ``step()``/``run()`` (the router), and the gateway owns one dispatcher
    thread per worker. All router state is guarded by one lock.
    """

    def __init__(
        self,
        workers: List[Any],
        *,
        queue_depth: int = 256,
        max_retries: int = 3,
        retry_backoff_s: float = 0.02,
        heartbeat_timeout_s: float = 30.0,
        straggler_window: int = 16,
        straggler_iqr_k: float = 3.0,
        straggler_min_factor: float = 4.0,
        spill_load: Optional[int] = None,
        devices_per_worker: int = 1,
        clock=time.monotonic,
    ):
        if not workers:
            raise ValueError("gateway needs at least one worker")
        ids = [w.worker_id for w in workers]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate worker ids: {ids}")
        self.workers = list(workers)
        self._by_id = {w.worker_id: w for w in workers}
        self._index = {w.worker_id: i for i, w in enumerate(workers)}
        self._clock = clock
        self.queue = RequestQueue(queue_depth, clock=clock)
        self.monitor = HeartbeatMonitor(
            n_hosts=len(workers),
            window=straggler_window,
            iqr_k=straggler_iqr_k,
            min_factor=straggler_min_factor,
            miss_timeout_s=heartbeat_timeout_s,
        )
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.devices_per_worker = devices_per_worker
        # Spill threshold: an affine worker deeper than this many queued +
        # in-flight requests loses the scene-affinity preference and load
        # wins (FlashGS-style many-client regime: affinity is a cache
        # optimization, not a correctness pin — only streams are sticky).
        self.spill_load = (
            spill_load
            if spill_load is not None
            else 2 * max(getattr(w, "max_batch", 8) for w in workers)
        )

        self._lock = threading.Lock()
        self._conds = {
            w.worker_id: threading.Condition(self._lock) for w in workers
        }
        self._inbox: Dict[str, deque] = {w.worker_id: deque() for w in workers}
        self._inflight: Dict[str, List[RenderRequest]] = {
            w.worker_id: [] for w in workers
        }
        self._events: deque = deque()            # worker -> router handoff
        self._routable = set(ids)
        self._stragglers: set = set()
        self._assigned: Dict[int, Optional[str]] = {}   # rid -> current worker
        self._attempts: Dict[int, int] = {}
        self._retries: List[Tuple[float, int, RenderRequest]] = []  # heap
        self._retry_seq = itertools.count()
        self._stream_route: Dict[str, str] = {}
        self._steps: Dict[str, int] = {w.worker_id: 0 for w in workers}
        self._dispatches: Dict[str, int] = {w.worker_id: 0 for w in workers}
        self._completed_by: Dict[str, int] = {w.worker_id: 0 for w in workers}

        self.results: Dict[int, GatewayResult] = {}
        self.failed: Dict[int, Exception] = {}
        self.counts = {
            "submitted": 0, "rejected": 0, "routed": 0, "completed": 0,
            "retries": 0, "failovers": 0, "failed": 0, "duplicates": 0,
            "recommits": 0, "stragglers": 0,
        }
        self._latency = Histogram()
        self.wall_s: Optional[float] = None
        self.plan: Optional[FleetPlan] = plan_fleet(ids, devices_per_worker)

        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._started = False
        self._started_at: Optional[float] = None
        self._closed = False
        # Dispatcher idle poll: bounds cond-miss latency and sets the idle
        # heartbeat (ping) cadence; keep well under the miss timeout.
        self._idle_wait = max(min(heartbeat_timeout_s / 4.0, 0.05), 0.005)

    # -- introspection -------------------------------------------------------

    @property
    def scene_ids(self) -> set:
        """Every scene SOME worker can host (admission screen)."""
        out: set = set()
        for w in self.workers:
            out |= set(w.scene_ids)
        return out

    @property
    def healthy_workers(self) -> List[str]:
        with self._lock:
            return sorted(self._routable)

    @property
    def completed(self) -> int:
        with self._lock:
            return len(self.results)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        """Start the per-worker dispatcher threads (idempotent)."""
        if self._started:
            return
        self._started = True
        self._started_at = self._clock()
        for w in self.workers:
            t = threading.Thread(
                target=self._dispatcher_loop, args=(w,),
                name=f"gw-{w.worker_id}", daemon=True,
            )
            self._threads.append(t)
            t.start()

    def close(self) -> None:
        """Stop dispatchers and shut every worker down (idempotent). Pending
        admitted requests are failed, not dropped."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self.queue.close()
        with self._lock:
            for cond in self._conds.values():
                cond.notify_all()
        for t in self._threads:
            t.join(timeout=30)
        # Terminate anything still pending so no caller waits forever.
        exc = RuntimeError("gateway closed before completion")
        with self._lock:
            pending = [r for box in self._inbox.values() for r in box]
            for box in self._inbox.values():
                box.clear()
            pending += [r for infl in self._inflight.values() for r in infl]
            pending += [r for _, _, r in self._retries]
            self._retries.clear()
        for req in self.queue.drain():
            pending.append(req)
        for req in pending:
            self._fail(req, exc)
        for w in self.workers:
            w.shutdown()

    def __enter__(self) -> "RenderGateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def kill_worker(self, worker_id: str) -> None:
        """Induce a worker death (chaos hook): the worker stops responding
        and the next dispatch/ping surfaces the failure through the normal
        failover path — exactly how an uninduced death would."""
        self._by_id[worker_id].kill()

    # -- admission -----------------------------------------------------------

    def submit(self, req: RenderRequest) -> bool:
        """Non-blocking admission; False = backpressure (queue at depth;
        counted in ``gateway.rejected_total``). KeyError for a scene no
        worker hosts — a caller bug, not load."""
        if req.scene_id not in self.scene_ids:
            raise KeyError(f"no worker hosts scene {req.scene_id!r}")
        self.counts["submitted"] += 1
        get_registry().counter("gateway.submitted_total").inc()
        ok = self.queue.try_put(req)
        if not ok:
            self._count_rejected()
        return ok

    def _count_rejected(self) -> None:
        self.counts["rejected"] += 1
        get_registry().counter("gateway.rejected_total").inc()

    # -- routing -------------------------------------------------------------

    def _load(self, worker_id: str) -> int:
        # caller holds self._lock
        return len(self._inbox[worker_id]) + len(self._inflight[worker_id])

    def _pick_worker(self, req: RenderRequest) -> Optional[str]:
        """The routing policy (caller holds the lock):

        1. stream-sticky: a stream's frames keep hitting the worker that
           holds their frontend cache (re-pinned only when it dies);
        2. residency-aware placement (DESIGN.md §17): prefer the worker
           that has the scene PAGED IN right now — a committed-but-evicted
           copy still costs a page-in the resident worker skips. Workers
           that do not report residency (e.g. plain stubs) fall back to
           their committed set, collapsing this tier into the next;
        3. scene-affinity: prefer workers that already committed the scene,
           least-loaded among them — unless the best is deeper than
           ``spill_load``, in which case load wins (spill);
        4. least-loaded routable worker hosting the scene (stragglers are
           deprioritized, not excluded — a drained straggler still beats
           no worker at all).
        """
        cands = [
            w for w in self.workers
            if w.worker_id in self._routable and req.scene_id in w.scene_ids
        ]
        if not cands:
            return None
        if req.stream_id is not None:
            pinned = self._stream_route.get(req.stream_id)
            if pinned is not None and any(
                w.worker_id == pinned for w in cands
            ):
                return pinned

        def key(w):
            # (straggler?, not-resident?, not-affine?, load):
            # healthy+resident+idle first.
            affine = req.scene_id in w.committed_scene_ids()
            resident_fn = getattr(w, "resident_scene_ids", None)
            resident = (
                req.scene_id in resident_fn()
                if resident_fn is not None
                else affine
            )
            load = self._load(w.worker_id)
            if affine and load >= self.spill_load:
                affine = resident = False   # pressure: spill to least-loaded
            return (
                w.worker_id in self._stragglers,
                not resident,
                not affine,
                load,
                self._index[w.worker_id],
            )

        best = min(cands, key=key)
        return best.worker_id

    def _route(self, req: RenderRequest, now: float) -> None:
        """Assign ``req`` to a worker inbox (or fail it terminally)."""
        tracer = get_tracer()
        t0 = self._clock()
        with self._lock:
            wid = self._pick_worker(req)
            if wid is not None:
                w = self._by_id[wid]
                if req.scene_id not in w.committed_scene_ids():
                    # The worker will (re-)commit the scene lazily at
                    # dispatch; count it so failover re-commits are visible.
                    self.counts["recommits"] += 1
                    get_registry().counter("gateway.recommits_total").inc()
                if req.stream_id is not None:
                    self._stream_route[req.stream_id] = wid
                self._assigned[req.request_id] = wid
                self._attempts.setdefault(req.request_id, 1)
                self._inbox[wid].append(req)
                self._conds[wid].notify_all()
        if wid is None:
            self._fail(req, NoWorkerAvailable(
                f"no routable worker hosts scene {req.scene_id!r} "
                f"(routable: {sorted(self._routable)})"
            ))
            return
        stamps = getattr(req, "stamps", None)
        if stamps is not None:
            stamps["batch_form"] = t0     # request/batch_wait = inbox wait
        self.counts["routed"] += 1
        get_registry().counter("gateway.routed_total").inc()
        if tracer.enabled:
            tracer.complete(
                "gateway/route", t0, self._clock(), category="gateway",
                args={"request_id": req.request_id, "worker": wid,
                      "attempt": self._attempts.get(req.request_id, 1)},
            )

    # -- dispatcher threads --------------------------------------------------

    def _dispatcher_loop(self, w) -> None:
        wid = w.worker_id
        idx = self._index[wid]
        cond = self._conds[wid]
        inbox = self._inbox[wid]
        self._heartbeat(w, idx, 0.0)      # seed: alive before first dispatch
        while not self._stop.is_set():
            batch: Optional[List[RenderRequest]] = None
            with self._lock:
                if not inbox:
                    cond.wait(self._idle_wait)
                if inbox:
                    n = min(len(inbox), getattr(w, "max_batch", 8))
                    batch = [inbox.popleft() for _ in range(n)]
                    self._inflight[wid] = list(batch)
            if batch is None:
                self._heartbeat(w, idx, 0.0)
                continue
            t0 = self._clock()
            try:
                out = w.dispatch(batch)
            except Exception as exc:      # noqa: BLE001 — failover owns it
                with self._lock:
                    self._inflight[wid] = []
                    self._events.append(("death", wid, batch, exc))
                continue
            t1 = self._clock()
            self._steps[wid] += 1
            self._dispatches[wid] += 1
            self.monitor.report(idx, self._steps[wid], t1 - t0, self._clock())
            registry = get_registry()
            registry.counter("gateway.dispatches_total").inc()
            registry.histogram("gateway.dispatch_s").observe(t1 - t0)
            tracer = get_tracer()
            if tracer.enabled:
                tracer.complete(
                    "gateway/dispatch", t0, t1, category="gateway",
                    args={"worker": wid, "batch_size": len(batch)},
                )
            for req in batch:
                stamps = getattr(req, "stamps", None)
                if stamps is not None:
                    stamps["dispatch"] = t0
                    stamps["device_done"] = t1
            with self._lock:
                self._inflight[wid] = []
                self._events.append(("done", wid, batch, out, t0, t1))

    def _heartbeat(self, w, idx: int, latency_s: float) -> None:
        """Idle/seed liveness: ping and report so a quiet worker is not
        mistaken for a dead one (``dead_hosts`` keys on last-seen)."""
        try:
            w.ping()
        except Exception as exc:          # noqa: BLE001 — failover owns it
            with self._lock:
                self._events.append(("death", w.worker_id, [], exc))
            return
        self.monitor.report(
            idx, self._steps[w.worker_id], latency_s, self._clock()
        )

    # -- router --------------------------------------------------------------

    def step(self, now: Optional[float] = None) -> int:
        """One router turn (single driver thread): fold dispatcher events,
        police heartbeats, release due retries, route new admissions.
        Returns the number of requests routed or resolved this turn."""
        self.start()
        now = self._clock() if now is None else now
        n = 0

        with self._lock:
            events = list(self._events)
            self._events.clear()
        for ev in events:
            if ev[0] == "done":
                _, wid, batch, out, t0, t1 = ev
                for req in batch:
                    self._resolve(wid, req, out.get(req.request_id), t0, t1)
                    n += 1
            else:
                _, wid, batch, exc = ev
                self._handle_death(wid, batch, exc, now)

        # Heartbeat police: only after the fleet had a chance to report.
        if (
            self._started_at is not None
            and now - self._started_at > self.heartbeat_timeout_s
        ):
            for idx in self.monitor.dead_hosts(now):
                wid = self.workers[idx].worker_id
                if wid in self._routable:
                    self._handle_death(
                        wid, [],
                        WorkerTimeout(
                            f"worker {wid} missed heartbeats for "
                            f"{self.heartbeat_timeout_s}s"
                        ),
                        now,
                    )
        report = self.monitor.check(max(self._steps.values(), default=0))
        with self._lock:
            flagged = set()
            if report is not None:
                flagged = {
                    self.workers[h].worker_id for h in report.stragglers
                } & self._routable
            newly = flagged - self._stragglers
            self._stragglers = flagged
        for wid in newly:
            self.counts["stragglers"] += 1
            get_registry().counter("gateway.stragglers_total").inc()

        # Due retries route before fresh admissions (oldest work first).
        while True:
            with self._lock:
                if not self._retries or self._retries[0][0] > now:
                    break
                _, _, req = heapq.heappop(self._retries)
            self._route(req, now)
            n += 1
        for req in self.queue.drain():
            self._route(req, now)
            n += 1
        return n

    def _resolve(
        self, wid: str, req: RenderRequest, res, t0: float, t1: float
    ) -> None:
        rid = req.request_id
        self._assigned.pop(rid, None)
        if rid in self.results or rid in self.failed:
            # A worker declared dead that was merely slow may still deliver:
            # request ids make the retry idempotent — first completion won.
            self.counts["duplicates"] += 1
            get_registry().counter("gateway.duplicate_results_total").inc()
            return
        if res is None:
            self._retry(req, WorkerDiedResult(wid), self._clock())
            return
        t_res = self._clock()
        enq = req.enqueue_time if req.enqueue_time is not None else t0
        attempts = self._attempts.pop(rid, 1)
        self.results[rid] = GatewayResult(
            request_id=rid,
            image=res.image,
            latency_s=t_res - enq,
            worker_id=wid,
            attempts=attempts,
            batch_size=getattr(res, "batch_size", 1),
        )
        self._completed_by[wid] += 1
        self._latency.observe(t_res - enq)
        self.counts["completed"] += 1
        registry = get_registry()
        registry.counter("gateway.completed_total").inc()
        registry.histogram("gateway.latency_s").observe(t_res - enq)
        stamps = getattr(req, "stamps", None)
        if stamps is not None:
            stamps["resolve"] = t_res
            emit_request_spans(
                get_tracer(), rid, stamps,
                args={"worker": wid, "scene_id": req.scene_id,
                      "attempts": attempts},
            )

    def _fail(self, req: RenderRequest, exc: Exception) -> None:
        rid = req.request_id
        self._assigned.pop(rid, None)
        self._attempts.pop(rid, None)
        if rid in self.results or rid in self.failed:
            return
        self.failed[rid] = exc
        self.counts["failed"] += 1
        get_registry().counter("gateway.failed_total").inc()

    def _retry(self, req: RenderRequest, exc: Exception, now: float) -> None:
        """Schedule one bounded-backoff retry (or fail terminally)."""
        rid = req.request_id
        if rid in self.results or rid in self.failed:
            return
        attempt = self._attempts.get(rid, 1)
        if attempt > self.max_retries:
            self._fail(req, exc)
            return
        self._attempts[rid] = attempt + 1
        self._assigned[rid] = None
        t0 = self._clock()
        with self._lock:
            heapq.heappush(
                self._retries,
                (now + self.retry_backoff_s * attempt,
                 next(self._retry_seq), req),
            )
        self.counts["retries"] += 1
        get_registry().counter("gateway.retries_total").inc()
        tracer = get_tracer()
        if tracer.enabled:
            tracer.complete(
                "gateway/retry", t0, self._clock(), category="gateway",
                args={"request_id": rid, "attempt": attempt + 1,
                      "error": type(exc).__name__},
            )

    def _handle_death(
        self, wid: str, batch: List[RenderRequest], exc: Exception, now: float
    ) -> None:
        """Drain a dead worker and fail over everything it held."""
        t0 = self._clock()
        with self._lock:
            first = wid in self._routable
            self._routable.discard(wid)
            self._stragglers.discard(wid)
            drained = list(self._inbox[wid])
            self._inbox[wid].clear()
            inflight = list(self._inflight[wid])
            for sid, pinned in list(self._stream_route.items()):
                if pinned == wid:
                    del self._stream_route[sid]   # re-pin at next frame
        # Retry everything the worker held, but only requests still assigned
        # to IT — a heartbeat-death may already have re-routed the batch the
        # dispatch error is now reporting.
        for req in batch + drained + inflight:
            if self._assigned.get(req.request_id) == wid:
                self._retry(req, exc, now)
        if not first:
            return
        self.plan = plan_fleet(self._routable, self.devices_per_worker)
        self.counts["failovers"] += 1
        registry = get_registry()
        registry.counter("gateway.failovers_total").inc()
        registry.counter("gateway.worker_deaths_total").inc()
        registry.gauge("gateway.healthy_workers").set(len(self._routable))
        tracer = get_tracer()
        if tracer.enabled:
            tracer.complete(
                "gateway/failover", t0, self._clock(), category="gateway",
                args={"worker": wid, "error": type(exc).__name__,
                      "requeued": len(batch) + len(drained) + len(inflight),
                      "routable": sorted(self._routable),
                      "plan": self.plan.note if self.plan else "fleet empty"},
            )

    # -- driver --------------------------------------------------------------

    def outstanding(self) -> int:
        """Admitted requests not yet terminated (results or failed)."""
        with self._lock:
            in_boxes = sum(len(b) for b in self._inbox.values())
            in_flight = sum(len(b) for b in self._inflight.values())
            retries = len(self._retries)
            events = len(self._events)
        return len(self.queue) + in_boxes + in_flight + retries + events

    def run(
        self,
        load: Iterable[Tuple[float, RenderRequest]],
        realtime: bool = False,
        kill_worker: Optional[str] = None,
        kill_after: Optional[int] = None,
    ) -> Dict[int, GatewayResult]:
        """Serve a timed load of ``(arrival_offset_s, request)`` pairs
        (mirrors ``RenderServer.run``). ``kill_worker``/``kill_after`` is
        the chaos hook the CLI and failover tests use: once ``kill_after``
        requests completed, ``kill_worker`` dies mid-load and the run must
        still terminate every request. Returns the results map.
        """
        self.start()
        t_start = self._clock()
        killed = kill_worker is None or kill_after is None

        def maybe_kill():
            nonlocal killed
            if not killed and len(self.results) >= kill_after:
                self.kill_worker(kill_worker)
                killed = True

        for offset, req in load:
            if req.scene_id not in self.scene_ids:
                self._count_rejected()
                continue
            if realtime:
                while self._clock() - t_start < offset:
                    self.step()
                    maybe_kill()
                    gap = offset - (self._clock() - t_start)
                    if gap > 0:
                        time.sleep(min(gap, self._idle_wait))
            if not self.queue.try_put(req):
                self.step()               # service the backlog, retry once
                if not self.queue.try_put(req):
                    self._count_rejected()
                    continue
            self.counts["submitted"] += 1
            get_registry().counter("gateway.submitted_total").inc()
            self.step()
            maybe_kill()
        while self.outstanding():
            if self.step() == 0:
                time.sleep(min(self._idle_wait, 0.005))
            maybe_kill()
        self.step()                        # fold the final completions
        self.wall_s = self._clock() - t_start
        return self.results

    # -- stats ---------------------------------------------------------------

    def summary(self) -> dict:
        lat = self._latency.values()
        with self._lock:
            routable = sorted(self._routable)
            stragglers = sorted(self._stragglers)
        wall = self.wall_s
        done = len(self.results)
        return {
            "gateway": True,
            **self.counts,
            "completed": done,
            "healthy_workers": len(routable),
            "routable": routable,
            "stragglers": stragglers,
            "p50_ms": percentile(lat, 50) * 1e3,
            "p99_ms": percentile(lat, 99) * 1e3,
            "wall_s": wall,
            "fps": (done / wall) if wall else float("nan"),
            "plan": self.plan.note if self.plan is not None else "fleet empty",
            "workers": {
                w.worker_id: {
                    "alive": w.alive(),
                    "routable": w.worker_id in routable,
                    "dispatches": self._dispatches[w.worker_id],
                    "completed": self._completed_by[w.worker_id],
                }
                for w in self.workers
            },
        }

    def format(self) -> str:
        s = self.summary()
        lines = [
            f"gateway: {s['completed']}/{s['submitted']} completed "
            f"({s['rejected']} rejected, {s['failed']} failed, "
            f"{s['retries']} retries, {s['failovers']} failovers)",
            f"  latency p50={s['p50_ms']:.1f}ms p99={s['p99_ms']:.1f}ms  "
            f"fps={s['fps']:.1f}  fleet={s['healthy_workers']} healthy "
            f"({s['plan']})",
        ]
        for wid, st in sorted(s["workers"].items()):
            state = "routable" if st["routable"] else (
                "alive" if st["alive"] else "dead")
            lines.append(
                f"  worker {wid}: {st['completed']} completed / "
                f"{st['dispatches']} dispatches [{state}]"
            )
        return "\n".join(lines)


class WorkerTimeout(RuntimeError):
    """A worker missed its heartbeat window (hung, not provably dead)."""


class WorkerDiedResult(RuntimeError):
    """A dispatch 'succeeded' but the worker returned no result for this
    request id — treated as a per-request failure and retried."""

    def __init__(self, worker_id: str):
        super().__init__(f"worker {worker_id} returned no result")
        self.worker_id = worker_id
