"""Fleet workers: one owned RenderServer per worker (DESIGN.md §16).

A *worker* is the unit the gateway routes to, health-checks, and fails over
— one per-host ``RenderServer`` plus the scenes it can host. Two
implementations share one duck-typed contract (``RenderGateway`` never
imports either directly):

  * :class:`InprocWorker` (here) owns a ``RenderServer`` in THIS process —
    the test/e2e form, where worker death is a flag and bitwise parity with
    a direct single-server run is assertable in one process;
  * :class:`~repro.gateway.transport.SubprocessWorker` owns a child process
    speaking the line-JSON protocol (``repro.gateway.worker_main``) — the
    CLI form, where each worker has its own jax runtime (its own virtual
    device set) and death is a real SIGKILL.

The contract (all methods may raise :class:`WorkerDied`):

  worker_id : str           stable routing key
  scene_ids : frozenset     scenes this worker can host (admission screen)
  max_batch : int           batch the gateway hands over per dispatch
  alive()                   liveness predicate (no I/O beyond a poll)
  committed_scene_ids()     scenes with a committed handle (affinity routing)
  resident_scene_ids()      OPTIONAL: committed scenes currently paged in
                            (residency-aware placement, DESIGN.md §17);
                            absent -> routed on the committed set alone
  commit(scene_id, cfg)     pre-commit / failover re-commit
  dispatch(requests)        -> {request_id: result-with-.image}, blocking
  ping()                    cheap liveness round-trip (idle heartbeat)
  kill()                    induce death (tests / chaos CLI flag)
  shutdown()                graceful close (releases handles / child proc)

``dispatch`` is all-or-nothing by design: a worker that dies mid-batch
raises for the WHOLE batch and completes none of it, so the gateway's
retry accounting never has to reason about partially-applied batches
(request ids make the retries idempotent at resolve time regardless).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

from repro.gateway.errors import WorkerDied
from repro.serving.queue import RenderRequest
from repro.serving.server import RenderServer

__all__ = ["InprocWorker", "WorkerDied", "strip_stamps"]


def strip_stamps(req: RenderRequest) -> RenderRequest:
    """A copy of ``req`` whose lifecycle-stamp dict is disabled.

    The GATEWAY owns the request lifecycle spans (enqueue -> route ->
    dispatch -> resolve on the gateway clock); an in-process worker's
    ``RenderServer`` would otherwise stamp and emit a second ``request``
    span family onto the same per-request trace lane, partially
    overlapping the gateway's and breaking the per-lane nesting contract
    (``validate_chrome_trace``). ``stamps=None`` is the documented
    duck-typed opt-out every stamp site already checks for.
    """
    copy = dataclasses.replace(req)
    object.__setattr__(copy, "stamps", None)
    return copy


# One process-wide dispatch lock for in-process workers: their servers share
# one jax runtime, and concurrent dispatch threads entering collective
# programs from different handles can deadlock the XLA rendezvous (same
# hazard — and same fix — as the stream speculation worker, DESIGN.md §15).
# Subprocess workers have their own runtimes and need no such lock.
_INPROC_DISPATCH_LOCK = threading.Lock()


class InprocWorker:
    """An in-process fleet member: an owned :class:`RenderServer`.

    ``kill()`` flips a flag checked at every dispatch/ping entry — the
    in-process simulation of a node loss: requests already handed to a
    dispatch complete or fail atomically with it, everything after raises
    :class:`WorkerDied`. ``shutdown()`` still closes the underlying server
    even after a kill, so a test's killed worker releases its handles.
    """

    def __init__(
        self,
        worker_id: str,
        scenes,
        *,
        mesh=None,
        max_batch: int = 8,
        max_wait: float = 0.05,
        queue_depth: int = 64,
        scene_shards: int = 1,
        device_budget_mb: Optional[float] = None,
        clock=None,
    ):
        self.worker_id = worker_id
        self.scene_ids = frozenset(scenes)
        self.max_batch = max_batch
        kwargs = {} if clock is None else {"clock": clock}
        self.server = RenderServer(
            scenes,
            mesh=mesh,
            max_batch=max_batch,
            max_wait=max_wait,
            queue_depth=queue_depth,
            scene_shards=scene_shards,
            device_budget_mb=device_budget_mb,
            **kwargs,
        )
        self._alive = True
        self._closed = False

    # -- liveness ------------------------------------------------------------

    def alive(self) -> bool:
        return self._alive

    def _check_alive(self) -> None:
        if not self._alive:
            raise WorkerDied(f"worker {self.worker_id} is dead")

    def ping(self) -> None:
        self._check_alive()

    def kill(self) -> None:
        """Simulated node loss: stop serving, leave state for shutdown()."""
        self._alive = False

    # -- scenes --------------------------------------------------------------

    def committed_scene_ids(self):
        return self.server.committed_scene_ids

    def resident_scene_ids(self):
        """Committed scenes currently paged IN on this worker's device
        (DESIGN.md §17) — the gateway's residency-aware placement signal.
        Optional in the worker contract: workers without it are routed on
        their committed set alone."""
        return self.server.resident_scene_ids

    def commit(self, scene_id: str, cfg) -> None:
        self._check_alive()
        with _INPROC_DISPATCH_LOCK:
            self.server.commit(scene_id, cfg)

    # -- dispatch ------------------------------------------------------------

    def dispatch(self, requests: List[RenderRequest]) -> Dict[int, object]:
        """Run ``requests`` through the owned server; returns
        ``{request_id: RequestResult}``. The server's own bucketing batches
        same-signature requests and pads to the server's fixed dispatch
        shape — which is exactly what makes a worker's output bitwise-
        identical to a direct single-server run with the same settings."""
        self._check_alive()
        with _INPROC_DISPATCH_LOCK:
            self._check_alive()
            for req in requests:
                wreq = strip_stamps(req)
                if not self.server.submit(wreq):
                    # Worker-queue backpressure: drain what is pending and
                    # retry once; a second failure means the gateway handed
                    # over more than queue_depth in one batch (caller bug).
                    self.server.drain()
                    if not self.server.submit(wreq):
                        raise WorkerDied(
                            f"worker {self.worker_id} queue jammed at depth "
                            f"{self.server.queue.maxsize}"
                        )
            self.server.drain()
        out = {}
        for req in requests:
            res = self.server.results.pop(req.request_id, None)
            if res is None:
                raise WorkerDied(
                    f"worker {self.worker_id} lost request {req.request_id}"
                )
            out[req.request_id] = res
        return out

    # -- lifecycle -----------------------------------------------------------

    def shutdown(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._alive = False
        self.server.close()

    def __repr__(self) -> str:
        state = "alive" if self._alive else "dead"
        return (
            f"<InprocWorker {self.worker_id} {state} "
            f"scenes={sorted(self.scene_ids)}>"
        )
