"""Gateway error types shared by both worker transports.

Pure Python on purpose: :class:`WorkerDied` is raised by the in-process
worker (which imports jax via its ``RenderServer``) AND by the subprocess
transport (which must stay importable without jax — it runs in the
gateway process, where all device work is delegated to children).
"""
from __future__ import annotations


class WorkerDied(RuntimeError):
    """A worker is gone (killed, crashed, or unresponsive past the
    heartbeat timeout). The gateway treats every in-flight request on the
    worker as retryable — the batch completed nothing."""
