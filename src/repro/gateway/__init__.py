"""Gateway tier: one front-end over a fleet of render workers (DESIGN.md §16).

``RenderGateway`` admits (bounded queue), routes (scene-affinity +
stream-sticky + least-loaded spill), health-checks (``ft.heartbeat``),
and fails over (bounded retries + ``ft.elastic`` fleet replanning) across
N workers — in-process :class:`InprocWorker` for tests,
:class:`SubprocessWorker` children over line-JSON pipes for the
``repro-gateway`` CLI. Importing this package must not import jax: the
gateway is pure scheduling; device work lives inside workers
(``repro.gateway.worker`` / ``repro.gateway.worker_main`` import jax on
first use, mirroring the serving-layer split).
"""
from repro.gateway.gateway import (
    FleetPlan,
    GatewayResult,
    NoWorkerAvailable,
    RenderGateway,
    WorkerTimeout,
    plan_fleet,
)

__all__ = [
    "FleetPlan",
    "GatewayResult",
    "NoWorkerAvailable",
    "RenderGateway",
    "WorkerTimeout",
    "plan_fleet",
    "InprocWorker",
    "SubprocessWorker",
    "WorkerDied",
]


def __getattr__(name: str):
    # Lazy: InprocWorker pulls in serving.server (jax); SubprocessWorker is
    # pure Python but lives with the wire protocol. Keeping both out of the
    # eager import preserves the no-jax guarantee for gateway scheduling.
    if name == "InprocWorker":
        from repro.gateway.worker import InprocWorker
        return InprocWorker
    if name == "WorkerDied":
        from repro.gateway.errors import WorkerDied
        return WorkerDied
    if name == "SubprocessWorker":
        from repro.gateway.transport import SubprocessWorker
        return SubprocessWorker
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
