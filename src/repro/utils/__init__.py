from repro.utils.misc import cdiv, round_up, pytree_bytes, pytree_count

__all__ = ["cdiv", "round_up", "pytree_bytes", "pytree_count"]
