from repro.utils.misc import (
    cdiv,
    pytree_bytes,
    pytree_count,
    round_up,
    wide_count_dtype,
    wide_count_sum,
)

__all__ = [
    "cdiv",
    "round_up",
    "pytree_bytes",
    "pytree_count",
    "wide_count_dtype",
    "wide_count_sum",
]
