"""Small shared utilities (no jax device state at import time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def wide_count_dtype():
    """Dtype for op counters that can exceed int32 on multi-million-Gaussian
    scenes (RenderStats sort_ops / fifo_ops / n_candidate_tests): int64 when
    x64 is enabled, float32 otherwise. float32 is exact for counts below
    2**24 (every parity test regime) and stays positive/monotone above —
    int32 silently wraps negative, which is the bug this guards against."""
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.float32


def wide_count_sum(values: jnp.ndarray) -> jnp.ndarray:
    """Overflow-safe sum for counter accumulation: accumulates in the widest
    available float (f64 under x64, else f32) and casts to
    ``wide_count_dtype``. Integer-exact whenever the total fits the
    accumulator mantissa; never wraps."""
    acc = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    return jnp.sum(values.astype(acc)).astype(wide_count_dtype())


def pytree_count(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def pytree_bytes(tree) -> int:
    """Total bytes across all leaves (uses declared dtypes)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )
