"""Small shared utilities (no jax device state at import time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pytree_count(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def pytree_bytes(tree) -> int:
    """Total bytes across all leaves (uses declared dtypes)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )
