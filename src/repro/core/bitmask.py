"""Bitmask generation + the RM's FIFO compaction (paper §IV-B, §V-B).

For every entry of a group's (depth-sorted) table, a gf^2-bit mask marks which
member tiles the Gaussian covers (bit set via the chosen boundary method at
tile granularity). Rasterization then consumes, per tile, the subsequence of
the group list whose bit is set — extracted here by a linear cumsum/scatter
compaction, the TPU analogue of the RM's bitwise-AND + FIFO stage. Compaction
is O(K) per group (no comparison sort), which is exactly why group-level
sorting is shared 'for free' across the gf^2 member tiles.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.boundary import boundary_test
from repro.core.grouping import BinTable, GridSpec, tile_rect_in_group
from repro.core.projection import Projected, proj_take


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GroupBitmasks:
    masks: jnp.ndarray        # (num_groups, K) uint32 — bit t == covers member tile t
    n_bit_tests: jnp.ndarray  # () int32 — tile-granularity boundary tests run


def generate_bitmasks(
    proj: Projected,
    table: BinTable,
    grid: GridSpec,
    method: str,
) -> GroupBitmasks:
    """BGM: per (group-entry, member-tile) boundary test, packed to bits."""
    num_groups, K = table.gauss_idx.shape
    tpg = grid.tiles_per_group
    group_ids = jnp.arange(num_groups, dtype=jnp.int32)

    gathered = _GatheredProj(proj, table.gauss_idx)  # (G, K) views

    slots = jnp.arange(tpg, dtype=jnp.int32)
    # rects: each component (G, 1, tpg) broadcast against (G, K, 1) features.
    rect = tile_rect_in_group(grid, group_ids[:, None, None], slots[None, None, :])

    hit = boundary_test(method, _Expand(gathered), rect)  # (G, K, tpg)

    # Tiles that fall outside the image (partial edge groups) are masked off.
    gf = grid.gf
    gx = group_ids % grid.n_groups_x
    gy = group_ids // grid.n_groups_x
    tx = gx[:, None] * gf + slots[None, :] % gf
    ty = gy[:, None] * gf + slots[None, :] // gf
    tile_in_image = (tx < grid.n_tiles_x) & (ty < grid.n_tiles_y)  # (G, tpg)
    hit = hit & tile_in_image[:, None, :] & table.entry_valid[:, :, None]

    weights = (jnp.uint32(1) << jnp.arange(tpg, dtype=jnp.uint32))
    masks = jnp.sum(
        hit.astype(jnp.uint32) * weights[None, None, :], axis=-1, dtype=jnp.uint32
    )
    n_tests = jnp.sum(table.entry_valid.astype(jnp.int32)) * tpg
    return GroupBitmasks(masks=masks, n_bit_tests=n_tests)


class _GatheredProj:
    """Projected fields gathered to a (G, K) index table.

    ``proj`` is a flat ``Projected`` or a ``ShardedProjected``: every field
    access routes through ``proj_take``, which decomposes the global index
    table into (shard, local) and fetches from the owning shard when the
    features are kept per-shard (DESIGN.md §12) — bitwise-identical to the
    flat gather either way."""

    def __init__(self, proj, idx: jnp.ndarray):
        self._p = proj
        self._idx = idx

    def __getattr__(self, name):
        return proj_take(self._p, name, self._idx)


class _Expand:
    """Lift (G, K[, F]) gathered fields to (G, K, 1[, F]) for tile broadcast."""

    def __init__(self, g):
        self._g = g

    def __getattr__(self, name):
        v = getattr(self._g, name)
        if v.ndim == 2:
            return v[:, :, None]
        return v[:, :, None, :]


def compact_tiles(
    table: BinTable,
    bitmasks: GroupBitmasks,
    grid: GridSpec,
    tile_capacity: int,
) -> BinTable:
    """RM FIFO stage: per member tile, compact the group-sorted entries whose
    bitmask bit is set, preserving order (hence still depth-sorted).

    Returns a tile-level BinTable of shape (num_tiles, tile_capacity) indexed
    by *global* tile id.
    """
    num_groups, K = table.gauss_idx.shape
    tpg = grid.tiles_per_group
    gf = grid.gf

    bits = (
        (bitmasks.masks[:, :, None] >> jnp.arange(tpg, dtype=jnp.uint32)) & 1
    ).astype(jnp.bool_)  # (G, K, tpg)
    bits = bits & table.entry_valid[:, :, None]

    # Stable compaction per (group, tile): position = exclusive cumsum of bits.
    pos = jnp.cumsum(bits.astype(jnp.int32), axis=1) - 1  # (G, K, tpg)
    lengths = jnp.sum(bits.astype(jnp.int32), axis=1)  # (G, tpg)

    out_idx = jnp.where(bits, pos, tile_capacity)  # overflow & dead -> dumped
    out_idx = jnp.minimum(out_idx, tile_capacity)  # slot tile_capacity = trash

    # Scatter entries into (G, tpg, tile_capacity + 1).
    src = jnp.broadcast_to(table.gauss_idx[:, :, None], bits.shape)
    compact = jnp.full(
        (num_groups, tpg, tile_capacity + 1), 0, dtype=jnp.int32
    )
    g_ix = jnp.broadcast_to(
        jnp.arange(num_groups, dtype=jnp.int32)[:, None, None], bits.shape
    )
    t_ix = jnp.broadcast_to(jnp.arange(tpg, dtype=jnp.int32)[None, None, :], bits.shape)
    compact = compact.at[g_ix, t_ix, out_idx].set(
        src, mode="drop", unique_indices=False
    )
    compact = compact[:, :, :tile_capacity]

    k = jnp.arange(tile_capacity, dtype=jnp.int32)
    entry_valid = k[None, None, :] < jnp.minimum(lengths, tile_capacity)[:, :, None]

    # Re-index (group, slot) -> global tile id.
    group_ids = jnp.arange(num_groups, dtype=jnp.int32)
    slots = jnp.arange(tpg, dtype=jnp.int32)
    gx = group_ids % grid.n_groups_x
    gy = group_ids // grid.n_groups_x
    tx = gx[:, None] * gf + slots[None, :] % gf  # (G, tpg)
    ty = gy[:, None] * gf + slots[None, :] // gf
    in_image = (tx < grid.n_tiles_x) & (ty < grid.n_tiles_y)
    gtile = jnp.where(in_image, ty * grid.n_tiles_x + tx, grid.num_tiles)

    num_tiles = grid.num_tiles
    flat_tile = gtile.reshape(-1)
    flat_idx = compact.reshape(num_groups * tpg, tile_capacity)
    flat_valid = (entry_valid & in_image[:, :, None]).reshape(
        num_groups * tpg, tile_capacity
    )
    flat_len = jnp.where(in_image, lengths, 0).reshape(-1)

    tile_gauss = jnp.zeros((num_tiles + 1, tile_capacity), jnp.int32)
    tile_valid = jnp.zeros((num_tiles + 1, tile_capacity), jnp.bool_)
    tile_len = jnp.zeros((num_tiles + 1,), jnp.int32)
    tile_gauss = tile_gauss.at[flat_tile].set(flat_idx, mode="drop")
    tile_valid = tile_valid.at[flat_tile].set(flat_valid, mode="drop")
    tile_len = tile_len.at[flat_tile].set(flat_len, mode="drop")

    overflow = jnp.sum(jnp.maximum(flat_len - tile_capacity, 0))
    return BinTable(
        gauss_idx=tile_gauss[:num_tiles],
        entry_valid=tile_valid[:num_tiles],
        lengths=tile_len[:num_tiles],
        overflow=overflow,
    )
