"""Gaussian scene container + synthetic scene generation.

A scene is a pytree of learnable parameters (the 3D-GS parameterization):
    means3d   (N, 3)   world-space centers
    log_scales(N, 3)   per-axis log std-dev
    quats     (N, 4)   rotation quaternions (unnormalized; normalized on use)
    opacity   (N,)     pre-sigmoid opacity logits
    sh        (N, K, 3) spherical-harmonics color coefficients (K = (deg+1)^2)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

SH_C0 = 0.28209479177387814  # Y_0^0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GaussianScene:
    means3d: jnp.ndarray
    log_scales: jnp.ndarray
    quats: jnp.ndarray
    opacity: jnp.ndarray
    sh: jnp.ndarray

    @property
    def num_gaussians(self) -> int:
        return self.means3d.shape[0]

    @property
    def sh_degree(self) -> int:
        return int(round(self.sh.shape[1] ** 0.5)) - 1

    def astype(self, dtype) -> "GaussianScene":
        return jax.tree.map(lambda x: x.astype(dtype), self)


def rgb_to_sh0(rgb: jnp.ndarray) -> jnp.ndarray:
    """Inverse of the degree-0 SH color decode (3D-GS convention)."""
    return (rgb - 0.5) / SH_C0


def sh0_to_rgb(sh0: jnp.ndarray) -> jnp.ndarray:
    return sh0 * SH_C0 + 0.5


def quat_to_rotmat(q: jnp.ndarray) -> jnp.ndarray:
    """(..., 4) quaternion (w, x, y, z) -> (..., 3, 3) rotation matrix."""
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    r00 = 1 - 2 * (y * y + z * z)
    r01 = 2 * (x * y - w * z)
    r02 = 2 * (x * z + w * y)
    r10 = 2 * (x * y + w * z)
    r11 = 1 - 2 * (x * x + z * z)
    r12 = 2 * (y * z - w * x)
    r20 = 2 * (x * z - w * y)
    r21 = 2 * (y * z + w * x)
    r22 = 1 - 2 * (x * x + y * y)
    rows = [
        jnp.stack([r00, r01, r02], axis=-1),
        jnp.stack([r10, r11, r12], axis=-1),
        jnp.stack([r20, r21, r22], axis=-1),
    ]
    return jnp.stack(rows, axis=-2)


def covariance3d(log_scales: jnp.ndarray, quats: jnp.ndarray) -> jnp.ndarray:
    """Sigma = R S S^T R^T, (N, 3, 3)."""
    R = quat_to_rotmat(quats)
    S = jnp.exp(log_scales)
    M = R * S[..., None, :]  # R @ diag(S)
    return M @ jnp.swapaxes(M, -1, -2)


def random_scene(
    key: jax.Array,
    num_gaussians: int,
    extent: float = 4.0,
    scale_range=(-4.6, -1.9),
    opacity_range=(-4.5, 3.5),
    sh_degree: int = 0,
    cluster: bool = True,
) -> GaussianScene:
    """Synthetic scene with clustered Gaussians (mimics real-scene tile-sharing
    statistics better than uniform: real 3D-GS scenes are strongly clustered).
    """
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    if cluster:
        n_clusters = max(1, num_gaussians // 64)
        centers = jax.random.uniform(
            k1, (n_clusters, 3), minval=-extent, maxval=extent
        )
        assign = jax.random.randint(k2, (num_gaussians,), 0, n_clusters)
        jitter = jax.random.normal(k3, (num_gaussians, 3)) * (extent * 0.08)
        means = centers[assign] + jitter
    else:
        means = jax.random.uniform(
            k1, (num_gaussians, 3), minval=-extent, maxval=extent
        )
    log_scales = jax.random.uniform(
        k4, (num_gaussians, 3), minval=scale_range[0], maxval=scale_range[1]
    )
    quats = jax.random.normal(k5, (num_gaussians, 4))
    opacity = jax.random.uniform(
        k6, (num_gaussians,), minval=opacity_range[0], maxval=opacity_range[1]
    )
    n_sh = (sh_degree + 1) ** 2
    rgb = jax.random.uniform(k7, (num_gaussians, 3), minval=0.05, maxval=0.95)
    sh = jnp.zeros((num_gaussians, n_sh, 3))
    sh = sh.at[:, 0, :].set(rgb_to_sh0(rgb))
    if n_sh > 1:
        hk = jax.random.fold_in(k7, 1)
        sh = sh.at[:, 1:, :].set(
            0.1 * jax.random.normal(hk, (num_gaussians, n_sh - 1, 3))
        )
    return GaussianScene(
        means3d=means.astype(jnp.float32),
        log_scales=log_scales.astype(jnp.float32),
        quats=quats.astype(jnp.float32),
        opacity=opacity.astype(jnp.float32),
        sh=sh.astype(jnp.float32),
    )


def scene_like_paper(key: jax.Array, name: str, num_gaussians: Optional[int] = None) -> GaussianScene:
    """Synthetic stand-in scaled to the paper's six evaluation scenes.

    Pretrained 3D-GS-30k checkpoints are not shipped offline; these scenes match
    the *statistics that drive the paper's effect* (Gaussian count scale, spatial
    clustering, screen-space footprint distribution), which is what Table I /
    Figs 5,7 measure.
    """
    from repro.configs.gs_scenes import PAPER_SCENES

    spec = PAPER_SCENES[name]
    n = num_gaussians if num_gaussians is not None else spec.synthetic_gaussians
    return random_scene(
        key,
        n,
        extent=spec.extent,
        cluster=True,
    )
