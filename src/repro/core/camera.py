"""Pinhole camera model for 3D-GS rendering.

World-to-camera extrinsics (R, t) with OpenCV conventions: +z looks into the
scene, x right, y down. Intrinsics are (fx, fy, cx, cy) in pixels.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Camera:
    """Static camera description. Arrays are small (3x3 / 3-vec) numpy values.

    Kept as a frozen dataclass of *numpy* arrays so it can be closed over by
    jitted renderers without becoming a traced argument.
    """

    R: np.ndarray          # (3, 3) world->camera rotation
    t: np.ndarray          # (3,)  world->camera translation
    fx: float
    fy: float
    cx: float
    cy: float
    width: int
    height: int
    znear: float = 0.2
    zfar: float = 1000.0

    def resolution(self) -> Tuple[int, int]:
        return self.width, self.height


def look_at(eye, target, up=(0.0, 1.0, 0.0)) -> Tuple[np.ndarray, np.ndarray]:
    """Build world->camera (R, t) looking from ``eye`` toward ``target``."""
    eye = np.asarray(eye, np.float32)
    target = np.asarray(target, np.float32)
    up = np.asarray(up, np.float32)
    fwd = target - eye
    fwd = fwd / (np.linalg.norm(fwd) + 1e-12)
    right = np.cross(fwd, up)
    right = right / (np.linalg.norm(right) + 1e-12)
    down = np.cross(fwd, right)
    R = np.stack([right, down, fwd], axis=0)  # rows = camera axes in world
    t = -R @ eye
    return R.astype(np.float32), t.astype(np.float32)


def make_camera(
    eye,
    target,
    width: int,
    height: int,
    fov_x_deg: float = 60.0,
    up=(0.0, 1.0, 0.0),
    znear: float = 0.2,
    zfar: float = 1000.0,
) -> Camera:
    R, t = look_at(eye, target, up)
    fx = 0.5 * width / np.tan(0.5 * np.deg2rad(fov_x_deg))
    fy = fx  # square pixels
    return Camera(
        R=R,
        t=t,
        fx=float(fx),
        fy=float(fy),
        cx=width / 2.0,
        cy=height / 2.0,
        width=int(width),
        height=int(height),
        znear=znear,
        zfar=zfar,
    )


def orbit_cameras(
    n: int,
    radius: float,
    width: int,
    height: int,
    elevation: float = 0.35,
    fov_x_deg: float = 60.0,
) -> list:
    """A ring of n cameras orbiting the origin — synthetic eval trajectory."""
    cams = []
    for i in range(n):
        ang = 2.0 * np.pi * i / max(n, 1)
        eye = (
            radius * np.cos(ang),
            radius * elevation,
            radius * np.sin(ang),
        )
        cams.append(make_camera(eye, (0.0, 0.0, 0.0), width, height, fov_x_deg))
    return cams


def world_to_cam(R: jnp.ndarray, t: jnp.ndarray, xyz: jnp.ndarray) -> jnp.ndarray:
    """(N,3) world points -> camera frame."""
    return xyz @ R.T + t[None, :]
