"""Group/tile identification and static-shape binning (paper §IV-B).

TPU adaptation: GPU 3D-GS builds variable-length per-tile lists with atomics +
radix sort over duplicated (tileID||depth) keys. XLA needs static shapes, so we
enumerate a bounded grid of candidate bins per Gaussian (span x span window over
the bin grid, pre-filtered by the circumscribed-radius bbox exactly like
GSCore/FlashGS pre-filter with the AABB before running finer tests), flatten
to a global pair list, and bin with a stable two-key sort (depth, then bin id
— jnp.lexsort semantics via composed stable argsorts). Per-bin segments are
then extracted with searchsorted into a fixed-capacity table.

The SAME machinery runs at group granularity (GS-TG) and tile granularity
(per-tile baseline): the redundant-sorting reduction the paper measures is the
ratio of valid pair counts between the two.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.boundary import boundary_test
from repro.core.camera import Camera
from repro.core.projection import Projected
from repro.utils import cdiv, wide_count_dtype, wide_count_sum


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static geometry of the tile/group decomposition."""

    width: int
    height: int
    tile: int           # small tile side in pixels (e.g. 16)
    group: int          # group side in pixels (e.g. 64); must be k*tile
    span: int = 4       # candidate window (in bins) per Gaussian at group level

    def __post_init__(self):
        if self.group % self.tile != 0:
            raise ValueError("group size must be a multiple of tile size")
        if self.width % self.tile or self.height % self.tile:
            raise ValueError("image dims must be multiples of the tile size")

    @property
    def gf(self) -> int:
        """Group factor: tiles per group side."""
        return self.group // self.tile

    @property
    def tiles_per_group(self) -> int:
        return self.gf * self.gf

    @property
    def n_tiles_x(self) -> int:
        return cdiv(self.width, self.tile)

    @property
    def n_tiles_y(self) -> int:
        return cdiv(self.height, self.tile)

    @property
    def n_groups_x(self) -> int:
        return cdiv(self.width, self.group)

    @property
    def n_groups_y(self) -> int:
        return cdiv(self.height, self.group)

    @property
    def num_tiles(self) -> int:
        return self.n_tiles_x * self.n_tiles_y

    @property
    def num_groups(self) -> int:
        return self.n_groups_x * self.n_groups_y

    def bins(self, level: str) -> Tuple[int, int, int]:
        """(n_bins_x, n_bins_y, bin_px) for 'group' or 'tile' level."""
        if level == "group":
            return self.n_groups_x, self.n_groups_y, self.group
        if level == "tile":
            return self.n_tiles_x, self.n_tiles_y, self.tile
        raise ValueError(level)

    def span_for(self, level: str) -> int:
        if level == "group":
            return self.span
        return self.span * self.gf


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PairSet:
    """Flattened (gaussian, bin) candidate pairs. All (P,) arrays."""

    bin_id: jnp.ndarray     # int32, == num_bins for invalid pairs (sorts last)
    gauss_idx: jnp.ndarray  # int32
    depth: jnp.ndarray      # float32, +inf for invalid
    valid: jnp.ndarray      # bool
    # -- counters (scalars) --
    n_candidate_tests: jnp.ndarray  # boundary tests (wide_count_dtype: can
                                    #   exceed int32 at tile level on big scenes)
    n_pairs: jnp.ndarray            # valid (gaussian, bin) pairs == sort keys
    n_span_overflow: jnp.ndarray    # bins lost to the static span window


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BinTable:
    """Fixed-capacity per-bin entry table (depth-sorted within each bin)."""

    gauss_idx: jnp.ndarray  # (B, K) int32 — index into the Projected arrays
    entry_valid: jnp.ndarray  # (B, K) bool
    lengths: jnp.ndarray    # (B,) int32 true segment length (pre-clamp)
    overflow: jnp.ndarray   # () int32 total entries dropped by capacity K

    @property
    def capacity(self) -> int:
        return self.gauss_idx.shape[1]

    @property
    def num_bins(self) -> int:
        return self.gauss_idx.shape[0]


def identify(
    proj: Projected,
    grid: GridSpec,
    level: str,
    method: str,
) -> PairSet:
    """Enumerate candidate (gaussian, bin) pairs and run the boundary test.

    This is the paper's 'tile identification' (level='tile') or 'group
    identification' (level='group') step.
    """
    n_bins_x, n_bins_y, bin_px = grid.bins(level)
    span = grid.span_for(level)
    num_bins = n_bins_x * n_bins_y

    mx, my = proj.mean2d[:, 0], proj.mean2d[:, 1]
    r = proj.radius
    # Circumscribed-radius pre-filter bbox (in bin coords), clipped to grid.
    bx0 = jnp.clip(jnp.floor((mx - r) / bin_px).astype(jnp.int32), 0, n_bins_x - 1)
    bx1 = jnp.clip(jnp.floor((mx + r) / bin_px).astype(jnp.int32), 0, n_bins_x - 1)
    by0 = jnp.clip(jnp.floor((my - r) / bin_px).astype(jnp.int32), 0, n_bins_y - 1)
    by1 = jnp.clip(jnp.floor((my + r) / bin_px).astype(jnp.int32), 0, n_bins_y - 1)

    dx = jnp.arange(span, dtype=jnp.int32)
    dy = jnp.arange(span, dtype=jnp.int32)
    # (N, span) each
    cand_x = bx0[:, None] + dx[None, :]
    cand_y = by0[:, None] + dy[None, :]
    in_bbox_x = cand_x <= bx1[:, None]
    in_bbox_y = cand_y <= by1[:, None]

    # (N, span, span)
    cx = cand_x[:, :, None]
    cy = cand_y[:, None, :]
    in_bbox = in_bbox_x[:, :, None] & in_bbox_y[:, None, :]
    in_bbox = in_bbox & proj.valid[:, None, None]

    rect = (
        (cx * bin_px).astype(jnp.float32),
        (cy * bin_px).astype(jnp.float32),
        ((cx + 1) * bin_px).astype(jnp.float32),
        ((cy + 1) * bin_px).astype(jnp.float32),
    )
    # Broadcast Projected fields to (N, 1, 1) for the test.
    bproj = _BroadcastProj(proj)
    hit = in_bbox & boundary_test(method, bproj, rect)

    bin_id = jnp.where(hit, cy * n_bins_x + cx, num_bins).astype(jnp.int32)
    N, S = proj.mean2d.shape[0], span
    flat = lambda a: a.reshape(N * S * S)
    gauss_idx = jnp.broadcast_to(
        jnp.arange(N, dtype=jnp.int32)[:, None, None], (N, S, S)
    )
    depth = jnp.where(hit, proj.depth[:, None, None], jnp.inf)

    # Span-window overflow: bbox bins beyond the static window.
    full_w = jnp.where(proj.valid, bx1 - bx0 + 1, 0)
    full_h = jnp.where(proj.valid, by1 - by0 + 1, 0)
    lost = full_w * full_h - jnp.minimum(full_w, span) * jnp.minimum(full_h, span)

    return PairSet(
        bin_id=flat(bin_id),
        gauss_idx=flat(gauss_idx),
        depth=flat(depth).astype(jnp.float32),
        valid=flat(hit),
        n_candidate_tests=wide_count_sum(in_bbox),
        n_pairs=jnp.sum(hit.astype(jnp.int32)),
        n_span_overflow=jnp.sum(lost),
    )


class _BroadcastProj:
    """View of Projected with (N,) fields lifted to (N, 1, 1)."""

    def __init__(self, proj: Projected):
        self._p = proj

    def __getattr__(self, name):
        v = getattr(self._p, name)
        if v.ndim == 1:
            return v[:, None, None]
        return v[:, None, None, :]


def bin_pairs(pairs: PairSet, num_bins: int, capacity: int) -> BinTable:
    """Stable (bin, depth) sort + fixed-capacity segment extraction.

    Stability gives the 3D-GS tie-break (insertion order == gaussian index),
    which is what makes the GS-TG per-tile subsequence *bitwise* identical to
    the per-tile baseline ordering.
    """
    # Two-pass stable sort == lexicographic (bin_id, depth, original index).
    # Ordering is non-differentiable by design (3D-GS treats it as constant);
    # stop_gradient also keeps sort JVP machinery out of the backward graph.
    depth_keys = jax.lax.stop_gradient(pairs.depth)
    order_d = jnp.argsort(depth_keys, stable=True)
    bin_by_d = pairs.bin_id[order_d]
    order_b = jnp.argsort(bin_by_d, stable=True)
    order = order_d[order_b]

    sorted_bins = pairs.bin_id[order]
    sorted_gauss = pairs.gauss_idx[order]

    starts = jnp.searchsorted(sorted_bins, jnp.arange(num_bins, dtype=jnp.int32))
    ends = jnp.searchsorted(
        sorted_bins, jnp.arange(1, num_bins + 1, dtype=jnp.int32)
    )
    lengths = (ends - starts).astype(jnp.int32)

    k = jnp.arange(capacity, dtype=jnp.int32)
    idx = starts[:, None] + k[None, :]
    entry_valid = k[None, :] < jnp.minimum(lengths, capacity)[:, None]
    idx = jnp.clip(idx, 0, sorted_gauss.shape[0] - 1)
    gauss_idx = sorted_gauss[idx]
    gauss_idx = jnp.where(entry_valid, gauss_idx, 0)

    overflow = jnp.sum(jnp.maximum(lengths - capacity, 0))
    return BinTable(
        gauss_idx=gauss_idx,
        entry_valid=entry_valid,
        lengths=lengths,
        overflow=overflow,
    )


def sort_op_count(lengths: jnp.ndarray) -> jnp.ndarray:
    """Comparator-op model: sum_b L_b * ceil(log2 max(L_b, 2)).

    The n·log n model matches both the GPU radix/merge path and the paper's
    GSM comparator tree up to a constant, so *ratios* between per-tile and
    per-group sorting are preserved. Accumulated in ``wide_count_dtype`` —
    an int32 total wraps negative around ~80M sort keys (multi-million-
    Gaussian scenes at tile granularity).
    """
    L = lengths.astype(jnp.float32)
    logL = jnp.ceil(jnp.log2(jnp.maximum(L, 2.0)))
    return wide_count_sum(L * logL)


def merge_bin_tables(tables: BinTable, depth: jnp.ndarray) -> BinTable:
    """Merge D per-shard bin tables into the global depth-ordered table.

    ``tables`` is a shard-stacked BinTable (every field with a leading shard
    axis: gauss_idx/entry_valid ``(D, B, K)``, lengths ``(D, B)``) whose
    ``gauss_idx`` entries are already GLOBAL gaussian indices; ``depth`` is
    the per-entry sort key ``(D, B, K)``.

    Bitwise-identity invariant (DESIGN.md §10): provided the shards partition
    the gaussian axis contiguously in global order (sharding/scene.py layout)
    and the per-shard capacity is >= the merged capacity K, the result equals
    ``bin_pairs`` on the unsharded pair set, field for field:

      * each shard's per-bin segment is a subsequence of the global segment
        (stable per-shard sort preserves relative order, and within a shard
        the flattened pair order equals the global one);
      * concatenating shard-major and re-sorting by depth with a STABLE sort
        breaks depth ties by concatenation position = (shard, within-shard
        insertion) = global insertion order — exactly the 3D-GS tie-break the
        losslessness proof needs (§7);
      * even under capacity overflow the first K merged entries equal the
        global top-K: any entry in the global top-K has < K predecessors in
        its own shard, so per-shard clamping at K never drops it.

    Invalid slots carry key +inf and sort last; merged lengths are the exact
    (pre-clamp) per-bin totals, so overflow accounting matches the replicated
    path integer for integer.

    Downstream, the merged ``gauss_idx`` stays GLOBAL: feature-sharded
    consumers (DESIGN.md §12) decompose it back into ``(idx // Ns, idx %
    Ns)`` at each gather site (``core/projection.py::proj_take``) — the
    contiguous layout makes the decomposition a pure arithmetic view, which
    is why the merge needs no layout changes for feature sharding.

    Property-tested standalone in tests/test_grouping.py (hypothesis: depth
    ties, per-bin overflow, all-padding shards, D ∈ {1..4}) on top of the
    end-to-end render parity suite (tests/test_sharding.py).
    """
    D, B, K = tables.gauss_idx.shape
    key = jnp.where(tables.entry_valid, depth, jnp.inf)
    cat = lambda a: jnp.swapaxes(a, 0, 1).reshape(B, D * K)  # shard-major
    order = jnp.argsort(cat(key), axis=1, stable=True)[:, :K]
    merged_idx = jnp.take_along_axis(cat(tables.gauss_idx), order, axis=1)

    lengths = jnp.sum(tables.lengths, axis=0)  # (B,) exact pre-clamp totals
    k = jnp.arange(K, dtype=jnp.int32)
    entry_valid = k[None, :] < jnp.minimum(lengths, K)[:, None]
    overflow = jnp.sum(jnp.maximum(lengths - K, 0))
    return BinTable(
        gauss_idx=jnp.where(entry_valid, merged_idx, 0),
        entry_valid=entry_valid,
        lengths=lengths,
        overflow=overflow,
    )


def tile_rect_in_group(grid: GridSpec, group_ids: jnp.ndarray, tile_slot: jnp.ndarray):
    """Pixel rect of member tile ``tile_slot`` (0..gf^2-1) of each group."""
    gf = grid.gf
    gx = (group_ids % grid.n_groups_x).astype(jnp.float32)
    gy = (group_ids // grid.n_groups_x).astype(jnp.float32)
    tx = (tile_slot % gf).astype(jnp.float32)
    ty = (tile_slot // gf).astype(jnp.float32)
    x0 = gx * grid.group + tx * grid.tile
    y0 = gy * grid.group + ty * grid.tile
    return (x0, y0, x0 + grid.tile, y0 + grid.tile)


def group_tile_to_global_tile(grid: GridSpec, group_id, tile_slot):
    """Map (group, member-slot) -> global tile id in the tile grid."""
    gf = grid.gf
    gx = group_id % grid.n_groups_x
    gy = group_id // grid.n_groups_x
    tx = gx * gf + tile_slot % gf
    ty = gy * gf + tile_slot // gf
    return ty * grid.n_tiles_x + tx
