from repro.core.camera import Camera, make_camera, orbit_cameras
from repro.core.gaussians import GaussianScene, random_scene
from repro.core.grouping import GridSpec
from repro.core.pipeline import (
    CameraBatch,
    RenderConfig,
    RenderResult,
    batch_signature,
    frontend_stats,
    register_render_cache,
    render,
    render_batch,
    render_cache_clear,
    render_cache_info,
    render_image,
    render_jit,
    unregister_render_cache,
)
from repro.core.projection import Projected, project
from repro.core.stages import Backend, get_backend, register_backend

__all__ = [
    "Camera",
    "CameraBatch",
    "make_camera",
    "orbit_cameras",
    "GaussianScene",
    "random_scene",
    "GridSpec",
    "RenderConfig",
    "RenderResult",
    "batch_signature",
    "frontend_stats",
    "register_render_cache",
    "render",
    "render_batch",
    "render_cache_clear",
    "render_cache_info",
    "render_image",
    "render_jit",
    "unregister_render_cache",
    "Projected",
    "project",
    "Backend",
    "get_backend",
    "register_backend",
]
