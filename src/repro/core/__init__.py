from repro.core.camera import Camera, make_camera, orbit_cameras
from repro.core.gaussians import GaussianScene, random_scene
from repro.core.grouping import GridSpec
from repro.core.pipeline import RenderConfig, RenderResult, render, render_image
from repro.core.projection import Projected, project

__all__ = [
    "Camera",
    "make_camera",
    "orbit_cameras",
    "GaussianScene",
    "random_scene",
    "GridSpec",
    "RenderConfig",
    "RenderResult",
    "render",
    "render_image",
    "Projected",
    "project",
]
