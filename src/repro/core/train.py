"""3D-GS scene optimization (the substrate the paper's renderer sits on).

Optimizes Gaussian parameters against target images with Adam — the standard
3D-GS training loop (L1 + D-SSIM loss), differentiable through the GS-TG
renderer (sorting order treated as constant, as in the reference
implementation). Lossless GS-TG means training through either pipeline is
identical; we default to gstg.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.metrics import dssim, psnr
from repro.core.pipeline import RenderConfig, render
from repro.optim.adamw import adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class SceneTrainConfig:
    lr_means: float = 1.6e-3
    lr_scales: float = 5e-3
    lr_quats: float = 1e-3
    lr_opacity: float = 5e-2
    lr_sh: float = 2.5e-3
    lambda_dssim: float = 0.2
    steps: int = 200


def scene_loss(scene: GaussianScene, cam: Camera, target, cfg: RenderConfig, lam: float):
    img = render(scene, cam, cfg).image
    l1 = jnp.mean(jnp.abs(img - target))
    return (1.0 - lam) * l1 + lam * dssim(img, target), img


def make_train_step(cam: Camera, cfg: RenderConfig, tcfg: SceneTrainConfig):
    lrs = GaussianScene(
        means3d=jnp.float32(tcfg.lr_means),
        log_scales=jnp.float32(tcfg.lr_scales),
        quats=jnp.float32(tcfg.lr_quats),
        opacity=jnp.float32(tcfg.lr_opacity),
        sh=jnp.float32(tcfg.lr_sh),
    )

    @jax.jit
    def step(scene: GaussianScene, opt_state, target, i):
        (loss, img), grads = jax.value_and_grad(
            lambda s: scene_loss(s, cam, target, cfg, tcfg.lambda_dssim),
            has_aux=True,
        )(scene)
        scene, opt_state = adamw_update(
            scene, grads, opt_state, i, lr=lrs, weight_decay=0.0
        )
        return scene, opt_state, loss, psnr(img, target)

    return step


def fit_scene(
    scene: GaussianScene,
    cams: List[Camera],
    targets: List[jnp.ndarray],
    cfg: RenderConfig,
    tcfg: SceneTrainConfig,
    log_every: int = 50,
) -> Tuple[GaussianScene, List[dict]]:
    """Optimize scene params against (camera, target image) pairs."""
    opt_state = adamw_init(scene)
    history = []
    steps = [make_train_step(cam, cfg, tcfg) for cam in cams]
    for i in range(tcfg.steps):
        vi = i % len(cams)
        scene, opt_state, loss, p = steps[vi](
            scene, opt_state, targets[vi], jnp.int32(i)
        )
        if i % log_every == 0 or i == tcfg.steps - 1:
            history.append({"step": i, "loss": float(loss), "psnr": float(p)})
    return scene, history
