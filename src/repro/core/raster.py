"""Tile-wise rasterization (paper Fig 1 right: alpha-computation + blending).

Pure-jnp differentiable reference. Consumes a tile-level BinTable (each tile's
depth-ordered entry list — produced either by the per-tile baseline binning or
by GS-TG's group-sort + bitmask compaction; both yield the same table, which
is the losslessness property).

Alpha rule (both pipelines, kernel and reference — this exact rule is what
makes any conservative boundary method lossless, see DESIGN.md):
    q     = (p - mu)^T Conic (p - mu)
    alpha = min(opacity * exp(-q/2), ALPHA_MAX)
    alpha = 0  if q > 9 (3-sigma)  or  alpha < 1/255
Blending is front-to-back with per-pixel early exit when transmittance drops
below T_EPS (identical chunked masking in both pipelines => identical fp op
order => bitwise-equal images).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.grouping import BinTable, GridSpec
from repro.core.projection import Projected, QMAX_3SIGMA, proj_take

ALPHA_MIN = 1.0 / 255.0
ALPHA_MAX = 0.99
T_EPS = 1e-4


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RasterOut:
    image: jnp.ndarray        # (H, W, 3)
    alpha_ops: jnp.ndarray    # (): per-pixel alpha computations executed
    blend_ops: jnp.ndarray    # (): blends that actually contributed
    processed: jnp.ndarray    # (num_tiles,): entries processed per tile


def tile_pixel_coords(grid: GridSpec) -> jnp.ndarray:
    """(num_tiles, T*T, 2) pixel-center coordinates per tile."""
    T = grid.tile
    tix = jnp.arange(grid.num_tiles, dtype=jnp.int32)
    tx = (tix % grid.n_tiles_x) * T
    ty = (tix // grid.n_tiles_x) * T
    px = jnp.arange(T, dtype=jnp.float32) + 0.5
    xx, yy = jnp.meshgrid(px, px, indexing="xy")
    offs = jnp.stack([xx.reshape(-1), yy.reshape(-1)], axis=-1)  # (T*T, 2)
    base = jnp.stack([tx, ty], axis=-1).astype(jnp.float32)
    return base[:, None, :] + offs[None, :, :]


def alpha_at(pix, mean2d, conic, opacity):
    """Alpha with the q<=9 and 1/255 cutoffs. Shapes broadcast; returns (...)."""
    d = pix - mean2d
    q = (
        conic[..., 0] * d[..., 0] * d[..., 0]
        + 2.0 * conic[..., 1] * d[..., 0] * d[..., 1]
        + conic[..., 2] * d[..., 1] * d[..., 1]
    )
    a = opacity * jnp.exp(-0.5 * q)
    a = jnp.minimum(a, ALPHA_MAX)
    return jnp.where((q > QMAX_3SIGMA) | (a < ALPHA_MIN), 0.0, a)


def rasterize(
    proj: Projected,
    table: BinTable,
    grid: GridSpec,
    background: jnp.ndarray | None = None,
    chunk: int = 32,
    early_exit: bool = True,
) -> RasterOut:
    """Rasterize all tiles. Differentiable w.r.t. scene features (the discrete
    ordering is treated as constant, as in standard 3D-GS training)."""
    if background is None:
        background = jnp.zeros((3,), jnp.float32)
    num_tiles, K = table.gauss_idx.shape
    assert num_tiles == grid.num_tiles
    T = grid.tile
    P = T * T
    pix = tile_pixel_coords(grid)  # (num_tiles, P, 2)

    # proj_take handles flat AND shard-kept features (DESIGN.md §12): the
    # global table indices decompose to (shard, local) and each entry's
    # features come from its owning shard, bitwise-equal to the flat gather.
    mean2d = proj_take(proj, "mean2d", table.gauss_idx)   # (num_tiles, K, 2)
    conic = proj_take(proj, "conic", table.gauss_idx)
    rgb = proj_take(proj, "rgb", table.gauss_idx)
    opac = jnp.where(
        table.entry_valid, proj_take(proj, "alpha", table.gauss_idx), 0.0
    )

    n_chunks = -(-K // chunk)
    pad = n_chunks * chunk - K
    if pad:
        padk = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        mean2d, conic, rgb, opac = map(padk, (mean2d, conic, rgb, opac))

    def render_tile(pix_t, m_all, cn_all, cl_all, op_all):
        def tile_body(carry, xs):
            t_run, c_run, a_ops, b_ops = carry
            m, cn, cl, op = xs  # (chunk, ...)
            alpha = alpha_at(
                pix_t[:, None, :], m[None, :, :], cn[None, :, :], op[None, :]
            )  # (P, chunk)
            one_m = 1.0 - alpha
            cp = jnp.cumprod(one_m, axis=1)
            excl = jnp.concatenate([jnp.ones_like(cp[:, :1]), cp[:, :-1]], axis=1)
            t_before = excl * t_run[:, None]  # transmittance BEFORE each entry
            w = alpha * t_before
            if early_exit:
                # Exact per-entry early exit: T is monotone non-increasing, so
                # gating each entry by its own T_before reproduces the
                # sequential 'break' semantics — and is bitwise insensitive to
                # interleaved zero-alpha entries (they leave T unchanged),
                # which is what makes every conservative boundary-method combo
                # exactly lossless.
                live = t_before > T_EPS
                w = jnp.where(live, w, 0.0)
            else:
                live = jnp.ones_like(w, dtype=jnp.bool_)
            c_run = c_run + w @ cl
            t_run = t_run * cp[:, -1]
            a_ops = a_ops + jnp.sum(
                live.astype(jnp.int32) * (op > 0).astype(jnp.int32)[None, :]
            )
            b_ops = b_ops + jnp.sum((w > 0).astype(jnp.int32))
            return (t_run, c_run, a_ops, b_ops), None

        resh = lambda a: a.reshape(n_chunks, chunk, *a.shape[1:])
        carry = (
            jnp.ones((P,), jnp.float32),
            jnp.zeros((P, 3), jnp.float32),
            jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32),
        )
        (t_run, c_run, a_ops, b_ops), _ = jax.lax.scan(
            tile_body, carry, (resh(m_all), resh(cn_all), resh(cl_all), resh(op_all))
        )
        color = c_run + t_run[:, None] * background[None, :]
        return color, a_ops, b_ops

    colors, a_ops, b_ops = jax.vmap(render_tile)(pix, mean2d, conic, rgb, opac)

    img = colors.reshape(grid.n_tiles_y, grid.n_tiles_x, T, T, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(
        grid.n_tiles_y * T, grid.n_tiles_x * T, 3
    )
    img = img[: grid.height, : grid.width]

    processed = jnp.sum(table.entry_valid.astype(jnp.int32), axis=1)
    return RasterOut(
        image=img,
        alpha_ops=jnp.sum(a_ops),
        blend_ops=jnp.sum(b_ops),
        processed=processed,
    )
