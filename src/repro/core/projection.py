"""Preprocessing stage of the 3D-GS pipeline (paper Fig 1, left).

Computes, per Gaussian: depth D, 2D center, 2D covariance (+ its conic
inverse), screen-space radius (3-sigma rule, as in the original 3D-GS), view
color from SH, and the frustum-culling validity mask.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene, covariance3d, SH_C0

# Low-pass filter added to the 2D covariance diagonal (anti-aliasing), exactly
# as in the reference 3D-GS rasterizer.
COV2D_BLUR = 0.3
# 3-sigma rule for the Gaussian's screen extent (paper §II-B).
SIGMA_CUT = 3.0
# Power threshold matching alpha >= 1/255 for the *ellipse* boundary:
# alpha = opa * exp(-q/2) >= 1/255  <=>  q <= 2*ln(255*opa).  The 3-sigma rule
# corresponds to q <= 9; we use q<=9 (paper) and keep the opacity-aware bound
# available as a beyond-paper optimization.
QMAX_3SIGMA = SIGMA_CUT * SIGMA_CUT

SH_C1 = 0.4886025119029199


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Projected:
    """Per-Gaussian screen-space features (all (N, ...))."""

    mean2d: jnp.ndarray      # (N, 2) pixel coords
    cov2d: jnp.ndarray       # (N, 3) upper-triangular (a, b, c): [[a, b], [b, c]]
    conic: jnp.ndarray       # (N, 3) inverse covariance, same packing
    depth: jnp.ndarray       # (N,)
    radius: jnp.ndarray      # (N,) 3-sigma screen radius (pixels)
    axis_radius: jnp.ndarray # (N, 2) 3-sigma per screen axis (AABB half-extent)
    eigvec: jnp.ndarray      # (N, 2) major-axis unit vector (for OBB)
    eigval: jnp.ndarray      # (N, 2) eigenvalues (major, minor) of cov2d
    rgb: jnp.ndarray         # (N, 3) decoded view-dependent color
    alpha: jnp.ndarray       # (N,) sigmoid opacity
    valid: jnp.ndarray       # (N,) bool frustum/size cull mask


# Per-field trailing widths of Projected — the basis of the per-camera
# activation term in the device-budget model (engine/handle.py): every field
# is float32 except ``valid`` (bool, 1 byte).
PROJECTED_FIELD_WIDTHS = {
    "mean2d": 2, "cov2d": 3, "conic": 3, "depth": 1, "radius": 1,
    "axis_radius": 2, "eigvec": 2, "eigval": 2, "rgb": 3, "alpha": 1,
    "valid": 1,
}


def projected_bytes_per_gaussian() -> int:
    """Bytes of projected per-camera features one (padded) gaussian costs.

    This is the N-proportional transient the feature-sharded gathers divide
    by D (DESIGN.md §12): with ``feature_gather != 'flat'`` each device
    materializes only its own ``N/D`` rows of every field below.
    """
    # Guard against schema drift: a Projected field added without updating
    # the widths dict would silently undercount the device-budget model.
    assert set(PROJECTED_FIELD_WIDTHS) == {
        f.name for f in dataclasses.fields(Projected)
    }, "PROJECTED_FIELD_WIDTHS out of sync with Projected's fields"
    return sum(
        w * (1 if name == "valid" else 4)
        for name, w in PROJECTED_FIELD_WIDTHS.items()
    )


@dataclasses.dataclass
class ShardedProjected:
    """Projected features kept in the per-shard layout (DESIGN.md §12).

    ``shards`` holds the ordinary :class:`Projected` arrays with a leading
    ``(D, Ns)`` shard axis — the direct output of the per-shard projection
    stage, NEVER concatenated to the flat padded ``(D * Ns, ...)`` view (the
    concat is the full-N per-camera allocation feature sharding removes).
    ``gather`` (static metadata) selects how downstream consumers fetch an
    entry's features from its owning shard:

      * ``'index'`` — plain 2-D indexed gather ``field[shard, local]``; the
        right strategy on one device or a logical-only shard axis.
      * ``'psum'``  — owner-masked per-shard gathers summed across the shard
        axis ON THE RAW BIT PATTERNS (exactly one shard owns each entry, so
        the integer sum reproduces the owner's float bits exactly). Under a
        2-D ``('data', 'model')`` mesh the sum over the sharded axis lowers
        to partial per-device gathers + an all-reduce — the Megatron-style
        collective form that never materializes full-N features per device.

    Both strategies are bitwise-identical to the flat gather
    ``concat(shards)[global_idx]`` because gathers commute with
    concatenation: ``flat[g] == shards[g // Ns, g % Ns]``.

    Differentiability: ``'index'`` (the default resolution of ``'auto'``)
    is an ordinary gather and differentiates like the flat path; ``'psum'``
    routes floats through a bit view (``bitcast_convert_type``) and is
    inference-only — exactly the serving paths the engine handle commits it
    for. Training with a sharded scene stays on ``'index'``/``'flat'``.
    """

    shards: Projected        # every field with leading (D, Ns) axes
    gather: str = "index"    # static: 'index' | 'psum'

    @property
    def num_shards(self) -> int:
        return self.shards.depth.shape[0]

    @property
    def shard_size(self) -> int:
        return self.shards.depth.shape[1]

    @property
    def valid(self) -> jnp.ndarray:
        """(D, Ns) cull mask — reductions over it equal the flat ones."""
        return self.shards.valid


jax.tree_util.register_dataclass(
    ShardedProjected, data_fields=["shards"], meta_fields=["gather"]
)

FEATURE_GATHER_STRATEGIES = ("index", "psum", "flat")


def _gather_owner_sum(x: jnp.ndarray, shard: jnp.ndarray, local: jnp.ndarray):
    """Owner-masked gather-and-sum over the shard axis, bit-exact.

    ``x``: (D, Ns, *F); ``shard``/``local``: any index shape. Each shard
    contributes its own rows where it owns the entry and zero bits
    elsewhere; the cross-shard sum runs on the raw bit patterns (uint view),
    so exactly-one-owner implies the result is the owner's bits verbatim —
    float signed zeros, NaN payloads and all. This is the form GSPMD
    partitions as per-device gathers + all-reduce when the leading axis lays
    over the mesh 'model' axis (sharding/policies.py::feature_shard_pspec).
    """
    D = x.shape[0]
    if jnp.issubdtype(x.dtype, jnp.floating):
        bits = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}[x.dtype.itemsize]
        view, restore = (
            jax.lax.bitcast_convert_type(x, bits),
            lambda v: jax.lax.bitcast_convert_type(v, x.dtype),
        )
    elif x.dtype == jnp.bool_:
        view, restore = x.astype(jnp.uint8), lambda v: v.astype(jnp.bool_)
    else:
        view, restore = x, lambda v: v

    def contrib(d, xd):
        own = shard == d
        g = xd[jnp.where(own, local, 0)]
        mask = own.reshape(own.shape + (1,) * (g.ndim - own.ndim))
        return jnp.where(mask, g, jnp.zeros((), view.dtype))

    # Pin the accumulator dtype: under x64, jnp.sum would promote a uint32
    # bit-view to uint64 and the bitcast back to float32 would then SPLIT a
    # trailing dimension. Exactly one contribution is nonzero, so the
    # same-width sum cannot overflow.
    out = jnp.sum(
        jax.vmap(contrib)(jnp.arange(D, dtype=shard.dtype), view),
        axis=0,
        dtype=view.dtype,
    )
    return restore(out)


def proj_take(proj, name: str, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather field ``name`` of a flat OR sharded Projected at global
    gaussian indices ``idx`` — THE single gather primitive every downstream
    consumer (reference bitmask/raster gathers, the pallas feature packer)
    routes through, so the (shard, local) index decomposition lives in one
    place and the bitwise-parity argument is made once (DESIGN.md §12)."""
    if not isinstance(proj, ShardedProjected):
        return getattr(proj, name)[idx]
    x = getattr(proj.shards, name)
    shard, local = jnp.divmod(idx, jnp.int32(proj.shard_size))
    if proj.gather == "psum":
        return _gather_owner_sum(x, shard, local)
    return x[shard, local]


def proj_valid_count(proj) -> jnp.ndarray:
    """Visible-gaussian count for flat or sharded features (exact integer
    reduction, so the shard-summed total equals the flat one bitwise)."""
    return jnp.sum(proj.valid.astype(jnp.int32))


def eval_sh(sh: jnp.ndarray, dirs: jnp.ndarray) -> jnp.ndarray:
    """Evaluate SH color (deg 0 or 1 supported; higher coeffs ignored).

    sh: (N, K, 3); dirs: (N, 3) unit view directions.
    """
    rgb = SH_C0 * sh[:, 0, :]
    if sh.shape[1] >= 4:
        x, y, z = dirs[:, 0:1], dirs[:, 1:2], dirs[:, 2:3]
        rgb = rgb + SH_C1 * (-y * sh[:, 1, :] + z * sh[:, 2, :] - x * sh[:, 3, :])
    return jnp.clip(rgb + 0.5, 0.0, 1.0)


def project(scene: GaussianScene, cam: Camera) -> Projected:
    """The preprocessing stage: features + culling (paper Fig 1)."""
    R = jnp.asarray(cam.R)
    t = jnp.asarray(cam.t)
    p_cam = scene.means3d @ R.T + t[None, :]  # (N, 3)
    x, y, z = p_cam[:, 0], p_cam[:, 1], p_cam[:, 2]
    z_safe = jnp.maximum(z, 1e-6)

    mean2d = jnp.stack(
        [cam.fx * x / z_safe + cam.cx, cam.fy * y / z_safe + cam.cy], axis=-1
    )

    # --- 2D covariance via the projective Jacobian (EWA splatting) ---
    cov3d = covariance3d(scene.log_scales, scene.quats)      # (N, 3, 3)
    cov3d_cam = jnp.einsum("ij,njk,lk->nil", R, cov3d, R)     # R Σ R^T
    inv_z = 1.0 / z_safe
    inv_z2 = inv_z * inv_z
    # J = [[fx/z, 0, -fx x / z^2], [0, fy/z, -fy y / z^2]]
    j00 = cam.fx * inv_z
    j02 = -cam.fx * x * inv_z2
    j11 = cam.fy * inv_z
    j12 = -cam.fy * y * inv_z2
    zeros = jnp.zeros_like(j00)
    J = jnp.stack(
        [
            jnp.stack([j00, zeros, j02], axis=-1),
            jnp.stack([zeros, j11, j12], axis=-1),
        ],
        axis=-2,
    )  # (N, 2, 3)
    cov2d_full = J @ cov3d_cam @ jnp.swapaxes(J, -1, -2)      # (N, 2, 2)
    a = cov2d_full[:, 0, 0] + COV2D_BLUR
    b = cov2d_full[:, 0, 1]
    c = cov2d_full[:, 1, 1] + COV2D_BLUR
    cov2d = jnp.stack([a, b, c], axis=-1)

    det = a * c - b * b
    det_safe = jnp.maximum(det, 1e-12)
    conic = jnp.stack([c / det_safe, -b / det_safe, a / det_safe], axis=-1)

    # Eigen-decomposition of [[a,b],[b,c]] (closed form).
    mid = 0.5 * (a + c)
    disc = jnp.sqrt(jnp.maximum(mid * mid - det, 1e-12))
    lam1 = mid + disc  # major
    lam2 = jnp.maximum(mid - disc, 1e-12)  # minor
    radius = SIGMA_CUT * jnp.sqrt(jnp.maximum(lam1, 1e-12))
    # Major-axis direction: eigenvector of lam1.
    ex = jnp.where(jnp.abs(b) > 1e-9, b, lam1 - c)
    ey = jnp.where(jnp.abs(b) > 1e-9, lam1 - a, jnp.zeros_like(b))
    # Degenerate (already axis-aligned): fall back to x-axis.
    enorm = jnp.sqrt(ex * ex + ey * ey)
    ex = jnp.where(enorm > 1e-9, ex / jnp.maximum(enorm, 1e-12), 1.0)
    ey = jnp.where(enorm > 1e-9, ey / jnp.maximum(enorm, 1e-12), 0.0)
    eigvec = jnp.stack([ex, ey], axis=-1)
    eigval = jnp.stack([lam1, lam2], axis=-1)

    # Tight per-axis 3-sigma extents (AABB of the ellipse, not of the circle).
    axis_radius = SIGMA_CUT * jnp.sqrt(
        jnp.maximum(jnp.stack([a, c], axis=-1), 1e-12)
    )

    # --- color + opacity ---
    cam_pos = -R.T @ t
    dirs = scene.means3d - cam_pos[None, :]
    dirs = dirs / (jnp.linalg.norm(dirs, axis=-1, keepdims=True) + 1e-12)
    rgb = eval_sh(scene.sh, dirs)
    alpha = jax.nn.sigmoid(scene.opacity)

    # --- culling (paper Fig 1: invisible Gaussians removed) ---
    in_front = z > cam.znear
    not_far = z < cam.zfar
    on_screen = (
        (mean2d[:, 0] + radius > 0.0)
        & (mean2d[:, 0] - radius < cam.width)
        & (mean2d[:, 1] + radius > 0.0)
        & (mean2d[:, 1] - radius < cam.height)
    )
    big_enough = det > 1e-12
    visible_alpha = alpha > (1.0 / 255.0)
    valid = in_front & not_far & on_screen & big_enough & visible_alpha

    # Sanitize culled Gaussians: behind-camera projections can overflow f32
    # (inf/inf = NaN conics), and a NaN feature would poison rasterization
    # through 0*NaN even at zero opacity (NaN fails every cutoff comparison).
    def _clean(x, default):
        mask = valid if x.ndim == 1 else valid[:, None]
        return jnp.where(mask, jnp.nan_to_num(x, posinf=1e30, neginf=-1e30), default)

    ident2 = jnp.array([1.0, 0.0, 1.0], jnp.float32)
    return Projected(
        mean2d=_clean(mean2d, 0.0),
        cov2d=_clean(cov2d, ident2),
        conic=_clean(conic, ident2),
        depth=_clean(z, jnp.inf),
        radius=_clean(radius, 0.0),
        axis_radius=_clean(axis_radius, 0.0),
        eigvec=_clean(eigvec, jnp.array([1.0, 0.0], jnp.float32)),
        eigval=_clean(eigval, 1.0),
        rgb=_clean(rgb, 0.0),
        alpha=_clean(alpha, 0.0),
        valid=valid,
    )
