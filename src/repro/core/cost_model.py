"""Analytic cost model of the GS-TG accelerator (paper §V, Table III).

Replaces the paper's cycle-level simulator: given the RenderStats counters
produced by an actual rendering run, estimate cycles / runtime / energy for a
given hardware configuration. Calibrated to the paper's published config:
4x PM, 4x GS-TG core (BGM: 4 tile-check units; GSM: 16 comparators; RM: 16
rasterization units), 1 GHz, DRAM 51.2 GB/s.

Two execution models:
  * ``asic``  — BGM and GSM run in PARALLEL (stage time = max), the paper's
    headline architectural feature (§V-A).
  * ``gpu``   — bitmask generation serializes with sorting (stage time = sum),
    reproducing the GPU limitation of Fig 13.

Energy: per-op energies for 28nm-class MAC/compare/bit ops plus DRAM energy
per bit (the paper cites Energon's DRAM model [16]).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    name: str = "gstg-asic"
    freq_hz: float = 1.0e9
    dram_gbps: float = 51.2          # GB/s (paper §VI-A)
    n_pm: int = 4                    # preprocessing modules
    n_cores: int = 4                 # GS-TG cores
    bgm_units: int = 4               # tile-check units per core
    gsm_comparators: int = 16        # comparators per core
    rm_units: int = 16               # rasterization units per core
    # per-op cycle costs
    cyc_feature: float = 4.0         # full per-gaussian feature pipeline (PM)
    cyc_boundary: float = 1.0        # one boundary test (any method base)
    boundary_scale: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"aabb": 1.0, "obb": 2.0, "ellipse": 3.0}
    )
    cyc_compare: float = 1.0         # comparator-tree op (GSM)
    # Sorting is MEMORY-bound in practice: a 64-bit radix sort streams the
    # key array multiple times. passes x (read+write) x key bytes / DRAM bw
    # reproduces Fig 3's stage shares (~35% sorting at 16x16) — the
    # comparator term almost never binds.
    radix_passes: int = 4
    cyc_alpha: float = 1.0           # one alpha computation (RU, pipelined)
    cyc_fifo: float = 1.0 / 16.0     # bitmask AND/OR filter, 16 lanes/cycle
    # per-op energies (pJ), 28nm-class estimates
    pj_feature: float = 30.0
    pj_boundary: float = 6.0
    pj_compare: float = 1.0
    pj_alpha: float = 8.0
    pj_fifo: float = 0.1
    pj_dram_per_byte: float = 20.0   # ~2.5 pJ/bit, Energon-style [16]
    # bytes per record (fp16 deployment per paper §VI-A)
    bytes_gaussian_feat: int = 2 * (2 + 3 + 1 + 3 + 1 + 1)  # fp16 feature set
    bytes_sort_key: int = 8
    bytes_pixel: int = 4


GSTG_ASIC = HardwareConfig()
GSTG_GPU_MODEL = dataclasses.replace(GSTG_ASIC, name="gstg-gpu")


@dataclasses.dataclass
class StageCosts:
    preprocess_s: float
    sort_s: float
    bitmask_s: float
    raster_s: float
    dram_s: float
    total_s: float
    energy_j: float

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, float]) -> "StageCosts":
        """Inverse of :meth:`as_dict` — the (de)serialization the autotune
        cache and the BENCH_autotune_*.json trajectory files rely on.
        Rejects unknown/missing keys so a schema drift fails loudly instead
        of silently zero-filling a stage."""
        names = [f.name for f in dataclasses.fields(cls)]
        if set(d) != set(names):
            raise ValueError(
                f"StageCosts dict keys {sorted(d)} != fields {sorted(names)}"
            )
        return cls(**{k: float(d[k]) for k in names})


def _f(x) -> float:
    return float(np.asarray(x))


def estimate(
    stats,
    hw: HardwareConfig,
    boundary_group: str = "ellipse",
    boundary_tile: str = "ellipse",
    mode: str = "gstg",
    execution: str = "asic",
) -> StageCosts:
    """Map RenderStats counters -> stage seconds + total energy.

    mode: 'gstg' or a baseline ('tile_baseline' / 'group_baseline'); baselines
    have no bitmask/FIFO stage.
    """
    f = hw.freq_hz
    bscale_g = hw.boundary_scale[boundary_group]
    bscale_t = hw.boundary_scale[boundary_tile]

    n_vis = _f(stats.n_visible)
    n_tests = _f(stats.n_candidate_tests)
    n_pairs = _f(stats.n_pairs_sort)
    sort_ops = _f(stats.sort_ops)
    bit_tests = _f(stats.n_bit_tests)
    fifo_ops = _f(stats.fifo_ops)
    alpha_ops = _f(stats.alpha_ops)
    tile_entries = _f(stats.tile_entries)

    # --- preprocessing: feature pipeline + identification tests ---
    pre_cycles = (
        n_vis * hw.cyc_feature + n_tests * hw.cyc_boundary * bscale_g
    ) / hw.n_pm
    pre_s = pre_cycles / f

    # --- sorting (GSM): max(comparator-bound, DRAM-bound radix) ---
    sort_cycles = sort_ops * hw.cyc_compare / (hw.n_cores * hw.gsm_comparators)
    sort_dram_s = (
        n_pairs * hw.bytes_sort_key * 2 * hw.radix_passes
    ) / (hw.dram_gbps * 1e9)
    sort_s = max(sort_cycles / f, sort_dram_s)

    # --- bitmask generation (BGM) ---
    bgm_cycles = bit_tests * hw.cyc_boundary * bscale_t / (
        hw.n_cores * hw.bgm_units
    )
    bgm_s = bgm_cycles / f

    # --- rasterization (RM): FIFO filter + alpha ops over RUs ---
    ru = hw.n_cores * hw.rm_units
    raster_cycles = alpha_ops * hw.cyc_alpha / ru + fifo_ops * hw.cyc_fifo
    raster_s = raster_cycles / f

    # --- DRAM traffic ---
    bytes_total = (
        n_vis * hw.bytes_gaussian_feat          # features read once
        + n_pairs * hw.bytes_sort_key * 2       # keys written + read
        + tile_entries * hw.bytes_gaussian_feat  # raster re-reads per tile list
    )
    dram_s = bytes_total / (hw.dram_gbps * 1e9)

    if mode == "gstg":
        if execution == "asic":
            mid_s = max(sort_s, bgm_s)  # BGM || GSM (the ASIC feature)
        else:
            mid_s = sort_s + bgm_s      # GPU: serialized
    else:
        mid_s = sort_s

    compute_s = pre_s + mid_s + raster_s
    total_s = max(compute_s, dram_s)

    energy = (
        n_vis * hw.pj_feature
        + n_tests * hw.pj_boundary * bscale_g
        + sort_ops * hw.pj_compare
        + bit_tests * hw.pj_boundary * bscale_t
        + fifo_ops * hw.pj_fifo
        + alpha_ops * hw.pj_alpha
        + bytes_total * hw.pj_dram_per_byte
    ) * 1e-12

    return StageCosts(
        preprocess_s=pre_s,
        sort_s=sort_s,
        bitmask_s=bgm_s,
        raster_s=raster_s,
        dram_s=dram_s,
        total_s=total_s,
        energy_j=energy,
    )
