"""Stage decomposition of the GS-TG rendering pipeline + backend dispatch.

The pipeline is expressed as six explicit stages (DESIGN.md §1):

    project -> identify -> bin/sort -> bitmask -> compact -> rasterize

``render()`` (core/pipeline.py) is the only public entry; a ``Backend``
supplies the stage implementations:

  * ``reference`` — pure-jnp XLA ops throughout (differentiable; the oracle
    every other backend is tested against).
  * ``pallas``    — BGM + fused RM run as Pallas TPU kernels (interpret mode
    off-TPU); identification and the group binning/sort stay on the XLA sort
    substrate (DESIGN.md §2: a stable lexicographic sort has no efficient
    Mosaic lowering, and stability is what the losslessness proof needs).

Both backends consume/produce the same dataclasses and emit the same
RenderStats counters, so they are interchangeable under ``render()`` and the
losslessness guarantees can be asserted across backends (tests/test_engine.py).

Every stage that consumes projected features (bitmask / rasterize) takes
``proj`` as a flat ``Projected`` OR a ``ShardedProjected`` kept in the
per-shard layout (DESIGN.md §12): the gathers route through
``core.projection.proj_take``, which decomposes the table's global gaussian
indices into (shard, local) and fetches from the owning shard —
bitwise-identical to the flat gather, so neither backend needs a sharded
fork of any stage.

The pallas 'compact' stage is *virtual*: the fused RM kernel applies the
bitmask filter in-register (paper Fig 10), so no per-tile table is ever
materialized — only the per-tile lengths/overflow counters are computed (a
cheap popcount) to keep the stats contract identical to the reference.
"""
from __future__ import annotations

import abc
import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.bitmask import GroupBitmasks, compact_tiles, generate_bitmasks
from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.grouping import (
    BinTable,
    GridSpec,
    PairSet,
    bin_pairs,
    identify,
    merge_bin_tables,
)
from repro.core.projection import Projected, project
from repro.core.raster import rasterize


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TileRaster:
    """Output of the rasterize stage over a tile-level work list."""

    image: jnp.ndarray       # (grid.height, grid.width, 3)
    alpha_ops: jnp.ndarray   # () int32
    blend_ops: jnp.ndarray   # () int32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompactedTiles:
    """Result of the compact stage (RM FIFO). ``table`` is only materialized
    by backends that need it (reference); the fused pallas RM consumes the
    group table + masks directly and leaves ``table`` as None."""

    tile_entries: jnp.ndarray   # () int32: sum of per-tile lengths (pre-clamp)
    overflow: jnp.ndarray       # () int32: entries dropped by tile_capacity
    table: Optional[BinTable] = None


def mask_tile_lengths(
    gtable: BinTable, masks: GroupBitmasks, grid: GridSpec
) -> jnp.ndarray:
    """(num_groups, tiles_per_group) per-member-tile entry counts — a popcount
    over the bitmask columns.

    Equals ``compact_tiles(...).lengths`` regrouped by (group, slot), without
    materializing the compacted table. Member tiles outside the image need no
    special-casing: both bitmask generators zero their mask bits already.
    """
    tpg = grid.tiles_per_group
    bits = (
        (masks.masks[:, :, None] >> jnp.arange(tpg, dtype=jnp.uint32)) & 1
    ).astype(jnp.int32)
    bits = bits * gtable.entry_valid[:, :, None].astype(jnp.int32)
    return jnp.sum(bits, axis=1)  # (G, tpg)


class Backend(abc.ABC):
    """Stage implementations behind ``render()``. Subclasses override the
    stages they accelerate; identification and binning default to the shared
    XLA substrate (stable sort => 3D-GS tie-break => losslessness)."""

    name: str = "abstract"

    # -- stage 1: preprocessing ------------------------------------------
    def project(self, scene: GaussianScene, cam: Camera) -> Projected:
        return project(scene, cam)

    # -- stage 2: group/tile identification ------------------------------
    def identify(
        self, proj: Projected, grid: GridSpec, level: str, method: str
    ) -> PairSet:
        return identify(proj, grid, level, method)

    # -- stage 3: binning + depth sort -----------------------------------
    def bin(self, pairs: PairSet, num_bins: int, capacity: int) -> BinTable:
        return bin_pairs(pairs, num_bins, capacity)

    # -- stage 3b: cross-shard merge (scene-sharded frontend) ------------
    def merge(self, tables: BinTable, depth: jnp.ndarray) -> BinTable:
        """Combine D per-shard bin tables (shard-stacked, gauss_idx already
        global) into the global depth-ordered table. Shared XLA substrate for
        every backend: the STABLE merge is what preserves the (depth,
        insertion-order) tie-break bitwise (core/grouping.py::
        merge_bin_tables, DESIGN.md §10) — a kernel backend may accelerate
        its own stages but must keep this merge order-exact."""
        return merge_bin_tables(tables, depth)

    # -- stage 4: bitmask generation (BGM) -------------------------------
    @abc.abstractmethod
    def bitmasks(
        self,
        proj: Projected,
        gtable: BinTable,
        grid: GridSpec,
        method: str,
        *,
        chunk: int = 32,
    ) -> GroupBitmasks:
        """``chunk`` is the raster chunk size — a layout hint so kernel
        backends can pack features once with the padding rasterization will
        want (the gathers then CSE under jit). Pure-XLA backends ignore it."""

    # -- stage 5: RM FIFO compaction -------------------------------------
    @abc.abstractmethod
    def compact(
        self,
        gtable: BinTable,
        masks: GroupBitmasks,
        grid: GridSpec,
        tile_capacity: int,
    ) -> CompactedTiles:
        ...

    # -- stage 6: rasterization ------------------------------------------
    @abc.abstractmethod
    def rasterize_tiles(
        self,
        proj: Projected,
        table: BinTable,
        grid: GridSpec,
        *,
        background: Optional[jnp.ndarray],
        chunk: int,
        early_exit: bool,
    ) -> TileRaster:
        """Rasterize a tile-level table (flat pipelines; reference gstg)."""

    @abc.abstractmethod
    def rasterize_groups(
        self,
        proj: Projected,
        gtable: BinTable,
        masks: GroupBitmasks,
        compacted: CompactedTiles,
        grid: GridSpec,
        *,
        background: Optional[jnp.ndarray],
        chunk: int,
        early_exit: bool,
        tile_capacity: int,
    ) -> TileRaster:
        """Rasterize the gstg work list (group table + per-entry bitmasks)."""


class ReferenceBackend(Backend):
    """Pure-jnp stages: the differentiable oracle (core/raster.py)."""

    name = "reference"

    def bitmasks(self, proj, gtable, grid, method, *, chunk=32):
        return generate_bitmasks(proj, gtable, grid, method)

    def compact(self, gtable, masks, grid, tile_capacity):
        table = compact_tiles(gtable, masks, grid, tile_capacity)
        return CompactedTiles(
            tile_entries=jnp.sum(table.lengths),
            overflow=table.overflow,
            table=table,
        )

    def rasterize_tiles(self, proj, table, grid, *, background, chunk, early_exit):
        rast = rasterize(
            proj, table, grid, background, chunk=chunk, early_exit=early_exit
        )
        return TileRaster(
            image=rast.image, alpha_ops=rast.alpha_ops, blend_ops=rast.blend_ops
        )

    def rasterize_groups(
        self, proj, gtable, masks, compacted, grid, *,
        background, chunk, early_exit, tile_capacity,
    ):
        return self.rasterize_tiles(
            proj, compacted.table, grid,
            background=background, chunk=chunk, early_exit=early_exit,
        )


class PallasBackend(Backend):
    """BGM + RM as Pallas kernels (interpret mode off-TPU), same counters.

    The fused RM never materializes per-tile tables; tile_capacity is honored
    in-register (entries past the capacity of a member tile's virtual FIFO are
    dropped, exactly like the reference compaction clamp), and alpha/blend op
    counters are accumulated inside the kernel.
    """

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None):
        self._interpret = interpret

    @property
    def interpret(self) -> Optional[bool]:
        return self._interpret

    def _resolve_interpret(self) -> bool:
        from repro.kernels.ops import default_interpret

        return default_interpret() if self._interpret is None else self._interpret

    @staticmethod
    def _pad_multiple(chunk: int) -> int:
        from repro.kernels.layout import LANE

        return math.lcm(LANE, max(int(chunk), 1))

    def bitmasks(self, proj, gtable, grid, method, *, chunk=32):
        from repro.kernels.bitmask_gen import bitmask_kernel
        from repro.kernels.layout import pack_features
        from repro.kernels.ops import group_origins, tiles_in_image

        # Same padding rasterize_groups uses => the gather is an identical
        # expression there and XLA CSE merges the two under jit (the hot
        # paths — render_jit/render_batch — are jit'd; eager render() pays
        # the gather twice, acceptable for demos/tests).
        feat = pack_features(
            proj, gtable.gauss_idx, gtable.entry_valid,
            multiple=self._pad_multiple(chunk),
        )
        masks = bitmask_kernel(
            feat,
            group_origins(grid),
            tiles_in_image(grid),
            grid.tile,
            grid.gf,
            method=method,
            interpret=self._resolve_interpret(),
        )
        # Kernel masks cover the padded K axis; crop to the table capacity.
        masks = masks[:, : gtable.capacity]
        n_tests = jnp.sum(gtable.entry_valid.astype(jnp.int32)) * grid.tiles_per_group
        return GroupBitmasks(masks=masks, n_bit_tests=n_tests)

    def compact(self, gtable, masks, grid, tile_capacity):
        lengths = mask_tile_lengths(gtable, masks, grid)
        return CompactedTiles(
            tile_entries=jnp.sum(lengths),
            overflow=jnp.sum(jnp.maximum(lengths - tile_capacity, 0)),
            table=None,
        )

    def rasterize_tiles(self, proj, table, grid, *, background, chunk, early_exit):
        from repro.kernels.layout import pack_features
        from repro.kernels.ops import assemble_image_tiles, tile_origins
        from repro.kernels.raster_tile import raster_tile_kernel

        feat = pack_features(
            proj, table.gauss_idx, table.entry_valid,
            multiple=self._pad_multiple(chunk),
        )
        K = feat.shape[-1]
        out, counts = raster_tile_kernel(
            feat,
            tile_origins(grid),
            grid.tile,
            chunk=min(chunk, K),
            early_exit=early_exit,
            with_stats=True,
            interpret=self._resolve_interpret(),
        )
        return TileRaster(
            image=assemble_image_tiles(out, grid, background),
            alpha_ops=jnp.sum(counts[:, 0]),
            blend_ops=jnp.sum(counts[:, 1]),
        )

    def rasterize_groups(
        self, proj, gtable, masks, compacted, grid, *,
        background, chunk, early_exit, tile_capacity,
    ):
        from repro.kernels.layout import pack_features
        from repro.kernels.ops import assemble_image, group_origins
        from repro.kernels.raster_tile import raster_group_fused_kernel

        feat = pack_features(
            proj, gtable.gauss_idx, gtable.entry_valid,
            multiple=self._pad_multiple(chunk),
        )
        K = feat.shape[-1]
        pad = K - masks.masks.shape[1]
        padded_masks = (
            jnp.pad(masks.masks, ((0, 0), (0, pad))) if pad else masks.masks
        )
        out, counts = raster_group_fused_kernel(
            feat,
            padded_masks,
            group_origins(grid),
            grid.tile,
            grid.gf,
            chunk=min(chunk, K),
            early_exit=early_exit,
            tile_capacity=tile_capacity,
            with_stats=True,
            interpret=self._resolve_interpret(),
        )
        return TileRaster(
            image=assemble_image(out, grid, background),
            alpha_ops=jnp.sum(counts[:, :, 0]),
            blend_ops=jnp.sum(counts[:, :, 1]),
        )


_BACKENDS: Dict[str, Backend] = {}


def register_backend(name: str, backend: Backend) -> None:
    _BACKENDS[name] = backend


def get_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {sorted(_BACKENDS)}"
        ) from None


register_backend("reference", ReferenceBackend())
register_backend("pallas", PallasBackend())
