"""Stage decomposition of the GS-TG rendering pipeline + backend dispatch.

The pipeline is expressed as six explicit stages (DESIGN.md §1):

    project -> identify -> bin/sort -> bitmask -> compact -> rasterize

``render()`` (core/pipeline.py) is the only public entry; a ``Backend``
supplies the stage implementations:

  * ``reference`` — pure-jnp XLA ops throughout (differentiable; the oracle
    every other backend is tested against).
  * ``pallas``    — BGM + fused RM run as Pallas TPU kernels (interpret mode
    off-TPU); identification and the group binning/sort stay on the XLA sort
    substrate (DESIGN.md §2: a stable lexicographic sort has no efficient
    Mosaic lowering, and stability is what the losslessness proof needs).

Both backends consume/produce the same dataclasses and emit the same
RenderStats counters, so they are interchangeable under ``render()`` and the
losslessness guarantees can be asserted across backends (tests/test_engine.py).

Every stage that consumes projected features (bitmask / rasterize) takes
``proj`` as a flat ``Projected`` OR a ``ShardedProjected`` kept in the
per-shard layout (DESIGN.md §12): the gathers route through
``core.projection.proj_take``, which decomposes the table's global gaussian
indices into (shard, local) and fetches from the owning shard —
bitwise-identical to the flat gather, so neither backend needs a sharded
fork of any stage.

The pallas 'compact' stage is *virtual*: the fused RM kernel applies the
bitmask filter in-register (paper Fig 10), so no per-tile table is ever
materialized — only the per-tile lengths/overflow counters are computed (a
cheap popcount) to keep the stats contract identical to the reference.
"""
from __future__ import annotations

import abc
import dataclasses
import math
import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.bitmask import GroupBitmasks, compact_tiles, generate_bitmasks
from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.grouping import (
    BinTable,
    GridSpec,
    PairSet,
    bin_pairs,
    identify,
    merge_bin_tables,
)
from repro.core.projection import Projected, project
from repro.core.raster import rasterize


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TileRaster:
    """Output of the rasterize stage over a tile-level work list."""

    image: jnp.ndarray       # (grid.height, grid.width, 3)
    alpha_ops: jnp.ndarray   # () int32
    blend_ops: jnp.ndarray   # () int32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompactedTiles:
    """Result of the compact stage (RM FIFO). ``table`` is only materialized
    by backends that need it (reference); the fused pallas RM consumes the
    group table + masks directly and leaves ``table`` as None."""

    tile_entries: jnp.ndarray   # () int32: sum of per-tile lengths (pre-clamp)
    overflow: jnp.ndarray       # () int32: entries dropped by tile_capacity
    table: Optional[BinTable] = None


def mask_tile_lengths(
    gtable: BinTable, masks: GroupBitmasks, grid: GridSpec
) -> jnp.ndarray:
    """(num_groups, tiles_per_group) per-member-tile entry counts — a popcount
    over the bitmask columns.

    Equals ``compact_tiles(...).lengths`` regrouped by (group, slot), without
    materializing the compacted table. Member tiles outside the image need no
    special-casing: both bitmask generators zero their mask bits already.
    """
    tpg = grid.tiles_per_group
    bits = (
        (masks.masks[:, :, None] >> jnp.arange(tpg, dtype=jnp.uint32)) & 1
    ).astype(jnp.int32)
    bits = bits * gtable.entry_valid[:, :, None].astype(jnp.int32)
    return jnp.sum(bits, axis=1)  # (G, tpg)


class Backend(abc.ABC):
    """Stage implementations behind ``render()``. Subclasses override the
    stages they accelerate; identification and binning default to the shared
    XLA substrate (stable sort => 3D-GS tie-break => losslessness)."""

    name: str = "abstract"

    # -- stage 1: preprocessing ------------------------------------------
    def project(self, scene: GaussianScene, cam: Camera) -> Projected:
        return project(scene, cam)

    # -- stage 2: group/tile identification ------------------------------
    def identify(
        self, proj: Projected, grid: GridSpec, level: str, method: str
    ) -> PairSet:
        return identify(proj, grid, level, method)

    # -- stage 3: binning + depth sort -----------------------------------
    def bin(self, pairs: PairSet, num_bins: int, capacity: int) -> BinTable:
        return bin_pairs(pairs, num_bins, capacity)

    # -- stage 3b: cross-shard merge (scene-sharded frontend) ------------
    def merge(self, tables: BinTable, depth: jnp.ndarray) -> BinTable:
        """Combine D per-shard bin tables (shard-stacked, gauss_idx already
        global) into the global depth-ordered table. Shared XLA substrate for
        every backend: the STABLE merge is what preserves the (depth,
        insertion-order) tie-break bitwise (core/grouping.py::
        merge_bin_tables, DESIGN.md §10) — a kernel backend may accelerate
        its own stages but must keep this merge order-exact."""
        return merge_bin_tables(tables, depth)

    # -- stage 4: bitmask generation (BGM) -------------------------------
    @abc.abstractmethod
    def bitmasks(
        self,
        proj: Projected,
        gtable: BinTable,
        grid: GridSpec,
        method: str,
        *,
        chunk: int = 32,
    ) -> GroupBitmasks:
        """``chunk`` is the raster chunk size — a layout hint so kernel
        backends can pack features once with the padding rasterization will
        want (the gathers then CSE under jit). Pure-XLA backends ignore it."""

    # -- stage 5: RM FIFO compaction -------------------------------------
    @abc.abstractmethod
    def compact(
        self,
        gtable: BinTable,
        masks: GroupBitmasks,
        grid: GridSpec,
        tile_capacity: int,
    ) -> CompactedTiles:
        ...

    # -- stage 6: rasterization ------------------------------------------
    @abc.abstractmethod
    def rasterize_tiles(
        self,
        proj: Projected,
        table: BinTable,
        grid: GridSpec,
        *,
        background: Optional[jnp.ndarray],
        chunk: int,
        early_exit: bool,
    ) -> TileRaster:
        """Rasterize a tile-level table (flat pipelines; reference gstg)."""

    @abc.abstractmethod
    def rasterize_groups(
        self,
        proj: Projected,
        gtable: BinTable,
        masks: GroupBitmasks,
        compacted: CompactedTiles,
        grid: GridSpec,
        *,
        background: Optional[jnp.ndarray],
        chunk: int,
        early_exit: bool,
        tile_capacity: int,
    ) -> TileRaster:
        """Rasterize the gstg work list (group table + per-entry bitmasks)."""


class ReferenceBackend(Backend):
    """Pure-jnp stages: the differentiable oracle (core/raster.py)."""

    name = "reference"

    def bitmasks(self, proj, gtable, grid, method, *, chunk=32):
        return generate_bitmasks(proj, gtable, grid, method)

    def compact(self, gtable, masks, grid, tile_capacity):
        table = compact_tiles(gtable, masks, grid, tile_capacity)
        return CompactedTiles(
            tile_entries=jnp.sum(table.lengths),
            overflow=table.overflow,
            table=table,
        )

    def rasterize_tiles(self, proj, table, grid, *, background, chunk, early_exit):
        rast = rasterize(
            proj, table, grid, background, chunk=chunk, early_exit=early_exit
        )
        return TileRaster(
            image=rast.image, alpha_ops=rast.alpha_ops, blend_ops=rast.blend_ops
        )

    def rasterize_groups(
        self, proj, gtable, masks, compacted, grid, *,
        background, chunk, early_exit, tile_capacity,
    ):
        return self.rasterize_tiles(
            proj, compacted.table, grid,
            background=background, chunk=chunk, early_exit=early_exit,
        )


class PallasBackend(Backend):
    """BGM + RM as Pallas kernels (interpret mode off-TPU), same counters.

    The fused RM never materializes per-tile tables; tile_capacity is honored
    in-register (entries past the capacity of a member tile's virtual FIFO are
    dropped, exactly like the reference compaction clamp), and alpha/blend op
    counters are accumulated inside the kernel.
    """

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None):
        self._interpret = interpret

    @property
    def interpret(self) -> Optional[bool]:
        return self._interpret

    def _resolve_interpret(self) -> bool:
        from repro.kernels.ops import default_interpret

        return default_interpret() if self._interpret is None else self._interpret

    @staticmethod
    def _pad_multiple(chunk: int) -> int:
        from repro.kernels.layout import LANE

        return math.lcm(LANE, max(int(chunk), 1))

    def bitmasks(self, proj, gtable, grid, method, *, chunk=32):
        from repro.kernels.bitmask_gen import bitmask_kernel
        from repro.kernels.layout import pack_features
        from repro.kernels.ops import group_origins, tiles_in_image

        # Same padding rasterize_groups uses => the gather is an identical
        # expression there and XLA CSE merges the two under jit (the hot
        # paths — render_jit/render_batch — are jit'd; eager render() pays
        # the gather twice, acceptable for demos/tests).
        feat = pack_features(
            proj, gtable.gauss_idx, gtable.entry_valid,
            multiple=self._pad_multiple(chunk),
        )
        masks = bitmask_kernel(
            feat,
            group_origins(grid),
            tiles_in_image(grid),
            grid.tile,
            grid.gf,
            method=method,
            interpret=self._resolve_interpret(),
        )
        # Kernel masks cover the padded K axis; crop to the table capacity.
        masks = masks[:, : gtable.capacity]
        n_tests = jnp.sum(gtable.entry_valid.astype(jnp.int32)) * grid.tiles_per_group
        return GroupBitmasks(masks=masks, n_bit_tests=n_tests)

    def compact(self, gtable, masks, grid, tile_capacity):
        lengths = mask_tile_lengths(gtable, masks, grid)
        return CompactedTiles(
            tile_entries=jnp.sum(lengths),
            overflow=jnp.sum(jnp.maximum(lengths - tile_capacity, 0)),
            table=None,
        )

    def rasterize_tiles(self, proj, table, grid, *, background, chunk, early_exit):
        from repro.kernels.layout import pack_features
        from repro.kernels.ops import assemble_image_tiles, tile_origins
        from repro.kernels.raster_tile import raster_tile_kernel

        feat = pack_features(
            proj, table.gauss_idx, table.entry_valid,
            multiple=self._pad_multiple(chunk),
        )
        K = feat.shape[-1]
        out, counts = raster_tile_kernel(
            feat,
            tile_origins(grid),
            grid.tile,
            chunk=min(chunk, K),
            early_exit=early_exit,
            with_stats=True,
            interpret=self._resolve_interpret(),
        )
        return TileRaster(
            image=assemble_image_tiles(out, grid, background),
            alpha_ops=jnp.sum(counts[:, 0]),
            blend_ops=jnp.sum(counts[:, 1]),
        )

    def rasterize_groups(
        self, proj, gtable, masks, compacted, grid, *,
        background, chunk, early_exit, tile_capacity,
    ):
        from repro.kernels.layout import pack_features
        from repro.kernels.ops import assemble_image, group_origins
        from repro.kernels.raster_tile import raster_group_fused_kernel

        feat = pack_features(
            proj, gtable.gauss_idx, gtable.entry_valid,
            multiple=self._pad_multiple(chunk),
        )
        K = feat.shape[-1]
        pad = K - masks.masks.shape[1]
        padded_masks = (
            jnp.pad(masks.masks, ((0, 0), (0, pad))) if pad else masks.masks
        )
        out, counts = raster_group_fused_kernel(
            feat,
            padded_masks,
            group_origins(grid),
            grid.tile,
            grid.gf,
            chunk=min(chunk, K),
            early_exit=early_exit,
            tile_capacity=tile_capacity,
            with_stats=True,
            interpret=self._resolve_interpret(),
        )
        return TileRaster(
            image=assemble_image(out, grid, background),
            alpha_ops=jnp.sum(counts[:, :, 0]),
            blend_ops=jnp.sum(counts[:, :, 1]),
        )


# ---------------------------------------------------------------------------
# Timed-stage execution (observability, DESIGN.md §14)
# ---------------------------------------------------------------------------

# Per-stage jit cache for TimedBackend. Keyed by the Python statics of one
# stage invocation (backend name, stage, grid/method/capacity/...); jax.jit
# itself retraces per input shape/dtype, so the key needs nothing dynamic.
# Bounded FIFO; registered with the render-cache registry by core/pipeline.py
# (name "timed_stage") so cache stats stay truthful under timed serving.
_TIMED_FN_MAX = 128
_timed_lock = threading.Lock()
_timed_fns: Dict[tuple, object] = {}
_timed_stats = {"hits": 0, "misses": 0}


def _timed_fn(key: tuple, build):
    with _timed_lock:
        fn = _timed_fns.get(key)
        if fn is not None:
            _timed_stats["hits"] += 1
            return fn
        _timed_stats["misses"] += 1
    fn = jax.jit(build())
    with _timed_lock:
        while len(_timed_fns) >= _TIMED_FN_MAX:
            _timed_fns.pop(next(iter(_timed_fns)))
        _timed_fns.setdefault(key, fn)
        return _timed_fns[key]


def timed_stage_cache_info() -> dict:
    with _timed_lock:
        return {
            "hits": _timed_stats["hits"],
            "misses": _timed_stats["misses"],
            "currsize": len(_timed_fns),
            "maxsize": _TIMED_FN_MAX,
        }


def timed_stage_cache_clear() -> None:
    with _timed_lock:
        _timed_fns.clear()
        _timed_stats["hits"] = 0
        _timed_stats["misses"] = 0


class TimedBackend(Backend):
    """Per-stage timed execution of a wrapped backend (DESIGN.md §14).

    Each stage runs as its OWN jit'd program followed by a
    ``jax.block_until_ready`` fence, so the host interval around it is real
    per-stage device time — recorded as a ``stage/<name>`` span on the
    process tracer (``force=True``: ``RenderConfig.timing`` is the opt-in)
    and bracketed by ``jax.profiler.TraceAnnotation`` so host spans line up
    with device traces when the jax profiler is on.

    The per-stage-jit chain is BITWISE-identical to the whole-program jit on
    both backends (tests/test_obs.py): every stage boundary already carries
    concrete dtypes, and the eager glue between stages (index offsets,
    selects, gathers) is exact integer/select arithmetic. The first call per
    static signature pays per-stage compiles (``_timed_fn`` cache); callers
    that want clean numbers warm once, then measure (benchmarks/
    bench_stages.py, launch/render.py --stats).

    ``core.pipeline.render`` only installs this wrapper when inputs are
    concrete — under an outer trace (legacy jit(vmap) paths, the autotune
    probe) fences would no-op and spans would record trace-time garbage, so
    those paths stay on the plain backend.
    """

    def __init__(self, inner: Backend):
        self.inner = inner
        self.name = f"timed:{inner.name}"

    # -- span + fence around one stage program ---------------------------

    def _run(self, stage: str, key: tuple, build, *args):
        from repro.obs import get_tracer

        fn = _timed_fn((self.inner.name,) + key, build)
        tracer = get_tracer()
        t0 = tracer.clock()
        with jax.profiler.TraceAnnotation(f"stage/{stage}"):
            out = jax.block_until_ready(fn(*args))
        tracer.complete(
            f"stage/{stage}", t0, tracer.clock(), category="stage",
            args={"backend": self.inner.name}, force=True,
        )
        return out

    # -- camera split: static geometry vs dynamic pose/intrinsics --------

    @staticmethod
    def _cam_static(cam) -> tuple:
        return (int(cam.width), int(cam.height),
                float(cam.znear), float(cam.zfar))

    @staticmethod
    def _cam_dynamic(cam) -> tuple:
        return (
            jnp.asarray(cam.R), jnp.asarray(cam.t),
            jnp.asarray(cam.fx, jnp.float32), jnp.asarray(cam.fy, jnp.float32),
            jnp.asarray(cam.cx, jnp.float32), jnp.asarray(cam.cy, jnp.float32),
        )

    # -- stages ----------------------------------------------------------

    def project(self, scene, cam):
        inner = self.inner
        w, h, zn, zf = self._cam_static(cam)

        def build():
            def fn(scene, R, t, fx, fy, cx, cy):
                c = Camera(R=R, t=t, fx=fx, fy=fy, cx=cx, cy=cy,
                           width=w, height=h, znear=zn, zfar=zf)
                return inner.project(scene, c)
            return fn

        return self._run("project", ("project", w, h, zn, zf), build,
                         scene, *self._cam_dynamic(cam))

    def identify(self, proj, grid, level, method):
        inner = self.inner

        def build():
            return lambda p: inner.identify(p, grid, level, method)

        return self._run("identify", ("identify", grid, level, method),
                         build, proj)

    def bin(self, pairs, num_bins, capacity):
        inner = self.inner

        def build():
            return lambda p: inner.bin(p, num_bins, capacity)

        return self._run("bin", ("bin", num_bins, capacity), build, pairs)

    def merge(self, tables, depth):
        inner = self.inner

        def build():
            return lambda t, d: inner.merge(t, d)

        return self._run("merge", ("merge",), build, tables, depth)

    # Vmapped per-shard forms of stages 1-3 for the scene-sharded frontend
    # (core/pipeline.py::_frontend): each vmapped stage is ONE timed program,
    # fenced at the jit(vmap) level — inside the vmap trace the per-shard
    # calls are tracers and could not be fenced individually.

    def project_shards(self, shards, cam):
        inner = self.inner
        w, h, zn, zf = self._cam_static(cam)

        def build():
            def fn(shards, R, t, fx, fy, cx, cy):
                c = Camera(R=R, t=t, fx=fx, fy=fy, cx=cx, cy=cy,
                           width=w, height=h, znear=zn, zfar=zf)
                return jax.vmap(lambda s: inner.project(s, c))(shards)
            return fn

        return self._run("project", ("project_s", w, h, zn, zf), build,
                         shards, *self._cam_dynamic(cam))

    def identify_shards(self, proj_s, grid, level, method):
        inner = self.inner

        def build():
            return jax.vmap(lambda p: inner.identify(p, grid, level, method))

        return self._run("identify", ("identify_s", grid, level, method),
                         build, proj_s)

    def bin_shards(self, pairs_s, num_bins, capacity):
        inner = self.inner

        def build():
            return jax.vmap(lambda p: inner.bin(p, num_bins, capacity))

        return self._run("bin", ("bin_s", num_bins, capacity), build, pairs_s)

    def bitmasks(self, proj, gtable, grid, method, *, chunk=32):
        inner = self.inner

        def build():
            return lambda p, g: inner.bitmasks(p, g, grid, method, chunk=chunk)

        return self._run("bitmask", ("bitmask", grid, method, chunk),
                         build, proj, gtable)

    def compact(self, gtable, masks, grid, tile_capacity):
        inner = self.inner

        def build():
            return lambda g, m: inner.compact(g, m, grid, tile_capacity)

        return self._run("compact", ("compact", grid, tile_capacity),
                         build, gtable, masks)

    def rasterize_tiles(self, proj, table, grid, *,
                        background, chunk, early_exit):
        inner = self.inner
        has_bg = background is not None

        def build():
            if has_bg:
                return lambda p, t, bg: inner.rasterize_tiles(
                    p, t, grid, background=bg, chunk=chunk,
                    early_exit=early_exit)
            return lambda p, t: inner.rasterize_tiles(
                p, t, grid, background=None, chunk=chunk,
                early_exit=early_exit)

        args = (proj, table) + ((background,) if has_bg else ())
        return self._run(
            "rasterize", ("rast_tiles", grid, chunk, early_exit, has_bg),
            build, *args)

    def rasterize_groups(self, proj, gtable, masks, compacted, grid, *,
                         background, chunk, early_exit, tile_capacity):
        inner = self.inner
        has_bg = background is not None

        def build():
            if has_bg:
                return lambda p, g, m, c, bg: inner.rasterize_groups(
                    p, g, m, c, grid, background=bg, chunk=chunk,
                    early_exit=early_exit, tile_capacity=tile_capacity)
            return lambda p, g, m, c: inner.rasterize_groups(
                p, g, m, c, grid, background=None, chunk=chunk,
                early_exit=early_exit, tile_capacity=tile_capacity)

        args = (proj, gtable, masks, compacted)
        args += (background,) if has_bg else ()
        return self._run(
            "rasterize",
            ("rast_groups", grid, chunk, early_exit, tile_capacity, has_bg),
            build, *args)


_BACKENDS: Dict[str, Backend] = {}


def register_backend(name: str, backend: Backend) -> None:
    _BACKENDS[name] = backend


def get_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {sorted(_BACKENDS)}"
        ) from None


register_backend("reference", ReferenceBackend())
register_backend("pallas", PallasBackend())
