"""End-to-end rendering engine (paper Fig 1 vs Fig 9).

``render()`` is the single public entry point. It expresses the pipeline as
explicit stages (project -> identify -> bin/sort -> bitmask -> compact ->
rasterize; see core/stages.py and DESIGN.md §1) and dispatches every stage to
the backend selected by ``RenderConfig.backend``:

  * ``reference`` — pure-jnp XLA stages (differentiable oracle).
  * ``pallas``    — BGM + fused RM as Pallas kernels, same RenderStats.

Three modes share the substrate regardless of backend:

  * ``tile_baseline``  — conventional 3D-GS: identify + sort + rasterize at
    the small-tile level (paper Fig 1). Sorting keys = (gaussian, tile) pairs.
  * ``group_baseline`` — 'large tile' baseline: identify + sort + rasterize at
    the group level (what Fig 13 calls baseline 64x64).
  * ``gstg``           — the paper's method (Fig 9): group identification,
    group-wise sorting, per-entry tile bitmasks, FIFO compaction, small-tile
    rasterization. Sorting keys = (gaussian, group) pairs only.

Every mode returns the image plus RenderStats counters that drive the
benchmarks and the accelerator cost model.

``render_batch()`` renders a batch of cameras in ONE jit-compiled call (vmap
over the camera parameters); compiled renderers are cached by the static
(RenderConfig, camera-geometry) signature so repeated multi-view calls reuse
the executable (DESIGN.md §6).

Losslessness guarantees (tested in tests/test_pipeline_lossless.py):
  * BITWISE image equality gstg == tile_baseline whenever the bitmask method
    is at least as tight as the group method (ellipse bitmask under any group
    method; matched aabb+aabb) and no capacity overflow occurs — the per-tile
    entry tables are then identical arrays.
  * For the remaining method combos the CONTRIBUTING Gaussian sequences are
    still identical per tile (exact-set losslessness); images agree to fp
    reassociation of interleaved zero-alpha entries (<=1e-6), because every
    boundary method conservatively over-approximates the q<=9 support that
    rasterization enforces.
  * Across backends: identical integer counters and allclose images (the
    pallas RM chunks the group list rather than the compacted tile lists, so
    partial-sum association may differ by fp rounding; tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.grouping import GridSpec, sort_op_count
from repro.core.stages import Backend, get_backend


@dataclasses.dataclass(frozen=True)
class RenderConfig:
    tile: int = 16
    group: int = 64
    mode: str = "gstg"                 # gstg | tile_baseline | group_baseline
    boundary_group: str = "ellipse"    # group-identification method (GS-TG)
    boundary_tile: str = "ellipse"     # tile identification / bitmask method
    group_capacity: int = 512          # K: entries per group segment
    tile_capacity: int = 256           # K_t: entries per tile segment
    span: int = 4                      # candidate window at group level (bins)
    chunk: int = 32                    # raster gaussian chunk
    early_exit: bool = True
    backend: str = "reference"         # stage implementation: reference | pallas


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RenderStats:
    """Operation counters for the paper's metrics + the cost model."""

    n_visible: jnp.ndarray           # gaussians surviving culling
    n_candidate_tests: jnp.ndarray   # identification boundary tests
    n_pairs_sort: jnp.ndarray        # sorting keys (the paper's redundancy axis)
    sort_ops: jnp.ndarray            # comparator-model ops sum L log L
    n_bit_tests: jnp.ndarray         # bitmask-generation tile tests (gstg only)
    fifo_ops: jnp.ndarray            # linear compaction ops (gstg only)
    alpha_ops: jnp.ndarray           # per-pixel alpha computations
    blend_ops: jnp.ndarray           # contributing blends
    tile_entries: jnp.ndarray        # total per-tile raster entries
    overflow: jnp.ndarray            # capacity-dropped entries (must be 0)
    span_overflow: jnp.ndarray       # candidate-window dropped bins (must be 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RenderResult:
    image: jnp.ndarray
    stats: RenderStats


def _grid(cam, cfg: RenderConfig) -> GridSpec:
    return GridSpec(
        width=cam.width,
        height=cam.height,
        tile=cfg.tile,
        group=cfg.group,
        span=cfg.span,
    )


def render(
    scene: GaussianScene,
    cam: Camera,
    cfg: RenderConfig,
    background: Optional[jnp.ndarray] = None,
) -> RenderResult:
    """Render one camera through the staged engine on ``cfg.backend``."""
    backend = get_backend(cfg.backend)
    proj = backend.project(scene, cam)
    if cfg.mode == "gstg":
        return _render_gstg(backend, proj, cam, cfg, background)
    if cfg.mode == "tile_baseline":
        return _render_flat(backend, proj, cam, cfg, background, level="tile")
    if cfg.mode == "group_baseline":
        return _render_flat(backend, proj, cam, cfg, background, level="group")
    raise ValueError(f"unknown mode {cfg.mode!r}")


def _render_flat(
    backend: Backend, proj, cam, cfg, background, level: str
) -> RenderResult:
    """Conventional per-bin pipeline at tile or group granularity."""
    grid = _grid(cam, cfg)
    if level == "tile":
        bins_xy = grid.num_tiles
        capacity = cfg.tile_capacity
        raster_grid = grid
    else:
        bins_xy = grid.num_groups
        capacity = cfg.group_capacity
        # Rasterize at group granularity: treat groups as (large) tiles.
        raster_grid = GridSpec(
            width=grid.n_groups_x * grid.group,
            height=grid.n_groups_y * grid.group,
            tile=grid.group,
            group=grid.group,
            span=cfg.span,
        )

    pairs = backend.identify(proj, grid, level, cfg.boundary_tile)
    table = backend.bin(pairs, bins_xy, capacity)
    rast = backend.rasterize_tiles(
        proj,
        table,
        raster_grid,
        background=background,
        chunk=cfg.chunk,
        early_exit=cfg.early_exit,
    )
    image = rast.image[: cam.height, : cam.width]
    stats = RenderStats(
        n_visible=jnp.sum(proj.valid.astype(jnp.int32)),
        n_candidate_tests=pairs.n_candidate_tests,
        n_pairs_sort=pairs.n_pairs,
        sort_ops=sort_op_count(table.lengths),
        n_bit_tests=jnp.zeros((), jnp.int32),
        fifo_ops=jnp.zeros((), jnp.int32),
        alpha_ops=rast.alpha_ops,
        blend_ops=rast.blend_ops,
        tile_entries=jnp.sum(table.lengths),
        overflow=table.overflow,
        span_overflow=pairs.n_span_overflow,
    )
    return RenderResult(image=image, stats=stats)


def _render_gstg(backend: Backend, proj, cam, cfg, background) -> RenderResult:
    """The paper's pipeline: Fig 9."""
    grid = _grid(cam, cfg)

    # 1) Group identification (coarse, cheap).
    pairs = backend.identify(proj, grid, "group", cfg.boundary_group)

    # 2) Group-wise sorting — ONE sort per group, shared by gf^2 tiles.
    gtable = backend.bin(pairs, grid.num_groups, cfg.group_capacity)

    # 3) Bitmask generation (BGM): tile-granularity tests on group entries.
    #    On the ASIC this overlaps GSM; in XLA the two ops have no data
    #    dependence and schedule freely (gtable order does not affect masks:
    #    masks are per-entry).
    masks = backend.bitmasks(proj, gtable, grid, cfg.boundary_tile, chunk=cfg.chunk)

    # 4) RM FIFO: per-tile compaction by bitmask (linear, order-preserving).
    #    Materialized by the reference backend; virtual (in-register) for the
    #    fused pallas RM, which still reports the same length/overflow stats.
    compacted = backend.compact(gtable, masks, grid, cfg.tile_capacity)

    # 5) Small-tile rasterization.
    rast = backend.rasterize_groups(
        proj,
        gtable,
        masks,
        compacted,
        grid,
        background=background,
        chunk=cfg.chunk,
        early_exit=cfg.early_exit,
        tile_capacity=cfg.tile_capacity,
    )
    stats = RenderStats(
        n_visible=jnp.sum(proj.valid.astype(jnp.int32)),
        n_candidate_tests=pairs.n_candidate_tests,
        n_pairs_sort=pairs.n_pairs,
        sort_ops=sort_op_count(gtable.lengths),
        n_bit_tests=masks.n_bit_tests,
        fifo_ops=jnp.sum(gtable.lengths) * grid.tiles_per_group,
        alpha_ops=rast.alpha_ops,
        blend_ops=rast.blend_ops,
        tile_entries=compacted.tile_entries,
        overflow=gtable.overflow + compacted.overflow,
        span_overflow=pairs.n_span_overflow,
    )
    return RenderResult(image=rast.image, stats=stats)


def render_image(scene, cam, cfg, background=None) -> jnp.ndarray:
    """Convenience: image only (used by training/loss code)."""
    return render(scene, cam, cfg, background).image


# ---------------------------------------------------------------------------
# Batched multi-camera rendering (jit-compiled, cached by static signature)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CameraBatch:
    """A batch of cameras sharing static geometry (resolution, clip planes).

    Dynamic per-camera parameters (pose + intrinsics) are stacked arrays and
    become traced arguments of the cached renderer; width/height stay static
    so the GridSpec — and therefore the compiled program — is shared.
    """

    R: jnp.ndarray    # (B, 3, 3)
    t: jnp.ndarray    # (B, 3)
    fx: jnp.ndarray   # (B,)
    fy: jnp.ndarray   # (B,)
    cx: jnp.ndarray   # (B,)
    cy: jnp.ndarray   # (B,)
    width: int
    height: int
    znear: float = 0.2
    zfar: float = 1000.0

    @classmethod
    def from_cameras(cls, cams: Sequence[Camera]) -> "CameraBatch":
        if not cams:
            raise ValueError("empty camera batch")
        w, h = cams[0].width, cams[0].height
        zn, zf = cams[0].znear, cams[0].zfar
        for c in cams:
            if (c.width, c.height, c.znear, c.zfar) != (w, h, zn, zf):
                raise ValueError(
                    "all cameras in a batch must share width/height/znear/zfar"
                )
        stack = lambda f: jnp.asarray(np.stack([np.asarray(f(c)) for c in cams]))
        return cls(
            R=stack(lambda c: c.R),
            t=stack(lambda c: c.t),
            fx=stack(lambda c: np.float32(c.fx)),
            fy=stack(lambda c: np.float32(c.fy)),
            cx=stack(lambda c: np.float32(c.cx)),
            cy=stack(lambda c: np.float32(c.cy)),
            width=w,
            height=h,
            znear=zn,
            zfar=zf,
        )

    def __len__(self) -> int:
        return int(self.R.shape[0])


def batch_signature(cfg: RenderConfig, cam) -> tuple:
    """The full static jit signature for one (config, camera-geometry) pair.

    Accepts a ``Camera`` or a ``CameraBatch`` (anything with width/height/
    znear/zfar). Two renders hit the SAME cached executable iff their
    signatures are equal — this is the key the serving bucketer groups
    requests by (serving/bucketing.py) and the key of the lru caches below.
    """
    return (cfg, cam.width, cam.height, cam.znear, cam.zfar)


jax.tree_util.register_dataclass(
    CameraBatch,
    data_fields=["R", "t", "fx", "fy", "cx", "cy"],
    meta_fields=["width", "height", "znear", "zfar"],
)


def _render_with_traced_camera(cfg: RenderConfig, width, height, znear, zfar):
    """The shared closure both cached renderers jit: rebuild a Camera from
    traced pose/intrinsics around the static geometry and render."""

    def one(scene, R, t, fx, fy, cx, cy, background):
        cam = Camera(
            R=R, t=t, fx=fx, fy=fy, cx=cx, cy=cy,
            width=width, height=height, znear=znear, zfar=zfar,
        )
        return render(scene, cam, cfg, background)

    return one


@functools.lru_cache(maxsize=64)
def _batch_renderer(cfg: RenderConfig, width, height, znear, zfar):
    """Build + jit the vmapped renderer for one static signature.

    lru-cached by (RenderConfig, camera-geometry) — RenderConfig is a frozen
    (hashable, eq-by-value) dataclass, so equal configs share the executable
    even across distinct instances; stale entries age out of the bounded
    cache (the jit wrapper itself is dropped, releasing the executable).
    """
    one = _render_with_traced_camera(cfg, width, height, znear, zfar)
    return jax.jit(jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0, None)))


@functools.lru_cache(maxsize=64)
def _single_renderer(cfg: RenderConfig, width, height, znear, zfar):
    """Cached jit renderer for a single camera of the given static geometry."""
    return jax.jit(_render_with_traced_camera(cfg, width, height, znear, zfar))


def render_cache_clear() -> None:
    """Drop all cached compiled renderers (single + batch)."""
    _batch_renderer.cache_clear()
    _single_renderer.cache_clear()


def _info_dict(info) -> dict:
    return {
        "hits": info.hits,
        "misses": info.misses,
        "currsize": info.currsize,
        "maxsize": info.maxsize,
    }


def render_cache_info() -> dict:
    """Executable-cache statistics as plain dicts.

    ``{"single": {hits, misses, currsize, maxsize}, "batch": {...}}`` — used
    by tests/benchmarks to assert signature reuse, by ``launch/render.py
    --stats``, and by the serving stats (serving/stats.py) so the CLI and the
    server report cache hits in the same units.
    """
    return {
        "single": _info_dict(_single_renderer.cache_info()),
        "batch": _info_dict(_batch_renderer.cache_info()),
    }


def _background_array(background) -> jnp.ndarray:
    if background is None:
        return jnp.zeros((3,), jnp.float32)
    return jnp.asarray(background, jnp.float32)


def render_jit(
    scene: GaussianScene,
    cam: Camera,
    cfg: RenderConfig,
    background: Optional[jnp.ndarray] = None,
) -> RenderResult:
    """Single-camera render through the cached jit entry point.

    Unlike ``jax.jit(render)`` ad hoc, repeated calls with ANY camera of the
    same resolution reuse one compiled executable (pose/intrinsics are traced
    arguments, not closure constants).
    """
    fn = _single_renderer(*batch_signature(cfg, cam))
    return fn(
        scene,
        jnp.asarray(cam.R), jnp.asarray(cam.t),
        jnp.float32(cam.fx), jnp.float32(cam.fy),
        jnp.float32(cam.cx), jnp.float32(cam.cy),
        _background_array(background),
    )


def render_batch(
    scene: GaussianScene,
    cams: Union[CameraBatch, Sequence[Camera]],
    cfg: RenderConfig,
    background: Optional[jnp.ndarray] = None,
) -> RenderResult:
    """Render B cameras in ONE jit call (image: (B, H, W, 3); stats: (B,)).

    The compiled fn is cached by the static (RenderConfig, geometry)
    signature, so multi-view serving amortizes compilation and dispatch
    across frames — the batching prerequisite named in the ROADMAP.
    """
    batch = cams if isinstance(cams, CameraBatch) else CameraBatch.from_cameras(cams)
    fn = _batch_renderer(*batch_signature(cfg, batch))
    return fn(
        scene,
        batch.R, batch.t, batch.fx, batch.fy, batch.cx, batch.cy,
        _background_array(background),
    )
