"""End-to-end rendering engine (paper Fig 1 vs Fig 9).

``render()`` is the single public entry point. It expresses the pipeline as
explicit stages (project -> identify -> bin/sort -> bitmask -> compact ->
rasterize; see core/stages.py and DESIGN.md §1) and dispatches every stage to
the backend selected by ``RenderConfig.backend``:

  * ``reference`` — pure-jnp XLA stages (differentiable oracle).
  * ``pallas``    — BGM + fused RM as Pallas kernels, same RenderStats.

Three modes share the substrate regardless of backend:

  * ``tile_baseline``  — conventional 3D-GS: identify + sort + rasterize at
    the small-tile level (paper Fig 1). Sorting keys = (gaussian, tile) pairs.
  * ``group_baseline`` — 'large tile' baseline: identify + sort + rasterize at
    the group level (what Fig 13 calls baseline 64x64).
  * ``gstg``           — the paper's method (Fig 9): group identification,
    group-wise sorting, per-entry tile bitmasks, FIFO compaction, small-tile
    rasterization. Sorting keys = (gaussian, group) pairs only.

Every mode returns the image plus RenderStats counters that drive the
benchmarks and the accelerator cost model.

``render_batch()`` renders a batch of cameras in ONE jit-compiled call (vmap
over the camera parameters); compiled renderers are cached by the static
(RenderConfig, camera-geometry) signature so repeated multi-view calls reuse
the executable (DESIGN.md §6).

Session-style rendering lives in ``repro.engine`` (DESIGN.md §11):
``engine.open(scene, cfg)`` commits the scene once and returns a handle with
``.render/.render_batch/.submit``; ``render_jit``/``render_image`` here are
deprecation shims over its module-default handle.

The GAUSSIAN axis is a sharding dimension too (DESIGN.md §10/§12): with
``cfg.scene_shards = D`` the frontend stages (project/identify/bin) run
per-shard on the canonical padded layout (sharding/scene.py) and a stable
merge stage rebuilds the global depth-ordered bin table bitwise-identically
to the replicated path. The projected features STAY in the per-shard layout
(``ShardedProjected``) all the way through bitmask/compact/rasterize: each
gather site decomposes the merged table's global indices into (shard,
local) and fetches from the owning shard (``cfg.feature_gather`` selects
the plain indexed gather or the owner-masked psum collective — both
bitwise-identical to the legacy flat concat), so per-camera activation
bytes scale 1/D alongside the persistent parameters. The engine handle
commits the strategy (engine/handle.py); ``serving/sharded.py`` lays the
shard axis over a 2-D (data=cameras, model=gaussians) mesh for scenes too
large to replicate.

Losslessness guarantees (tested in tests/test_pipeline_lossless.py):
  * BITWISE image equality gstg == tile_baseline whenever the bitmask method
    is at least as tight as the group method (ellipse bitmask under any group
    method; matched aabb+aabb) and no capacity overflow occurs — the per-tile
    entry tables are then identical arrays.
  * For the remaining method combos the CONTRIBUTING Gaussian sequences are
    still identical per tile (exact-set losslessness); images agree to fp
    reassociation of interleaved zero-alpha entries (<=1e-6), because every
    boundary method conservatively over-approximates the q<=9 support that
    rasterization enforces.
  * Across backends: identical integer counters and allclose images (the
    pallas RM chunks the group list rather than the compacted tile lists, so
    partial-sum association may differ by fp rounding; tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.grouping import GridSpec, sort_op_count
from repro.core.projection import (
    FEATURE_GATHER_STRATEGIES,
    ShardedProjected,
    proj_valid_count,
)
from repro.core.stages import (
    Backend,
    TimedBackend,
    get_backend,
    timed_stage_cache_clear,
    timed_stage_cache_info,
)
from repro.sharding.scene import SceneLike, ShardedScene, shard_scene
from repro.utils import wide_count_dtype, wide_count_sum


@dataclasses.dataclass(frozen=True)
class RenderConfig:
    tile: int = 16
    group: int = 64
    mode: str = "gstg"                 # gstg | tile_baseline | group_baseline
    boundary_group: str = "ellipse"    # group-identification method (GS-TG)
    boundary_tile: str = "ellipse"     # tile identification / bitmask method
    group_capacity: int = 512          # K: entries per group segment
    tile_capacity: int = 256           # K_t: entries per tile segment
    span: int = 4                      # candidate window at group level (bins)
    chunk: int = 32                    # raster gaussian chunk
    early_exit: bool = True
    backend: str = "reference"         # stage implementation: reference | pallas
    scene_shards: int = 1              # D: gaussian-axis shards (DESIGN.md §10);
                                       #   part of the static jit/bucket signature
    feature_gather: str = "auto"       # projected-feature gather strategy when
                                       #   scene-sharded (DESIGN.md §12):
                                       #   auto (-> index) | index | psum | flat
    timing: bool = False               # timed-stage mode (DESIGN.md §14): run
                                       #   each stage as its own jit'd program
                                       #   with a block_until_ready fence and a
                                       #   stage/<name> span; bitwise-identical
                                       #   images, and part of the static
                                       #   signature so timed and untimed never
                                       #   share an executable


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RenderStats:
    """Operation counters for the paper's metrics + the cost model."""

    n_visible: jnp.ndarray           # gaussians surviving culling
    n_candidate_tests: jnp.ndarray   # identification boundary tests (wide)
    n_pairs_sort: jnp.ndarray        # sorting keys (the paper's redundancy axis)
    sort_ops: jnp.ndarray            # comparator-model ops sum L log L (wide)
    n_bit_tests: jnp.ndarray         # bitmask-generation tile tests (gstg only)
    fifo_ops: jnp.ndarray            # linear compaction ops (gstg only, wide)
    # 'wide' counters use utils.wide_count_dtype (int64 under x64, else f32):
    # they exceed int32 on multi-million-Gaussian scenes and must never wrap.
    alpha_ops: jnp.ndarray           # per-pixel alpha computations
    blend_ops: jnp.ndarray           # contributing blends
    tile_entries: jnp.ndarray        # total per-tile raster entries
    overflow: jnp.ndarray            # capacity-dropped entries (must be 0)
    span_overflow: jnp.ndarray       # candidate-window dropped bins (must be 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RenderResult:
    image: jnp.ndarray
    stats: RenderStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FrontendResult:
    """Everything the frontend program (project -> identify -> bin -> merge)
    hands the backend program (bitmask -> compact -> rasterize).

    A registered pytree so it crosses jit boundaries as-is: the engine
    handle compiles the two halves as SEPARATE programs (DESIGN.md §15) and
    a stream session parks these in its exact-reuse cache — feeding a cached
    FrontendResult to ``render_backend`` is bitwise-identical to the fused
    ``render`` because the backend consumes only ``proj``/``table`` and the
    frontend counters ride through untouched.
    """

    proj: Any                        # Projected | ShardedProjected
    table: Any                       # BinTable (group- or tile-level)
    n_visible: jnp.ndarray           # gaussians surviving culling
    n_candidate_tests: jnp.ndarray   # identification boundary tests (wide)
    n_pairs_sort: jnp.ndarray        # sorting keys produced by identify
    span_overflow: jnp.ndarray       # candidate-window dropped bins


def _grid(cam, cfg: RenderConfig) -> GridSpec:
    return GridSpec(
        width=cam.width,
        height=cam.height,
        tile=cfg.tile,
        group=cfg.group,
        span=cfg.span,
    )


def _scene_for_render(scene: SceneLike, cfg: RenderConfig) -> SceneLike:
    """Resolve the scene into the layout ``cfg.scene_shards`` asks for.

    A plain GaussianScene with ``scene_shards > 1`` is padded/sharded
    in-trace (sharding/scene.py canonical layout) — a real device placement
    only needs the caller to device_put a pre-sharded scene instead
    (serving/sharded.py). A ShardedScene is accepted at any D as long as it
    matches the config, including D == 1, which is how the sharded frontend
    is exercised degenerately (bitwise-identical to the replicated path).
    """
    if isinstance(scene, ShardedScene):
        if scene.num_shards != cfg.scene_shards:
            raise ValueError(
                f"scene has {scene.num_shards} shards but cfg.scene_shards="
                f"{cfg.scene_shards}; the shard count is part of the static "
                "signature and must agree"
            )
        return scene
    if cfg.scene_shards > 1:
        return shard_scene(scene, cfg.scene_shards)
    return scene


def resolve_feature_gather(cfg: RenderConfig) -> str:
    """Resolve ``cfg.feature_gather`` to a concrete strategy.

    ``'auto'`` resolves to ``'index'`` — the plain (shard, local) indexed
    gather, correct everywhere and optimal on one device or a logical-only
    shard axis. The engine handle commits ``'psum'`` instead when the scene
    is PHYSICALLY sharded over a mesh 'model' axis (engine/handle.py): the
    owner-masked collective form is what keeps per-camera features at N/D
    per device. ``'flat'`` is the legacy full-N concat, kept so benchmarks
    can A/B the memory/throughput tradeoff. All strategies are
    bitwise-identical (DESIGN.md §12); only memory/layout differ.
    """
    if cfg.feature_gather == "auto":
        return "index"
    if cfg.feature_gather not in FEATURE_GATHER_STRATEGIES:
        raise ValueError(
            f"unknown feature_gather {cfg.feature_gather!r}; expected "
            f"'auto' or one of {FEATURE_GATHER_STRATEGIES}"
        )
    return cfg.feature_gather


def _frontend(
    backend: Backend,
    scene: SceneLike,
    cam,
    grid: GridSpec,
    level: str,
    method: str,
    num_bins: int,
    capacity: int,
    feature_gather: str = "index",
):
    """Stages 1-3 (project / identify / bin) with the gaussian axis as a
    first-class sharding dimension.

    Replicated scene: the three stages run directly. ShardedScene: each
    stage runs per-shard (vmap over the leading shard axis D — laid over a
    mesh 'model' axis by the caller's input shardings), then the new merge
    stage combines the D fixed-capacity BinTables into the global
    depth-ordered table, bitwise-identical to the replicated path
    (core/grouping.py::merge_bin_tables, DESIGN.md §10). Downstream stages
    (bitmask/compact/rasterize) consume the merged table plus the projected
    features in the PER-SHARD layout (`ShardedProjected`): each gather site
    decomposes the table's global ``gauss_idx`` into (shard, local) and
    fetches from the owning shard (core/projection.py::proj_take,
    DESIGN.md §12) — the full padded-N flat feature concat only exists
    under the legacy ``feature_gather='flat'`` strategy.

    Returns ``(proj, table, (n_candidate_tests, n_pairs, n_span_overflow))``
    with ``proj`` a flat ``Projected`` (replicated scene or 'flat' strategy)
    or a ``ShardedProjected``, and the counters shard-summed —
    bitwise-equal to the replicated reduction whenever every partial fits
    the wide dtype's exact-integer range (always under x64; below 2**24 per
    counter under x64-off, which covers every parity test; above that the
    f32 counters are approximate-but-monotone on BOTH paths).
    """
    if isinstance(scene, GaussianScene):
        proj = backend.project(scene, cam)
        pairs = backend.identify(proj, grid, level, method)
        table = backend.bin(pairs, num_bins, capacity)
        return proj, table, (
            pairs.n_candidate_tests, pairs.n_pairs, pairs.n_span_overflow
        )

    D, shard_size = scene.num_shards, scene.shard_size
    if isinstance(backend, TimedBackend):
        # Timed mode: each vmapped stage is one fenced jit(vmap) program —
        # the per-shard calls below run inside the vmap trace, where fences
        # would no-op (core/stages.py::TimedBackend).
        proj_s = backend.project_shards(scene.shards, cam)
        pairs_s = backend.identify_shards(proj_s, grid, level, method)
        tables_s = backend.bin_shards(pairs_s, num_bins, capacity)
    else:
        proj_s = jax.vmap(lambda s: backend.project(s, cam))(scene.shards)
        pairs_s = jax.vmap(
            lambda p: backend.identify(p, grid, level, method)
        )(proj_s)
        tables_s = jax.vmap(lambda p: backend.bin(p, num_bins, capacity))(pairs_s)

    # Shard-local -> global gaussian indices: the canonical layout is
    # gaussian-contiguous, so shard d starts at d * shard_size.
    offsets = (jnp.arange(D, dtype=jnp.int32) * shard_size)[:, None, None]
    gauss_idx = jnp.where(
        tables_s.entry_valid, tables_s.gauss_idx + offsets, 0
    )
    # Merge keys gathered SHARD-LOCALLY (each shard reads only its own
    # rows): bitwise-equal to the flat proj.depth[global_idx] gather because
    # flat[d * Ns + l] == proj_s.depth[d, l].
    depth = jnp.where(
        tables_s.entry_valid,
        jax.vmap(lambda p, t: p.depth[t.gauss_idx])(proj_s, tables_s),
        jnp.inf,
    )
    table = backend.merge(
        dataclasses.replace(tables_s, gauss_idx=gauss_idx), depth
    )
    if feature_gather == "flat":
        proj = jax.tree.map(
            lambda x: x.reshape(D * shard_size, *x.shape[2:]), proj_s
        )
    else:
        proj = ShardedProjected(shards=proj_s, gather=feature_gather)
    return proj, table, (
        jnp.sum(pairs_s.n_candidate_tests),
        jnp.sum(pairs_s.n_pairs),
        jnp.sum(pairs_s.n_span_overflow),
    )


def render(
    scene: SceneLike,
    cam: Camera,
    cfg: RenderConfig,
    background: Optional[jnp.ndarray] = None,
) -> RenderResult:
    """Render one camera through the staged engine on ``cfg.backend``.

    ``scene`` is a plain (replicated) GaussianScene or a ShardedScene in the
    canonical gaussian-sharded layout; ``cfg.scene_shards`` selects the
    frontend and is part of every cache/bucket signature.
    """
    backend = get_backend(cfg.backend)
    scene = _scene_for_render(scene, cfg)
    if _timed_eligible(cfg, scene, cam, background):
        from repro.obs import get_tracer

        backend = TimedBackend(backend)
        tracer = get_tracer()
        t0 = tracer.clock()
        out = _render_mode(backend, scene, cam, cfg, background)
        # Umbrella span over the whole staged render; the per-stage spans
        # TimedBackend recorded nest under it on the same thread lane.
        tracer.complete(
            "stage/render", t0, tracer.clock(), category="stage",
            args={"mode": cfg.mode, "backend": cfg.backend}, force=True,
        )
        return out
    return _render_mode(backend, scene, cam, cfg, background)


def _timed_eligible(cfg: RenderConfig, scene, cam, background) -> bool:
    """Timed-stage mode applies only to CONCRETE inputs: under an outer
    trace (legacy jit(vmap) renderers, the jit'd autotune probe) fences
    would no-op and per-stage spans would record trace-time garbage, so
    traced calls stay on the plain backend — which is bitwise-identical."""
    return cfg.timing and not _has_tracers(
        (scene, cam.R, cam.fx, background)
    )


def _render_mode(backend, scene, cam, cfg, background) -> RenderResult:
    # The fused path IS the composition of the two halves (DESIGN.md §15):
    # same stage calls, same dataflow, so splitting the program at this
    # boundary (engine stream sessions jit each half separately) keeps
    # images bitwise-identical to the one-program render.
    front = _run_frontend(backend, scene, cam, cfg)
    return _run_backend(backend, front, cam, cfg, background)


def _frontend_spec(cfg: RenderConfig, grid: GridSpec) -> tuple:
    """The (level, method, num_bins, capacity) the mode's frontend runs at.

    gstg sorts once per GROUP with the group-identification method (the
    paper's redundancy win); tile_baseline sorts per tile; group_baseline
    sorts per group but with the tile method (Fig 13's 'large tile'
    baseline).
    """
    if cfg.mode == "gstg":
        return "group", cfg.boundary_group, grid.num_groups, cfg.group_capacity
    if cfg.mode == "tile_baseline":
        return "tile", cfg.boundary_tile, grid.num_tiles, cfg.tile_capacity
    if cfg.mode == "group_baseline":
        return "group", cfg.boundary_tile, grid.num_groups, cfg.group_capacity
    raise ValueError(f"unknown mode {cfg.mode!r}")


def _run_frontend(
    backend: Backend, scene, cam, cfg: RenderConfig
) -> FrontendResult:
    """Stages 1-3 (+ merge when scene-sharded) for any mode: ONE sort per
    bin at the mode's granularity. Per-shard + stable merge when sharded."""
    grid = _grid(cam, cfg)
    level, method, num_bins, capacity = _frontend_spec(cfg, grid)
    proj, table, (n_tests, n_pairs, n_span) = _frontend(
        backend, scene, cam, grid, level, method, num_bins, capacity,
        resolve_feature_gather(cfg),
    )
    return FrontendResult(
        proj=proj,
        table=table,
        n_visible=proj_valid_count(proj),
        n_candidate_tests=n_tests,
        n_pairs_sort=n_pairs,
        span_overflow=n_span,
    )


def _run_backend(
    backend: Backend, front: FrontendResult, cam, cfg: RenderConfig, background
) -> RenderResult:
    """Stages 4-6 on a FrontendResult: bitmask/compact/rasterize for gstg,
    direct per-bin rasterization for the baselines."""
    grid = _grid(cam, cfg)
    proj, table = front.proj, front.table

    if cfg.mode == "gstg":
        # 4) Bitmask generation (BGM): tile-granularity tests on group
        #    entries. On the ASIC this overlaps GSM; in XLA the two ops have
        #    no data dependence and schedule freely (table order does not
        #    affect masks: masks are per-entry — which is also why bitmasks
        #    need no cross-shard pass: they run on the already-merged table).
        masks = backend.bitmasks(
            proj, table, grid, cfg.boundary_tile, chunk=cfg.chunk
        )
        # 5) RM FIFO: per-tile compaction by bitmask (linear, order-
        #    preserving). Materialized by the reference backend; virtual
        #    (in-register) for the fused pallas RM, which still reports the
        #    same length/overflow stats.
        compacted = backend.compact(table, masks, grid, cfg.tile_capacity)
        # 6) Small-tile rasterization.
        rast = backend.rasterize_groups(
            proj,
            table,
            masks,
            compacted,
            grid,
            background=background,
            chunk=cfg.chunk,
            early_exit=cfg.early_exit,
            tile_capacity=cfg.tile_capacity,
        )
        stats = RenderStats(
            n_visible=front.n_visible,
            n_candidate_tests=front.n_candidate_tests,
            n_pairs_sort=front.n_pairs_sort,
            sort_ops=sort_op_count(table.lengths),
            n_bit_tests=masks.n_bit_tests,
            fifo_ops=wide_count_sum(table.lengths) * grid.tiles_per_group,
            alpha_ops=rast.alpha_ops,
            blend_ops=rast.blend_ops,
            tile_entries=compacted.tile_entries,
            overflow=table.overflow + compacted.overflow,
            span_overflow=front.span_overflow,
        )
        return RenderResult(image=rast.image, stats=stats)

    if cfg.mode == "tile_baseline":
        raster_grid = grid
    else:
        # Rasterize at group granularity: treat groups as (large) tiles.
        raster_grid = GridSpec(
            width=grid.n_groups_x * grid.group,
            height=grid.n_groups_y * grid.group,
            tile=grid.group,
            group=grid.group,
            span=cfg.span,
        )
    rast = backend.rasterize_tiles(
        proj,
        table,
        raster_grid,
        background=background,
        chunk=cfg.chunk,
        early_exit=cfg.early_exit,
    )
    image = rast.image[: cam.height, : cam.width]
    stats = RenderStats(
        n_visible=front.n_visible,
        n_candidate_tests=front.n_candidate_tests,
        n_pairs_sort=front.n_pairs_sort,
        sort_ops=sort_op_count(table.lengths),
        n_bit_tests=jnp.zeros((), jnp.int32),
        fifo_ops=jnp.zeros((), wide_count_dtype()),
        alpha_ops=rast.alpha_ops,
        blend_ops=rast.blend_ops,
        tile_entries=jnp.sum(table.lengths),
        overflow=table.overflow,
        span_overflow=front.span_overflow,
    )
    return RenderResult(image=image, stats=stats)


def render_frontend(
    scene: SceneLike, cam: Camera, cfg: RenderConfig
) -> FrontendResult:
    """The frontend HALF of :func:`render` as its own entry point.

    Runs project -> identify -> bin (-> merge when scene-sharded) and
    returns the :class:`FrontendResult` that :func:`render_backend` turns
    into pixels. The split is camera-pose-heavy but pixel-free: everything
    here depends on the pose, nothing on the background or the raster
    loop — which is what makes frontend results reusable across identical
    poses (engine/stream.py) and speculatively precomputable off the
    critical path (DESIGN.md §15).
    """
    backend = get_backend(cfg.backend)
    scene = _scene_for_render(scene, cfg)
    if _timed_eligible(cfg, scene, cam, None):
        backend = TimedBackend(backend)
    return _run_frontend(backend, scene, cam, cfg)


def render_backend(
    front: FrontendResult,
    cam: Camera,
    cfg: RenderConfig,
    background: Optional[jnp.ndarray] = None,
) -> RenderResult:
    """The backend HALF of :func:`render`: pixels from a FrontendResult.

    ``render_backend(render_frontend(scene, cam, cfg), cam, cfg, bg)`` is
    bitwise-identical to ``render(scene, cam, cfg, bg)`` — the fused path
    is literally this composition (tests/test_stream.py). Only the static
    geometry of ``cam`` is read (grid + crop); the pose was consumed by the
    frontend.
    """
    backend = get_backend(cfg.backend)
    if _timed_eligible(cfg, front, cam, background):
        backend = TimedBackend(backend)
    return _run_backend(backend, front, cam, cfg, background)


def frontend_stats(
    scene: SceneLike, cam: Camera, cfg: RenderConfig
) -> RenderStats:
    """Counters WITHOUT rasterization: the autotune phase-1 probe.

    Runs stages 1-5 (project / identify / bin, plus bitmask + compact for
    gstg) and returns a :class:`RenderStats` whose frontend counters are
    exactly what ``render()`` would report for the same config. The raster
    counters that would need the (expensive) stage 6 are replaced by the
    cost model's worst-case alpha estimate — ``tile_entries`` x pixels per
    bin, i.e. every surviving entry alpha-tested against every pixel of its
    bin, which is the no-early-exit upper bound and is MONOTONE across
    candidate configs (the property the phase-1 pruning needs;
    autotune/search.py). ``blend_ops`` is reported as 0 (the cost model
    never reads it). Traceable: jit it per candidate config.
    """
    backend = get_backend(cfg.backend)
    scene = _scene_for_render(scene, cfg)
    if _timed_eligible(cfg, scene, cam, None):
        backend = TimedBackend(backend)
    grid = _grid(cam, cfg)
    gather = resolve_feature_gather(cfg)

    if cfg.mode == "gstg":
        proj, gtable, (n_tests, n_pairs, n_span) = _frontend(
            backend, scene, cam, grid, "group", cfg.boundary_group,
            grid.num_groups, cfg.group_capacity, gather,
        )
        masks = backend.bitmasks(
            proj, gtable, grid, cfg.boundary_tile, chunk=cfg.chunk
        )
        compacted = backend.compact(gtable, masks, grid, cfg.tile_capacity)
        pixels_per_bin = cfg.tile * cfg.tile
        return RenderStats(
            n_visible=proj_valid_count(proj),
            n_candidate_tests=n_tests,
            n_pairs_sort=n_pairs,
            sort_ops=sort_op_count(gtable.lengths),
            n_bit_tests=masks.n_bit_tests,
            fifo_ops=wide_count_sum(gtable.lengths) * grid.tiles_per_group,
            alpha_ops=compacted.tile_entries * pixels_per_bin,
            blend_ops=jnp.zeros((), jnp.int32),
            tile_entries=compacted.tile_entries,
            overflow=gtable.overflow + compacted.overflow,
            span_overflow=n_span,
        )

    if cfg.mode == "tile_baseline":
        level, bins_xy, capacity, bin_px = (
            "tile", grid.num_tiles, cfg.tile_capacity, cfg.tile
        )
    elif cfg.mode == "group_baseline":
        level, bins_xy, capacity, bin_px = (
            "group", grid.num_groups, cfg.group_capacity, cfg.group
        )
    else:
        raise ValueError(f"unknown mode {cfg.mode!r}")
    proj, table, (n_tests, n_pairs, n_span) = _frontend(
        backend, scene, cam, grid, level, cfg.boundary_tile, bins_xy,
        capacity, gather,
    )
    tile_entries = jnp.sum(table.lengths)
    return RenderStats(
        n_visible=proj_valid_count(proj),
        n_candidate_tests=n_tests,
        n_pairs_sort=n_pairs,
        sort_ops=sort_op_count(table.lengths),
        n_bit_tests=jnp.zeros((), jnp.int32),
        fifo_ops=jnp.zeros((), wide_count_dtype()),
        alpha_ops=tile_entries * (bin_px * bin_px),
        blend_ops=jnp.zeros((), jnp.int32),
        tile_entries=tile_entries,
        overflow=table.overflow,
        span_overflow=n_span,
    )


def _has_tracers(tree) -> bool:
    """True when any leaf is a jax Tracer — the deprecation shims then stay
    on the eager ``render`` path (a handle cannot commit a traced scene)."""
    return any(isinstance(x, jax.core.Tracer) for x in jax.tree.leaves(tree))


def render_image(scene, cam, cfg, background=None) -> jnp.ndarray:
    """Deprecated: ``render(scene, cam, cfg).image`` for differentiable /
    in-trace use, or ``repro.engine.open(scene, cfg).render(cam).image`` for
    repeated rendering through a committed handle (DESIGN.md §11)."""
    warnings.warn(
        "render_image() is deprecated; use render(scene, cam, cfg).image "
        "(differentiable) or repro.engine.open(scene, cfg).render(cam).image",
        DeprecationWarning,
        stacklevel=2,
    )
    if _has_tracers(scene):
        return render(scene, cam, cfg, background).image
    from repro import engine

    return engine.default_renderer(scene, cfg).render(cam, background).image


# ---------------------------------------------------------------------------
# Batched multi-camera rendering (jit-compiled, cached by static signature)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CameraBatch:
    """A batch of cameras sharing static geometry (resolution, clip planes).

    Dynamic per-camera parameters (pose + intrinsics) are stacked arrays and
    become traced arguments of the cached renderer; width/height stay static
    so the GridSpec — and therefore the compiled program — is shared.
    """

    R: jnp.ndarray    # (B, 3, 3)
    t: jnp.ndarray    # (B, 3)
    fx: jnp.ndarray   # (B,)
    fy: jnp.ndarray   # (B,)
    cx: jnp.ndarray   # (B,)
    cy: jnp.ndarray   # (B,)
    width: int
    height: int
    znear: float = 0.2
    zfar: float = 1000.0

    @classmethod
    def from_cameras(cls, cams: Sequence[Camera]) -> "CameraBatch":
        if not cams:
            raise ValueError("empty camera batch")
        w, h = cams[0].width, cams[0].height
        zn, zf = cams[0].znear, cams[0].zfar
        for c in cams:
            if (c.width, c.height, c.znear, c.zfar) != (w, h, zn, zf):
                raise ValueError(
                    "all cameras in a batch must share width/height/znear/zfar"
                )
        stack = lambda f: jnp.asarray(np.stack([np.asarray(f(c)) for c in cams]))
        return cls(
            R=stack(lambda c: c.R),
            t=stack(lambda c: c.t),
            fx=stack(lambda c: np.float32(c.fx)),
            fy=stack(lambda c: np.float32(c.fy)),
            cx=stack(lambda c: np.float32(c.cx)),
            cy=stack(lambda c: np.float32(c.cy)),
            width=w,
            height=h,
            znear=zn,
            zfar=zf,
        )

    def __len__(self) -> int:
        return int(self.R.shape[0])


def batch_signature(cfg: RenderConfig, cam) -> tuple:
    """The full static jit signature for one (config, camera-geometry) pair.

    Accepts a ``Camera`` or a ``CameraBatch`` (anything with width/height/
    znear/zfar). Two renders hit the SAME cached executable iff their
    signatures are equal — this is the key the serving bucketer groups
    requests by (serving/bucketing.py) and the key of the lru caches below.
    """
    return (cfg, cam.width, cam.height, cam.znear, cam.zfar)


jax.tree_util.register_dataclass(
    CameraBatch,
    data_fields=["R", "t", "fx", "fy", "cx", "cy"],
    meta_fields=["width", "height", "znear", "zfar"],
)


def _render_with_traced_camera(cfg: RenderConfig, width, height, znear, zfar):
    """The shared closure both cached renderers jit: rebuild a Camera from
    traced pose/intrinsics around the static geometry and render."""

    def one(scene, R, t, fx, fy, cx, cy, background):
        cam = Camera(
            R=R, t=t, fx=fx, fy=fy, cx=cx, cy=cy,
            width=width, height=height, znear=znear, zfar=zfar,
        )
        return render(scene, cam, cfg, background)

    return one


def _frontend_with_traced_camera(cfg: RenderConfig, width, height, znear, zfar):
    """The frontend-program closure the engine handle jits (DESIGN.md §15):
    same traced-camera convention as ``_render_with_traced_camera`` minus
    the background (the frontend never reads it)."""

    def one(scene, R, t, fx, fy, cx, cy):
        cam = Camera(
            R=R, t=t, fx=fx, fy=fy, cx=cx, cy=cy,
            width=width, height=height, znear=znear, zfar=zfar,
        )
        return render_frontend(scene, cam, cfg)

    return one


def _backend_with_static_geometry(cfg: RenderConfig, width, height, znear, zfar):
    """The backend-program closure the engine handle jits (DESIGN.md §15).

    The backend reads only the STATIC camera geometry (grid + crop), so the
    closure bakes a placeholder pose in — the traced inputs are the
    FrontendResult pytree and the background.
    """
    geom_cam = Camera(
        R=np.eye(3, dtype=np.float32), t=np.zeros(3, np.float32),
        fx=np.float32(1.0), fy=np.float32(1.0),
        cx=np.float32(0.0), cy=np.float32(0.0),
        width=width, height=height, znear=znear, zfar=zfar,
    )

    def one(front, background):
        return render_backend(front, geom_cam, cfg, background)

    return one


@functools.lru_cache(maxsize=64)
def _batch_renderer(cfg: RenderConfig, width, height, znear, zfar):
    """Build + jit the vmapped renderer for one static signature.

    lru-cached by (RenderConfig, camera-geometry) — RenderConfig is a frozen
    (hashable, eq-by-value) dataclass, so equal configs share the executable
    even across distinct instances; stale entries age out of the bounded
    cache (the jit wrapper itself is dropped, releasing the executable).
    """
    one = _render_with_traced_camera(cfg, width, height, znear, zfar)
    return jax.jit(jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0, None)))


# Auxiliary renderer-adjacent caches (name -> (info_fn, clear_fn)). Any
# module that builds a private cache on the render path (the sharded
# scene-layout cache in serving/sharded.py, every open engine handle's jit
# cache) MUST register it here so ``render_cache_clear``/
# ``render_cache_info`` stay the single source of truth — the serving
# cache-hit stats are deltas of render_cache_info and a cache outside this
# registry would make them lie.
_AUX_RENDER_CACHES: dict = {}


def register_render_cache(name: str, *, info, clear) -> None:
    """Register an auxiliary cache under ``name``. ``info()`` must return a
    dict with at least ``hits``/``misses`` ints (the cache_delta contract,
    serving/stats.py); ``clear()`` must drop every entry and reset both."""
    if name in ("single", "batch"):
        raise ValueError(f"cache name {name!r} is reserved")
    _AUX_RENDER_CACHES[name] = (info, clear)


def unregister_render_cache(name: str) -> None:
    """Remove an auxiliary cache from the registry (a closed engine handle
    must leave no trace in ``render_cache_info()``). Unknown names are a
    no-op so close() stays idempotent."""
    _AUX_RENDER_CACHES.pop(name, None)


def render_cache_clear() -> None:
    """Drop ALL cached compiled renderers and registered auxiliary caches."""
    _batch_renderer.cache_clear()
    for _, clear in _AUX_RENDER_CACHES.values():
        clear()


def _info_dict(info) -> dict:
    return {
        "hits": info.hits,
        "misses": info.misses,
        "currsize": info.currsize,
        "maxsize": info.maxsize,
    }


def render_cache_info() -> dict:
    """Statistics for EVERY renderer cache as plain dicts.

    ``{"batch": {hits, misses, currsize, maxsize}, **aux}`` where ``aux``
    covers each registered auxiliary cache (``"scene_layout"`` once
    serving/sharded.py is imported, one ``"engineN"`` entry per open handle)
    — used by tests/benchmarks to assert signature reuse, by
    ``launch/render.py --stats``, and by the serving stats
    (serving/stats.py) so the CLI and the server report cache hits in the
    same units.
    """
    out = {
        "batch": _info_dict(_batch_renderer.cache_info()),
    }
    for name, (info, _) in _AUX_RENDER_CACHES.items():
        out[name] = info()
    return out


# The timed-stage jit cache (core/stages.py::TimedBackend) is a render-path
# cache like any other: registering it keeps the serving cache-hit deltas
# truthful when `RenderConfig.timing` is on.
register_render_cache(
    "timed_stage", info=timed_stage_cache_info, clear=timed_stage_cache_clear
)


def _collect_render_caches(registry) -> None:
    """Metrics collector: publish every render-cache's hit/miss/size table
    as ``render_cache.<name>.<field>`` gauges at snapshot time (DESIGN.md
    §14). Gauges, not counters, because the totals are owned by the caches;
    the prefix is dropped first so caches that unregistered (closed engine
    handles) leave no stale series behind."""
    registry.drop("render_cache.")
    for kind, info in render_cache_info().items():
        for k, v in info.items():
            if isinstance(v, (int, float)):
                registry.gauge(f"render_cache.{kind}.{k}").set(v)


from repro.obs import get_registry as _obs_registry  # noqa: E402

_obs_registry().register_collector("render_caches", _collect_render_caches)


def _background_array(background) -> jnp.ndarray:
    if background is None:
        return jnp.zeros((3,), jnp.float32)
    return jnp.asarray(background, jnp.float32)


def render_jit(
    scene: SceneLike,
    cam: Camera,
    cfg: RenderConfig,
    background: Optional[jnp.ndarray] = None,
) -> RenderResult:
    """Deprecated: ``repro.engine.open(scene, cfg).render(cam)``.

    Delegates to the module-default handle for ``(scene, cfg)``
    (``repro.engine.default_renderer``), which keeps the legacy behavior —
    repeated calls with ANY camera of the same resolution reuse one compiled
    executable — while the handle owns the committed scene (DESIGN.md §11).
    """
    warnings.warn(
        "render_jit() is deprecated; open a handle with "
        "repro.engine.open(scene, cfg) and call .render(cam)",
        DeprecationWarning,
        stacklevel=2,
    )
    if _has_tracers(scene):
        return render(scene, cam, cfg, background)
    from repro import engine

    return engine.default_renderer(scene, cfg).render(cam, background)


def render_batch(
    scene: SceneLike,
    cams: Union[CameraBatch, Sequence[Camera]],
    cfg: RenderConfig,
    background: Optional[jnp.ndarray] = None,
) -> RenderResult:
    """Render B cameras in ONE jit call (image: (B, H, W, 3); stats: (B,)).

    The compiled fn is cached by the static (RenderConfig, geometry)
    signature, so multi-view serving amortizes compilation and dispatch
    across frames — the batching prerequisite named in the ROADMAP.
    """
    batch = cams if isinstance(cams, CameraBatch) else CameraBatch.from_cameras(cams)
    fn = _batch_renderer(*batch_signature(cfg, batch))
    return fn(
        scene,
        batch.R, batch.t, batch.fx, batch.fy, batch.cx, batch.cy,
        _background_array(background),
    )
