"""End-to-end rendering pipelines (paper Fig 1 vs Fig 9).

Three modes sharing one substrate:

  * ``tile_baseline``  — conventional 3D-GS: identify + sort + rasterize at
    the small-tile level (paper Fig 1). Sorting keys = (gaussian, tile) pairs.
  * ``group_baseline`` — 'large tile' baseline: identify + sort + rasterize at
    the group level (what Fig 13 calls baseline 64x64).
  * ``gstg``           — the paper's method (Fig 9): group identification,
    group-wise sorting, per-entry tile bitmasks, FIFO compaction, small-tile
    rasterization. Sorting keys = (gaussian, group) pairs only.

Every mode returns the image plus RenderStats counters that drive the
benchmarks and the accelerator cost model.

Losslessness guarantees (tested in tests/test_pipeline_lossless.py):
  * BITWISE image equality gstg == tile_baseline whenever the bitmask method
    is at least as tight as the group method (ellipse bitmask under any group
    method; matched aabb+aabb) and no capacity overflow occurs — the per-tile
    entry tables are then identical arrays.
  * For the remaining method combos the CONTRIBUTING Gaussian sequences are
    still identical per tile (exact-set losslessness); images agree to fp
    reassociation of interleaved zero-alpha entries (<=1e-6), because every
    boundary method conservatively over-approximates the q<=9 support that
    rasterization enforces.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bitmask import compact_tiles, generate_bitmasks
from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.grouping import (
    BinTable,
    GridSpec,
    bin_pairs,
    identify,
    sort_op_count,
)
from repro.core.projection import Projected, project
from repro.core.raster import RasterOut, rasterize


@dataclasses.dataclass(frozen=True)
class RenderConfig:
    tile: int = 16
    group: int = 64
    mode: str = "gstg"                 # gstg | tile_baseline | group_baseline
    boundary_group: str = "ellipse"    # group-identification method (GS-TG)
    boundary_tile: str = "ellipse"     # tile identification / bitmask method
    group_capacity: int = 512          # K: entries per group segment
    tile_capacity: int = 256           # K_t: entries per tile segment
    span: int = 4                      # candidate window at group level (bins)
    chunk: int = 32                    # raster gaussian chunk
    early_exit: bool = True
    use_kernels: bool = False          # route sort/bitmask/raster via Pallas


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RenderStats:
    """Operation counters for the paper's metrics + the cost model."""

    n_visible: jnp.ndarray           # gaussians surviving culling
    n_candidate_tests: jnp.ndarray   # identification boundary tests
    n_pairs_sort: jnp.ndarray        # sorting keys (the paper's redundancy axis)
    sort_ops: jnp.ndarray            # comparator-model ops sum L log L
    n_bit_tests: jnp.ndarray         # bitmask-generation tile tests (gstg only)
    fifo_ops: jnp.ndarray            # linear compaction ops (gstg only)
    alpha_ops: jnp.ndarray           # per-pixel alpha computations
    blend_ops: jnp.ndarray           # contributing blends
    tile_entries: jnp.ndarray        # total per-tile raster entries
    overflow: jnp.ndarray            # capacity-dropped entries (must be 0)
    span_overflow: jnp.ndarray       # candidate-window dropped bins (must be 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RenderResult:
    image: jnp.ndarray
    stats: RenderStats


def _grid(cam: Camera, cfg: RenderConfig) -> GridSpec:
    return GridSpec(
        width=cam.width,
        height=cam.height,
        tile=cfg.tile,
        group=cfg.group,
        span=cfg.span,
    )


def render(
    scene: GaussianScene,
    cam: Camera,
    cfg: RenderConfig,
    background: Optional[jnp.ndarray] = None,
) -> RenderResult:
    proj = project(scene, cam)
    if cfg.mode == "gstg":
        return _render_gstg(proj, cam, cfg, background)
    if cfg.mode == "tile_baseline":
        return _render_flat(proj, cam, cfg, background, level="tile")
    if cfg.mode == "group_baseline":
        return _render_flat(proj, cam, cfg, background, level="group")
    raise ValueError(f"unknown mode {cfg.mode!r}")


def _render_flat(proj, cam, cfg, background, level: str) -> RenderResult:
    """Conventional per-bin pipeline at tile or group granularity."""
    grid = _grid(cam, cfg)
    if level == "tile":
        bins_xy = grid.num_tiles
        capacity = cfg.tile_capacity
        raster_grid = grid
    else:
        bins_xy = grid.num_groups
        capacity = cfg.group_capacity
        # Rasterize at group granularity: treat groups as (large) tiles.
        raster_grid = GridSpec(
            width=grid.n_groups_x * grid.group,
            height=grid.n_groups_y * grid.group,
            tile=grid.group,
            group=grid.group,
            span=cfg.span,
        )

    pairs = identify(proj, grid, level, cfg.boundary_tile)
    table = bin_pairs(pairs, bins_xy, capacity)
    rast = rasterize(
        proj,
        table,
        raster_grid,
        background,
        chunk=cfg.chunk,
        early_exit=cfg.early_exit,
    )
    image = rast.image[: cam.height, : cam.width]
    stats = RenderStats(
        n_visible=jnp.sum(proj.valid.astype(jnp.int32)),
        n_candidate_tests=pairs.n_candidate_tests,
        n_pairs_sort=pairs.n_pairs,
        sort_ops=sort_op_count(table.lengths),
        n_bit_tests=jnp.zeros((), jnp.int32),
        fifo_ops=jnp.zeros((), jnp.int32),
        alpha_ops=rast.alpha_ops,
        blend_ops=rast.blend_ops,
        tile_entries=jnp.sum(table.lengths),
        overflow=table.overflow,
        span_overflow=pairs.n_span_overflow,
    )
    return RenderResult(image=image, stats=stats)


def _render_gstg(proj, cam, cfg, background) -> RenderResult:
    """The paper's pipeline: Fig 9."""
    grid = _grid(cam, cfg)

    # 1) Group identification (coarse, cheap).
    pairs = identify(proj, grid, "group", cfg.boundary_group)

    # 2) Group-wise sorting — ONE sort per group, shared by gf^2 tiles.
    gtable = bin_pairs(pairs, grid.num_groups, cfg.group_capacity)

    # 3) Bitmask generation (BGM): tile-granularity tests on group entries.
    #    On the ASIC this overlaps GSM; in XLA the two ops have no data
    #    dependence and schedule freely (gtable order does not affect masks:
    #    masks are per-entry).
    masks = generate_bitmasks(proj, gtable, grid, cfg.boundary_tile)

    # 4) RM FIFO: per-tile compaction by bitmask (linear, order-preserving).
    ttable = compact_tiles(gtable, masks, grid, cfg.tile_capacity)

    # 5) Small-tile rasterization.
    rast = rasterize(
        proj,
        ttable,
        grid,
        background,
        chunk=cfg.chunk,
        early_exit=cfg.early_exit,
    )
    stats = RenderStats(
        n_visible=jnp.sum(proj.valid.astype(jnp.int32)),
        n_candidate_tests=pairs.n_candidate_tests,
        n_pairs_sort=pairs.n_pairs,
        sort_ops=sort_op_count(gtable.lengths),
        n_bit_tests=masks.n_bit_tests,
        fifo_ops=jnp.sum(gtable.lengths) * grid.tiles_per_group,
        alpha_ops=rast.alpha_ops,
        blend_ops=rast.blend_ops,
        tile_entries=jnp.sum(ttable.lengths),
        overflow=gtable.overflow + ttable.overflow,
        span_overflow=pairs.n_span_overflow,
    )
    return RenderResult(image=rast.image, stats=stats)


def render_image(scene, cam, cfg, background=None) -> jnp.ndarray:
    """Convenience: image only (used by training/loss code)."""
    return render(scene, cam, cfg, background).image
