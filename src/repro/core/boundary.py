"""Gaussian-vs-rectangle intersection tests (paper Fig 2).

Three methods, all conservative supersets of the true 3-sigma ellipse
coverage and all monotone under rectangle containment (tile ⊂ group ⇒
test(tile) ⇒ test(group)) — the property that makes tile grouping lossless:

  * ``aabb``    — square box from the circumscribed 3σ radius (original 3D-GS)
  * ``obb``     — oriented bounding box of the 3σ ellipse via SAT (GSCore)
  * ``ellipse`` — exact ellipse/rect intersection: closed-form minimum of the
                  conic quadratic form over the rectangle (FlashGS-style,
                  but exact rather than edge-sampled)

All tests are vectorized over arbitrary leading batch dims; a rect is
(x0, y0, x1, y1) in pixels.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.projection import QMAX_3SIGMA, SIGMA_CUT

BOUNDARY_METHODS = ("aabb", "obb", "ellipse", "ellipse_opacity")


def opacity_qmax(alpha):
    """Beyond-paper: opacity-aware support bound (FlashGS-style power cut).

    alpha * exp(-q/2) < 1/255 contributes nothing (the rasterizer's exact
    alpha cutoff), so the support truly ends at q = 2*ln(255*alpha); the
    3-sigma rule (q<=9) is only tight for alpha ~= 1. Using
    min(9, 2 ln(255 alpha)) shrinks low-opacity footprints — fewer sorting
    keys AND fewer alpha ops, still exactly lossless."""
    return jnp.minimum(
        QMAX_3SIGMA, 2.0 * jnp.log(jnp.maximum(255.0 * alpha, 1.0 + 1e-6))
    )


def aabb_test(mean2d, radius, rect):
    """Square AABB from circumscribed radius (3D-GS default)."""
    x0, y0, x1, y1 = rect
    mx, my = mean2d[..., 0], mean2d[..., 1]
    return (
        (mx + radius >= x0)
        & (mx - radius <= x1)
        & (my + radius >= y0)
        & (my - radius <= y1)
    )


def obb_test(mean2d, eigvec, eigval, rect):
    """Separating-axis test between the ellipse's OBB and an axis rect.

    OBB: center mean2d, axes u (major eigvec) and v (perp), half-extents
    3*sqrt(eigval). Four candidate separating axes: x, y, u, v.
    """
    x0, y0, x1, y1 = rect
    ux, uy = eigvec[..., 0], eigvec[..., 1]
    vx, vy = -uy, ux
    e1 = SIGMA_CUT * jnp.sqrt(jnp.maximum(eigval[..., 0], 0.0))
    e2 = SIGMA_CUT * jnp.sqrt(jnp.maximum(eigval[..., 1], 0.0))

    cx = 0.5 * (x0 + x1)
    cy = 0.5 * (y0 + y1)
    hx = 0.5 * (x1 - x0)
    hy = 0.5 * (y1 - y0)
    dx = mean2d[..., 0] - cx
    dy = mean2d[..., 1] - cy

    # Axis X: |dx| <= hx + |ux| e1 + |vx| e2
    sep_x = jnp.abs(dx) > hx + jnp.abs(ux) * e1 + jnp.abs(vx) * e2
    sep_y = jnp.abs(dy) > hy + jnp.abs(uy) * e1 + jnp.abs(vy) * e2
    # Axis U: |d . u| <= e1 + hx |ux| + hy |uy|
    sep_u = jnp.abs(dx * ux + dy * uy) > e1 + hx * jnp.abs(ux) + hy * jnp.abs(uy)
    sep_v = jnp.abs(dx * vx + dy * vy) > e2 + hx * jnp.abs(vx) + hy * jnp.abs(vy)
    return ~(sep_x | sep_y | sep_u | sep_v)


def ellipse_min_q(mean2d, conic, rect):
    """Exact min over the rect of q(p) = (p-mu)^T Conic (p-mu).

    Closed form: 0 if mu inside; otherwise the minimum lies on one of the four
    edges, and each edge restriction is a 1D quadratic minimized by clamping
    its unconstrained stationary point to the edge interval.
    """
    x0, y0, x1, y1 = rect
    A = conic[..., 0]
    B = conic[..., 1]
    C = conic[..., 2]
    mx, my = mean2d[..., 0], mean2d[..., 1]

    def q_at(px, py):
        ddx = px - mx
        ddy = py - my
        return A * ddx * ddx + 2.0 * B * ddx * ddy + C * ddy * ddy

    C_safe = jnp.where(jnp.abs(C) > 1e-12, C, 1e-12)
    A_safe = jnp.where(jnp.abs(A) > 1e-12, A, 1e-12)

    # Vertical edges x = xe: y* = my - (B/C)(xe - mx), clamped.
    def edge_v(xe):
        ys = my - (B / C_safe) * (xe - mx)
        ys = jnp.clip(ys, y0, y1)
        return q_at(xe, ys)

    # Horizontal edges y = ye: x* = mx - (B/A)(ye - my), clamped.
    def edge_h(ye):
        xs = mx - (B / A_safe) * (ye - my)
        xs = jnp.clip(xs, x0, x1)
        return q_at(xs, ye)

    edge_min = jnp.minimum(
        jnp.minimum(edge_v(x0), edge_v(x1)),
        jnp.minimum(edge_h(y0), edge_h(y1)),
    )
    inside = (mx >= x0) & (mx <= x1) & (my >= y0) & (my <= y1)
    return jnp.where(inside, 0.0, edge_min)


def ellipse_test(mean2d, conic, rect):
    return ellipse_min_q(mean2d, conic, rect) <= QMAX_3SIGMA


def boundary_test(method: str, proj, rect):
    """Dispatch on method name. ``proj`` is a Projected (or equivalent struct
    with mean2d/radius/eigvec/eigval/conic/alpha broadcastable against rect)."""
    if method == "aabb":
        return aabb_test(proj.mean2d, proj.radius, rect)
    if method == "obb":
        return obb_test(proj.mean2d, proj.eigvec, proj.eigval, rect)
    if method == "ellipse":
        return ellipse_test(proj.mean2d, proj.conic, rect)
    if method == "ellipse_opacity":
        qmax = opacity_qmax(proj.alpha)
        return ellipse_min_q(proj.mean2d, proj.conic, rect) <= qmax
    raise ValueError(f"unknown boundary method: {method!r}")
