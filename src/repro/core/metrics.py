"""Image quality metrics: PSNR and SSIM (pure jnp)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def psnr(img: jnp.ndarray, ref: jnp.ndarray, data_range: float = 1.0) -> jnp.ndarray:
    mse = jnp.mean((img - ref) ** 2)
    return 10.0 * jnp.log10(data_range**2 / jnp.maximum(mse, 1e-12))


def _gaussian_kernel(size: int = 11, sigma: float = 1.5) -> jnp.ndarray:
    x = jnp.arange(size, dtype=jnp.float32) - (size - 1) / 2.0
    g = jnp.exp(-0.5 * (x / sigma) ** 2)
    g = g / jnp.sum(g)
    return jnp.outer(g, g)


def ssim(
    img: jnp.ndarray,
    ref: jnp.ndarray,
    data_range: float = 1.0,
    size: int = 11,
    sigma: float = 1.5,
) -> jnp.ndarray:
    """Mean SSIM over channels. img/ref: (H, W, C) in [0, data_range]."""
    c1 = (0.01 * data_range) ** 2
    c2 = (0.03 * data_range) ** 2
    win = _gaussian_kernel(size, sigma)[:, :, None, None]  # (s, s, 1, 1)

    def filt(x):  # (H, W, C) -> valid conv per channel
        x = x.transpose(2, 0, 1)[:, None, :, :]  # (C, 1, H, W)
        out = jax.lax.conv_general_dilated(
            x,
            win.transpose(3, 2, 0, 1),  # (1, 1, s, s)
            window_strides=(1, 1),
            padding="VALID",
        )
        return out[:, 0].transpose(1, 2, 0)

    mu_x = filt(img)
    mu_y = filt(ref)
    xx = filt(img * img) - mu_x * mu_x
    yy = filt(ref * ref) - mu_y * mu_y
    xy = filt(img * ref) - mu_x * mu_y
    s = ((2 * mu_x * mu_y + c1) * (2 * xy + c2)) / (
        (mu_x**2 + mu_y**2 + c1) * (xx + yy + c2)
    )
    return jnp.mean(s)


def dssim(img, ref, data_range: float = 1.0):
    return (1.0 - ssim(img, ref, data_range)) / 2.0
