"""Thin public wrappers + layout glue for the GS-TG Pallas kernels.

On CPU (this container) the kernels execute via Pallas interpret mode; on a
real TPU backend the same code lowers to Mosaic. There is NO standalone
kernel-path renderer here: the Pallas kernels are stage implementations of
the unified engine — select them with ``RenderConfig(backend="pallas")`` and
go through ``repro.core.pipeline.render`` (see core/stages.PallasBackend).
Identification and group binning stay on the XLA sort substrate (DESIGN.md
§2); this module only hosts the geometry/layout helpers those stages share.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

# Pallas lowers natively on these platforms (Mosaic on TPU, Triton on GPU);
# everywhere else the kernels run through the interpreter.
_ACCEL_PLATFORMS = ("tpu", "gpu", "cuda", "rocm")


def default_interpret() -> bool:
    """Should Pallas kernels run in interpret mode?

    Resolution order (DESIGN.md §13, the real-hardware lane):
      1. ``REPRO_PALLAS_INTERPRET`` env var — ``0/false/off`` forces
         compiled kernels, anything else truthy forces the interpreter
         (useful to keep interpret mode ON for debugging on a TPU host).
      2. Platform auto-detect: compile on TPU/GPU, interpret elsewhere
         (CPU has no Mosaic/Triton lowering).

    Per-call ``interpret=`` arguments on the kernel wrappers and
    ``PallasBackend(interpret=...)`` still override both.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env.strip() != "":
        return env.strip().lower() not in ("0", "false", "off", "no")
    return jax.default_backend() not in _ACCEL_PLATFORMS


def group_origins(grid) -> jnp.ndarray:
    g = jnp.arange(grid.num_groups, dtype=jnp.int32)
    return jnp.stack(
        [(g % grid.n_groups_x) * grid.group, (g // grid.n_groups_x) * grid.group],
        axis=-1,
    ).astype(jnp.float32)


def tile_origins(grid) -> jnp.ndarray:
    t = jnp.arange(grid.num_tiles, dtype=jnp.int32)
    return jnp.stack(
        [(t % grid.n_tiles_x) * grid.tile, (t // grid.n_tiles_x) * grid.tile],
        axis=-1,
    ).astype(jnp.float32)


def tiles_in_image(grid) -> jnp.ndarray:
    """(num_groups, tpg) bool: member tile lies inside the image."""
    g = jnp.arange(grid.num_groups, dtype=jnp.int32)[:, None]
    s = jnp.arange(grid.tiles_per_group, dtype=jnp.int32)[None, :]
    gf = grid.gf
    tx = (g % grid.n_groups_x) * gf + s % gf
    ty = (g // grid.n_groups_x) * gf + s // gf
    return (tx < grid.n_tiles_x) & (ty < grid.n_tiles_y)


def sort_groups_bitonic(depth_keys, payload_idx, interpret=None):
    """GSM path: per-group depth sort via the bitonic kernel.

    depth_keys: (G, K) float32 with +inf at invalid slots.
    payload_idx: (G, K) int32. Returns (keys, idx) sorted ascending.

    Note: the engine's binning uses the XLA *stable* sort (the tie-break the
    losslessness proof needs); the bitonic kernel is the ASIC GSM model and
    is validated standalone (tests/test_kernels_sort.py, DESIGN.md §2).
    """
    from repro.kernels.bitonic_sort import bitonic_sort_kernel

    interpret = default_interpret() if interpret is None else interpret
    payload_f = payload_idx.astype(jnp.float32)  # indices < 2^24: exact in f32
    k, v = bitonic_sort_kernel(depth_keys, payload_f, interpret=interpret)
    return k, v.astype(jnp.int32)


def assemble_image(out, grid, background=None):
    """(G, tpg, 4, P) kernel output -> (H, W, 3) image."""
    if background is None:
        background = jnp.zeros((3,), jnp.float32)
    G, tpg, _, P = out.shape
    gf = grid.gf
    T = grid.tile
    rgb = out[:, :, :3, :] + out[:, :, 3:4, :] * background[None, None, :, None]
    # (gy, gx, ty, tx, c, py, px)
    rgb = rgb.reshape(grid.n_groups_y, grid.n_groups_x, gf, gf, 3, T, T)
    rgb = rgb.transpose(0, 2, 5, 1, 3, 6, 4)
    img = rgb.reshape(
        grid.n_groups_y * gf * T, grid.n_groups_x * gf * T, 3
    )
    return img[: grid.height, : grid.width]


def assemble_image_tiles(out, grid, background=None):
    """(num_tiles, 4, P) raster_tile_kernel output -> (H, W, 3)."""
    if background is None:
        background = jnp.zeros((3,), jnp.float32)
    T = grid.tile
    rgb = out[:, :3, :] + out[:, 3:4, :] * background[None, :, None]
    rgb = rgb.reshape(grid.n_tiles_y, grid.n_tiles_x, 3, T, T)
    rgb = rgb.transpose(0, 3, 1, 4, 2)
    img = rgb.reshape(grid.n_tiles_y * T, grid.n_tiles_x * T, 3)
    return img[: grid.height, : grid.width]
