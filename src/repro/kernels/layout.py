"""Shared feature layout for the GS-TG kernels.

Kernels consume gathered per-bin Gaussian features in an SoA (feature-major)
layout (F, K): the K entry axis maps to VPU lanes, features to sublanes. K is
padded to a multiple of 128 (lane width); F is 16 so fp32 blocks tile the
(8, 128) VMEM layout exactly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.projection import proj_take
from repro.utils import round_up

F_MEAN_X = 0
F_MEAN_Y = 1
F_CONIC_A = 2
F_CONIC_B = 3
F_CONIC_C = 4
F_OPACITY = 5   # 0 for invalid entries
F_RGB_R = 6
F_RGB_G = 7
F_RGB_B = 8
F_RADIUS = 9
F_EIGVEC_X = 10
F_EIGVEC_Y = 11
F_EIGVAL_1 = 12
F_EIGVAL_2 = 13
F_DEPTH = 14
F_VALID = 15
NUM_FEATURES = 16

LANE = 128


def pack_features(
    proj,
    gauss_idx: jnp.ndarray,
    entry_valid: jnp.ndarray,
    multiple: int = LANE,
):
    """Gather Projected fields into (B, NUM_FEATURES, K_pad) fp32 blocks.

    gauss_idx/entry_valid: (B, K). Invalid entries get opacity 0 (=> alpha 0 in
    the raster kernel) and valid flag 0. ``multiple`` sets the K padding
    granularity — pass lcm(LANE, chunk) so any raster chunk size divides K_pad.

    ``proj`` may be a flat ``Projected`` or a ``ShardedProjected`` kept in
    the per-shard layout (DESIGN.md §12): the gathers route through
    ``proj_take``, so the kernel-facing packed block is built straight from
    the owning shards without ever materializing the flat full-N features —
    and is bitwise-identical to the flat-gathered block.
    """
    B, K = gauss_idx.shape
    K_pad = round_up(max(K, 1), max(int(multiple), 1))
    v = entry_valid

    def g(field, ch=None):
        out = proj_take(proj, field, gauss_idx)
        if ch is not None:
            out = out[..., ch]
        return jnp.where(v, out, 0.0).astype(jnp.float32)

    feats = [
        g("mean2d", 0),
        g("mean2d", 1),
        g("conic", 0),
        g("conic", 1),
        g("conic", 2),
        g("alpha"),
        g("rgb", 0),
        g("rgb", 1),
        g("rgb", 2),
        g("radius"),
        g("eigvec", 0),
        g("eigvec", 1),
        g("eigval", 0),
        g("eigval", 1),
        g("depth"),
        v.astype(jnp.float32),
    ]
    packed = jnp.stack(feats, axis=1)  # (B, F, K)
    if K_pad != K:
        packed = jnp.pad(packed, ((0, 0), (0, 0), (0, K_pad - K)))
    return packed
