"""Pure-jnp oracles for the Pallas kernels (independent implementations).

Each oracle recomputes the kernel's output with straightforward dense jnp ops
(no chunking, no early-exit skipping — per-entry T_before gating only), so a
kernel/oracle match validates both the math and the chunked control flow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.layout import (
    F_CONIC_A,
    F_CONIC_B,
    F_CONIC_C,
    F_EIGVAL_1,
    F_EIGVAL_2,
    F_EIGVEC_X,
    F_EIGVEC_Y,
    F_MEAN_X,
    F_MEAN_Y,
    F_OPACITY,
    F_RADIUS,
    F_RGB_B,
    F_RGB_G,
    F_RGB_R,
    F_VALID,
)

ALPHA_MIN = 1.0 / 255.0
ALPHA_MAX = 0.99
T_EPS = 1e-4
QMAX = 9.0
SIGMA_CUT = 3.0


def _pixels(origin, tile_px):
    lin = jnp.arange(tile_px * tile_px, dtype=jnp.float32)
    px = origin[0] + jnp.mod(lin, tile_px) + 0.5
    py = origin[1] + jnp.floor(lin / tile_px) + 0.5
    return px, py


def _alphas(feat, px, py):
    mx, my = feat[F_MEAN_X], feat[F_MEAN_Y]
    dx = px[:, None] - mx[None, :]
    dy = py[:, None] - my[None, :]
    q = (
        feat[F_CONIC_A][None, :] * dx * dx
        + 2.0 * feat[F_CONIC_B][None, :] * dx * dy
        + feat[F_CONIC_C][None, :] * dy * dy
    )
    a = jnp.minimum(feat[F_OPACITY][None, :] * jnp.exp(-0.5 * q), ALPHA_MAX)
    return jnp.where((q > QMAX) | (a < ALPHA_MIN), 0.0, a)


def _blend(a, feat):
    """(P, K) alphas -> (4, P) rgb+T with per-entry early-exit gating."""
    one_m = 1.0 - a
    cp = jnp.cumprod(one_m, axis=1)
    t_before = jnp.concatenate([jnp.ones_like(cp[:, :1]), cp[:, :-1]], axis=1)
    w = jnp.where(t_before > T_EPS, a * t_before, 0.0)
    rgb = jnp.stack(
        [w @ feat[F_RGB_R], w @ feat[F_RGB_G], w @ feat[F_RGB_B]], axis=0
    )
    return jnp.concatenate([rgb, cp[:, -1][None, :]], axis=0)


def ref_raster_tiles(feat, tile_origin, tile_px: int):
    """Oracle for raster_tile_kernel: (num_tiles, 4, P)."""

    def one(f, origin):
        px, py = _pixels(origin, tile_px)
        return _blend(_alphas(f, px, py), f)

    return jax.vmap(one)(feat, tile_origin)


def ref_raster_group_fused(feat, masks, group_origin, tile_px: int, gf: int):
    """Oracle for raster_group_fused_kernel: (num_groups, gf^2, 4, P)."""
    tpg = gf * gf

    def one_tile(f, m, origin, slot):
        ox = origin[0] + (slot % gf) * tile_px
        oy = origin[1] + (slot // gf) * tile_px
        px, py = _pixels(jnp.array([ox, oy]), tile_px)
        a = _alphas(f, px, py)
        keep = ((m.astype(jnp.uint32) >> slot.astype(jnp.uint32)) & 1) > 0
        a = jnp.where(keep[None, :], a, 0.0)
        return _blend(a, f)

    def one_group(f, m, origin):
        slots = jnp.arange(tpg, dtype=jnp.int32)
        return jax.vmap(lambda s: one_tile(f, m, origin, s))(slots)

    return jax.vmap(one_group)(feat, masks, group_origin)


def ref_bitmask(feat, group_origin, tile_in_image, tile_px: int, gf: int,
                method: str = "ellipse"):
    """Oracle for bitmask_kernel via the core boundary tests."""
    from repro.core import boundary

    tpg = gf * gf
    num_groups, F, K = feat.shape

    class P:  # adapter exposing boundary-test fields, (G, K, 1) broadcast
        mean2d = jnp.stack([feat[:, F_MEAN_X], feat[:, F_MEAN_Y]], axis=-1)[:, :, None, :]
        radius = feat[:, F_RADIUS][:, :, None]
        conic = jnp.stack(
            [feat[:, F_CONIC_A], feat[:, F_CONIC_B], feat[:, F_CONIC_C]], axis=-1
        )[:, :, None, :]
        eigvec = jnp.stack([feat[:, F_EIGVEC_X], feat[:, F_EIGVEC_Y]], axis=-1)[:, :, None, :]
        eigval = jnp.stack([feat[:, F_EIGVAL_1], feat[:, F_EIGVAL_2]], axis=-1)[:, :, None, :]

    slots = jnp.arange(tpg, dtype=jnp.float32)
    x0 = group_origin[:, 0][:, None, None] + (slots % gf)[None, None, :] * tile_px
    y0 = group_origin[:, 1][:, None, None] + jnp.floor(slots / gf)[None, None, :] * tile_px
    rect = (x0, y0, x0 + tile_px, y0 + tile_px)
    hit = boundary.boundary_test(method, P, rect)  # (G, K, tpg)
    valid = feat[:, F_VALID] > 0.5
    hit = hit & valid[:, :, None] & (tile_in_image[:, None, :])
    weights = jnp.uint32(1) << jnp.arange(tpg, dtype=jnp.uint32)
    return jnp.sum(hit.astype(jnp.uint32) * weights[None, None, :], axis=-1,
                   dtype=jnp.uint32)


def ref_sort(keys, payload):
    """Oracle for bitonic_sort_kernel (ascending by key; ties unordered —
    compare via composite where needed in tests)."""
    order = jnp.argsort(keys, axis=-1, stable=True)
    return (
        jnp.take_along_axis(keys, order, axis=-1),
        jnp.take_along_axis(payload, order, axis=-1),
    )
