"""Pallas TPU kernel for the Bitmask Generation Module (BGM, paper Fig 10).

Per group entry, runs the chosen boundary test against each of the gf^2
member tiles and packs the results into a uint32 bitmask. The ASIC's four
tile-check units become VPU lanes: each BK-wide entry chunk tests all member
tiles with the tile loop unrolled at trace time (static gf^2 <= 16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.layout import (
    F_CONIC_A,
    F_CONIC_B,
    F_CONIC_C,
    F_EIGVAL_1,
    F_EIGVAL_2,
    F_EIGVEC_X,
    F_EIGVEC_Y,
    F_MEAN_X,
    F_MEAN_Y,
    F_RADIUS,
    F_VALID,
    NUM_FEATURES,
)

QMAX = 9.0
SIGMA_CUT = 3.0


def _aabb(mx, my, r, x0, y0, x1, y1):
    return (mx + r >= x0) & (mx - r <= x1) & (my + r >= y0) & (my - r <= y1)


def _obb(mx, my, ux, uy, l1, l2, x0, y0, x1, y1):
    vx, vy = -uy, ux
    e1 = SIGMA_CUT * jnp.sqrt(jnp.maximum(l1, 0.0))
    e2 = SIGMA_CUT * jnp.sqrt(jnp.maximum(l2, 0.0))
    cx, cy = 0.5 * (x0 + x1), 0.5 * (y0 + y1)
    hx, hy = 0.5 * (x1 - x0), 0.5 * (y1 - y0)
    dx, dy = mx - cx, my - cy
    sep_x = jnp.abs(dx) > hx + jnp.abs(ux) * e1 + jnp.abs(vx) * e2
    sep_y = jnp.abs(dy) > hy + jnp.abs(uy) * e1 + jnp.abs(vy) * e2
    sep_u = jnp.abs(dx * ux + dy * uy) > e1 + hx * jnp.abs(ux) + hy * jnp.abs(uy)
    sep_v = jnp.abs(dx * vx + dy * vy) > e2 + hx * jnp.abs(vx) + hy * jnp.abs(vy)
    return ~(sep_x | sep_y | sep_u | sep_v)


def _ellipse(mx, my, A, B, C, x0, y0, x1, y1):
    C_s = jnp.where(jnp.abs(C) > 1e-12, C, 1e-12)
    A_s = jnp.where(jnp.abs(A) > 1e-12, A, 1e-12)

    def q_at(px, py):
        dx, dy = px - mx, py - my
        return A * dx * dx + 2.0 * B * dx * dy + C * dy * dy

    def edge_v(xe):
        ys = jnp.clip(my - (B / C_s) * (xe - mx), y0, y1)
        return q_at(xe, ys)

    def edge_h(ye):
        xs = jnp.clip(mx - (B / A_s) * (ye - my), x0, x1)
        return q_at(xs, ye)

    qmin = jnp.minimum(
        jnp.minimum(edge_v(x0), edge_v(x1)), jnp.minimum(edge_h(y0), edge_h(y1))
    )
    inside = (mx >= x0) & (mx <= x1) & (my >= y0) & (my <= y1)
    return jnp.where(inside, 0.0, qmin) <= QMAX


def bitmask_kernel(
    feat: jnp.ndarray,          # (num_groups, F, K)
    group_origin: jnp.ndarray,  # (num_groups, 2) float32
    tile_in_image: jnp.ndarray, # (num_groups, tpg) bool -> float32 mask
    tile_px: int,
    gf: int,
    method: str = "ellipse",
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns (num_groups, K) uint32 bitmasks."""
    num_groups, F, K = feat.shape
    assert F == NUM_FEATURES
    tpg = gf * gf

    def kernel(origin_ref, img_ref, feat_ref, out_ref):
        feat_b = feat_ref[0]
        ox = origin_ref[0, 0]
        oy = origin_ref[0, 1]
        mx = feat_b[F_MEAN_X, :]
        my = feat_b[F_MEAN_Y, :]
        valid = feat_b[F_VALID, :] > 0.5
        mask = jnp.zeros((K,), jnp.uint32)
        for slot in range(tpg):  # static unroll: the 4 tile-check units
            x0 = ox + (slot % gf) * tile_px
            y0 = oy + (slot // gf) * tile_px
            x1, y1 = x0 + tile_px, y0 + tile_px
            if method == "aabb":
                hit = _aabb(mx, my, feat_b[F_RADIUS, :], x0, y0, x1, y1)
            elif method == "obb":
                hit = _obb(
                    mx, my,
                    feat_b[F_EIGVEC_X, :], feat_b[F_EIGVEC_Y, :],
                    feat_b[F_EIGVAL_1, :], feat_b[F_EIGVAL_2, :],
                    x0, y0, x1, y1,
                )
            else:
                hit = _ellipse(
                    mx, my,
                    feat_b[F_CONIC_A, :], feat_b[F_CONIC_B, :], feat_b[F_CONIC_C, :],
                    x0, y0, x1, y1,
                )
            hit = hit & valid & (img_ref[0, slot] > 0.5)
            mask = mask | (hit.astype(jnp.uint32) << slot)
        out_ref[0] = mask

    return pl.pallas_call(
        kernel,
        grid=(num_groups,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda g: (g, 0)),
            pl.BlockSpec((1, tpg), lambda g: (g, 0)),
            pl.BlockSpec((1, F, K), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, K), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, K), jnp.uint32),
        interpret=interpret,
    )(group_origin, tile_in_image.astype(jnp.float32), feat)
