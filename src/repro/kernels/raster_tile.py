"""Pallas TPU kernel for the Rasterization Module (RM, paper Fig 10).

Two entry points:

  * ``raster_tile_kernel`` — per-tile rasterization over pre-compacted,
    depth-sorted entry lists (the RM after its FIFO stage). Used by both the
    per-tile baseline and GS-TG (whose FIFO compaction ran upstream).
  * ``raster_group_fused_kernel`` — the fused GS-TG RM: consumes *group*
    entry lists plus per-entry tile bitmasks and applies the bitwise-AND
    valid-flag filter in-register (paper's 8-wide AND/OR logic becomes lane
    predication), so no compacted per-tile tables ever materialize in HBM.
    ``tile_capacity`` bounds each member tile's virtual FIFO: mask-selected
    entries past the capacity are dropped in-register, mirroring the
    reference compaction clamp bit for bit.

Both kernels optionally emit the engine's RenderStats counters (pass
``with_stats=True``): per-block (alpha_ops, blend_ops) accumulated alongside
the blend, with exactly the reference semantics (core/raster.py) so the
pallas backend reports identical integers.

TPU mapping notes (vs the ASIC):
  - grid iterates tiles (or group x member-tile); each step owns a T*T pixel
    block in VMEM and streams the entry list in BK-wide chunks.
  - front-to-back blending uses the exclusive-cumprod formulation along the
    chunk axis; the running transmittance carries between chunks.
  - early exit is block-granular: when every pixel's transmittance is below
    T_EPS the remaining chunks are skipped (lax.cond), the TPU analogue of
    the per-Gaussian FIFO drain. Per-entry exactness is preserved by gating
    each entry's weight on its own T_before (see core/raster.py). Passing
    ``early_exit=False`` disables both the gate and the skip, matching the
    reference's exhaustive blend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.layout import (
    F_CONIC_A,
    F_CONIC_B,
    F_CONIC_C,
    F_MEAN_X,
    F_MEAN_Y,
    F_OPACITY,
    F_RGB_B,
    F_RGB_G,
    F_RGB_R,
    F_VALID,
    NUM_FEATURES,
)

ALPHA_MIN = 1.0 / 255.0
ALPHA_MAX = 0.99
T_EPS = 1e-4
QMAX = 9.0


def _blend_chunk(fc, px, py, carry, *, early_exit, mask_chunk=None,
                 tile_bit=None, tile_capacity=None):
    """Blend one BK-wide feature chunk fc=(F, BK) into the running carry
    (t_run (P,), rgb_acc (3, P), alpha_ops, blend_ops, kept)."""
    t_run, rgb_acc, a_ops, b_ops, kept = carry
    mx = fc[F_MEAN_X]
    my = fc[F_MEAN_Y]
    ca = fc[F_CONIC_A]
    cb = fc[F_CONIC_B]
    cc = fc[F_CONIC_C]
    op = fc[F_OPACITY]
    cr = fc[F_RGB_R]
    cg = fc[F_RGB_G]
    cbl = fc[F_RGB_B]

    dx = px[:, None] - mx[None, :]          # (P, BK)
    dy = py[:, None] - my[None, :]
    q = ca[None, :] * dx * dx + 2.0 * cb[None, :] * dx * dy + cc[None, :] * dy * dy
    a = jnp.minimum(op[None, :] * jnp.exp(-0.5 * q), ALPHA_MAX)
    a = jnp.where((q > QMAX) | (a < ALPHA_MIN), 0.0, a)

    # Which entries belong to this tile's (virtual) compacted list — used both
    # to filter alphas (GS-TG RM) and to count alpha ops like the reference.
    valid_entry = op > 0.0                          # (BK,)
    if mask_chunk is not None:
        # GS-TG RM filter: keep entries whose bitmask covers this tile. The
        # compaction stream is mask & entry-valid — the same predicate
        # core/bitmask.compact_tiles streams by.
        keep = ((mask_chunk.astype(jnp.uint32) >> tile_bit) & 1) > 0
        stream = keep & (fc[F_VALID] > 0.5)
        if tile_capacity is not None:
            # Virtual FIFO clamp: position of each streamed entry in this
            # tile's compaction list; entries past the capacity are dropped,
            # exactly like the reference compaction clamp.
            pos = kept + jnp.cumsum(stream.astype(jnp.int32)) - 1
            kept = kept + jnp.sum(stream.astype(jnp.int32))
            stream = stream & (pos < tile_capacity)
        valid_entry = valid_entry & stream
        a = jnp.where(stream[None, :], a, 0.0)

    one_m = 1.0 - a
    cp = jnp.cumprod(one_m, axis=1)
    excl = jnp.concatenate([jnp.ones_like(cp[:, :1]), cp[:, :-1]], axis=1)
    t_before = t_run[:, None] * excl
    if early_exit:
        live = t_before > T_EPS
        w = jnp.where(live, a * t_before, 0.0)
    else:
        live = jnp.ones_like(t_before, dtype=jnp.bool_)
        w = a * t_before
    rgb_acc = rgb_acc + jnp.stack(
        [w @ cr, w @ cg, w @ cbl], axis=0
    )  # (3, P)
    t_run = t_run * cp[:, -1]
    a_ops = a_ops + jnp.sum(
        (live & valid_entry[None, :]).astype(jnp.int32)
    )
    b_ops = b_ops + jnp.sum((w > 0.0).astype(jnp.int32))
    return t_run, rgb_acc, a_ops, b_ops, kept


def _raster_body(feat_ref, out_ref, counts_ref, *, tile_px, n_chunks, chunk,
                 pix_x, pix_y, early_exit=True, mask_ref=None,
                 tile_bit_fn=None, tile_capacity=None):
    P = tile_px * tile_px
    feat = feat_ref[0]  # (F, K)
    mask = mask_ref[0] if mask_ref is not None else None
    tile_bit = tile_bit_fn() if tile_bit_fn is not None else None

    def body(i, carry):
        def live_fn(c):
            fc = jax.lax.dynamic_slice_in_dim(feat, i * chunk, chunk, axis=1)
            mc = (
                jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=0)
                if mask is not None
                else None
            )
            return _blend_chunk(
                fc, pix_x, pix_y, c,
                early_exit=early_exit,
                mask_chunk=mc,
                tile_bit=tile_bit,
                tile_capacity=tile_capacity,
            )

        if not early_exit:
            return live_fn(carry)
        # Block-granular early exit: skip the chunk when all pixels are dead.
        return jax.lax.cond(
            jnp.any(carry[0] > T_EPS), live_fn, lambda c: c, carry
        )

    carry = (
        jnp.ones((P,), jnp.float32),
        jnp.zeros((3, P), jnp.float32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
    )
    t_run, rgb_acc, a_ops, b_ops, _ = jax.lax.fori_loop(0, n_chunks, body, carry)
    result = jnp.concatenate([rgb_acc, t_run[None, :]], axis=0)  # (4, P)
    out_ref[...] = result.reshape(out_ref.shape)
    counts_ref[...] = jnp.stack([a_ops, b_ops]).reshape(counts_ref.shape)


def _pixel_coords(tile_px: int):
    """In-tile pixel center offsets as two (P,) arrays."""
    P = tile_px * tile_px
    lin = jax.lax.iota(jnp.float32, P)
    px = jnp.mod(lin, tile_px) + 0.5
    py = jnp.floor(lin / tile_px) + 0.5
    return px, py


def raster_tile_kernel(
    feat: jnp.ndarray,          # (num_tiles, F, K)
    tile_origin: jnp.ndarray,   # (num_tiles, 2) float32 pixel origin
    tile_px: int,
    chunk: int = 128,
    interpret: bool = True,
    early_exit: bool = True,
    with_stats: bool = False,
):
    """Returns (num_tiles, 4, tile_px^2): rgb + final transmittance.

    With ``with_stats=True`` also returns (num_tiles, 2) int32
    (alpha_ops, blend_ops) per tile.
    """
    num_tiles, F, K = feat.shape
    assert F == NUM_FEATURES and K % chunk == 0
    P = tile_px * tile_px

    def kernel(origin_ref, feat_ref, out_ref, counts_ref):
        ox = origin_ref[0, 0]
        oy = origin_ref[0, 1]
        dx, dy = _pixel_coords(tile_px)
        _raster_body(
            feat_ref,
            out_ref,
            counts_ref,
            tile_px=tile_px,
            n_chunks=K // chunk,
            chunk=chunk,
            pix_x=ox + dx,
            pix_y=oy + dy,
            early_exit=early_exit,
        )

    out, counts = pl.pallas_call(
        kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda t: (t, 0)),
            pl.BlockSpec((1, F, K), lambda t: (t, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 4, P), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, 2), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles, 4, P), jnp.float32),
            jax.ShapeDtypeStruct((num_tiles, 2), jnp.int32),
        ],
        interpret=interpret,
    )(tile_origin, feat)
    return (out, counts) if with_stats else out


def raster_group_fused_kernel(
    feat: jnp.ndarray,          # (num_groups, F, K) group-sorted entries
    masks: jnp.ndarray,         # (num_groups, K) uint32 tile bitmasks
    group_origin: jnp.ndarray,  # (num_groups, 2) float32
    tile_px: int,
    gf: int,                    # tiles per group side
    chunk: int = 128,
    interpret: bool = True,
    early_exit: bool = True,
    tile_capacity: int | None = None,
    with_stats: bool = False,
):
    """Fused GS-TG RM. Returns (num_groups, gf*gf, 4, tile_px^2).

    With ``with_stats=True`` also returns (num_groups, gf*gf, 2) int32
    (alpha_ops, blend_ops) per member tile.
    """
    num_groups, F, K = feat.shape
    assert F == NUM_FEATURES and K % chunk == 0
    P = tile_px * tile_px
    tpg = gf * gf

    def kernel(origin_ref, feat_ref, mask_ref, out_ref, counts_ref):
        slot = pl.program_id(1)
        ox = origin_ref[0, 0] + (slot % gf).astype(jnp.float32) * tile_px
        oy = origin_ref[0, 1] + (slot // gf).astype(jnp.float32) * tile_px
        dx, dy = _pixel_coords(tile_px)
        _raster_body(
            feat_ref,
            out_ref,
            counts_ref,
            tile_px=tile_px,
            n_chunks=K // chunk,
            chunk=chunk,
            pix_x=ox + dx,
            pix_y=oy + dy,
            early_exit=early_exit,
            mask_ref=mask_ref,
            tile_bit_fn=lambda: slot.astype(jnp.uint32),
            tile_capacity=tile_capacity,
        )

    out, counts = pl.pallas_call(
        kernel,
        grid=(num_groups, tpg),
        in_specs=[
            pl.BlockSpec((1, 2), lambda g, s: (g, 0)),
            pl.BlockSpec((1, F, K), lambda g, s: (g, 0, 0)),
            pl.BlockSpec((1, K), lambda g, s: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 4, P), lambda g, s: (g, s, 0, 0)),
            pl.BlockSpec((1, 1, 2), lambda g, s: (g, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_groups, tpg, 4, P), jnp.float32),
            jax.ShapeDtypeStruct((num_groups, tpg, 2), jnp.int32),
        ],
        interpret=interpret,
    )(group_origin, feat, masks)
    return (out, counts) if with_stats else out
