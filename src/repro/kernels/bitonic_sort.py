"""Pallas TPU kernel for the Group-wise Sorting Module (GSM, paper Fig 10).

TPU adaptation (DESIGN.md §2): the ASIC's 16-comparator *quicksort* unit
relies on data-dependent pivots, which do not map to the VPU. A bitonic
network is the branch-free equivalent: log^2(K) compare-exchange stages,
each fully vectorized across lanes. Compare-exchange partners at distance d
are materialized by a reshape to (K/2d, 2, d) and a min/max swap along the
middle axis — no gathers, pure layout ops, which is what the TPU wants.

Sorts (key, payload) pairs ascending by key within each group segment.
Invalid entries must carry key=+inf so they sink to the end.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(keys, vals, dist: int, asc_mask):
    """One stage: partner = index XOR dist, ascending where asc_mask."""
    K = keys.shape[0]
    kr = keys.reshape(K // (2 * dist), 2, dist)
    vr = vals.reshape(K // (2 * dist), 2, dist)
    am = asc_mask.reshape(K // (2 * dist), 2, dist)[:, 0, :]  # same for pair

    lo_k, hi_k = kr[:, 0, :], kr[:, 1, :]
    lo_v, hi_v = vr[:, 0, :], vr[:, 1, :]
    swap = jnp.where(am, lo_k > hi_k, lo_k < hi_k)
    new_lo_k = jnp.where(swap, hi_k, lo_k)
    new_hi_k = jnp.where(swap, lo_k, hi_k)
    new_lo_v = jnp.where(swap, hi_v, lo_v)
    new_hi_v = jnp.where(swap, lo_v, hi_v)
    keys = jnp.stack([new_lo_k, new_hi_k], axis=1).reshape(K)
    vals = jnp.stack([new_lo_v, new_hi_v], axis=1).reshape(K)
    return keys, vals


def _bitonic_network(keys, vals, K: int):
    # iota computed in-kernel (constants cannot be captured by pallas).
    idx = jax.lax.iota(jnp.int32, K)
    for k in [2 ** p for p in range(1, K.bit_length())]:
        if k > K:
            break
        asc = (idx & k) == 0  # ascending blocks of size k
        for j in [k >> s for s in range(1, k.bit_length())]:
            if j < 1:
                break
            # Partner distance j: reshape trick needs contiguous pairs, which
            # XOR-at-distance-j provides when flattened as (K/2j, 2, j).
            keys, vals = _compare_exchange(keys, vals, j, asc)
    return keys, vals


def bitonic_sort_kernel(
    keys: jnp.ndarray,   # (num_groups, K) float32, +inf padding
    payload: jnp.ndarray,  # (num_groups, K) float32 (bit-cast your ints)
    interpret: bool = True,
):
    """Returns (sorted_keys, permuted_payload), both (num_groups, K)."""
    num_groups, K = keys.shape
    if K & (K - 1):
        raise ValueError("bitonic sort requires power-of-two capacity")

    def kernel(k_ref, v_ref, ko_ref, vo_ref):
        k = k_ref[0]
        v = v_ref[0]
        k, v = _bitonic_network(k, v, K)
        ko_ref[0] = k
        vo_ref[0] = v

    return pl.pallas_call(
        kernel,
        grid=(num_groups,),
        in_specs=[
            pl.BlockSpec((1, K), lambda g: (g, 0)),
            pl.BlockSpec((1, K), lambda g: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, K), lambda g: (g, 0)),
            pl.BlockSpec((1, K), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_groups, K), jnp.float32),
            jax.ShapeDtypeStruct((num_groups, K), jnp.float32),
        ],
        interpret=interpret,
    )(keys, payload)
