"""Checkpointing: atomic, integrity-checked, async, retention-managed.

Format: one .npz (zstd-compressed stream) per checkpoint holding the flat
param/opt pytree plus a JSON manifest (step, rng, data cursor, tree structure,
per-leaf sha256). Writes go to a temp name + fsync + rename (atomic on POSIX),
so a preempted writer never corrupts the latest-good checkpoint. An async
writer thread overlaps serialization with the next training steps — on
multi-host TPU this becomes per-host shard files; here single-host.

Restore validates hashes and can RESHARD: restore(mesh=...) re-places each
leaf with jax.device_put under the target mesh sharding, which is how elastic
re-scaling (ft/elastic.py) resumes on a different device count.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import queue
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

try:  # optional: checkpoints are written uncompressed when unavailable
    import zstandard
except ImportError:  # pragma: no cover - depends on environment
    zstandard = None

# zstd frame magic number — lets restore() auto-detect how a file was written
# regardless of which environment wrote it.
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


@dataclasses.dataclass
class CheckpointInfo:
    step: int
    path: str
    manifest: Dict[str, Any]


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep: int = 3,
        async_write: bool = True,
    ):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._worker: Optional[threading.Thread] = None
        self._errors: List[BaseException] = []
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ---------- public API ----------

    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None):
        """Snapshot ``tree`` at ``step``. Host-copies immediately (so training
        can mutate buffers), then writes sync or async."""
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        payload = (step, host_leaves, str(treedef), extra or {})
        if self.async_write:
            self._q.put(payload)
        else:
            self._write(payload)

    def wait(self):
        """Block until pending async writes are on disk."""
        if self.async_write:
            self._q.join()
        if self._errors:
            raise RuntimeError(f"checkpoint writer failed: {self._errors[0]}")

    def latest(self) -> Optional[CheckpointInfo]:
        steps = self.all_steps()
        return self._info(steps[-1]) if steps else None

    def all_steps(self) -> List[int]:
        out = []
        for f in os.listdir(self.directory):
            if f.startswith("ckpt_") and f.endswith(".npz"):
                out.append(int(f[5:-4]))
        return sorted(out)

    def restore(self, step: Optional[int] = None, sharding_tree=None):
        """Load (tree_leaves, manifest). With ``sharding_tree`` (a pytree of
        NamedSharding matching the saved structure) leaves are device_put
        under it — the elastic-reshard path."""
        if step is None:
            info = self.latest()
            if info is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
            step = info.step
        path = self._path(step)
        with open(path, "rb") as f:
            raw = f.read()
        if raw[:4] == _ZSTD_MAGIC:
            if zstandard is None:
                raise ImportError(
                    f"checkpoint {path} is zstd-compressed but the 'zstandard' "
                    "package is not installed"
                )
            raw = zstandard.ZstdDecompressor().decompress(raw)
        buf = io.BytesIO(raw)
        npz = np.load(buf, allow_pickle=False)
        manifest = json.loads(str(npz["__manifest__"]))
        leaves = []
        for i in range(manifest["n_leaves"]):
            arr = npz[f"leaf_{i}"]
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            if digest != manifest["hashes"][i]:
                raise IOError(
                    f"checkpoint {path} leaf {i} hash mismatch — corrupt file"
                )
            leaves.append(arr)
        if sharding_tree is not None:
            shardings = jax.tree.leaves(sharding_tree)
            leaves = [
                jax.device_put(a, s) for a, s in zip(leaves, shardings)
            ]
        return leaves, manifest

    # ---------- internals ----------

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:010d}.npz")

    def _info(self, step: int) -> CheckpointInfo:
        return CheckpointInfo(step=step, path=self._path(step), manifest={})

    def _drain(self):
        while True:
            payload = self._q.get()
            try:
                self._write(payload)
            except BaseException as e:  # surfaced by wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, payload):
        step, leaves, treedef_str, extra = payload
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": treedef_str,
            "hashes": [hashlib.sha256(a.tobytes()).hexdigest() for a in leaves],
            "time": time.time(),
            "extra": extra,
        }
        buf = io.BytesIO()
        arrays = {f"leaf_{i}": a for i, a in enumerate(leaves)}
        arrays["__manifest__"] = np.asarray(json.dumps(manifest))
        np.savez(buf, **arrays)
        comp = buf.getvalue()
        if zstandard is not None:
            comp = zstandard.ZstdCompressor(level=3).compress(comp)
        path = self._path(step)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(comp)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, path)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass
