"""Autotuned tile-grouping (DESIGN.md §13).

Sweeps the paper's core trade-off — ``tile x group x tile_capacity`` — for a
committed scene: a cost-model-guided pruning phase over cheap stats-only
frontend passes, then real walltime on the survivors through the exact
jit'd engine-handle path. Winners are cached per (scene geometry,
resolution, backend, mesh) signature in the render-cache registry and
persisted to disk; ``engine.open(..., tile_params='auto')`` consults the
cache and commits the tuned config.
"""
from repro.autotune.cache import (
    autotune_signature,
    cache_path,
    evict_autotune_entries,
)
from repro.autotune.search import (
    DEFAULT_CAPACITIES,
    DEFAULT_GROUP_FACTORS,
    DEFAULT_TILES,
    AutotuneResult,
    Candidate,
    autotune,
    candidate_grid,
    config_for,
    cost_phase,
    measure_phase,
    stats_pass,
    sweep,
)

__all__ = [
    "AutotuneResult",
    "Candidate",
    "DEFAULT_CAPACITIES",
    "DEFAULT_GROUP_FACTORS",
    "DEFAULT_TILES",
    "autotune",
    "autotune_signature",
    "cache_path",
    "candidate_grid",
    "config_for",
    "cost_phase",
    "evict_autotune_entries",
    "measure_phase",
    "stats_pass",
    "sweep",
]
