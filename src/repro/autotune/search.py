"""Cost-model-guided (tile x group x tile_capacity) search (DESIGN.md §13).

GS-TG's contribution is a trade-off knob — group small tiles during sorting,
rasterize the original small tiles through bitmasks — and the optimal
setting shifts with scene scale and resolution (FlashGS, PAPERS.md). This
module picks it automatically, in two phases:

  phase 1 (``cost_phase``)    — for every candidate, ONE cheap stats-only
      frontend pass (``core.pipeline.frontend_stats``: project/identify/bin
      + bitmask/compact, no rasterization) feeds
      ``core.cost_model.estimate``; candidates whose tables overflow are
      INFEASIBLE (overflow breaks the losslessness guarantee) and the rest
      are ranked by modeled total seconds.
  phase 2 (``measure_phase``) — the top-k survivors are measured for real
      walltime through the exact jit'd engine-handle path
      (``engine.open`` -> ``Renderer.render``), warm-up excluded,
      median-of-n. The winner is the measured minimum.

Losslessness: the group and tile_capacity axes are BITWISE-lossless
(identical per-tile entry tables whenever nothing overflows — DESIGN.md §7;
infeasible candidates are discarded for exactly that reason). The tile axis
changes the rasterization partition, which reorders interleaved zero-alpha
blends — images then agree to fp reassociation (~1e-7), not bitwise.
``autotune(verify=True)`` asserts the applicable guarantee against the base
config after every fresh search; selecting params via ``tile_params='auto'``
is ALWAYS bitwise-identical to committing the same params fixed (the handle
compiles the identical program — tests/test_autotune.py).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.autotune.cache import autotune_signature, lookup, store
from repro.core.cost_model import GSTG_ASIC, HardwareConfig, estimate
from repro.core.pipeline import RenderConfig, frontend_stats

# The default sweep: 3 tiles x 3 group factors = 9 (tile, group) points
# (the acceptance floor of the BENCH trajectory), each at two capacities.
DEFAULT_TILES: Tuple[int, ...] = (8, 16, 32)
DEFAULT_GROUP_FACTORS: Tuple[int, ...] = (2, 4, 8)
DEFAULT_CAPACITIES: Tuple[int, ...] = (256, 512)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the paper's trade-off grid."""

    tile: int
    group: int
    tile_capacity: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AutotuneResult:
    """The winner plus the full search trajectory (what the BENCH persists).

    ``trajectory`` holds one dict per swept candidate: the knobs, the
    feasibility verdict, the phase-1 cost-model estimate (``est``,
    ``StageCosts.as_dict()``) and — for measured candidates — the phase-2
    ``measured_ms`` median. ``source`` is ``"search"`` for a fresh sweep or
    ``"cache"``/``"disk"`` when the signature hit the autotune cache.
    """

    tile: int
    group: int
    tile_capacity: int
    measured_ms: Optional[float]
    source: str
    signature: tuple
    trajectory: list

    @property
    def candidate(self) -> Candidate:
        return Candidate(self.tile, self.group, self.tile_capacity)


def candidate_grid(
    tiles: Sequence[int] = DEFAULT_TILES,
    group_factors: Sequence[int] = DEFAULT_GROUP_FACTORS,
    capacities: Sequence[int] = DEFAULT_CAPACITIES,
) -> list:
    """The sweep grid: group = tile x factor keeps every candidate a legal
    GridSpec (group must be a tile multiple)."""
    out = []
    for t in tiles:
        for f in group_factors:
            for c in capacities:
                out.append(Candidate(tile=int(t), group=int(t * f),
                                     tile_capacity=int(c)))
    return out


def config_for(base: RenderConfig, cand: Candidate) -> RenderConfig:
    """The base config with one candidate's knobs committed.

    ``group_capacity`` rides along: a group table can never be smaller than
    its member tiles' capacity (entries are compacted INTO tiles from it),
    so it is floored at both the base value and the candidate tile
    capacity. Everything else — mode, backend, boundaries, sharding — is
    part of the autotune signature, not the sweep.
    """
    return dataclasses.replace(
        base,
        tile=cand.tile,
        group=cand.group,
        tile_capacity=cand.tile_capacity,
        group_capacity=max(base.group_capacity, cand.tile_capacity),
    )


def stats_pass(scene, cam, cfg: RenderConfig):
    """One jit'd stats-only frontend pass -> host RenderStats (phase 1)."""
    out = jax.jit(lambda s: frontend_stats(s, cam, cfg))(scene)
    return jax.tree.map(np.asarray, out)


def cost_phase(
    scene,
    cam,
    base_cfg: RenderConfig,
    candidates: Sequence[Candidate],
    hw: HardwareConfig = GSTG_ASIC,
) -> list:
    """Rank candidates by the cost model; flag overflow as infeasible.

    Returns one trajectory entry per candidate (Candidate knobs + ``est`` +
    ``feasible`` + the raw counters the figures derive from), ordered as
    given — ranking happens on the ``est_total_s`` field.
    """
    execution = "asic" if base_cfg.mode == "gstg" else "gpu"
    entries = []
    for cand in candidates:
        cfg = config_for(base_cfg, cand)
        s = stats_pass(scene, cam, cfg)
        est = estimate(
            s, hw,
            boundary_group=cfg.boundary_group,
            boundary_tile=cfg.boundary_tile,
            mode=cfg.mode,
            execution=execution,
        )
        overflow = int(s.overflow) + int(s.span_overflow)
        entries.append({
            **cand.as_dict(),
            "feasible": overflow == 0,
            "overflow": overflow,
            "est": est.as_dict(),
            "est_total_s": est.total_s,
            "n_visible": int(s.n_visible),
            "n_pairs_sort": float(s.n_pairs_sort),
            "tile_entries": float(s.tile_entries),
            "measured_ms": None,
        })
    return entries


def measure_phase(
    scene,
    cam,
    base_cfg: RenderConfig,
    candidates: Sequence[Candidate],
    mesh=None,
    warmup: int = 1,
    reps: int = 3,
) -> dict:
    """Median real walltime (ms) per candidate through the EXACT production
    path: a committed engine handle's jit'd ``render`` (warm-up renders
    excluded, so compile time never pollutes the median)."""
    from repro import engine

    out = {}
    for cand in candidates:
        cfg = config_for(base_cfg, cand)
        with engine.open(scene, cfg, mesh=mesh) as r:
            for _ in range(max(warmup, 1)):
                jax.block_until_ready(r.render(cam).image)
            times = []
            for _ in range(max(reps, 1)):
                t0 = time.perf_counter()
                jax.block_until_ready(r.render(cam).image)
                times.append((time.perf_counter() - t0) * 1e3)
            out[cand] = statistics.median(times)
    return out


def autotune(
    scene,
    cam,
    base_cfg: RenderConfig,
    *,
    mesh=None,
    tiles: Sequence[int] = DEFAULT_TILES,
    group_factors: Sequence[int] = DEFAULT_GROUP_FACTORS,
    capacities: Sequence[int] = DEFAULT_CAPACITIES,
    top_k: Optional[int] = 3,
    warmup: int = 1,
    reps: int = 3,
    hw: HardwareConfig = GSTG_ASIC,
    use_cache: bool = True,
    persist: bool = True,
    verify: bool = False,
) -> AutotuneResult:
    """The full two-phase search for one (scene, camera, config) commit.

    ``top_k=None`` measures EVERY feasible candidate (the benchmark sweep);
    otherwise only the k best by modeled cost are measured. With
    ``use_cache`` the signature is consulted first (memory, then the
    persisted file) and a fresh result is stored back (``persist`` controls
    the disk write). ``verify`` renders the winner and the base config once
    and asserts the losslessness guarantee (bitwise when the tile is
    unchanged, allclose across tiles — module docstring).
    """
    sig = autotune_signature(scene, cam.width, cam.height, base_cfg, mesh)
    if use_cache:
        hit = lookup(sig, scene=scene)
        if hit is not None:
            return AutotuneResult(
                tile=int(hit["tile"]),
                group=int(hit["group"]),
                tile_capacity=int(hit["tile_capacity"]),
                measured_ms=hit.get("measured_ms"),
                source=hit.get("source", "cache"),
                signature=sig,
                trajectory=[],
            )

    entries = cost_phase(
        scene, cam, base_cfg,
        candidate_grid(tiles, group_factors, capacities), hw,
    )
    feasible = [e for e in entries if e["feasible"]]
    if not feasible:
        raise ValueError(
            "no feasible autotune candidate (every swept point overflowed); "
            "raise the capacity axis or group_capacity"
        )
    ranked = sorted(feasible, key=lambda e: e["est_total_s"])
    survivors = ranked if top_k is None else ranked[: max(top_k, 1)]

    measured = measure_phase(
        scene, cam, base_cfg,
        [Candidate(e["tile"], e["group"], e["tile_capacity"])
         for e in survivors],
        mesh=mesh, warmup=warmup, reps=reps,
    )
    for e in survivors:
        e["measured_ms"] = measured[
            Candidate(e["tile"], e["group"], e["tile_capacity"])
        ]
    win = min(survivors, key=lambda e: e["measured_ms"])
    result = AutotuneResult(
        tile=win["tile"],
        group=win["group"],
        tile_capacity=win["tile_capacity"],
        measured_ms=win["measured_ms"],
        source="search",
        signature=sig,
        trajectory=entries,
    )
    if verify:
        _verify_lossless(scene, cam, base_cfg, result.candidate, mesh)
    if use_cache:
        store(
            sig,
            {
                "tile": result.tile,
                "group": result.group,
                "tile_capacity": result.tile_capacity,
                "measured_ms": result.measured_ms,
            },
            scene=scene,
            persist=persist,
        )
    return result


def sweep(scene, cam, base_cfg: RenderConfig, **kw) -> AutotuneResult:
    """Measure EVERY feasible grid point (the BENCH trajectory mode): the
    selected config's measured walltime is <= every other swept point by
    construction. Never consults or writes the cache — a benchmark must
    re-measure."""
    kw.setdefault("top_k", None)
    return autotune(scene, cam, base_cfg, use_cache=False, persist=False, **kw)


def _verify_lossless(scene, cam, base_cfg, cand: Candidate, mesh) -> None:
    """Assert the knobs' losslessness for this scene: winner vs base config
    through the same handle path — bitwise when the tile is unchanged
    (group/capacity reorder nothing), allclose (fp reassociation of
    zero-alpha interleaving, DESIGN.md §7) across tiles."""
    from repro import engine

    tuned = config_for(base_cfg, cand)
    with engine.open(scene, base_cfg, mesh=mesh) as rb, \
            engine.open(scene, tuned, mesh=mesh) as rt:
        a = np.asarray(rb.render(cam).image)
        b = np.asarray(rt.render(cam).image)
    if cand.tile == base_cfg.tile:
        if not (a == b).all():
            raise AssertionError(
                f"autotuned {cand} is not bitwise-identical to the base "
                f"config (tile unchanged — group/capacity must be lossless)"
            )
    elif not np.allclose(a, b, atol=1e-5, rtol=1e-5):
        raise AssertionError(
            f"autotuned {cand} diverges from the base config beyond fp "
            f"reassociation tolerance"
        )
