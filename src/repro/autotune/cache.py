"""Autotune result cache: tuned tile params per scene/resolution signature.

Two layers (DESIGN.md §13):

  * an in-process dict keyed by :func:`autotune_signature`, registered with
    the engine-wide render-cache registry (``core.pipeline
    .register_render_cache``) under ``"autotune"`` so ``render_cache_info()``
    / ``render_cache_clear()`` cover it and the serving cache-hit stats stay
    truthful;
  * a best-effort JSON file (``REPRO_AUTOTUNE_CACHE`` env override, default
    ``results/autotune_cache.json``) so a tuned config survives the process
    — a later ``engine.open(tile_params='auto')`` for the same signature
    reloads the winner instead of re-running the search.

The in-memory layer also tracks which SCENE OBJECT produced each entry so
``Renderer.close()`` can evict its handle's entries
(:func:`evict_autotune_entries` — the same lifecycle fix
``evict_scene_layouts`` applies to the scene-layout cache): a served scene
that is committed and closed repeatedly must not accrete per-scene state in
a process-wide dict. The disk layer is untouched by eviction — that is the
persistence the trajectory needs.

A signature deliberately hashes GEOMETRY, not parameter values: the tuned
trade-off depends on how many gaussians cover how many pixels, not on the
exact float contents, so a retrained checkpoint of the same scene reuses
the tune.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Dict, Optional

from repro.core.pipeline import RenderConfig, register_render_cache
from repro.obs import get_registry
from repro.sharding.scene import ShardedScene

_ENV_PATH = "REPRO_AUTOTUNE_CACHE"
_DEFAULT_PATH = os.path.join("results", "autotune_cache.json")
# In-memory bound (the registry contract wants bounded caches with int
# maxsize — engine/handle.py, serving/sharded.py). FIFO on overflow; the
# disk layer keeps everything, so an evicted signature reloads, never
# re-searches.
_CACHE_MAX = 64

_lock = threading.RLock()
_cache: Dict[tuple, dict] = {}
_by_scene: Dict[int, set] = {}
_stats = {"hits": 0, "misses": 0}
_disk_loaded = False


def cache_path() -> str:
    return os.environ.get(_ENV_PATH) or _DEFAULT_PATH


def autotune_signature(scene, width: int, height: int, cfg: RenderConfig,
                       mesh=None) -> tuple:
    """The cache key: (scene geometry, resolution, backend, mesh layout).

    Scene geometry is the gaussian count (+ shard layout for a pre-sharded
    scene); the config contributes every knob that changes which candidate
    wins EXCEPT the three swept ones (tile/group/tile_capacity — the result,
    not the key — plus group_capacity, which the search derives from them).
    """
    if isinstance(scene, ShardedScene):
        geom = ("sharded", scene.num_shards, scene.shard_size)
    else:
        geom = ("scene", int(scene.num_gaussians))
    mesh_shape = tuple(sorted(dict(mesh.shape).items())) if mesh is not None else ()
    return (
        geom,
        int(width), int(height),
        cfg.backend, cfg.mode,
        cfg.boundary_group, cfg.boundary_tile,
        cfg.span, cfg.chunk, cfg.early_exit,
        cfg.scene_shards, cfg.feature_gather,
        mesh_shape,
    )


# -- disk layer (best-effort) -------------------------------------------------


def _load_disk() -> None:
    """Merge the persisted file into memory once per process (or after a
    clear). Missing/corrupt files are treated as empty — persistence is
    best-effort, never load-bearing for correctness."""
    global _disk_loaded
    if _disk_loaded:
        return
    _disk_loaded = True
    try:
        with open(cache_path()) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return
    for key, entry in doc.get("entries", {}).items():
        try:
            sig = eval(key, {"__builtins__": {}})  # repr'd tuple of literals
        except Exception:
            continue
        if isinstance(sig, tuple) and isinstance(entry, dict):
            _cache.setdefault(sig, dict(entry, source="disk"))


def _save_disk() -> None:
    """Rewrite the persisted file from the in-memory entries (atomic
    tmp+rename; failures are swallowed — a read-only checkout still tunes,
    it just re-tunes next process)."""
    path = cache_path()
    try:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        entries = {
            repr(sig): {k: v for k, v in e.items() if k != "source"}
            for sig, e in _cache.items()
        }
        fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump({"schema": "repro.autotune_cache/v1", "entries": entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass


# -- in-memory layer ----------------------------------------------------------


def lookup(sig: tuple, scene=None) -> Optional[dict]:
    """The cached entry for ``sig`` (memory, then the persisted file), or
    None. Counts a hit/miss; a hit with ``scene`` given is re-attributed to
    that scene object for close()-time eviction."""
    with _lock:
        _load_disk()
        entry = _cache.get(sig)
        if entry is None:
            _stats["misses"] += 1
            get_registry().counter("autotune.cache_misses_total").inc()
            return None
        _stats["hits"] += 1
        get_registry().counter("autotune.cache_hits_total").inc()
        if scene is not None:
            _by_scene.setdefault(id(scene), set()).add(sig)
        return dict(entry)


def store(sig: tuple, entry: dict, scene=None, persist: bool = True) -> None:
    """Record a tuned result. ``entry`` must be JSON-serializable (the disk
    layer round-trips it); ``persist=False`` keeps it in-memory only."""
    with _lock:
        _load_disk()
        get_registry().counter("autotune.stores_total").inc()
        _cache[sig] = dict(entry)
        if scene is not None:
            _by_scene.setdefault(id(scene), set()).add(sig)
        if persist:
            _save_disk()
        while len(_cache) > _CACHE_MAX:   # FIFO; disk (above) keeps them all
            _cache.pop(next(iter(_cache)))


def evict_autotune_entries(scene) -> int:
    """Drop every IN-MEMORY entry attributed to ``scene`` (any signature).

    The ``Renderer.close()`` lifecycle hook, mirroring
    ``serving.sharded.evict_scene_layouts``: per-scene state must not
    outlive the handle that created it. The persisted file keeps the
    entries — a re-open reloads the tune from disk instead of re-searching.
    Returns the number of entries evicted."""
    global _disk_loaded
    with _lock:
        sigs = _by_scene.pop(id(scene), set())
        n = 0
        for sig in sigs:
            if _cache.pop(sig, None) is not None:
                n += 1
        if n:
            # The persisted file may still hold the evicted signatures; mark
            # it unmerged so the next lookup reloads instead of re-searching.
            _disk_loaded = False
        return n


def _info() -> dict:
    with _lock:
        return {
            "hits": _stats["hits"],
            "misses": _stats["misses"],
            "currsize": len(_cache),
            "maxsize": _CACHE_MAX,
        }


def _clear() -> None:
    global _disk_loaded
    with _lock:
        _cache.clear()
        _by_scene.clear()
        _stats["hits"] = 0
        _stats["misses"] = 0
        _disk_loaded = False   # next lookup reloads the persisted file


register_render_cache("autotune", info=_info, clear=_clear)
