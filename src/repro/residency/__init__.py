"""Paged scene residency under the device budget (DESIGN.md §17).

``ResidencyManager`` pages committed scene shards in and out of device
memory against ``device_budget_mb``: host-staged layouts are the backing
store, ``device_put`` on page-in, dropping the manager's device reference
on page-out. ``repro.engine.Renderer`` commits through an entry here; a
``RenderServer`` shares ONE manager across every handle so an over-budget
commit evicts cold scenes instead of failing fast.
"""
from repro.residency.manager import ResidencyEntry, ResidencyManager

__all__ = ["ResidencyEntry", "ResidencyManager"]
