"""The LRU residency manager: paged scene storage under a device budget.

Every committed scene layout in the engine stays device-resident forever
(PR 4/5 budget accounting is per-handle and admission-time only), so a host
can serve at most ``device_budget_mb``-worth of scenes. This module applies
the paged-KV-cache idiom (fixed per-signature workspaces + paged residency)
to gaussian scene shards (DESIGN.md §17):

  * ``register()`` files one :class:`ResidencyEntry` per committed
    ``(scene identity, shard layout, mesh)`` — the HOST-staged layout
    (numpy leaves) is kept as the paging backing store, so a page-out never
    loses the scene and a page-in is exactly the commit's own
    ``device_put``. Entries are refcounted: every handle over the same
    layout shares ONE entry (and therefore one device copy — the
    committed-scene sharing the serving tier relied on before).
  * ``acquire()`` returns the device-resident pytree, paging it in on a
    miss. Page-in evicts least-recently-ACQUIRED resident entries until the
    aggregate cost fits the budget; eviction drops the manager's device
    reference (the backing buffers free as soon as no in-flight dispatch
    holds them — in-flight renders keep their own transient reference, so
    paging can never corrupt a dispatch).
  * Paging is bitwise-invisible: the backing store holds the exact bits the
    original commit transferred, and ``device_put`` of the same bits under
    the same sharding reproduces the same committed scene — a
    paged-out-then-reloaded scene renders identically to one that never
    moved (tests/test_residency.py round-robins at 2x the budget).
  * Entry cost = the handle's static per-device model (scene params +
    per-camera projected features, DESIGN.md §12) PLUS dynamic cost
    callbacks — the stream sessions' frontend caches the budget model used
    to undercount register themselves here (``Renderer.frontend_cache_mb``).

Observability: ``residency.*`` counters and ``residency/page_in`` /
``residency/page_out`` spans are recorded together in the same critical
section, so ``scripts/validate_trace.py --residency`` can cross-check them
exactly (the ``spec.*`` precedent from DESIGN.md §15).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.obs import get_registry, get_tracer


def _host_backing(tree):
    """A host (numpy) copy of a staged scene pytree — the paging backing
    store. ``np.asarray`` passes host-staged numpy leaves through without a
    copy (the ``shard_scene_cached`` layouts) and pulls jax.Array leaves to
    host bit-exactly (a replicated scene built with jnp), so the backing
    never pins device memory and page-in reproduces the original bits."""
    return jax.tree.map(np.asarray, tree)


class ResidencyEntry:
    """One committed scene layout: host backing + (maybe) a device copy.

    All mutation happens under the owning manager's lock; handles hold the
    entry object itself and go through :meth:`ResidencyManager.acquire` for
    every device use.
    """

    __slots__ = (
        "key", "label", "backing", "sharding", "static_mb", "device",
        "refs", "seq", "cost_fns", "page_ins",
    )

    def __init__(self, key, backing, sharding, static_mb, label):
        self.key = key
        self.label = label
        self.backing = backing
        self.sharding = sharding
        self.static_mb = float(static_mb)
        self.device: Any = None          # the device pytree; None = paged out
        self.refs = 0
        self.seq = 0                     # LRU stamp (manager clock)
        self.page_ins = 0
        # Dynamic per-entry cost callbacks (MB): live device memory the
        # static model cannot see — today the handles' stream frontend
        # caches (the budget-undercount fix). Weakref-backed so an entry
        # never pins its handles.
        self.cost_fns: List[Callable[[], float]] = []

    @property
    def resident(self) -> bool:
        return self.device is not None

    def cost_mb(self) -> float:
        """Static model + dynamic callbacks, in per-device MB."""
        extra = 0.0
        for fn in list(self.cost_fns):
            try:
                extra += float(fn())
            except Exception:            # noqa: BLE001 — a closing stream
                pass                     # must not poison eviction decisions
        return self.static_mb + extra


class ResidencyManager:
    """LRU paging of committed scenes against a per-device MB budget.

    ``budget_mb=None`` never evicts (every entry stays resident once paged
    in) but still dedupes device copies per layout — the unbudgeted default
    behaves exactly like the pre-residency engine. Thread-safe: one lock
    serializes register/acquire/release/eviction (device transfers are
    serialized by the hardware anyway).
    """

    def __init__(self, budget_mb: Optional[float] = None,
                 name: str = "residency"):
        self.budget_mb = budget_mb
        self.name = name
        self._lock = threading.RLock()
        self._entries: Dict[Any, ResidencyEntry] = {}
        self._seq = 0
        self._counters = {
            "page_ins": 0, "page_outs": 0, "evictions": 0,
            "hits": 0, "prefetches": 0, "over_budget": 0,
        }

    # -- registration / lifecycle -------------------------------------------

    def register(
        self,
        key,
        staged,
        sharding,
        static_mb: float,
        label: Optional[str] = None,
    ) -> ResidencyEntry:
        """File (or ref-share) the entry for ``key``; does NOT page in.

        A second handle over the same layout gets the SAME entry (refs+1) —
        that is what keeps two configs over one scene at one scene copy.
        ``static_mb`` takes the max across registrants (configs may resolve
        different feature-gather divisors; the conservative cost wins).
        """
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                entry = ResidencyEntry(
                    key, _host_backing(staged), sharding, static_mb,
                    label or repr(key),
                )
                self._entries[key] = entry
            else:
                entry.static_mb = max(entry.static_mb, float(static_mb))
            entry.refs += 1
            return entry

    def release(self, entry: ResidencyEntry) -> None:
        """Drop one reference; the last release pages out and removes the
        entry entirely (device copy AND host backing)."""
        with self._lock:
            entry.refs -= 1
            if entry.refs > 0:
                return
            if entry.resident:
                self._page_out(entry, reason="release")
            self._entries.pop(entry.key, None)
            entry.cost_fns.clear()

    # -- the paging protocol -------------------------------------------------

    def acquire(self, entry: ResidencyEntry):
        """The device-resident scene for ``entry``, paging in on a miss.

        Every render path calls this — a resident acquire is an LRU touch
        plus a counter (no device work)."""
        with self._lock:
            self._seq += 1
            entry.seq = self._seq
            if entry.resident:
                self._counters["hits"] += 1
                get_registry().counter("residency.hits_total").inc()
                return entry.device
            return self._page_in(entry)

    def prefetch(self, entry: ResidencyEntry) -> bool:
        """Admission-time page-in: warm the scene before its dispatch
        arrives. True when a transfer actually happened (resident scenes
        are a cheap no-op that does NOT touch LRU order — a queued request
        must not shield a cold scene from eviction forever)."""
        with self._lock:
            if entry.resident:
                return False
            self._counters["prefetches"] += 1
            get_registry().counter("residency.prefetch_total").inc()
            self._seq += 1
            entry.seq = self._seq
            self._page_in(entry)
            return True

    def _page_in(self, entry: ResidencyEntry):
        """Lock held. Evict LRU-cold residents until ``entry`` fits, then
        transfer the backing store to the committed sharding."""
        registry = get_registry()
        tracer = get_tracer()
        if self.budget_mb is not None:
            need = entry.cost_mb()
            while self._resident_mb() + need > self.budget_mb:
                victim = min(
                    (e for e in self._entries.values()
                     if e.resident and e is not entry),
                    key=lambda e: e.seq,
                    default=None,
                )
                if victim is None:
                    # Nothing left to evict: the single active scene (plus
                    # its live stream caches) exceeds the budget on its
                    # own. Rendering must proceed — count the violation
                    # instead of deadlocking the dispatch.
                    self._counters["over_budget"] += 1
                    registry.counter("residency.over_budget_total").inc()
                    break
                self._evict(victim)
        t0 = tracer.clock()
        entry.device = jax.device_put(entry.backing, entry.sharding)
        t1 = tracer.clock()
        entry.page_ins += 1
        # Counter + span in ONE critical section: the validate_trace.py
        # residency cross-check (spans == counters) can never race.
        self._counters["page_ins"] += 1
        registry.counter("residency.page_ins_total").inc()
        tracer.complete(
            "residency/page_in", t0, t1, category="residency",
            args={"entry": entry.label, "mb": round(entry.static_mb, 4)},
        )
        self._publish_gauges()
        return entry.device

    def _evict(self, entry: ResidencyEntry) -> None:
        """Lock held. Budget eviction = a counted page-out."""
        self._counters["evictions"] += 1
        get_registry().counter("residency.evictions_total").inc()
        self._page_out(entry, reason="evict")

    def _page_out(self, entry: ResidencyEntry, reason: str) -> None:
        """Lock held. Drop the manager's device reference — the explicit
        buffer release: the manager holds the only persistent reference to
        the committed pytree, so the device buffers free as soon as any
        in-flight dispatch's transient reference resolves (immediately in
        the common idle case). The host backing store stays."""
        tracer = get_tracer()
        t0 = tracer.clock()
        entry.device = None
        t1 = tracer.clock()
        self._counters["page_outs"] += 1
        get_registry().counter("residency.page_outs_total").inc()
        tracer.complete(
            "residency/page_out", t0, t1, category="residency",
            args={"entry": entry.label, "reason": reason},
        )
        self._publish_gauges()

    # -- accounting / introspection ------------------------------------------

    def _resident_mb(self) -> float:
        return sum(
            e.cost_mb() for e in self._entries.values() if e.resident
        )

    def _publish_gauges(self) -> None:
        registry = get_registry()
        registry.gauge("residency.resident_mb").set(self._resident_mb())
        registry.gauge("residency.resident_entries").set(
            sum(1 for e in self._entries.values() if e.resident)
        )

    def resident_keys(self) -> list:
        with self._lock:
            return [e.key for e in self._entries.values() if e.resident]

    def stats(self) -> dict:
        with self._lock:
            resident = [e for e in self._entries.values() if e.resident]
            return {
                "budget_mb": self.budget_mb,
                "entries": len(self._entries),
                "resident_entries": len(resident),
                "resident_mb": self._resident_mb(),
                **dict(self._counters),
            }

    def __repr__(self) -> str:
        s = self.stats()
        return (
            f"<ResidencyManager {self.name} budget={self.budget_mb} "
            f"resident={s['resident_entries']}/{s['entries']} "
            f"page_ins={s['page_ins']} page_outs={s['page_outs']}>"
        )
