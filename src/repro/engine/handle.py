"""Session-style rendering engine: commit a scene ONCE, render through a handle.

``open(scene, cfg)`` resolves everything the five legacy free entry points
(`render`, `render_jit`, `render_batch`, `render_batch_sharded`,
``RenderServer``) each re-derived per call — scene placement (replicated vs
the canonical :class:`~repro.sharding.scene.ShardedScene` layout), the 1-D or
2-D render mesh, and the jit-cache keys — and commits them into a
:class:`Renderer` handle (DESIGN.md §11):

  * the scene is staged on the HOST (``acquire_scene_layout`` when gaussian-
    sharded, so the full padded scene never allocates on one device) and
    ``device_put`` exactly once through a residency entry; every subsequent
    call reuses the device copy — unless a budgeted shared manager paged it
    out, in which case the next use pages it back in bitwise-identically
    from the host backing store (DESIGN.md §17);
  * the handle owns a per-handle jit cache, registered with the engine-wide
    ``register_render_cache`` registry so ``render_cache_info()`` /
    ``render_cache_clear()`` and the serving cache-hit stats keep covering it;
  * ``.render(cam)`` / ``.render_batch(cams, pad_to=...)`` are the synchronous
    entry points — bitwise-identical to the legacy ``render_jit`` /
    ``render_batch`` / ``render_batch_sharded`` paths (tests/
    test_engine_handle.py);
  * ``.submit(cam)`` returns a ``concurrent.futures.Future`` served by an
    internal queue -> bucketing-scheduler worker thread (the ROADMAP's
    "threaded front-end": batching becomes an implementation detail of the
    handle, and an asyncio caller just wraps the future);
  * ``.close()`` (or the context manager) drains the worker, unregisters and
    drops the jit cache, and releases the handle's refcounted residency
    entry and scene-layout reference — shared state (the host layout, the
    committed device copy) frees when the LAST handle over it closes, never
    under another open handle's feet.

The handle is intentionally a COMMIT of (scene, config): per-request knobs
that change the compiled program (mode, backend, capacities, scene_shards,
feature_gather) belong to a different handle — that is what makes the
jit-cache key within a handle collapse to the camera geometry alone. The
feature-sharded gathers (DESIGN.md §12) land exactly here as promised: the
commit resolves ``feature_gather='auto'`` to the owner-masked psum
collective when the mesh realizes a physical 'model' axis, and the budget
model counts the per-camera projected features at N/D accordingly;
multi-host serving remains the next commit-time decision to land.
"""
from __future__ import annotations

import dataclasses
import itertools
import sys
import threading
import time
import weakref
from concurrent.futures import Future
from concurrent.futures import wait as _futures_wait
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.pipeline import (
    CameraBatch,
    FrontendResult,
    RenderConfig,
    RenderResult,
    _background_array,
    _backend_with_static_geometry,
    _frontend_with_traced_camera,
    _render_with_traced_camera,
    register_render_cache,
    resolve_feature_gather,
    unregister_render_cache,
)
from repro.core.projection import projected_bytes_per_gaussian
from repro.launch.mesh import make_render_mesh, render_mesh_shards
from repro.obs import emit_request_spans, get_registry, get_tracer
from repro.serving.bucketing import BucketingScheduler, padded_size
from repro.serving.queue import QueueClosed, RequestQueue
from repro.residency import ResidencyManager
from repro.serving.sharded import (
    acquire_scene_layout,
    pad_camera_batch,
    release_scene_layout,
)
from repro.sharding.policies import (
    camera_batch_pspec,
    data_extent,
    render_replicated_pspec,
    scene_shard_pspec,
)
from repro.sharding.scene import ShardedScene
from repro.utils import pytree_bytes

_HANDLE_SEQ = itertools.count()
_FN_CACHE_MAX = 64          # per-handle compiled-renderer bound (mirrors the
                            # legacy global lru maxsize)


@dataclasses.dataclass(frozen=True)
class _Submitted:
    """One queued ``submit()`` request: the camera plus its future.

    Shaped for the serving primitives: the ``RequestQueue`` stamps
    ``enqueue_time`` via ``dataclasses.replace`` and the
    ``BucketingScheduler`` groups by ``signature()`` — within one handle the
    config and scene are fixed, so the signature collapses to the camera
    geometry (one bucket per resolution).
    """

    camera: Any
    future: Future
    enqueue_time: Optional[float] = None
    request_id: str = ""
    # Lifecycle stamps (DESIGN.md §14): the dict OBJECT rides through the
    # queue's dataclasses.replace copies, so every phase writes into one
    # shared map; compare=False keeps it out of the generated eq.
    stamps: Dict[str, float] = dataclasses.field(
        default_factory=dict, compare=False, repr=False
    )

    def signature(self) -> tuple:
        c = self.camera
        return (c.width, c.height, c.znear, c.zfar)


def _timed_batch(one):
    """Batch renderer for timed-stage mode: loop lanes eagerly and stack.

    The vmapped jit batch and the per-lane jit are bitwise-identical
    (tests/test_engine_handle.py relies on the same property), so looping
    keeps pixels exact while letting TimedBackend fence every stage — a
    vmapped timed render would see only tracers.
    """

    def fn(scene, R, t, fx, fy, cx, cy, background):
        outs = [
            one(scene, R[i], t[i], fx[i], fy[i], cx[i], cy[i], background)
            for i in range(int(R.shape[0]))
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    return fn


class Renderer:
    """A committed (scene, config) pair with render/serve entry points.

    Construct through :func:`open`. Not thread-safe for concurrent
    ``render``/``render_batch`` calls from multiple threads (device dispatch
    is serialized anyway); ``submit`` is the thread-safe entry — the bounded
    queue is the boundary, and the internal worker owns all device work for
    the futures path.
    """

    def __init__(
        self,
        scene: Union[GaussianScene, ShardedScene],
        cfg: RenderConfig,
        *,
        devices: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        scene_shards: Union[str, int] = "auto",
        device_budget_mb: Optional[float] = None,
        max_batch: int = 8,
        max_wait: float = 0.05,
        queue_depth: int = 64,
        tile_params: Union[None, str, tuple] = None,
        autotune_opts: Optional[dict] = None,
        residency: Optional[ResidencyManager] = None,
        clock=time.monotonic,
    ):
        if devices is not None and mesh is not None:
            raise ValueError("pass devices or mesh, not both")
        # Tile-grouping params (DESIGN.md §13): an explicit (tile, group,
        # tile_capacity) triple commits immediately; 'auto' defers to the
        # autotune cache/search at FIRST render — the search needs a camera
        # resolution, which the handle only learns then. The committed cfg
        # is frozen from that point on; images are bitwise-identical to a
        # fixed-config open of the same params (same compiled program).
        self._autotune_opts = dict(autotune_opts or {})
        self._tune_pending = False
        self._tune_lock = threading.Lock()
        if tile_params == "auto":
            self._tune_pending = True
        elif tile_params is not None:
            try:
                t, g, c = (int(x) for x in tile_params)
            except (TypeError, ValueError):
                raise ValueError(
                    f"tile_params must be None, 'auto', or a (tile, group, "
                    f"tile_capacity) triple; got {tile_params!r}"
                ) from None
            cfg = dataclasses.replace(
                cfg, tile=t, group=g, tile_capacity=c,
                group_capacity=max(cfg.group_capacity, c),
            )
        shards = self._resolve_shards(scene, cfg, scene_shards)
        self._source = scene if isinstance(scene, GaussianScene) else None

        # The PHYSICAL shard count: what actually divides per-device bytes.
        # On an explicit mesh it is the mesh's 'model' extent (a mesh without
        # one leaves the shard axis logical — every device still holds the
        # whole scene); otherwise the render_mesh_shards policy over the
        # devices we are about to build the mesh from.
        if mesh is not None:
            n_dev = mesh.size
            phys = (
                shards
                if shards > 1 and dict(mesh.shape).get("model", 1) == shards
                else 1
            )
        else:
            n_dev = devices if devices is not None else len(jax.devices())
            phys = render_mesh_shards(n_dev, shards)
        # The effective per-device cap: an explicit device_budget_mb wins;
        # a shared residency manager's budget otherwise. The static check
        # below remains PER SCENE — a scene that cannot fit alone (even
        # after shard escalation) must still fail fast; the AGGREGATE
        # overflow across scenes is what the residency manager pages
        # against (DESIGN.md §17).
        budget_mb = device_budget_mb
        if budget_mb is None and residency is not None:
            budget_mb = residency.budget_mb
        if budget_mb is not None:
            # Per-device budget model (DESIGN.md §12): persistent scene
            # parameters at 1/phys PLUS the transient per-camera projected
            # features — N/phys ONLY under the resolved 'psum' strategy
            # over a physical 'model' axis (_feature_div: an explicit
            # 'index' gather may be all-gathered by GSPMD, so it counts
            # full N, as do replicated/logical-only/'flat' commits).
            scene_mb = pytree_bytes(scene) / 2**20
            # model(s, p) = per-device MB at shard count s realized p ways:
            # parameters at 1/p + per-camera features at N_pad(s)/fdiv.
            model = lambda s, p: (
                scene_mb / p
                + self._feature_mb(scene, s) / self._feature_div(cfg, s, p)
            )
            # Budget escalation only applies when the caller left BOTH the
            # layout and the mesh to us ('auto' shards, no explicit mesh —
            # an explicit mesh cannot grow a 'model' axis): pick the
            # smallest shard count the device count can realize that fits
            # the per-device cap (candidate counts are evaluated as a
            # PHYSICAL d-way commit: d divides both terms).
            if (
                scene_shards == "auto"
                and mesh is None
                and self._source is not None
                and model(shards, phys) > budget_mb
            ):
                for d in range(max(shards, 1), n_dev + 1):
                    if n_dev % d == 0 and model(d, d) <= budget_mb:
                        shards, phys = d, d
                        break
            if model(shards, phys) > budget_mb:
                layout = f"{phys}-way sharded" if phys > 1 else "replicated"
                fdiv = self._feature_div(cfg, shards, phys)
                raise ValueError(
                    f"scene needs {model(shards, phys):.2f} MB/device "
                    f"{layout} ({scene_mb / phys:.2f} MB parameters + "
                    f"{self._feature_mb(scene, shards) / fdiv:.2f} MB "
                    f"per-camera projected features at N/{fdiv}), over the "
                    f"{budget_mb} MB budget — raise scene_shards or "
                    f"the device count"
                )

        cfg_updates = {}
        if cfg.scene_shards != shards:
            cfg_updates["scene_shards"] = shards
        # The gather strategy is a commit-time decision (DESIGN.md §12):
        # 'auto' resolves to the owner-masked collective form when the
        # scene is PHYSICALLY sharded over a mesh 'model' axis — the form
        # whose per-device feature footprint is N/D — and to the plain
        # (shard, local) indexed gather otherwise. An explicit strategy in
        # cfg is respected (benchmarks A/B the legacy 'flat' concat).
        if shards > 1 and cfg.feature_gather == "auto":
            cfg_updates["feature_gather"] = "psum" if phys > 1 else "index"
        self._cfg = (
            dataclasses.replace(cfg, **cfg_updates) if cfg_updates else cfg
        )
        if mesh is None:
            mesh = make_render_mesh(devices, scene_shards=phys)
        model_extent = dict(mesh.shape).get("model", 1)
        if shards > 1 and model_extent not in (1, shards):
            raise ValueError(
                f"mesh model axis ({model_extent}) must match scene_shards="
                f"{shards} (or be absent for a logical-only shard axis)"
            )
        self._mesh = mesh

        # Stream-session registry BEFORE the commit: the residency entry's
        # dynamic-cost callback (frontend_cache_mb) may run during the
        # eager page-in below.
        self._worker_lock = threading.Lock()
        self._streams: List[Any] = []

        # Commit: host-staged layout when sharded (refcounted — the
        # layout survives until the LAST handle over it closes), then
        # registration with the residency manager (DESIGN.md §17). The
        # eager acquire below IS the one device_put the commit promises;
        # under a budgeted shared manager the scene may later page out and
        # back in bitwise-identically through the host backing store.
        staged = scene
        self._layout_ref = None
        if shards > 1 and isinstance(scene, GaussianScene):
            staged = acquire_scene_layout(scene, shards)
            self._layout_ref = (scene, shards)
        spec = (
            scene_shard_pspec(mesh)
            if isinstance(staged, ShardedScene)
            else render_replicated_pspec()
        )
        self._scene_mb_per_device = pytree_bytes(scene) / phys / 2**20
        self._feature_mb_per_device = self._feature_mb(scene, shards) / (
            self._feature_div(cfg, shards, phys)
        )
        # What the commit actually RUNS ('flat' for a replicated frontend,
        # even though cfg.feature_gather may still read 'auto').
        self._feature_gather = self._resolved_gather(cfg, shards, phys)
        self._phys_shards = phys
        self._residency = (
            residency if residency is not None
            else ResidencyManager(budget_mb=device_budget_mb)
        )
        self._res_entry = self._residency.register(
            (id(scene), shards, mesh),
            staged,
            NamedSharding(mesh, spec),
            self._scene_mb_per_device + self._feature_mb_per_device,
            label=f"scene@{id(scene):#x}/D{shards}",
        )
        # Dynamic cost: the stream sessions' frontend caches (the budget-
        # undercount fix) — weakref'd so the shared entry never pins the
        # handle. release() on the LAST close drops the entry and with it
        # every registered callback.
        self_ref = weakref.ref(self)

        def _dyn_cost(ref=self_ref):
            h = ref()
            return h.frontend_cache_mb() if h is not None else 0.0

        self._dyn_cost = _dyn_cost
        self._res_entry.cost_fns.append(_dyn_cost)
        # A handle dropped WITHOUT close() must still release its residency
        # reference, or the shared manager would pin the entry forever.
        self._res_finalizer = weakref.finalize(
            self, self._residency.release, self._res_entry
        )
        self._residency.acquire(self._res_entry)

        # Per-handle jit cache, visible through the engine-wide registry.
        # Registered through a weakref so the registry never pins the handle:
        # a Renderer dropped WITHOUT close() still gets collected (freeing
        # its executables and committed device scene), and the finalizer
        # removes the registry entry close() would have removed.
        self._fns: Dict[tuple, Any] = {}
        self._fn_stats = {"hits": 0, "misses": 0}
        self.cache_name = f"engine{next(_HANDLE_SEQ)}"
        self_ref = weakref.ref(self)

        def _info(ref=self_ref):
            h = ref()
            return h.cache_info() if h is not None else {
                "hits": 0, "misses": 0, "currsize": 0,
                "maxsize": _FN_CACHE_MAX,
            }

        def _clear(ref=self_ref):
            h = ref()
            if h is not None:
                h._cache_clear()

        register_render_cache(self.cache_name, info=_info, clear=_clear)
        weakref.finalize(self, unregister_render_cache, self.cache_name)

        # Futures front-end (worker started lazily on first submit()).
        self._clock = clock
        self._max_batch = max_batch
        self._queue = RequestQueue(queue_depth, clock=clock)
        self._scheduler = BucketingScheduler(max_batch, max_wait, clock=clock)
        self._worker: Optional[threading.Thread] = None
        self._flush_event = threading.Event()
        self._outstanding: List[Future] = []
        self._counters = {
            "submitted": 0, "completed": 0, "batches": 0, "padded_lanes": 0,
        }
        self._closed = False

    # -- committed-state introspection --------------------------------------

    @property
    def cfg(self) -> RenderConfig:
        return self._cfg

    @property
    def mesh(self) -> Mesh:
        return self._mesh

    @property
    def scene_shards(self) -> int:
        return self._cfg.scene_shards

    @property
    def _scene(self):
        """The device-resident committed scene, acquired through the
        residency manager on every use: a no-op LRU touch while resident,
        a bitwise-identical ``device_put`` of the host backing store after
        a page-out (DESIGN.md §17)."""
        entry = self._res_entry
        if entry is None:
            return None
        return self._residency.acquire(entry)

    @property
    def committed_scene(self):
        """The device-resident committed scene (paged in if needed).

        Handles opened through ONE residency manager on the same
        (scene, layout, mesh) share a single entry — and therefore one
        device copy (e.g. one per config in a server adds no scene HBM,
        serving/server.py::commit)."""
        self._check_open()
        return self._scene

    @property
    def resident(self) -> bool:
        """Whether the committed scene is device-resident RIGHT NOW (it may
        be paged out under a budgeted shared manager; any render pages it
        back in transparently)."""
        entry = self._res_entry
        return entry is not None and entry.resident

    def prefetch(self) -> bool:
        """Page the committed scene in ahead of a render — the serving
        tier's admission-time prefetch hook. True when a transfer actually
        happened; a resident scene is a no-op."""
        self._check_open()
        return self._residency.prefetch(self._res_entry)

    def frontend_cache_mb(self) -> float:
        """Device MB held by this handle's stream sessions' frontend caches
        (up to ``cache_frames`` FrontendResult pytrees per stream) — memory
        the static budget model cannot see; charged against the residency
        budget as the entry's dynamic cost."""
        with self._worker_lock:
            streams = list(self._streams)
        return sum(
            s.cache_bytes() for s in streams if not s.closed
        ) / 2**20

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def tile_params(self) -> Union[str, tuple]:
        """The committed (tile, group, tile_capacity) — or 'auto (pending)'
        while an 'auto' open is still waiting for its first camera."""
        if self._tune_pending:
            return "auto (pending)"
        return (self._cfg.tile, self._cfg.group, self._cfg.tile_capacity)

    def stats(self) -> dict:
        """Committed layout + per-handle cache and futures counters. Also
        publishes the committed-layout numbers as per-handle gauges in the
        metrics registry (DESIGN.md §14; dropped again by close())."""
        registry = get_registry()
        prefix = f"engine.{self.cache_name}."
        registry.gauge(prefix + "scene_mb_per_device").set(
            self._scene_mb_per_device)
        registry.gauge(prefix + "feature_mb_per_device").set(
            self._feature_mb_per_device)
        registry.gauge(prefix + "physical_shards").set(self._phys_shards)
        frontend_cache_mb = self.frontend_cache_mb()
        registry.gauge(prefix + "frontend_cache_mb").set(frontend_cache_mb)
        for k, v in self._counters.items():
            registry.gauge(prefix + k).set(v)
        return {
            "config": self._cfg,
            "tile_params": self.tile_params,
            "mesh": dict(self._mesh.shape),
            "scene_shards": self._cfg.scene_shards,
            "physical_shards": self._phys_shards,
            "scene_mb_per_device": self._scene_mb_per_device,
            "feature_mb_per_device": self._feature_mb_per_device,
            # The budget-undercount fix (DESIGN.md §17): live stream
            # frontend-cache memory, charged against the residency budget.
            "frontend_cache_mb": frontend_cache_mb,
            "resident": self.resident,
            "feature_gather": self._feature_gather,
            "cache": self.cache_info(),
            **self._counters,
        }

    def cache_info(self) -> dict:
        return {
            "hits": self._fn_stats["hits"],
            "misses": self._fn_stats["misses"],
            "currsize": len(self._fns),
            "maxsize": _FN_CACHE_MAX,
        }

    def _cache_clear(self) -> None:
        self._fns.clear()
        self._fn_stats["hits"] = 0
        self._fn_stats["misses"] = 0

    # -- budget model (DESIGN.md §12) ----------------------------------------

    @staticmethod
    def _feature_mb(scene, shards: int) -> float:
        """Per-camera projected-feature MB at the PADDED gaussian count
        (padding rows project too; they are culled, not skipped)."""
        if isinstance(scene, ShardedScene):
            n_pad = scene.padded_size
        else:
            n = scene.num_gaussians
            n_pad = -(-n // max(shards, 1)) * max(shards, 1)
        return n_pad * projected_bytes_per_gaussian() / 2**20

    @staticmethod
    def _resolved_gather(cfg: RenderConfig, shards: int, phys: int) -> str:
        """The gather strategy this commit would run (mirrors the 'auto'
        resolution applied to the committed cfg)."""
        if shards <= 1:
            return "flat"       # replicated frontend: features are flat-N
        if cfg.feature_gather == "auto":
            return "psum" if phys > 1 else "index"
        return resolve_feature_gather(cfg)

    @classmethod
    def _feature_div(cls, cfg: RenderConfig, shards: int, phys: int) -> int:
        """What divides the per-camera feature bytes per device: phys only
        when the owner-gather collective keeps them sharded over a PHYSICAL
        'model' axis; 1 for replicated scenes, logical-only shard axes, the
        plain indexed gather (GSPMD may gather the operand), and the legacy
        'flat' concat."""
        if phys > 1 and cls._resolved_gather(cfg, shards, phys) == "psum":
            return phys
        return 1

    # -- shard resolution ----------------------------------------------------

    @staticmethod
    def _resolve_shards(scene, cfg, scene_shards) -> int:
        requested = (
            cfg.scene_shards if scene_shards == "auto" else int(scene_shards)
        )
        if requested < 1:
            raise ValueError(f"scene_shards must be >= 1, got {requested}")
        if isinstance(scene, ShardedScene):
            if scene_shards != "auto" and requested != scene.num_shards:
                raise ValueError(
                    f"scene is pre-sharded {scene.num_shards} ways but "
                    f"scene_shards={requested} was requested"
                )
            return scene.num_shards
        return requested

    # -- per-handle jit cache ------------------------------------------------

    def _fn(self, kind: str, cam):
        """The compiled renderer for ``kind`` x this camera's geometry.

        The handle's config is committed, so the cache key is the geometry
        alone; the jit wrappers are per-handle (close() really releases the
        executables) and are built from the same traced-camera closure the
        legacy entry points jit — which is what makes the outputs bitwise
        match them.
        """
        key = (kind, cam.width, cam.height, cam.znear, cam.zfar)
        fn = self._fns.get(key)
        if fn is not None:
            self._fn_stats["hits"] += 1
            return fn
        self._fn_stats["misses"] += 1
        geom = (cam.width, cam.height, cam.znear, cam.zfar)
        if kind in ("frontend", "backend"):
            # The split programs (DESIGN.md §15): the frontend consumes the
            # traced pose, the backend consumes a FrontendResult pytree +
            # background — together bitwise-identical to the fused 'single'
            # program (tests/test_stream.py).
            one = (
                _frontend_with_traced_camera(self._cfg, *geom)
                if kind == "frontend"
                else _backend_with_static_geometry(self._cfg, *geom)
            )
            # Timed-stage mode runs the closure eagerly, same rationale as
            # below: only concrete inputs let TimedBackend fence stages.
            fn = one if self._cfg.timing else jax.jit(one)
        else:
            one = _render_with_traced_camera(self._cfg, *geom)
            if self._cfg.timing:
                # Timed-stage mode (DESIGN.md §14): the closure runs EAGERLY
                # so core.pipeline installs TimedBackend and fences each
                # stage's own jit'd program; under the usual outer jit every
                # input is a tracer and no stage could be timed. Bitwise-
                # identical pixels either way (tests/test_obs.py).
                fn = one if kind == "single" else _timed_batch(one)
            else:
                fn = (
                    jax.jit(one)
                    if kind == "single"
                    else jax.jit(
                        jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0, None))
                    )
                )
        while len(self._fns) >= _FN_CACHE_MAX:
            self._fns.pop(next(iter(self._fns)))
        self._fns[key] = fn
        return fn

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("Renderer is closed")

    # -- deferred tile-param autotune (DESIGN.md §13) -------------------------

    def _resolve_tile_params(self, cam) -> None:
        """Resolve a pending ``tile_params='auto'`` against this camera's
        resolution: consult the autotune cache (memory, then disk) and run
        the two-phase search on a miss, then commit the winner into the
        handle's config. Runs at most once per handle — before the first
        compiled renderer exists, so every subsequent geometry reuses the
        tuned knobs. Thread-safe (the submit() worker may race a direct
        render call here)."""
        if not self._tune_pending:
            return
        with self._tune_lock:
            if not self._tune_pending:
                return
            from repro.autotune import autotune as _autotune

            scene = self._source if self._source is not None else self._scene
            res = _autotune(
                scene, cam, self._cfg, mesh=self._mesh, **self._autotune_opts
            )
            self._cfg = dataclasses.replace(
                self._cfg,
                tile=res.tile,
                group=res.group,
                tile_capacity=res.tile_capacity,
                group_capacity=max(self._cfg.group_capacity,
                                   res.tile_capacity),
            )
            self._tune_pending = False

    # -- synchronous entry points -------------------------------------------

    def render(
        self, cam: Camera, background: Optional[jnp.ndarray] = None
    ) -> RenderResult:
        """Render one camera against the committed scene (jit-cached)."""
        self._check_open()
        self._resolve_tile_params(cam)
        fn = self._fn("single", cam)
        return fn(
            self._scene,
            jnp.asarray(cam.R), jnp.asarray(cam.t),
            jnp.float32(cam.fx), jnp.float32(cam.fy),
            jnp.float32(cam.cx), jnp.float32(cam.cy),
            _background_array(background),
        )

    def render_frontend(self, cam: Camera) -> FrontendResult:
        """Run ONLY the frontend half (project -> identify -> bin -> merge)
        for one camera — the separately compiled program the stream sessions
        cache and speculate over (DESIGN.md §15). Feed the result to
        :meth:`render_backend` for pixels."""
        self._check_open()
        self._resolve_tile_params(cam)
        fn = self._fn("frontend", cam)
        return fn(
            self._scene,
            jnp.asarray(cam.R), jnp.asarray(cam.t),
            jnp.float32(cam.fx), jnp.float32(cam.fy),
            jnp.float32(cam.cx), jnp.float32(cam.cy),
        )

    def render_backend(
        self,
        front: FrontendResult,
        cam: Camera,
        background: Optional[jnp.ndarray] = None,
    ) -> RenderResult:
        """Run ONLY the backend half (bitmask -> compact -> rasterize) on a
        :class:`FrontendResult`. ``render_backend(render_frontend(cam), cam)``
        is bitwise-identical to ``render(cam)`` — only the static geometry
        of ``cam`` is read (it must match the frontend camera's)."""
        self._check_open()
        self._resolve_tile_params(cam)
        fn = self._fn("backend", cam)
        return fn(front, _background_array(background))

    def open_stream(
        self,
        *,
        cache_frames: int = 32,
        spec_depth: int = 2,
        speculate: bool = True,
    ):
        """Open a :class:`~repro.engine.stream.StreamRenderer` session over
        this handle (DESIGN.md §15): a bounded exact-reuse frontend cache
        (``cache_frames`` poses, LRU) plus a background speculation worker
        (``spec_depth`` pending predictions, drop-oldest; ``speculate=False``
        keeps reuse-only behavior). The stream registers its cache in the
        render-cache registry and is closed by :meth:`close`."""
        self._check_open()
        from repro.engine.stream import StreamRenderer

        stream = StreamRenderer(
            self, cache_frames=cache_frames, spec_depth=spec_depth,
            speculate=speculate,
        )
        with self._worker_lock:
            self._streams.append(stream)
        return stream

    def _forget_stream(self, stream) -> None:
        with self._worker_lock:
            if stream in self._streams:
                self._streams.remove(stream)

    def render_batch(
        self,
        cams: Union[CameraBatch, Sequence[Camera]],
        pad_to: Optional[int] = None,
        background: Optional[jnp.ndarray] = None,
    ) -> RenderResult:
        """Render B cameras in ONE jit call over the handle's mesh.

        The batch is padded to ``max(B, pad_to)`` rounded up to the mesh's
        DATA extent (serving loops pass their max batch so every dispatch of
        a geometry compiles one shape); exactly B images/stats come back.
        """
        self._check_open()
        batch = (
            cams if isinstance(cams, CameraBatch)
            else CameraBatch.from_cameras(cams)
        )
        if self._tune_pending:
            # The search probes through lane 0 — any lane would do, the
            # signature only reads the shared geometry.
            self._resolve_tile_params(Camera(
                R=np.asarray(batch.R[0]), t=np.asarray(batch.t[0]),
                fx=float(batch.fx[0]), fy=float(batch.fy[0]),
                cx=float(batch.cx[0]), cy=float(batch.cy[0]),
                width=batch.width, height=batch.height,
                znear=batch.znear, zfar=batch.zfar,
            ))
        orig = len(batch)
        lanes = data_extent(self._mesh)
        padded = pad_camera_batch(
            batch, padded_size(max(orig, pad_to or 0), lanes)
        )
        shard = NamedSharding(self._mesh, camera_batch_pspec(self._mesh))
        repl = NamedSharding(self._mesh, render_replicated_pspec())
        if self._cfg.timing:
            # Timed-stage mode loops lanes eagerly (_timed_batch); keep the
            # camera arrays uncommitted so the per-lane indexing stays a
            # local host slice instead of a cross-device gather.
            put_b = put_bg = lambda a: a
        else:
            put_b = lambda a: jax.device_put(a, shard)
            put_bg = lambda a: jax.device_put(a, repl)
        fn = self._fn("batch", padded)
        out = fn(
            self._scene,
            put_b(padded.R), put_b(padded.t),
            put_b(padded.fx), put_b(padded.fy),
            put_b(padded.cx), put_b(padded.cy),
            put_bg(_background_array(background)),
        )
        if len(padded) != orig:
            out = jax.tree.map(lambda x: x[:orig], out)
        return out

    # -- futures front-end ---------------------------------------------------

    def submit(self, cam: Camera) -> Future:
        """Enqueue one camera; returns a Future of its ``RenderResult``.

        The result's leaves are HOST numpy arrays (the worker thread blocks
        on device completion before resolving futures). Requests batch with
        other submits of the same geometry up to the handle's
        ``max_batch``/``max_wait``; a full queue blocks the producer
        (bounded-queue backpressure). Thread-safe.
        """
        self._check_open()
        fut: Future = Future()
        self._ensure_worker()
        # Track BEFORE enqueueing: the worker may dispatch (and untrack) the
        # request the instant it lands in the queue.
        with self._worker_lock:
            self._counters["submitted"] += 1
            seq = self._counters["submitted"]
            self._outstanding.append(fut)
        get_registry().counter("engine.submitted_total").inc()
        try:
            self._queue.put(_Submitted(
                camera=cam, future=fut,
                request_id=f"{self.cache_name}#{seq}",
            ))
        except QueueClosed:
            with self._worker_lock:
                self._counters["submitted"] -= 1
                self._outstanding.remove(fut)
            raise RuntimeError("Renderer is closed") from None
        return fut

    def flush(self, timeout: Optional[float] = None) -> None:
        """Force-dispatch pending buckets and wait for outstanding futures."""
        self._flush_event.set()
        with self._worker_lock:
            futs = list(self._outstanding)
        _, not_done = _futures_wait(futs, timeout=timeout)
        if not_done:
            raise TimeoutError(f"flush timed out with {len(not_done)} pending")

    def _ensure_worker(self) -> None:
        with self._worker_lock:
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(
                    target=self._worker_loop,
                    name=f"{self.cache_name}-worker",
                    daemon=True,
                )
                self._worker.start()

    def _worker_loop(self) -> None:
        q, sched = self._queue, self._scheduler
        poll_s = max(min(sched.max_wait, 0.01), 0.001)
        try:
            while True:
                for req in q.get_batch(timeout=poll_s):
                    for bucket in sched.add(req):
                        self._dispatch_bucket(bucket)
                if self._flush_event.is_set():
                    self._flush_event.clear()
                    for req in q.drain():
                        for bucket in sched.add(req):
                            self._dispatch_bucket(bucket)
                    for bucket in sched.flush_all():
                        self._dispatch_bucket(bucket)
                for bucket in sched.poll():
                    self._dispatch_bucket(bucket)
                if q.closed and len(q) == 0:
                    for bucket in sched.flush_all():
                        self._dispatch_bucket(bucket)
                    return
        except BaseException as exc:      # noqa: BLE001 — futures must terminate
            # A crash OUTSIDE _dispatch_bucket's own handler (scheduler bug,
            # queue misuse) would otherwise strand every outstanding future
            # unresolved forever — and the gateway's failover accounting
            # depends on futures always terminating.
            self._fail_outstanding(exc)
            raise

    def _fail_outstanding(self, exc: BaseException) -> None:
        """Terminate every tracked future with ``exc`` (queue + scheduler
        pending are all in ``_outstanding``: submit tracks before enqueue)."""
        with self._worker_lock:
            futs, self._outstanding[:] = list(self._outstanding), []
        self._queue.drain()
        self._scheduler.flush_all()
        for fut in futs:
            if fut.set_running_or_notify_cancel():
                fut.set_exception(exc)

    def _dispatch_bucket(self, bucket) -> None:
        reqs = bucket.requests
        tracer = get_tracer()
        t0 = self._clock()
        try:
            out = self.render_batch(
                [r.camera for r in reqs], pad_to=self._max_batch
            )
            host = jax.tree.map(np.asarray, out)   # blocks on device work
            results = [
                jax.tree.map(lambda x, i=i: x[i], host)
                for i in range(len(reqs))
            ]
        except Exception as exc:                   # noqa: BLE001 — futures own it
            with self._worker_lock:
                for r in reqs:
                    self._outstanding.remove(r.future)
            for r in reqs:
                # A future cancelled between submit and dispatch must not
                # kill the worker (set_* on a cancelled Future raises).
                if r.future.set_running_or_notify_cancel():
                    r.future.set_exception(exc)
            return
        t1 = self._clock()
        lanes = data_extent(self._mesh)
        padded = padded_size(max(len(reqs), self._max_batch), lanes)
        with self._worker_lock:
            self._counters["batches"] += 1
            self._counters["completed"] += len(reqs)
            self._counters["padded_lanes"] += padded - len(reqs)
            for r in reqs:
                self._outstanding.remove(r.future)
        registry = get_registry()
        registry.counter("engine.batches_total").inc()
        registry.counter("engine.completed_total").inc(len(reqs))
        registry.counter("engine.padded_lanes_total").inc(padded - len(reqs))
        registry.histogram("engine.dispatch_s").observe(t1 - t0)
        if tracer.enabled:
            tracer.complete(
                "engine/dispatch", t0, t1, category="engine",
                args={"handle": self.cache_name, "batch_size": len(reqs),
                      "padded": padded},
            )
        for r, res in zip(reqs, results):
            st = getattr(r, "stamps", None)
            if st is not None:
                st["dispatch"] = t0
                st["device_done"] = t1
            if r.future.set_running_or_notify_cancel():
                r.future.set_result(res)
            if st is not None:
                st["resolve"] = self._clock()
                emit_request_spans(tracer, r.request_id, st,
                                   args={"handle": self.cache_name})

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Drain the worker, drop + unregister the jit cache, and release
        this handle's residency entry and scene-layout reference (the
        shared host layout and device copy free when the LAST handle over
        them closes). Idempotent; the handle is unusable afterwards."""
        if self._closed:
            return
        # Streams first: their speculation workers dispatch through this
        # handle's programs and their caches hold device arrays.
        with self._worker_lock:
            streams = list(self._streams)
        for stream in streams:
            stream.close()
        self._queue.close()                 # wakes the worker; drains pending
        worker = self._worker
        if worker is not None and worker.is_alive():
            worker.join()
        # A healthy worker resolved everything on the way out; if it died
        # earlier (or dispatch left stragglers) the remaining futures must
        # still terminate — callers blocked on .result() would otherwise
        # hang forever.
        self._fail_outstanding(RuntimeError(
            f"Renderer {self.cache_name} closed before the request resolved"
        ))
        self._closed = True
        self._worker = None
        unregister_render_cache(self.cache_name)
        # Per-handle gauges must not outlive the handle (same hygiene as the
        # render-cache registry entry); the aggregate engine.* counters stay.
        get_registry().drop(f"engine.{self.cache_name}.")
        self._cache_clear()
        # Residency release: refcounted, so a second handle (or server)
        # committed on the same (scene, layout, mesh) keeps its entry —
        # the shared-eviction fix: close() used to call
        # evict_scene_layouts(self._source) unconditionally, nuking
        # layouts other open handles still referenced.
        try:
            self._res_entry.cost_fns.remove(self._dyn_cost)
        except ValueError:
            pass
        if self._res_finalizer.detach():
            self._residency.release(self._res_entry)
        self._res_entry = None
        if self._layout_ref is not None:
            # Scoped to this handle's own (scene, D) layout reference; the
            # cached host layout drops only when the last reference goes.
            release_scene_layout(*self._layout_ref)
            self._layout_ref = None
        if self._source is not None:
            # Lifecycle fix for the autotune result cache: drop this
            # scene's in-memory entries (the persisted file keeps them, so
            # a re-open still skips the search). Lazy import — only a
            # process that autotuned has the cache registered/populated.
            if "repro.autotune.cache" in sys.modules:
                sys.modules["repro.autotune.cache"].evict_autotune_entries(
                    self._source
                )
        self._source = None

    def __enter__(self) -> "Renderer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        state = "closed" if self._closed else "open"
        return (
            f"<Renderer {self.cache_name} {state} mode={self._cfg.mode!r} "
            f"backend={self._cfg.backend!r} "
            f"scene_shards={self._cfg.scene_shards} "
            f"mesh={dict(self._mesh.shape)}>"
        )


def open(  # noqa: A001 — the module-level session verb is the API
    scene: Union[GaussianScene, ShardedScene],
    cfg: RenderConfig,
    *,
    devices: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    scene_shards: Union[str, int] = "auto",
    device_budget_mb: Optional[float] = None,
    max_batch: int = 8,
    max_wait: float = 0.05,
    queue_depth: int = 64,
    tile_params: Union[None, str, tuple] = None,
    autotune_opts: Optional[dict] = None,
    residency: Optional[ResidencyManager] = None,
) -> Renderer:
    """Commit ``(scene, cfg)`` and return the :class:`Renderer` handle.

    * ``devices``/``mesh`` — where to commit: an explicit mesh, a local
      device count, or (default) every local device through
      ``make_render_mesh``.
    * ``scene_shards`` — ``'auto'`` takes the layout from ``cfg.scene_shards``
      (or the shard count of a pre-sharded scene); an int overrides it. The
      physical shard count follows the ``render_mesh_shards`` policy (logical
      shard axis when the device count cannot realize it).
    * ``device_budget_mb`` — per-device HBM cap counting the persistent
      scene parameters (1/D when physically sharded) PLUS the transient
      per-camera projected features — N/D under the feature-sharded psum
      gathers, full N otherwise (DESIGN.md §12). With
      ``scene_shards='auto'`` the handle escalates the shard count until
      the committed scene fits; otherwise an over-budget commit raises.
      The commit also resolves ``cfg.feature_gather='auto'``: 'psum' (the
      owner-masked collective) over a physical 'model' axis, 'index'
      otherwise.
    * ``max_batch``/``max_wait``/``queue_depth`` — the ``submit()`` futures
      front-end's batching knobs (same dials as the serving tier).
    * ``tile_params`` — ``None`` keeps the config's (tile, group,
      tile_capacity); an explicit triple overrides them at commit;
      ``'auto'`` consults the autotune cache (memory, then the persisted
      file) at FIRST render and runs the cost-model-guided search on a miss
      (DESIGN.md §13), committing the winner — images are bitwise-identical
      to a fixed-config open of the same resolved params.
      ``autotune_opts`` forwards search knobs (tiles/group_factors/
      capacities/top_k/warmup/reps/verify/persist) to
      :func:`repro.autotune.autotune`.
    * ``residency`` — a shared :class:`~repro.residency.ResidencyManager`
      (DESIGN.md §17): handles committed through one manager share device
      copies per (scene, layout, mesh) and page in/out against the
      manager's budget — many scenes serve from a device that fits only a
      few, bitwise-identically. Without it the handle gets a private
      manager (no paging unless ``device_budget_mb`` forces it; identical
      to the pre-residency semantics). When both are given,
      ``device_budget_mb`` still bounds THIS scene alone; the manager's
      budget drives aggregate paging.

    Use as a context manager (``with engine.open(...) as r:``) or call
    ``r.close()`` to release the committed state.
    """
    return Renderer(
        scene, cfg,
        devices=devices, mesh=mesh, scene_shards=scene_shards,
        device_budget_mb=device_budget_mb,
        max_batch=max_batch, max_wait=max_wait, queue_depth=queue_depth,
        tile_params=tile_params, autotune_opts=autotune_opts,
        residency=residency,
    )


# ---------------------------------------------------------------------------
# Module-default handles (the deprecation shims' delegate)
# ---------------------------------------------------------------------------

_DEFAULT_MAX = 32
_default_handles: Dict[tuple, Renderer] = {}


def default_renderer(
    scene: Union[GaussianScene, ShardedScene],
    cfg: RenderConfig,
    *,
    mesh: Optional[Mesh] = None,
) -> Renderer:
    """The module-default handle for ``(scene, cfg, mesh)``.

    Backs the deprecated free functions (``render_jit``/``render_image``/
    ``render_batch_sharded``): repeated legacy calls with the same scene and
    config reuse ONE committed handle — same executable-reuse behavior the
    old global lru caches provided for a fixed scene. Bounded FIFO; evicted
    handles are closed (which also evicts their scene layouts). Known
    tradeoff of per-handle caches: legacy callers LOOPING over many scenes
    under one config recompile per scene (the old global cache shared the
    executable); that is the migration pressure — new code should hold its
    own handle from :func:`open`.
    """
    key = (id(scene), cfg, mesh)
    handle = _default_handles.get(key)
    if handle is not None and not handle.closed:
        return handle
    handle = Renderer(scene, cfg, mesh=mesh)
    while len(_default_handles) >= _DEFAULT_MAX:
        _default_handles.pop(next(iter(_default_handles))).close()
    _default_handles[key] = handle
    # id() keys alone could alias a recycled object (a pre-sharded scene the
    # handle keeps no strong reference to could be collected and its id
    # reused): drop + close the entry when the source scene goes away.
    weakref.finalize(scene, _drop_default_handle, key)
    return handle


def _drop_default_handle(key) -> None:
    handle = _default_handles.pop(key, None)
    if handle is not None:
        handle.close()


def close_default_renderers() -> None:
    """Close and drop every module-default handle (test isolation hook)."""
    while _default_handles:
        _default_handles.pop(next(iter(_default_handles))).close()
