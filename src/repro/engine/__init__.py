"""The session-style rendering engine (DESIGN.md §11).

``engine.open(scene, cfg)`` commits a scene ONCE — placement (replicated or
gaussian-sharded), render mesh, jit caches — and returns a ``Renderer``
handle exposing ``.render``, ``.render_batch``, the futures-based
``.submit`` front-end, ``.stats`` and context-manager ``.close``. The legacy
free functions (``render_jit``/``render_image``/``render_batch_sharded``)
are deprecation shims over :func:`default_renderer`.
"""
from repro.engine.handle import (
    Renderer,
    close_default_renderers,
    default_renderer,
    open,
)
from repro.engine.stream import StreamRenderer, pose_key, predict_next_camera

__all__ = [
    "Renderer",
    "StreamRenderer",
    "close_default_renderers",
    "default_renderer",
    "open",
    "pose_key",
    "predict_next_camera",
]
