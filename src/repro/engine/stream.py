"""Stream sessions: frame-coherent rendering over a committed handle.

``Renderer.open_stream()`` returns a :class:`StreamRenderer` — a per-stream
session exploiting the temporal coherence of interactive camera paths
(DESIGN.md §15). The handle compiles the render pipeline as TWO programs
(core/pipeline.py): the pose-heavy frontend (project -> identify -> bin ->
merge) and the pixel-producing backend (bitmask -> compact -> rasterize).
The stream keeps a bounded LRU cache of ``FrontendResult``s keyed by
:func:`pose_key` — the exact float32-canonicalized bit pattern of the pose
and intrinsics the compiled program consumes — so a frame whose pose was
seen before (an orbit lap, a paused viewer, a replayed path) skips straight
to the backend: the sort is free, as if the previous frame paid for it.

A background speculation worker extrapolates the stream's recent camera
trajectory and pre-runs the FRONTEND for the predicted next pose(s), parking
the binned table in the same cache:

  * successor replay — the pose observed to follow the current one last
    time around (exact on looping/replayed paths);
  * constant-velocity fallback — ``R_pred = (R1 R0^T) R1``,
    ``t_pred = 2 t1 - t0`` in float32 (exact on linear dollies whose steps
    are float32-representable).

The invariant is **verify-or-discard, never approximate**: a speculative
entry is used only when the ARRIVING camera's key matches it exactly, so
stream output is bitwise-identical to stateless rendering by construction —
a wrong prediction costs device time, never pixels. Speculation is bounded:
the per-stream prediction queue holds ``spec_depth`` cameras (drop-oldest
under pressure, counted in ``spec.dropped_total``), and the frontend cache
itself holds ``cache_frames`` entries, so a runaway stream cannot grow
device memory.

Observability: the cache registers with the engine-wide render-cache
registry (``render_cache_info()['<handle>.streamN']``; exact-reuse hits/
misses), the speculation lifecycle is counted in the metrics registry
(``stream.*`` / ``spec.*`` counters) and spanned in the Chrome trace
(``spec/verify`` per frame, ``spec/run`` per speculative frontend,
``stream/frontend``/``stream/backend`` device work), and
``scripts/validate_trace.py`` cross-checks spans against counters in CI.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.camera import Camera
from repro.core.pipeline import register_render_cache, unregister_render_cache
from repro.obs import get_registry, get_tracer
from repro.utils import pytree_bytes

_STREAM_SEQ = itertools.count()

# ONE dispatch lock for every stream in the process (foreground frames AND
# speculation workers): two threads concurrently launching programs that
# contain cross-device collectives (the feature-sharded psum gathers over a
# multi-device mesh) can interleave their rendezvous and deadlock XLA — the
# per-stream serialization that would allow A's worker to overlap B's frame
# is exactly the interleaving that hangs. Device work is serialized by the
# hardware anyway; the speculation win is caching, not dispatch overlap.
_DEVICE_DISPATCH_LOCK = threading.Lock()


def _pose_array(x) -> np.ndarray:
    """Canonicalize a pose array to the dtype the compiled program consumes:
    ``jnp.asarray`` downcasts float64 to float32 unless x64 is enabled, so
    the key must collapse exactly the inputs the renderer collapses."""
    a = np.asarray(x)
    if a.dtype == np.float64 and not jax.config.jax_enable_x64:
        a = a.astype(np.float32)
    return a


def pose_key(cam) -> bytes:
    """The exact quantized pose/config signature of one camera.

    'Quantized' means canonicalized to the bit patterns the compiled
    frontend actually consumes — intrinsics as float32 (mirroring the
    ``jnp.float32`` casts in ``Renderer.render``), pose arrays through the
    same float64->float32 collapse ``jnp.asarray`` applies — and nothing
    coarser: two cameras share a key iff the frontend program would receive
    identical input bits, which is what makes exact-key reuse bitwise-safe.
    Injective on distinct (canonicalized) poses: every segment is either
    fixed-length or a length-determining dtype tag, so the encoding parses
    unambiguously. Stable on bit-identical poses: pure bytes of the
    canonical arrays, no id()/hash() involvement.
    """
    R = _pose_array(cam.R)
    t = _pose_array(cam.t)
    return b"|".join((
        np.array([cam.width, cam.height], np.int64).tobytes(),
        np.array([cam.znear, cam.zfar], np.float64).tobytes(),
        R.dtype.str.encode(), R.tobytes(),
        t.dtype.str.encode(), t.tobytes(),
        np.array([cam.fx, cam.fy, cam.cx, cam.cy], np.float32).tobytes(),
    ))


def _geometry(cam) -> tuple:
    return (cam.width, cam.height, cam.znear, cam.zfar)


def predict_next_camera(c0, c1) -> Optional[Camera]:
    """Constant-velocity pose extrapolation: the camera that continues the
    ``c0 -> c1`` motion one more step.

    Rotation advances by the observed relative rotation (``R_d = R1 R0^T``,
    ``R_pred = R_d R1``); translation and intrinsics extrapolate linearly in
    float32. For poses that genuinely follow such a path in exactly-
    representable steps the prediction is bit-exact (tests/test_stream.py's
    dolly); anywhere else it merely misses the exact-match cache — never
    corrupts it. Returns None when the static geometry changed (a predicted
    pose across a resolution bump is meaningless).
    """
    if _geometry(c0) != _geometry(c1):
        return None
    R0, t0 = _pose_array(c0.R), _pose_array(c0.t)
    R1, t1 = _pose_array(c1.R), _pose_array(c1.t)
    # Constant components short-circuit BEFORE any arithmetic: a component
    # that did not move is predicted to stay put bit-exactly (the general
    # formula would round — e.g. (R1 R0^T) R1 != R1 bitwise for a generic
    # rotation even when R0 == R1). This makes pure-translation dollies
    # under ANY fixed rotation exact, not just identity poses.
    if np.array_equal(R0, R1):
        R_pred = R1
    else:
        R_pred = ((R1 @ R0.T) @ R1).astype(R1.dtype)
    if np.array_equal(t0, t1):
        t_pred = t1
    else:
        t_pred = (2.0 * t1 - t0).astype(t1.dtype)
    f32 = np.float32

    def lin(a, b):
        a, b = f32(a), f32(b)
        return b if a == b else f32(2.0 * b - a)

    return dataclasses.replace(
        c1,
        R=R_pred,
        t=t_pred,
        fx=lin(c0.fx, c1.fx),
        fy=lin(c0.fy, c1.fy),
        cx=lin(c0.cx, c1.cx),
        cy=lin(c0.cy, c1.cy),
    )


@dataclasses.dataclass
class _CacheEntry:
    front: Any                  # FrontendResult (device arrays)
    speculative: bool           # parked by the worker, not yet verified
    used: bool = False          # served at least one frame


class StreamRenderer:
    """One interactive camera stream over a committed :class:`Renderer`.

    ``render(cam)`` is the synchronous per-frame entry point; frames are
    expected in path order from ONE caller (per-stream frame order is what
    the predictor learns from). Thread-safe with respect to its own
    speculation worker; distinct streams over one handle are independent.
    Close the stream (or its handle, which closes it) to stop the worker
    and evict the cache from the registry.
    """

    def __init__(
        self,
        handle,
        *,
        cache_frames: int = 32,
        spec_depth: int = 2,
        speculate: bool = True,
    ):
        if cache_frames < 1:
            raise ValueError(f"cache_frames must be >= 1, got {cache_frames}")
        if spec_depth < 0:
            raise ValueError(f"spec_depth must be >= 0, got {spec_depth}")
        self._handle = handle
        self.cache_frames = cache_frames
        self.spec_depth = spec_depth
        self.speculate = speculate and spec_depth > 0
        self.name = f"{handle.cache_name}.stream{next(_STREAM_SEQ)}"

        self._lock = threading.Lock()          # cache + predictor state
        self._device_lock = _DEVICE_DISPATCH_LOCK   # shared across ALL streams
        self._cache: "OrderedDict[bytes, _CacheEntry]" = OrderedDict()
        self._succ: "OrderedDict[bytes, Camera]" = OrderedDict()
        self._geom: Optional[tuple] = None
        self._prev: "deque[Camera]" = deque(maxlen=2)
        self._counters = {
            "frames": 0, "hits": 0, "misses": 0,
            "spec_hits": 0, "spec_runs": 0,
            "spec_dropped": 0, "spec_discarded": 0,
            "invalidations": 0,
        }

        self._spec_queue: "deque[Camera]" = deque()
        self._spec_event = threading.Event()
        self._spec_busy = False
        self._spec_idle = threading.Condition(self._lock)
        self._spec_thread: Optional[threading.Thread] = None
        self._closed = False

        def _info(self_ref=self):
            return self_ref.cache_info()

        def _clear(self_ref=self):
            self_ref.cache_clear()

        register_render_cache(self.name, info=_info, clear=_clear)

    # -- cache bookkeeping (registry contract) -------------------------------

    def cache_info(self) -> dict:
        with self._lock:
            return {
                "hits": self._counters["hits"],
                "misses": self._counters["misses"],
                "currsize": len(self._cache),
                "maxsize": self.cache_frames,
            }

    def cache_bytes(self) -> int:
        """Total DEVICE bytes held by the cached FrontendResult pytrees —
        the memory the handle's budget model used to undercount; summed
        into ``Renderer.frontend_cache_mb()`` and charged against the
        residency budget (DESIGN.md §17)."""
        with self._lock:
            return sum(
                pytree_bytes(e.front) for e in self._cache.values()
            )

    def cache_clear(self) -> None:
        """Drop every cached frontend result and reset hit/miss counts
        (the ``render_cache_clear()`` contract). Unused speculative entries
        are counted discarded — their device work never paid off."""
        with self._lock:
            self._drop_all_entries_locked()
            self._counters["hits"] = 0
            self._counters["misses"] = 0

    def _drop_all_entries_locked(self) -> None:
        discarded = sum(
            1 for e in self._cache.values() if e.speculative and not e.used
        )
        if discarded:
            self._counters["spec_discarded"] += discarded
            get_registry().counter("spec.discarded_total").inc(discarded)
        self._cache.clear()
        self._succ.clear()
        self._prev.clear()
        dropped = len(self._spec_queue)
        if dropped:
            self._counters["spec_dropped"] += dropped
            get_registry().counter("spec.dropped_total").inc(dropped)
        self._spec_queue.clear()

    def _evict_overflow_locked(self) -> None:
        while len(self._cache) > self.cache_frames:
            _, entry = self._cache.popitem(last=False)
            if entry.speculative and not entry.used:
                self._counters["spec_discarded"] += 1
                get_registry().counter("spec.discarded_total").inc()
        while len(self._succ) > 4 * self.cache_frames:
            self._succ.popitem(last=False)

    # -- the per-frame entry point -------------------------------------------

    def render(self, cam: Camera, background=None):
        """Render one stream frame — bitwise-identical to
        ``handle.render(cam, background)`` by construction.

        Exact pose-key hit: the cached FrontendResult feeds the backend
        program directly (the frontend is skipped entirely). Miss: the full
        frontend + backend path runs and the fresh result is cached for the
        frames (or laps) behind it. Either way the trajectory tracker learns
        the transition and wakes the speculation worker.
        """
        if self._closed:
            raise RuntimeError("StreamRenderer is closed")
        registry = get_registry()
        tracer = get_tracer()
        key = pose_key(cam)
        geom = _geometry(cam)

        t_verify0 = tracer.clock()
        with self._lock:
            if self._geom is not None and geom != self._geom:
                # Mid-stream config change (e.g. resolution bump): every
                # cached table was binned for another grid — invalidate.
                self._drop_all_entries_locked()
                self._counters["invalidations"] += 1
                registry.counter("stream.invalidations_total").inc()
            self._geom = geom
            entry = self._cache.get(key)
            if entry is not None:
                self._cache.move_to_end(key)
                hit = True
                self._counters["hits"] += 1
                if entry.speculative and not entry.used:
                    self._counters["spec_hits"] += 1
                    registry.counter("spec.hits_total").inc()
                entry.used = True
                front = entry.front
            else:
                hit = False
                self._counters["misses"] += 1
            self._counters["frames"] += 1
        registry.counter("stream.frames_total").inc()
        registry.counter(
            "stream.hits_total" if hit else "stream.misses_total"
        ).inc()
        tracer.complete(
            "spec/verify", t_verify0, tracer.clock(), category="spec",
            args={"stream": self.name, "hit": hit},
        )

        if not hit:
            t0 = tracer.clock()
            with self._device_lock:
                front = self._handle.render_frontend(cam)
            tracer.complete(
                "stream/frontend", t0, tracer.clock(), category="stream",
                args={"stream": self.name},
            )
            with self._lock:
                # A speculative run may have raced us to the same key; the
                # results are bitwise-identical (same program, same input
                # bits) so last-writer-wins is safe.
                self._cache[key] = _CacheEntry(front, speculative=False,
                                               used=True)
                self._cache.move_to_end(key)
                self._evict_overflow_locked()

        t0 = tracer.clock()
        with self._device_lock:
            out = self._handle.render_backend(front, cam, background)
        tracer.complete(
            "stream/backend", t0, tracer.clock(), category="stream",
            args={"stream": self.name},
        )

        self._observe_trajectory(cam, key)
        return out

    # -- trajectory tracking + speculation -----------------------------------

    def _observe_trajectory(self, cam: Camera, key: bytes) -> None:
        with self._lock:
            if self._prev:
                self._succ[pose_key(self._prev[-1])] = cam
                self._succ.move_to_end(pose_key(self._prev[-1]))
            self._prev.append(cam)
            if not self.speculate:
                return
            predictions = self._predict_locked(cam, key)
            for p in predictions:
                self._spec_queue.append(p)
                if len(self._spec_queue) > self.spec_depth:
                    self._spec_queue.popleft()
                    self._counters["spec_dropped"] += 1
                    get_registry().counter("spec.dropped_total").inc()
        if self.speculate:
            self._ensure_spec_worker()
            self._spec_event.set()

    def _predict_locked(self, cam: Camera, key: bytes) -> List[Camera]:
        """Predicted next camera(s): successor replay first (exact on
        looping paths), constant-velocity extrapolation as the fallback.
        Predictions whose pose is already cached are skipped here — steady-
        state replay costs no device work at all."""
        preds: List[Camera] = []
        succ = self._succ.get(key)
        if succ is not None and _geometry(succ) == self._geom:
            # Replay is authoritative once this transition has been seen:
            # on a lapping path the successor is usually already cached
            # (filtered below — steady state costs NO device work), and
            # extrapolating a second, fabricated pose on top would burn a
            # frontend run per frame that can never hit.
            preds.append(succ)
        elif len(self._prev) == 2:
            cv = predict_next_camera(self._prev[0], self._prev[1])
            if cv is not None:
                preds.append(cv)
        return [
            p for p in preds
            if pose_key(p) not in self._cache
        ][: max(self.spec_depth, 0)]

    def _ensure_spec_worker(self) -> None:
        if self._spec_thread is None or not self._spec_thread.is_alive():
            with self._lock:
                if self._closed:
                    return
                if self._spec_thread is not None and self._spec_thread.is_alive():
                    return
                self._spec_thread = threading.Thread(
                    target=self._spec_loop, name=f"{self.name}-spec",
                    daemon=True,
                )
                self._spec_thread.start()

    def _spec_loop(self) -> None:
        registry = get_registry()
        tracer = get_tracer()
        while True:
            self._spec_event.wait()
            self._spec_event.clear()
            if self._closed:
                return
            while True:
                with self._lock:
                    cam = None
                    while self._spec_queue:
                        c = self._spec_queue.popleft()
                        if pose_key(c) in self._cache:
                            continue        # already cached — nothing to do
                        cam = c
                        break
                    if cam is None:
                        self._spec_busy = False
                        self._spec_idle.notify_all()
                        break
                    self._spec_busy = True
                try:
                    t0 = tracer.clock()
                    with self._device_lock:
                        front = self._handle.render_frontend(cam)
                    t1 = tracer.clock()
                except Exception:           # noqa: BLE001 — a failed
                    # speculation must never kill the stream; the real frame
                    # will take the miss path and surface any real error.
                    with self._lock:
                        self._spec_busy = False
                        self._spec_idle.notify_all()
                    continue
                with self._lock:
                    if self._closed:
                        self._spec_busy = False
                        self._spec_idle.notify_all()
                        return
                    # Span + counter recorded together (same critical
                    # section) so the validate_trace.py cross-check
                    # spec/run == spec.runs_total can never race a close.
                    registry.counter("spec.runs_total").inc()
                    self._counters["spec_runs"] += 1
                    tracer.complete(
                        "spec/run", t0, t1, category="spec",
                        args={"stream": self.name},
                    )
                    if _geometry(cam) == self._geom:
                        k = pose_key(cam)
                        if k not in self._cache:
                            self._cache[k] = _CacheEntry(
                                front, speculative=True
                            )
                            self._evict_overflow_locked()
            if self._closed:
                return

    def wait_spec_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until the speculation queue is drained and the worker is
        parked (deterministic tests/benchmarks). True on idle."""
        with self._lock:
            return self._spec_idle.wait_for(
                lambda: not self._spec_queue and not self._spec_busy,
                timeout=timeout,
            )

    # -- introspection / lifecycle -------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            hits, misses = self._counters["hits"], self._counters["misses"]
            return {
                "stream": self.name,
                "cache": {
                    "hits": hits,
                    "misses": misses,
                    "currsize": len(self._cache),
                    "maxsize": self.cache_frames,
                },
                "hit_rate": hits / max(hits + misses, 1),
                "cache_bytes": sum(
                    pytree_bytes(e.front) for e in self._cache.values()
                ),
                **{k: v for k, v in self._counters.items()},
            }

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Stop the speculation worker, discard the cache (unused
        speculative entries count as discarded), and unregister from the
        render-cache registry. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._spec_event.set()
        thread = self._spec_thread
        if thread is not None and thread.is_alive():
            thread.join(timeout=30.0)
        with self._lock:
            self._drop_all_entries_locked()
        unregister_render_cache(self.name)
        self._handle._forget_stream(self)

    def __enter__(self) -> "StreamRenderer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        state = "closed" if self._closed else "open"
        return (
            f"<StreamRenderer {self.name} {state} "
            f"cache={len(self._cache)}/{self.cache_frames} "
            f"spec_depth={self.spec_depth}>"
        )
