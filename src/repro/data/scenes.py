"""Synthetic Gaussian scenes + camera trajectories for renderer benchmarks."""
from __future__ import annotations

from typing import List, Tuple

import jax

from repro.core.camera import Camera, orbit_cameras
from repro.core.gaussians import GaussianScene, random_scene


def synthetic_scene_and_views(
    seed: int,
    num_gaussians: int,
    width: int,
    height: int,
    n_views: int = 4,
    extent: float = 4.0,
) -> Tuple[GaussianScene, List[Camera]]:
    key = jax.random.key(seed)
    scene = random_scene(key, num_gaussians, extent=extent)
    cams = orbit_cameras(
        n_views, radius=extent * 1.6, width=width, height=height
    )
    return scene, cams
