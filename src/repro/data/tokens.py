"""Deterministic, seekable synthetic token pipeline.

Every batch is a pure function of (seed, step) — restart at step k reproduces
the exact stream with no cursor files (the checkpoint only stores the step).
Generation uses a counter-based hash (splitmix64) so any (step, position) can
be materialized independently: this is what makes elastic resharding trivial
— a host can produce any slice of the global batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int, lo: int = 0, hi: Optional[int] = None) -> np.ndarray:
        """Rows [lo, hi) of the global batch at ``step`` (for sharded hosts)."""
        hi = self.global_batch if hi is None else hi
        rows = np.arange(lo, hi, dtype=np.uint64)[:, None]
        cols = np.arange(self.seq_len + 1, dtype=np.uint64)[None, :]
        ctr = (
            np.uint64(self.seed) * np.uint64(0x1000003)
            + np.uint64(step) * np.uint64(0x100000001B3)
            + rows * np.uint64(self.seq_len + 1)
            + cols
        )
        h = _splitmix64(ctr)
        return (h % np.uint64(self.vocab)).astype(np.int32)


def make_batch(
    stream: TokenStream,
    step: int,
    frontend: str = "text",
    n_frontend_tokens: int = 0,
    d_model: int = 0,
) -> Dict[str, np.ndarray]:
    """Model-ready batch: inputs + next-token labels (+ frontend stubs)."""
    full = stream.batch_at(step)                 # (B, S+1)
    tokens, labels = full[:, :-1], full[:, 1:]
    B, S = tokens.shape
    if frontend == "vision_stub":
        s_text = S - n_frontend_tokens
        rng = np.random.default_rng(stream.seed * 7919 + step)
        return {
            "tokens": tokens[:, :s_text],
            "labels": labels[:, :s_text],
            "patch_embeds": rng.standard_normal(
                (B, n_frontend_tokens, d_model), dtype=np.float32
            )
            * 0.02,
        }
    if frontend == "audio_stub":
        rng = np.random.default_rng(stream.seed * 104729 + step)
        return {
            "frames": rng.standard_normal((B, S, d_model), dtype=np.float32)
            * 0.02,
            "labels": labels % 504,
        }
    return {"tokens": tokens, "labels": labels}
