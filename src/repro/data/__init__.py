from repro.data.tokens import TokenStream, make_batch
from repro.data.scenes import synthetic_scene_and_views

__all__ = ["TokenStream", "make_batch", "synthetic_scene_and_views"]
