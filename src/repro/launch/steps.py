"""Step builders + abstract input specs for train / prefill / decode.

Everything here is AOT-friendly: input_specs() returns ShapeDtypeStructs with
NamedShardings attached, so ``jax.jit(step).lower(**input_specs(...))``
compiles the production mesh program without allocating a single buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (
    build_cache_spec,
    build_param_spec,
    decode_step,
    forward,
    loss_fn,
)
from repro.models.config import ModelConfig
from repro.models.spec import LeafSpec, abstract_from_spec, is_leaf, partition_from_spec
from repro.optim import (
    adafactor_init,
    adamw_init,
    adafactor_update,
    adamw_update,
    clip_by_global_norm,
    linear_warmup_cosine,
)
from repro.sharding.policies import (
    activation_rules,
    batch_specs,
    make_constrain,
    param_rules,
)
from repro.launch.shapes import ShapeCell

ADAFACTOR_THRESHOLD = 50_000_000_000  # >=50B params -> factored optimizer


def pick_optimizer(cfg: ModelConfig) -> str:
    return "adafactor" if cfg.param_count() >= ADAFACTOR_THRESHOLD else "adamw"


# --------------------------------------------------------------------------
# sharding helpers
# --------------------------------------------------------------------------

def _dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


FSDP_THRESHOLD_BYTES = 2 << 30  # per-device weight bytes above which we
                                 # additionally shard weights over data (FSDP)


def use_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """FSDP decision: TP alone must leave <2GiB/device of weights, else we
    shard weights over the data axes too (XLA all-gathers per layer at use —
    the GSPMD realization of FSDP/ZeRO-3). cfg.force_fsdp pins the decision
    (used by roofline calibration configs with reduced depth)."""
    if cfg.force_fsdp is not None:
        return cfg.force_fsdp
    from repro.models.spec import spec_bytes

    per_dev = spec_bytes(build_param_spec(cfg)) / mesh.shape["model"]
    return per_dev > FSDP_THRESHOLD_BYTES


def param_pspecs(cfg: ModelConfig, mesh: Mesh, fsdp: Optional[bool] = None):
    spec = build_param_spec(cfg)
    base = partition_from_spec(spec, param_rules(cfg, mesh))
    if fsdp is None:
        fsdp = use_fsdp(cfg, mesh)
    if not fsdp:
        return base
    return jax.tree.map(
        lambda l, ps: zero1_axis(l, ps, mesh), spec, base, is_leaf=is_leaf
    )


def zero1_axis(leaf: LeafSpec, pspec: P, mesh: Mesh) -> P:
    """ZeRO-1: additionally shard optimizer state over the data axes on the
    first free (unsharded, divisible) dimension of each leaf."""
    dp = _dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    parts = list(pspec) + [None] * (len(leaf.shape) - len(pspec))
    # Already data-sharded (e.g. FSDP params feeding optimizer states): keep.
    flat = []
    for pp in parts:
        flat.extend(pp if isinstance(pp, tuple) else (pp,))
    if any(a in flat for a in dp):
        return P(*parts)
    for i, (dim, cur) in enumerate(zip(leaf.shape, parts)):
        if cur is None and dim % dp_size == 0 and dim >= dp_size:
            parts[i] = dp
            return P(*parts)
    return P(*parts)


def _fsdp_reshard(x, compute_sh: NamedSharding, store_sh: NamedSharding):
    """FSDP boundary op: all-gather to the compute sharding on the forward
    pass, reduce-scatter the cotangent back to the storage sharding on the
    backward pass. A plain with_sharding_constraint transposes to ITSELF, so
    gradients would stay in (full) compute sharding and stack un-scattered —
    this custom_vjp is what makes per-layer reduce-scatter happen inside the
    backward scan."""

    @jax.custom_vjp
    def f(v):
        return jax.lax.with_sharding_constraint(v, compute_sh)

    def fwd(v):
        return jax.lax.with_sharding_constraint(v, compute_sh), None

    def bwd(_, g):
        return (jax.lax.with_sharding_constraint(g, store_sh),)

    f.defvjp(fwd, bwd)
    return f(x)


def make_unit_constrain(cfg: ModelConfig, mesh: Mesh):
    """Reshard per-layer weight slices to the COMPUTE sharding inside the
    scan body (FSDP: gather layer-by-layer fwd, reduce-scatter grads bwd)."""
    spec = build_param_spec(cfg)
    base = partition_from_spec(spec, param_rules(cfg, mesh))["units"]
    stored = param_pspecs(cfg, mesh)["units"]

    def drop_lead(ps: P) -> NamedSharding:
        parts = list(ps)[1:]  # axis 0 is the stacked-units axis (always None)
        return NamedSharding(mesh, P(*parts))

    compute_sh = jax.tree.map(drop_lead, base)
    store_sh = jax.tree.map(drop_lead, stored)

    def unit_constrain(up):
        return jax.tree.map(_fsdp_reshard, up, compute_sh, store_sh)

    return unit_constrain


def abstract_params(cfg: ModelConfig, mesh: Mesh):
    spec = build_param_spec(cfg)
    pspecs = param_pspecs(cfg, mesh)
    ab = abstract_from_spec(spec)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=_named(mesh, s)),
        ab,
        pspecs,
    )


def abstract_opt_state(cfg: ModelConfig, mesh: Mesh, optimizer: str, zero1: bool):
    """ShapeDtypeStructs (with shardings) for the optimizer state."""
    spec = build_param_spec(cfg)
    pspecs = param_pspecs(cfg, mesh)

    def adam_leaf(leaf: LeafSpec, ps: P):
        sp = zero1_axis(leaf, ps, mesh) if zero1 else ps
        return jax.ShapeDtypeStruct(
            leaf.shape, jnp.float32, sharding=_named(mesh, sp)
        )

    if optimizer == "adamw":
        mu = jax.tree.map(adam_leaf, spec, pspecs, is_leaf=is_leaf)
        from repro.optim.adamw import AdamWState

        return AdamWState(mu=mu, nu=jax.tree.map(lambda x: x, mu))

    # adafactor: factored stats for >=2D leaves
    from repro.optim.adafactor import AdafactorState, FactoredLeaf

    def fac_leaf(leaf: LeafSpec, ps: P):
        parts = list(ps) + [None] * (len(leaf.shape) - len(ps))
        if len(leaf.shape) >= 2:
            vr = jax.ShapeDtypeStruct(
                leaf.shape[:-1], jnp.float32,
                sharding=_named(mesh, P(*parts[:-1])),
            )
            vc = jax.ShapeDtypeStruct(
                leaf.shape[:-2] + leaf.shape[-1:], jnp.float32,
                sharding=_named(mesh, P(*(parts[:-2] + parts[-1:]))),
            )
            v = jax.ShapeDtypeStruct((1,), jnp.float32, sharding=_named(mesh, P(None)))
        else:
            vr = jax.ShapeDtypeStruct((1,), jnp.float32, sharding=_named(mesh, P(None)))
            vc = jax.ShapeDtypeStruct((1,), jnp.float32, sharding=_named(mesh, P(None)))
            v = jax.ShapeDtypeStruct(
                leaf.shape, jnp.float32, sharding=_named(mesh, P(*parts))
            )
        return FactoredLeaf(vr=vr, vc=vc, v=v)

    stats = jax.tree.map(fac_leaf, spec, pspecs, is_leaf=is_leaf)
    return AdafactorState(stats=stats)


def abstract_batch(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh) -> Dict[str, Any]:
    dp = _dp_axes(mesh)
    B, S = shape.global_batch, shape.seq_len

    def sd(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype, sharding=_named(mesh, spec))

    if cfg.frontend == "vision_stub":
        s_text = S - cfg.n_frontend_tokens
        return {
            "tokens": sd((B, s_text), jnp.int32, P(dp, None)),
            "labels": sd((B, s_text), jnp.int32, P(dp, None)),
            "patch_embeds": sd(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16,
                P(dp, None, None),
            ),
        }
    if cfg.frontend == "audio_stub":
        return {
            "frames": sd((B, S, cfg.d_model), jnp.bfloat16, P(dp, None, None)),
            "labels": sd((B, S), jnp.int32, P(dp, None)),
        }
    return {
        "tokens": sd((B, S), jnp.int32, P(dp, None)),
        "labels": sd((B, S), jnp.int32, P(dp, None)),
    }


def _batch_shardable(shape: ShapeCell, mesh: Mesh) -> bool:
    dp_size = 1
    for a in _dp_axes(mesh):
        dp_size *= mesh.shape[a]
    return shape.global_batch % dp_size == 0


def abstract_cache(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh):
    rules = activation_rules(cfg, mesh)
    if not _batch_shardable(shape, mesh):
        rules = dict(rules, batch=None, cache_batch=None)
    cspec = build_cache_spec(cfg, shape.global_batch, shape.seq_len)
    pspecs = partition_from_spec(cspec, rules)
    ab = abstract_from_spec(cspec)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=_named(mesh, s)),
        ab,
        pspecs,
    )


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------

def make_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    optimizer: Optional[str] = None,
    lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    clip_norm: float = 1.0,
):
    optimizer = optimizer or pick_optimizer(cfg)
    constrain = make_constrain(cfg, mesh)
    uc = make_unit_constrain(cfg, mesh) if mesh is not None else None
    schedule = linear_warmup_cosine(lr, warmup, total_steps)

    def train_step(params, opt_state, batch, step):
        def lfn(p):
            return loss_fn(cfg, p, batch, constrain, uc)

        (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        if optimizer == "adamw":
            params, opt_state = adamw_update(
                params, grads, opt_state, step, lr=schedule, weight_decay=0.01
            )
        else:
            params, opt_state = adafactor_update(
                params, grads, opt_state, step, lr=schedule
            )
        out_metrics = {
            "loss": loss,
            "ce": metrics["ce"],
            "aux": metrics["aux"],
            "grad_norm": gnorm,
        }
        return params, opt_state, out_metrics

    return train_step, optimizer


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    constrain = make_constrain(cfg, mesh)
    uc = make_unit_constrain(cfg, mesh) if mesh is not None else None

    def prefill_step(params, batch):
        logits, _aux = forward(cfg, params, batch, constrain, uc)
        if cfg.family == "encoder":
            return logits          # full frame-level logits (504-way)
        return logits[:, -1, :]    # TTFT: next-token logits only

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh, batch_shardable: bool = True,
                     weight_gather: bool = False):
    """weight_gather=False (default, §Perf iteration): decode keeps weights in
    their 2D storage sharding (model x data) and lets XLA psum the tiny
    per-token activations. Gathering FSDP weights per decode step moves the
    full parameter bytes across the ICI to produce ONE token — measured 460x
    more collective traffic on qwen decode_32k (EXPERIMENTS.md §Perf)."""
    # §Perf note: an activation-replicated "weight-stationary" layout was
    # tried here and REFUTED (5x more flops, no collective win — see
    # EXPERIMENTS.md §Perf); batch-sharded activations stay.
    constrain = make_constrain(cfg, mesh, batch_shardable=batch_shardable)
    uc = (
        make_unit_constrain(cfg, mesh)
        if (mesh is not None and weight_gather)
        else None
    )

    def serve_step(params, cache, tokens, pos):
        next_tokens, logits, new_cache = decode_step(
            cfg, params, cache, tokens, pos, constrain, uc
        )
        return next_tokens, new_cache

    return serve_step


def _sh_of(tree):
    return jax.tree.map(lambda a: a.sharding, tree)


def jit_for_cell(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh,
                 optimizer: Optional[str] = None):
    """(jitted_fn, kwargs of ShapeDtypeStructs) for one (arch x shape) cell.

    Pins out_shardings to the input state shardings (params/opt/cache) and
    donates the state buffers — as a production step would.
    """
    dp = _dp_axes(mesh)
    if shape.kind == "train":
        optimizer = optimizer or pick_optimizer(cfg)
        step_fn, _ = make_train_step(cfg, mesh, optimizer=optimizer)
        kwargs = dict(
            params=abstract_params(cfg, mesh),
            opt_state=abstract_opt_state(cfg, mesh, optimizer, zero1=True),
            batch=abstract_batch(cfg, shape, mesh),
            step=jax.ShapeDtypeStruct((), jnp.int32),
        )
        out_sh = (_sh_of(kwargs["params"]), _sh_of(kwargs["opt_state"]), None)
        fn = jax.jit(
            step_fn,
            out_shardings=out_sh,
            donate_argnames=("params", "opt_state"),
        )
        return fn, kwargs
    if shape.kind == "prefill":
        step_fn = make_prefill_step(cfg, mesh)
        batch = abstract_batch(cfg, shape, mesh)
        batch.pop("labels", None)
        return jax.jit(step_fn), dict(
            params=abstract_params(cfg, mesh), batch=batch
        )
    if shape.kind == "decode":
        shardable = _batch_shardable(shape, mesh)
        step_fn = make_decode_step(cfg, mesh, batch_shardable=shardable)
        B = shape.global_batch
        tok_spec = P(dp) if shardable else P(None)
        kwargs = dict(
            params=abstract_params(cfg, mesh),
            cache=abstract_cache(cfg, shape, mesh),
            tokens=jax.ShapeDtypeStruct(
                (B,), jnp.int32, sharding=_named(mesh, tok_spec)
            ),
            pos=jax.ShapeDtypeStruct((), jnp.int32),
        )
        out_sh = (
            jax.tree.map(lambda a: a.sharding, kwargs["tokens"]),
            _sh_of(kwargs["cache"]),
        )
        fn = jax.jit(step_fn, out_shardings=out_sh, donate_argnames=("cache",))
        return fn, kwargs
    raise ValueError(shape.kind)


def abstract_inputs_for_cell(
    cfg: ModelConfig, shape: ShapeCell, mesh: Mesh, optimizer: Optional[str] = None
) -> Tuple[Any, Dict[str, Any]]:
    """Back-compat shim: (raw step_fn, kwargs) — prefer jit_for_cell."""
    fn, kwargs = jit_for_cell(cfg, shape, mesh, optimizer)
    return fn, kwargs
