import os
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=convert-mover,while-loop-invariant-code-motion",
)

"""HBM buffer inspector for dry-run compiles: top value-producing buffers.

  PYTHONPATH=src python -m repro.launch.meminspect --arch granite-3-2b \
      --shape train_4k [--multi-pod] [--min-gb 0.5]
"""

import argparse
import re

import jax

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES
from repro.launch.steps import jit_for_cell

_DT = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2,
       "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}
_TYRE = re.compile(r"(pred|[a-z]+\d+)\[([\d,]+)\]")


def buffer_table(hlo_text: str, min_bytes: float, skip_plumbing=True):
    sizes = {}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.*?)\s+([a-z][a-z0-9\-\.]*)\(", line.strip())
        if not m:
            continue
        tstr, op = m.group(1), m.group(2)
        if skip_plumbing and op in ("tuple", "parameter", "get-tuple-element", "while"):
            continue
        total = 0
        for mm in _TYRE.finditer(tstr):
            dt, dims = mm.group(1), mm.group(2)
            if dt not in _DT:
                continue
            n = 1
            for d in dims.split(","):
                n *= int(d)
            total += n * _DT[dt]
        if total >= min_bytes:
            key = (op, tstr[:80])
            e = sizes.setdefault(key, [0, 0])
            e[0] = max(e[0], total)
            e[1] += 1
    return sorted(sizes.items(), key=lambda kv: -kv[1][0])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--min-gb", type=float, default=0.5)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    step_fn, kwargs = jit_for_cell(cfg, SHAPES[args.shape], mesh)
    with mesh:
        compiled = step_fn.lower(**kwargs).compile()
    m = compiled.memory_analysis()
    print(
        f"args={m.argument_size_in_bytes/2**30:.2f}GiB "
        f"temp={m.temp_size_in_bytes/2**30:.2f}GiB "
        f"out={m.output_size_in_bytes/2**30:.2f}GiB "
        f"alias={m.alias_size_in_bytes/2**30:.2f}GiB"
    )
    for (op, t), (tot, cnt) in buffer_table(
        compiled.as_text(), args.min_gb * 2**30
    )[: args.top]:
        print(f"{tot/2**30:8.2f} GiB  x{cnt:3d}  {op:22s} {t}")


if __name__ == "__main__":
    main()
