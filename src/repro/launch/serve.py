"""Batched greedy serving driver: prefill (teacher-forced decode) + decode.

Serves a (smoke or full) LM with a batch of requests: fills the KV cache by
stepping the prompt tokens, then greedily decodes continuations. On TPU the
same decode step runs against the sequence-sharded cache (launch/steps.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_cache_spec, build_param_spec, decode_step
from repro.models.spec import init_from_spec


def serve(
    arch: str,
    batch: int = 4,
    prompt_len: int = 16,
    gen_len: int = 16,
    smoke: bool = True,
    seed: int = 0,
):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if cfg.family == "encoder":
        raise SystemExit(f"{arch} is encoder-only: no decode serving")
    params = init_from_spec(build_param_spec(cfg), jax.random.key(seed))
    max_seq = prompt_len + gen_len
    cache = jax.tree.map(
        jnp.zeros_like,
        init_from_spec(build_cache_spec(cfg, batch, max_seq), jax.random.key(1)),
    )
    ident = lambda x, a: x
    step = jax.jit(
        lambda p, c, t, pos: decode_step(cfg, p, c, t, pos, ident),
        donate_argnums=(1,),
    )

    prompts = np.asarray(
        jax.random.randint(jax.random.key(2), (batch, prompt_len), 0, cfg.vocab)
    )
    t0 = time.time()
    toks = jnp.asarray(prompts[:, 0])
    for pos in range(prompt_len):  # prefill by teacher-forced stepping
        nxt, _, cache = step(params, cache, jnp.asarray(prompts[:, pos]), jnp.int32(pos))
    generated = [np.asarray(nxt)]
    for pos in range(prompt_len, max_seq - 1):
        nxt, _, cache = step(params, cache, jnp.asarray(generated[-1]), jnp.int32(pos))
        generated.append(np.asarray(nxt))
    dt = time.time() - t0
    gen = np.stack(generated, axis=1)
    tput = batch * gen.shape[1] / dt
    print(
        f"{arch}: served batch={batch} prompt={prompt_len} gen={gen.shape[1]} "
        f"in {dt:.2f}s ({tput:.1f} tok/s incl prefill steps)"
    )
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    serve(
        args.arch,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen_len=args.gen_len,
        smoke=not args.full_config,
    )


if __name__ == "__main__":
    main()
