import os
# NOTE: convert-mover/WLICM are disabled as an XLA:CPU workaround — they
# widen remat-saved bf16 stacks to f32 at save time (verified via HLO dumps;
# see EXPERIMENTS.md §Dry-run). Device count MUST be set before jax import.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=convert-mover,while-loop-invariant-code-motion"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell we
``jax.jit(step).lower(**abstract_inputs).compile()`` against the production
mesh (16x16 single pod, and 2x16x16 multi-pod), print memory_analysis() and
cost_analysis(), and derive the roofline terms (launch/roofline.py). The
XLA_FLAGS line above MUST run before any other import — jax locks the device
count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --report results/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes, derive_terms, model_flops_for_cell
from repro.launch.shapes import SHAPES, cell_supported
from repro.launch.steps import jit_for_cell, use_fsdp


def _cell_costs(cfg, shape, mesh):
    """(flops/dev, bytes/dev, collective bytes) for one compiled cell."""
    step_fn, kwargs = jit_for_cell(cfg, shape, mesh)
    with mesh:
        compiled = step_fn.lower(**kwargs).compile()
    cost = compiled.cost_analysis()
    coll = sum(collective_bytes(compiled.as_text()).values())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(coll),
    )


def calibrated_costs(cfg, shape, mesh):
    """XLA cost_analysis counts while-loop bodies ONCE, so the layer scan's
    flops/bytes/collectives are undercounted by the trip count. Calibrate by
    compiling 1-unit and 2-unit variants of the same config (identical width
    and sharding; force_fsdp pins the FSDP decision of the full model) and
    extrapolating linearly: cost(U) = fixed + per_unit * U.
    """
    plen = len(cfg.pattern)
    fsdp = use_fsdp(cfg, mesh)
    # Costing compiles unroll the attention kv scan; cap the block count at 8
    # by enlarging the chunk (identical flops — same math, coarser blocking)
    # so 32k-seq cells don't trace/compile thousands of unrolled ops.
    chunk = max(cfg.attn_chunk, shape.seq_len // 8)
    c1 = dataclasses.replace(cfg, n_layers=plen, force_fsdp=fsdp,
                             unroll_for_costing=True, attn_chunk=chunk)
    c2 = dataclasses.replace(cfg, n_layers=2 * plen, force_fsdp=fsdp,
                             unroll_for_costing=True, attn_chunk=chunk)
    f1 = _cell_costs(c1, shape, mesh)
    f2 = _cell_costs(c2, shape, mesh)
    U = cfg.n_units
    per_unit = tuple(b - a for a, b in zip(f1, f2))
    fixed = tuple(a - d for a, d in zip(f1, per_unit))
    total = tuple(f + d * U for f, d in zip(fixed, per_unit))
    # NOTE: 'fixed' includes embed/head/loss/optimizer-fixed parts from the
    # unrolled 1-unit compile; the full-model compile is only used for
    # memory_analysis and the collective schedule (loop bodies count once
    # there — see EXPERIMENTS.md §Dry-run).
    return {
        "flops_per_device": max(total[0], 0.0),
        "bytes_per_device": max(total[1], 0.0),
        "collective_bytes": max(total[2], 0.0),
        "per_unit": per_unit,
        "fixed": fixed,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             calibrate: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        if verbose:
            print(f"SKIP {arch} x {shape_name} [{mesh_name}]: {reason}")
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skip", "reason": reason,
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(len(mesh.devices.reshape(-1)))
    t0 = time.time()
    step_fn, kwargs = jit_for_cell(cfg, shape, mesh)
    with mesh:
        lowered = step_fn.lower(**kwargs)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    if calibrate:
        cal = calibrated_costs(cfg, shape, mesh)
        cost = dict(cost)
        cost["flops"] = cal["flops_per_device"]
        cost["bytes accessed"] = cal["bytes_per_device"]
        # collective bytes: inject via a synthetic single line is fragile —
        # derive_terms accepts the raw hlo; patch the result after instead.
    terms = derive_terms(
        arch=arch,
        shape_name=shape_name,
        mesh_name=mesh_name,
        chips=chips,
        cost=cost,
        hlo_text=hlo,
        model_flops=model_flops_for_cell(cfg, shape),
        mem_stats=mem,
    )
    if calibrate:
        from repro.launch.roofline import ICI_BW

        terms.collective_bytes_total = int(cal["collective_bytes"])
        terms.collective_s = cal["collective_bytes"] / (ICI_BW * 4.0)
        tvals = {
            "compute": terms.compute_s,
            "memory": terms.memory_s,
            "collective": terms.collective_s,
        }
        terms.dominant = max(tvals, key=tvals.get)
        total_flops = terms.flops_per_device * chips
        terms.useful_flops_ratio = (
            terms.model_flops / total_flops if total_flops else 0.0
        )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "compile_s": round(t1 - t0, 2),
        "memory_analysis": {
            "argument_bytes_per_device": int(mem.argument_size_in_bytes),
            "output_bytes_per_device": int(mem.output_size_in_bytes),
            "temp_bytes_per_device": int(mem.temp_size_in_bytes),
            "alias_bytes_per_device": int(mem.alias_size_in_bytes),
        },
        "roofline": terms.as_dict(),
    }
    if verbose:
        ma = rec["memory_analysis"]
        hbm_gb = (ma["argument_bytes_per_device"] + ma["temp_bytes_per_device"]) / 2**30
        print(
            f"OK   {arch} x {shape_name} [{mesh_name}] "
            f"compile={rec['compile_s']}s  hbm/dev={hbm_gb:.2f}GiB  "
            f"flops/dev={terms.flops_per_device:.3e}  "
            f"coll={terms.collective_bytes_total:.3e}B  dom={terms.dominant}"
        )
        print(f"     memory_analysis: {mem}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh (default 16x16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--report", default=None, help="append JSON records here")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the 1/2-unit trip-count calibration compiles")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    records.append(
                        run_cell(arch, shape, mp, calibrate=not args.no_calibrate)
                    )
                except Exception as e:
                    failures += 1
                    traceback.print_exc()
                    records.append({
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "fail", "error": f"{type(e).__name__}: {e}",
                    })

    if args.report:
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.report):
            with open(args.report) as f:
                existing = json.load(f)
        # replace same-key records
        keyf = lambda r: (r["arch"], r["shape"], r["mesh"])
        merged = {keyf(r): r for r in existing}
        for r in records:
            merged[keyf(r)] = r
        with open(args.report, "w") as f:
            json.dump(list(merged.values()), f, indent=1)
        print(f"wrote {len(records)} records -> {args.report}")

    n_ok = sum(1 for r in records if r["status"] == "ok")
    n_skip = sum(1 for r in records if r["status"] == "skip")
    print(f"\nsummary: {n_ok} ok, {n_skip} skip, {failures} fail")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
