"""Render-serving driver: synthetic Poisson load through the RenderServer.

  PYTHONPATH=src python -m repro.launch.render_serve --requests 32 --rate 60
  PYTHONPATH=src python -m repro.launch.render_serve --backend pallas --devices 2

Generates an open-loop Poisson arrival stream over a mix of scenes and
resolutions (so the bucketer has real work to do), replays it through
queue -> bucketing -> sharded dispatch, and reports per-bucket latency,
throughput, and executable-cache counters. ``--devices N`` on CPU forces N
virtual host devices (XLA flag set BEFORE jax initializes — which is why the
arg parsing below happens before any repro/jax import) so the sharded path
is exercisable on a laptop.

Exits non-zero if any request was lost or p99 is not finite — the CI smoke
in scripts/check.sh relies on this.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--devices", type=int, default=None,
                    help="shard dispatches over N devices (CPU: forces N "
                         "virtual host devices; must run before jax init)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=60.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--scenes", default="train,truck",
                    help="comma-separated scene ids to serve")
    ap.add_argument("--gaussians", type=int, default=1500,
                    help="gaussians per synthetic scene")
    ap.add_argument("--resolutions", default="128x128,192x128",
                    help="comma-separated WxH mix; each request draws one "
                         "(each distinct resolution is its own bucket)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="bucket flush deadline (s)")
    ap.add_argument("--queue-depth", type=int, default=128)
    ap.add_argument("--mode", default="gstg",
                    choices=["gstg", "tile_baseline", "group_baseline"])
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--no-realtime", action="store_true",
                    help="replay arrivals as fast as possible (throughput mode)")
    ap.add_argument("--trace-json", default=None,
                    help="write the full stats summary + per-request records")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def _parse_resolutions(spec: str):
    out = []
    for item in spec.split(","):
        w, h = item.lower().split("x")
        out.append((int(w), int(h)))
    return out


def main(argv=None):
    args = parse_args(argv)

    # Virtual host devices must be configured before jax touches the backend.
    if args.devices and args.devices > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={args.devices}"
            ).strip()

    import jax
    import numpy as np

    from repro.core.camera import orbit_cameras
    from repro.core.gaussians import scene_like_paper
    from repro.core.pipeline import RenderConfig
    from repro.launch.mesh import make_render_mesh
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer, poisson_arrivals

    n_dev = len(jax.devices())
    use_dev = min(args.devices or n_dev, n_dev)
    if args.devices and args.devices > n_dev:
        print(f"warning: requested {args.devices} devices, have {n_dev}")
    mesh = make_render_mesh(use_dev)

    scene_ids = [s.strip() for s in args.scenes.split(",") if s.strip()]
    scenes = {
        sid: scene_like_paper(jax.random.key(i), sid, args.gaussians)
        for i, sid in enumerate(scene_ids)
    }
    cfg = RenderConfig(
        mode=args.mode,
        backend=args.backend,
        group_capacity=args.capacity,
        tile_capacity=args.capacity,
        span=6,
    )

    # Camera pools per resolution: orbit viewpoints, drawn round-robin per
    # request so repeated signatures exercise the executable cache.
    resolutions = _parse_resolutions(args.resolutions)
    pools = {(w, h): orbit_cameras(16, 4.5, w, h) for w, h in resolutions}

    rng = np.random.default_rng(args.seed)
    offsets = poisson_arrivals(args.requests, args.rate, seed=args.seed)
    load = []
    for i, t in enumerate(offsets):
        res = resolutions[rng.integers(len(resolutions))]
        sid = scene_ids[rng.integers(len(scene_ids))]
        cam = pools[res][i % len(pools[res])]
        load.append((t, RenderRequest(i, sid, cam, cfg)))

    server = RenderServer(
        scenes,
        mesh=mesh,
        max_batch=args.max_batch,
        max_wait=args.max_wait,
        queue_depth=args.queue_depth,
    )
    print(f"serving {args.requests} requests @ {args.rate:.0f} req/s "
          f"({len(scene_ids)} scenes x {len(resolutions)} resolutions, "
          f"backend={args.backend}, devices={use_dev})")
    results = server.run(load, realtime=not args.no_realtime)
    print(server.stats.format())

    if args.trace_json:
        trace = {
            "config": vars(args),
            "devices": use_dev,
            **server.stats.summary(),
            "requests": [
                {
                    "request_id": r.request_id,
                    "latency_ms": r.latency_s * 1e3,
                    "batch_size": r.batch_size,
                    "signature": repr(r.signature),
                    "deadline_missed": r.deadline_missed,
                }
                for r in sorted(results.values(), key=lambda r: r.request_id)
            ],
        }
        with open(args.trace_json, "w") as f:
            json.dump(trace, f, indent=2)
        print(f"wrote {args.trace_json}")

    # CI assertions: nothing lost, latency distribution sane.
    lost = args.requests - len(results) - server.stats.rejected
    p99 = server.stats.summary()["p99_ms"]
    ok = lost == 0 and len(results) > 0 and math.isfinite(p99)
    print(f"render_serve: {'OK' if ok else 'FAILED'} "
          f"(completed={len(results)}/{args.requests}, "
          f"rejected={server.stats.rejected}, lost={lost}, p99={p99:.1f}ms)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
