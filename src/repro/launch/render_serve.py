"""Render-serving driver: synthetic Poisson load through the RenderServer.

  PYTHONPATH=src python -m repro.launch.render_serve --requests 32 --rate 60
  PYTHONPATH=src python -m repro.launch.render_serve --backend pallas --devices 2
  PYTHONPATH=src python -m repro.launch.render_serve --devices 2 \
      --scene-shards 2 --parity-check   # gaussian-sharded scenes, DESIGN.md §10

Generates an open-loop Poisson arrival stream over a mix of scenes and
resolutions (so the bucketer has real work to do), replays it through
queue -> bucketing -> committed engine handles (``RenderServer`` is a thin
loop over ``repro.engine.Renderer``s, DESIGN.md §11), and reports per-bucket
latency, throughput, and executable-cache counters. ``--devices N`` on CPU forces N
virtual host devices (XLA flag set BEFORE jax initializes — which is why the
arg parsing below happens before any repro/jax import) so the sharded path
is exercisable on a laptop.

Exits non-zero if any request was lost or p99 is not finite — the CI smoke
in scripts/check.sh relies on this.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--devices", type=int, default=None,
                    help="shard dispatches over N devices (CPU: forces N "
                         "virtual host devices; must run before jax init)")
    ap.add_argument("--scene-shards", type=int, default=1,
                    help="shard the GAUSSIAN axis D ways over the mesh "
                         "'model' axis (DESIGN.md §10); must divide the "
                         "device count to be physically sharded, otherwise "
                         "the shard axis stays logical")
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="simulated per-device HBM cap counting the "
                         "persistent scene parameters (full size "
                         "replicated; 1/D physically sharded) PLUS the "
                         "transient per-camera projected features "
                         "(DESIGN.md §12). A single scene over the cap "
                         "even alone still refuses to serve; scenes that "
                         "fit individually but not TOGETHER page in/out "
                         "LRU through the server's residency manager "
                         "(DESIGN.md §17) — bitwise-invisibly")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the admission-time residency prefetch "
                         "(a queued request's paged-out scene normally "
                         "pages back in before its dispatch)")
    ap.add_argument("--parity-check", action="store_true",
                    help="re-render every completed request on the "
                         "replicated single-camera path and require BITWISE "
                         "identical images (the scene-sharded CI smoke)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=60.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--streams", type=int, default=0,
                    help="serve N interactive camera STREAMS instead of the "
                         "stateless request mix (DESIGN.md §15): each stream "
                         "replays an orbit path in frame order through its "
                         "own stream session (exact-reuse frontend cache + "
                         "speculative pre-binning); frames of different "
                         "streams interleave round-robin in the arrival "
                         "process")
    ap.add_argument("--stream-frames", type=int, default=24,
                    help="frames per stream (orbit poses cycle every 16 "
                         "frames, so longer streams lap into the exact-reuse "
                         "cache)")
    ap.add_argument("--spec-depth", type=int, default=2,
                    help="per-stream speculation queue depth (predictions "
                         "pending beyond it drop oldest-first; 0 disables "
                         "speculation)")
    ap.add_argument("--stream-cache-frames", type=int, default=32,
                    help="per-stream frontend-cache capacity (poses, LRU)")
    ap.add_argument("--scenes", default="train,truck",
                    help="comma-separated scene ids to serve")
    ap.add_argument("--gaussians", type=int, default=1500,
                    help="gaussians per synthetic scene")
    ap.add_argument("--resolutions", default="128x128,192x128",
                    help="comma-separated WxH mix; each request draws one "
                         "(each distinct resolution is its own bucket)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="bucket flush deadline (s)")
    ap.add_argument("--queue-depth", type=int, default=128)
    ap.add_argument("--mode", default="gstg",
                    choices=["gstg", "tile_baseline", "group_baseline"])
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--autotune", action="store_true",
                    help="open every handle with tile_params='auto' "
                         "(DESIGN.md §13): the first dispatch per (scene, "
                         "resolution) pays a tuning sweep — or hits the "
                         "persisted autotune cache — then serves the tuned "
                         "tile/group/capacity")
    ap.add_argument("--no-realtime", action="store_true",
                    help="replay arrivals as fast as possible (throughput mode)")
    ap.add_argument("--trace-json", default=None,
                    help="write a Chrome trace (load in Perfetto / "
                         "chrome://tracing) of every stage/serving/request "
                         "span; the old stats summary + per-request records "
                         "ride along under the top-level 'summary' key")
    ap.add_argument("--metrics-json", default=None,
                    help="write a repro.metrics/v1 snapshot of the process "
                         "metrics registry (serving.* / engine.* counters, "
                         "gauges, latency histograms)")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def _parse_resolutions(spec: str):
    out = []
    for item in spec.split(","):
        w, h = item.lower().split("x")
        out.append((int(w), int(h)))
    return out


def main(argv=None):
    args = parse_args(argv)

    # Virtual host devices must be configured before jax touches the backend.
    if args.devices and args.devices > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={args.devices}"
            ).strip()

    import jax
    import numpy as np

    from repro.core.camera import orbit_cameras
    from repro.core.gaussians import scene_like_paper
    from repro.core.pipeline import RenderConfig
    from repro.launch.mesh import make_render_mesh
    from repro.obs import get_registry, get_tracer, trace_env_enabled
    from repro.serving.queue import RenderRequest
    from repro.serving.server import RenderServer, poisson_arrivals

    # Asking for a trace (or metrics) file opts the process into span
    # recording; REPRO_TRACE=1 additionally turns on fenced per-stage device
    # timing (RenderConfig.timing — a different, per-stage-jit executable;
    # bitwise-identical images, see DESIGN.md §14).
    tracer = get_tracer()
    if args.trace_json or args.metrics_json:
        tracer.enable()
    timing = trace_env_enabled()

    n_dev = len(jax.devices())
    use_dev = min(args.devices or n_dev, n_dev)
    if args.devices and args.devices > n_dev:
        print(f"warning: requested {args.devices} devices, have {n_dev}")
    from repro.launch.mesh import render_mesh_shards

    shards = max(args.scene_shards, 1)
    phys_shards = render_mesh_shards(use_dev, shards)
    if shards > 1 and phys_shards == 1:
        print(f"note: scene_shards={shards} does not divide "
              f"{use_dev} devices; shard axis stays logical")
    mesh = make_render_mesh(use_dev, scene_shards=phys_shards)

    scene_ids = [s.strip() for s in args.scenes.split(",") if s.strip()]
    scenes = {
        sid: scene_like_paper(jax.random.key(i), sid, args.gaussians)
        for i, sid in enumerate(scene_ids)
    }

    cfg = RenderConfig(
        mode=args.mode,
        backend=args.backend,
        group_capacity=args.capacity,
        tile_capacity=args.capacity,
        span=6,
        scene_shards=shards,
        timing=timing,
    )

    # Camera pools per resolution: orbit viewpoints, drawn round-robin per
    # request so repeated signatures exercise the executable cache.
    resolutions = _parse_resolutions(args.resolutions)
    pools = {(w, h): orbit_cameras(16, 4.5, w, h) for w, h in resolutions}

    rng = np.random.default_rng(args.seed)
    if args.streams > 0:
        # Stream mode: N orbiting viewers, frames interleaved round-robin
        # across streams (arrival order preserves per-stream frame order —
        # the property the stream-affinity bucketing relies on).
        total = args.streams * args.stream_frames
        offsets = poisson_arrivals(total, args.rate, seed=args.seed)
        load = []
        i = 0
        for frame in range(args.stream_frames):
            for s in range(args.streams):
                res = resolutions[s % len(resolutions)]
                sid = scene_ids[s % len(scene_ids)]
                cam = pools[res][frame % len(pools[res])]
                load.append((offsets[i], RenderRequest(
                    i, sid, cam, cfg, stream_id=f"s{s}")))
                i += 1
    else:
        total = args.requests
        offsets = poisson_arrivals(total, args.rate, seed=args.seed)
        load = []
        for i, t in enumerate(offsets):
            res = resolutions[rng.integers(len(resolutions))]
            sid = scene_ids[rng.integers(len(scene_ids))]
            cam = pools[res][i % len(pools[res])]
            load.append((t, RenderRequest(i, sid, cam, cfg)))

    server = RenderServer(
        scenes,
        mesh=mesh,
        max_batch=args.max_batch,
        max_wait=args.max_wait,
        queue_depth=args.queue_depth,
        scene_shards=shards,
        device_budget_mb=args.device_budget_mb,
        autotune=args.autotune,
        # Serving tunes on the critical path of the first dispatch, so keep
        # the measured phase short; the cost-model phase still prunes the
        # full default grid.
        autotune_opts={"top_k": 2, "warmup": 1, "reps": 2}
        if args.autotune else None,
        stream_cache_frames=args.stream_cache_frames,
        spec_depth=args.spec_depth,
        prefetch=not args.no_prefetch,
    )

    # Pre-commit every scene through the engine handle (DESIGN.md §11): the
    # simulated device-HBM cap is enforced per scene at commit time — a
    # scene over the budget even ALONE (after shard escalation) fails fast
    # here instead of mid-stream. Scenes that fit individually but not
    # TOGETHER do commit: the server's residency manager pages the cold
    # ones out LRU and back in on demand (DESIGN.md §17).
    for sid in scene_ids:
        try:
            handle = server.commit(sid, cfg)
        except ValueError as e:
            print(f"render_serve: FAILED (scene {sid!r}: {e})")
            server.close()
            return 2
        if args.device_budget_mb is not None:
            hs = handle.stats()
            print(f"scene {sid!r}: "
                  f"{hs['scene_mb_per_device'] + hs['feature_mb_per_device']:.2f}"
                  f" MB/device ({hs['scene_mb_per_device']:.2f} params + "
                  f"{hs['feature_mb_per_device']:.2f} per-camera features, "
                  f"gather={hs['feature_gather']}) within "
                  f"{args.device_budget_mb} MB budget "
                  f"(shards={hs['physical_shards']}, "
                  f"resident={handle.resident})")

    if args.streams > 0:
        print(f"serving {args.streams} streams x {args.stream_frames} frames "
              f"@ {args.rate:.0f} req/s (spec_depth={args.spec_depth}, "
              f"backend={args.backend}, devices={use_dev}, "
              f"scene_shards={shards})")
    else:
        print(f"serving {total} requests @ {args.rate:.0f} req/s "
              f"({len(scene_ids)} scenes x {len(resolutions)} resolutions, "
              f"backend={args.backend}, devices={use_dev}, "
              f"scene_shards={shards})")
    results = server.run(load, realtime=not args.no_realtime)
    print(server.stats.format())
    rs = server.residency.stats()
    print(f"residency: page_ins={rs['page_ins']} "
          f"page_outs={rs['page_outs']} evictions={rs['evictions']} "
          f"hits={rs['hits']} prefetches={rs['prefetches']} "
          f"resident={rs['resident_entries']}/{rs['entries']} "
          f"({rs['resident_mb']:.2f} MB"
          + (f" / {rs['budget_mb']:.2f} MB budget)" if rs["budget_mb"]
             else ", unbudgeted)"))
    if args.streams > 0:
        # Quiesce speculation before any snapshot: in-flight spec runs
        # would otherwise race the trace/metrics dumps below.
        for s in server._streams.values():
            s.wait_spec_idle(timeout=30)
    stream_summaries = server.stream_stats() if args.streams > 0 else {}
    for name, st in sorted(stream_summaries.items()):
        print(f"stream {name}: frames={st['frames']} "
              f"hit_rate={st['hit_rate']:.2f} "
              f"(hits={st['hits']} misses={st['misses']}) "
              f"spec: runs={st['spec_runs']} hits={st['spec_hits']} "
              f"dropped={st['spec_dropped']} discarded={st['spec_discarded']}")
    if args.autotune:
        for (sid, _), handle in sorted(
            server._renderers.items(), key=lambda kv: kv[0][0]
        ):
            print(f"autotuned {sid!r}: tile_params={handle.tile_params}")

    parity_failures = 0
    if args.parity_check:
        import dataclasses as _dc

        from repro import engine
        from repro.serving.bucketing import padded_size
        from repro.sharding.policies import data_extent

        # Compare through the SAME padded dispatch shape the server compiles
        # (pad_to=max_batch over the same mesh) — only the gaussian layout
        # differs, which is exactly the invariant under test. (Eager render()
        # or an unpadded B=1 batch is NOT the reference: a differently-shaped
        # program may fuse differently, moving fp rounding by ~1 ulp for
        # sharded and replicated alike.)
        cfg_repl = _dc.replace(cfg, scene_shards=1)
        pad_shape = padded_size(args.max_batch, data_extent(mesh))
        by_id = {r.request_id: r for _, r in load}
        refs = {
            sid: engine.open(scenes[sid], cfg_repl, mesh=mesh)
            for sid in scene_ids
        }
        for rid, res in sorted(results.items()):
            req = by_id[rid]
            if getattr(req, "stream_id", None) is not None:
                # Stream frames ran the single-camera split path; their
                # stateless reference is the single-camera fused program
                # (bitwise-identical by the §15 invariant) — NOT the padded
                # batch program, whose different shape may fuse differently.
                expect = np.asarray(refs[req.scene_id].render(req.camera).image)
            else:
                expect = np.asarray(
                    refs[req.scene_id]
                    .render_batch([req.camera], pad_to=pad_shape)
                    .image[0]
                )
            if not (expect == res.image).all():
                parity_failures += 1
                print(f"parity MISMATCH: request {rid} (scene "
                      f"{req.scene_id!r}) diverges from the "
                      f"{'stateless' if req.stream_id else 'replicated'} path")
        for ref in refs.values():
            ref.close()
        print(f"parity-check: {len(results) - parity_failures}/{len(results)} "
              f"bitwise-identical to the replicated path")

    if args.trace_json:
        # Chrome trace-event format (repro.trace/v1): traceEvents carry the
        # stage/serving/request spans; the pre-existing stats summary and
        # per-request records ride under "summary" (Perfetto ignores unknown
        # top-level keys, old consumers read doc["summary"]).
        doc = tracer.chrome_trace()
        doc["summary"] = {
            "config": vars(args),
            "devices": use_dev,
            **server.stats.summary(),
            "requests": [
                {
                    "request_id": r.request_id,
                    "latency_ms": r.latency_s * 1e3,
                    "batch_size": r.batch_size,
                    "signature": repr(r.signature),
                    "deadline_missed": r.deadline_missed,
                }
                for r in sorted(results.values(), key=lambda r: r.request_id)
            ],
        }
        with open(args.trace_json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.trace_json} "
              f"({len(doc['traceEvents'])} events, {doc['dropped']} dropped)")

    if args.metrics_json:
        # Snapshot BEFORE close(): Renderer.close() drops its per-handle
        # engine.<name>.* gauges, and the traced smoke validator cross-checks
        # them against the trace.
        with open(args.metrics_json, "w") as f:
            json.dump(get_registry().snapshot(), f, indent=2)
        print(f"wrote {args.metrics_json}")

    server.close()   # releases every committed handle (jit caches + layouts)

    # CI assertions: nothing lost, latency distribution sane, parity holds.
    lost = total - len(results) - server.stats.rejected
    p99 = server.stats.summary()["p99_ms"]
    ok = (
        lost == 0 and len(results) > 0 and math.isfinite(p99)
        and parity_failures == 0
    )
    # Stream smokes must actually exercise reuse: a stream run whose
    # sessions never hit the exact-reuse cache (hit_rate 0 with laps in the
    # load) would silently stop testing the tentpole.
    if args.streams > 0 and args.stream_frames > 16:
        hits = sum(st["hits"] for st in stream_summaries.values())
        if hits == 0:
            ok = False
            print("render_serve: stream load lapped its orbit but recorded "
                  "0 exact-reuse hits")
    print(f"render_serve: {'OK' if ok else 'FAILED'} "
          f"(completed={len(results)}/{total}, "
          f"rejected={server.stats.rejected}, lost={lost}, p99={p99:.1f}ms, "
          f"parity_failures={parity_failures})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
