"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the assignment:
    compute    = HLO_FLOPs / (chips * peak_FLOPs)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` reports PER-DEVICE flops/bytes (verified against
a hand-checked matmul), so the chip division is already applied there; we
document both conventions in the emitted record. Collective bytes are parsed
from the optimized post-SPMD HLO text: the result-shape bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

# --- TPU v5e hardware constants (per assignment) ---
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_TYPE_RE = re.compile(r"(pred|[a-z]+\d+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind over the whole module.

    -start/-done pairs are counted once (only -start carries the payload
    type on its result tuple; -done lines are skipped).
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        tstr = m.group(1) or m.group(2) or ""
        out[kind] = out.get(kind, 0) + _type_bytes(tstr)
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_total: int
    collective_by_kind: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float               # 6*N*D (or 6*N_active*D) global
    useful_flops_ratio: float        # model_flops / (flops_per_device*chips)
    dominant: str
    arg_bytes_per_device: int = 0
    temp_bytes_per_device: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


def derive_terms(
    *,
    arch: str,
    shape_name: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    model_flops: float,
    mem_stats=None,
    links_per_chip: float = 4.0,
) -> RooflineTerms:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    colls = collective_bytes(hlo_text)
    coll_total = sum(colls.values())

    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    # collective bytes parsed from the (per-device) module; each chip drives
    # links_per_chip ICI links concurrently on a 2D torus axis.
    collective_s = coll_total / (ICI_BW * links_per_chip)

    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    total_flops = flops_dev * chips
    ratio = model_flops / total_flops if total_flops else 0.0

    arg_b = temp_b = 0
    if mem_stats is not None:
        arg_b = int(mem_stats.argument_size_in_bytes)
        temp_b = int(mem_stats.temp_size_in_bytes)

    return RooflineTerms(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_total=coll_total,
        collective_by_kind=colls,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops=model_flops,
        useful_flops_ratio=ratio,
        dominant=dominant,
        arg_bytes_per_device=arg_b,
        temp_bytes_per_device=temp_b,
    )


def model_flops_for_cell(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D forward-only (N = active params,
    D = tokens processed)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
