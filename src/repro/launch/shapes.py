"""The assigned input-shape cells + per-arch support rules (DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_supported(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """(supported, reason-if-not). The skip rules from the assignment:
    encoder-only archs have no decode step; long_500k needs sub-quadratic
    sequence mixing (SSM/hybrid only)."""
    if cfg.family == "encoder" and shape.kind == "decode":
        return False, "encoder-only arch: no decode step"
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic mixing"
    return True, ""


def all_cells():
    from repro.configs import ARCHS

    for arch in ARCHS:
        for shape in SHAPES.values():
            yield arch, shape
