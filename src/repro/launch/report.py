"""Render the §Roofline per-cell table from the dry-run report JSON.

  PYTHONPATH=src python -m repro.launch.report [--report results/dryrun_final.json]
      [--append EXPERIMENTS.md]
"""
from __future__ import annotations

import argparse
import json


def fmt_table(records) -> str:
    ok = sorted(
        (r for r in records if r["status"] == "ok"),
        key=lambda r: (r["arch"], r["shape"], r["mesh"]),
    )
    skip = [r for r in records if r["status"] == "skip"]
    lines = [
        "| arch | shape | mesh | HBM GiB/dev | compute_s | memory_s | coll_s |"
        " dominant | useful |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in ok:
        ma, rf = r["memory_analysis"], r["roofline"]
        hbm = (
            ma["argument_bytes_per_device"] + ma["temp_bytes_per_device"]
        ) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {hbm:.1f} "
            f"| {rf['compute_s']:.3g} | {rf['memory_s']:.3g} "
            f"| {rf['collective_s']:.3g} | {rf['dominant']} "
            f"| {rf['useful_flops_ratio']:.2f} |"
        )
    lines.append("")
    lines.append(
        f"Skipped cells ({len(skip)}): "
        + "; ".join(
            sorted({f"{r['arch']} x {r['shape']} ({r['reason']})" for r in skip})
        )
    )
    # dominant-term histogram + bottleneck sentences
    doms = {}
    for r in ok:
        doms.setdefault(r["roofline"]["dominant"], []).append(r)
    lines.append("")
    for d, rs in sorted(doms.items()):
        lines.append(f"* **{d}-dominated**: {len(rs)} cells.")
    lines.append(
        "\nPer-cell 'what moves the dominant term': memory-dominated cells "
        "need coarser fusion / fewer materialized intermediates (the HLO "
        "bytes figure is a CPU upper bound — see §Dry-run artifacts); "
        "collective-dominated cells need the FSDP gather and MoE all-to-all "
        "reductions applied in §Perf; compute-dominated cells track "
        "MODEL_FLOPS x remat within 2x."
    )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="results/dryrun_final.json")
    ap.add_argument("--fallback", default=None,
                    help="fill cells missing from --report (e.g. an "
                         "uncalibrated sweep); such rows are marked *")
    ap.add_argument("--append", default=None)
    args = ap.parse_args()
    with open(args.report) as f:
        records = json.load(f)
    if args.fallback:
        have = {(r["arch"], r["shape"], r["mesh"]) for r in records}
        with open(args.fallback) as f:
            for r in json.load(f):
                key = (r["arch"], r["shape"], r["mesh"])
                if key not in have:
                    r["arch"] = r["arch"] + "*"  # * = uncalibrated fallback
                    records.append(r)
    table = fmt_table(records)
    print(table)
    if args.append:
        with open(args.append, "a") as f:
            f.write("\n## §Roofline — per-cell baseline table (final sweep)\n\n")
            f.write(table + "\n")


if __name__ == "__main__":
    main()
