"""Gateway fleet driver: Poisson load over N render workers + chaos hook.

  PYTHONPATH=src python -m repro.launch.render_gateway --workers 2 \
      --devices-per-worker 2 --requests 24 --kill-worker auto --kill-after 4

Spawns a worker fleet — subprocess children by default (each with its OWN
jax runtime and virtual-device set, speaking line-JSON over pipes), or
in-process with ``--inproc`` — fronted by a :class:`RenderGateway`
(admission, scene-affinity + stream-sticky routing, heartbeats, failover;
DESIGN.md §16), replays a Poisson arrival stream through it, and reports
fleet-level latency/routing/failover stats. ``--kill-worker/--kill-after``
is the chaos hook: the named worker is SIGKILLed (subprocess) or
flag-killed (inproc) mid-load and the run must still complete every
request — the CI smoke in scripts/check.sh gates on exactly that, plus
``--parity-check`` proving failover is invisible in the pixels.

Exits non-zero if any request was lost, p99 is not finite, parity fails,
or an induced kill produced no failover.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--devices-per-worker", type=int, default=1,
                    help="virtual host devices per worker (each subprocess "
                         "worker forces this count in its own runtime; "
                         "inproc workers share one runtime of this size)")
    ap.add_argument("--inproc", action="store_true",
                    help="in-process workers (one shared jax runtime) "
                         "instead of subprocess children")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--scenes", default="train,truck")
    ap.add_argument("--gaussians", type=int, default=1500)
    ap.add_argument("--scene-shards", type=int, default=1)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=60.0)
    ap.add_argument("--streams", type=int, default=0,
                    help="serve N camera streams (stream_id-sticky routing) "
                         "instead of the stateless mix")
    ap.add_argument("--stream-frames", type=int, default=16)
    ap.add_argument("--resolutions", default="96x96")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-wait", type=float, default=0.05)
    ap.add_argument("--queue-depth", type=int, default=256)
    ap.add_argument("--worker-queue-depth", type=int, default=128)
    ap.add_argument("--mode", default="gstg",
                    choices=["gstg", "tile_baseline", "group_baseline"])
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="per-device HBM cap forwarded to EVERY worker: "
                         "each worker's RenderServer pages its committed "
                         "scenes in/out LRU against this budget "
                         "(DESIGN.md §17), and the gateway's router "
                         "prefers workers holding the request's scene "
                         "resident")
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--heartbeat-timeout", type=float, default=30.0)
    ap.add_argument("--kill-worker", default=None,
                    help="worker id to kill mid-load ('auto' = first)")
    ap.add_argument("--kill-after", type=int, default=None,
                    help="kill once this many requests completed")
    ap.add_argument("--parity-check", action="store_true",
                    help="re-render every completed request on a direct "
                         "single-server handle and require BITWISE identical "
                         "images (failover must be invisible in the pixels)")
    ap.add_argument("--no-realtime", action="store_true")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the per-worker warmup dispatch (first real "
                         "dispatch then pays jit compile under heartbeat "
                         "timing)")
    ap.add_argument("--trace-json", default=None)
    ap.add_argument("--metrics-json", default=None)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def _parse_resolutions(spec: str):
    out = []
    for item in spec.split(","):
        w, h = item.lower().split("x")
        out.append((int(w), int(h)))
    return out


def main(argv=None):
    args = parse_args(argv)

    # The parent runtime sizes itself like ONE worker: subprocess children
    # inherit XLA_FLAGS (same virtual-device count in their own runtimes),
    # and the parity reference must render over the same mesh extent.
    dpw = max(args.devices_per_worker, 1)
    if dpw > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={dpw}"
            ).strip()

    import jax
    import numpy as np

    from repro.core.camera import orbit_cameras
    from repro.core.gaussians import scene_like_paper
    from repro.core.pipeline import RenderConfig
    from repro.gateway import RenderGateway
    from repro.launch.mesh import make_render_mesh, render_mesh_shards
    from repro.obs import get_registry, get_tracer
    from repro.serving.queue import RenderRequest
    from repro.serving.server import poisson_arrivals

    tracer = get_tracer()
    if args.trace_json or args.metrics_json:
        tracer.enable()

    scene_ids = [s.strip() for s in args.scenes.split(",") if s.strip()]
    shards = max(args.scene_shards, 1)
    cfg = RenderConfig(
        mode=args.mode,
        backend=args.backend,
        group_capacity=args.capacity,
        tile_capacity=args.capacity,
        span=6,
        scene_shards=shards,
    )

    # -- fleet ----------------------------------------------------------------
    worker_ids = [f"w{i}" for i in range(max(args.workers, 1))]
    if args.inproc:
        from repro.gateway.worker import InprocWorker

        n_dev = len(jax.devices())
        use_dev = min(dpw, n_dev)
        mesh = make_render_mesh(use_dev, render_mesh_shards(use_dev, shards))
        scenes = {
            sid: scene_like_paper(jax.random.key(i), sid, args.gaussians)
            for i, sid in enumerate(scene_ids)
        }
        workers = [
            InprocWorker(
                wid, scenes, mesh=mesh,
                max_batch=args.max_batch, max_wait=args.max_wait,
                queue_depth=args.worker_queue_depth, scene_shards=shards,
                device_budget_mb=args.device_budget_mb,
            )
            for wid in worker_ids
        ]
    else:
        from repro.gateway.transport import SubprocessWorker, worker_argv

        specs = [f"{sid}:{i}" for i, sid in enumerate(scene_ids)]
        extra = [
            "--gaussians", str(args.gaussians),
            "--scene-shards", str(shards),
            "--max-batch", str(args.max_batch),
            "--max-wait", str(args.max_wait),
            "--queue-depth", str(args.worker_queue_depth),
            "--mode", args.mode,
            "--backend", args.backend,
            "--capacity", str(args.capacity),
        ]
        if args.device_budget_mb is not None:
            extra += ["--device-budget-mb", str(args.device_budget_mb)]
        print(f"spawning {len(worker_ids)} workers x {dpw} devices ...")
        workers = [
            SubprocessWorker(
                wid, scene_ids,
                worker_argv(wid, specs, devices=dpw, extra=extra),
                max_batch=args.max_batch,
            )
            for wid in worker_ids
        ]

    gw = RenderGateway(
        workers,
        queue_depth=args.queue_depth,
        max_retries=args.max_retries,
        heartbeat_timeout_s=args.heartbeat_timeout,
        devices_per_worker=dpw,
    )

    # Pre-commit scenes round-robin (worker i gets scene i, i+N, ...): the
    # affinity signal the router prefers — and warm every worker's compiled
    # program per (scene, resolution) signature so heartbeat timing sees
    # steady-state dispatches, not jit compiles.
    resolutions = _parse_resolutions(args.resolutions)
    pools = {(w, h): orbit_cameras(16, 4.5, w, h) for w, h in resolutions}
    for i, sid in enumerate(scene_ids):
        workers[i % len(workers)].commit(sid, cfg)
    if not args.no_warmup:
        warm_id = -1
        for w in workers:
            for sid in scene_ids:
                for res in resolutions:
                    w.dispatch([RenderRequest(
                        warm_id, sid, pools[res][0], cfg)])
                    warm_id -= 1

    # -- load -----------------------------------------------------------------
    rng = np.random.default_rng(args.seed)
    if args.streams > 0:
        total = args.streams * args.stream_frames
        offsets = poisson_arrivals(total, args.rate, seed=args.seed)
        load, i = [], 0
        for frame in range(args.stream_frames):
            for s in range(args.streams):
                res = resolutions[s % len(resolutions)]
                sid = scene_ids[s % len(scene_ids)]
                cam = pools[res][frame % len(pools[res])]
                load.append((offsets[i], RenderRequest(
                    i, sid, cam, cfg, stream_id=f"s{s}")))
                i += 1
    else:
        total = args.requests
        offsets = poisson_arrivals(total, args.rate, seed=args.seed)
        load = []
        for i, t in enumerate(offsets):
            res = resolutions[rng.integers(len(resolutions))]
            sid = scene_ids[rng.integers(len(scene_ids))]
            cam = pools[res][i % len(pools[res])]
            load.append((t, RenderRequest(i, sid, cam, cfg)))

    kill_worker = args.kill_worker
    if kill_worker == "auto":
        kill_worker = worker_ids[0]
    print(f"gateway: {total} requests @ {args.rate:.0f} req/s over "
          f"{len(workers)} workers ({'inproc' if args.inproc else 'subproc'}"
          f", {dpw} devices each"
          + (f", killing {kill_worker} after {args.kill_after}"
             if kill_worker else "") + ")")
    results = gw.run(
        load,
        realtime=not args.no_realtime,
        kill_worker=kill_worker,
        kill_after=args.kill_after if kill_worker else None,
    )
    summary = gw.summary()
    print(gw.format())
    if args.device_budget_mb is not None:
        # Residency roll call: cached on the parent (subprocess replies
        # piggyback the set), a server property for inproc — no RPC, safe
        # even for a killed worker.
        for w in workers:
            try:
                resident = sorted(w.resident_scene_ids())
            except Exception:       # noqa: BLE001 — reporting only
                resident = ["?"]
            print(f"worker {w.worker_id}: "
                  f"resident={','.join(resident) or '-'} / "
                  f"committed={','.join(sorted(w.committed_scene_ids()))}")

    # -- parity ---------------------------------------------------------------
    parity_failures = 0
    if args.parity_check:
        import dataclasses as _dc

        from repro import engine
        from repro.serving.bucketing import padded_size
        from repro.sharding.policies import data_extent

        n_dev = len(jax.devices())
        use_dev = min(dpw, n_dev)
        mesh = make_render_mesh(use_dev, render_mesh_shards(use_dev, shards))
        ref_scenes = {
            sid: scene_like_paper(jax.random.key(i), sid, args.gaussians)
            for i, sid in enumerate(scene_ids)
        }
        cfg_repl = _dc.replace(cfg, scene_shards=1)
        pad = padded_size(args.max_batch, data_extent(mesh))
        by_id = {r.request_id: r for _, r in load}
        refs = {
            sid: engine.open(ref_scenes[sid], cfg_repl, mesh=mesh)
            for sid in scene_ids
        }
        for rid, res in sorted(results.items()):
            req = by_id[rid]
            expect = np.asarray(
                refs[req.scene_id]
                .render_batch([req.camera], pad_to=pad)
                .image[0]
            )
            if not (expect == np.asarray(res.image)).all():
                parity_failures += 1
                print(f"parity MISMATCH: request {rid} "
                      f"(worker {res.worker_id}, attempts {res.attempts})")
        for ref in refs.values():
            ref.close()
        retried = sum(1 for r in results.values() if r.attempts > 1)
        print(f"parity-check: {len(results) - parity_failures}/"
              f"{len(results)} bitwise-identical to the direct handle "
              f"({retried} of them failover retries)")

    if args.trace_json:
        doc = tracer.chrome_trace()
        doc["summary"] = {
            "config": vars(args),
            **summary,
            "requests": [
                {
                    "request_id": r.request_id,
                    "latency_ms": r.latency_s * 1e3,
                    "worker_id": r.worker_id,
                    "attempts": r.attempts,
                }
                for r in sorted(results.values(), key=lambda r: r.request_id)
            ],
        }
        with open(args.trace_json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.trace_json} "
              f"({len(doc['traceEvents'])} events, {doc['dropped']} dropped)")

    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(get_registry().snapshot(), f, indent=2)
        print(f"wrote {args.metrics_json}")

    gw.close()

    # CI assertions: nothing lost, latency sane, parity holds, and an
    # induced kill must actually have exercised failover.
    lost = total - len(results) - summary["rejected"] - summary["failed"]
    p99 = summary["p99_ms"]
    ok = (
        lost == 0
        and summary["failed"] == 0
        and len(results) > 0
        and math.isfinite(p99)
        and parity_failures == 0
        and (kill_worker is None or summary["failovers"] >= 1)
    )
    print(f"render_gateway: {'OK' if ok else 'FAILED'} "
          f"(completed={len(results)}/{total}, "
          f"rejected={summary['rejected']}, failed={summary['failed']}, "
          f"lost={lost}, retries={summary['retries']}, "
          f"failovers={summary['failovers']}, p99={p99:.1f}ms)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
