"""LM training driver: data pipeline -> sharded train step -> checkpoints.

On this CPU container it runs reduced configs end-to-end (examples/train_lm.py);
on a TPU fleet the same driver runs the production mesh — the only difference
is the mesh construction and per-host data slicing (both isolated here).

Fault-tolerance wiring: async checkpoints every --ckpt-every steps with
integrity hashes; on restart the latest checkpoint restores (params, opt,
step) and the counter-based TokenStream regenerates the exact batch sequence.
A HeartbeatMonitor hook flags stragglers (single-host here: illustrative).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import TokenStream, make_batch
from repro.ft import HeartbeatMonitor
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step, pick_optimizer
from repro.models import build_param_spec
from repro.models.spec import init_from_spec
from repro.optim import adafactor_init, adamw_init


def train(
    arch: str,
    steps: int = 100,
    batch: int = 4,
    seq: int = 128,
    lr: float = 3e-3,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    smoke: bool = True,
    seed: int = 0,
    log_every: int = 10,
):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = None  # single host; make_production_mesh() on a real fleet
    step_fn, optname = make_train_step(cfg, mesh, lr=lr, total_steps=steps)
    step_fn = jax.jit(step_fn)

    params = init_from_spec(build_param_spec(cfg), jax.random.key(seed))
    opt_state = (
        adamw_init(params) if optname == "adamw" else adafactor_init(params)
    )
    stream = TokenStream(cfg.vocab, batch, seq, seed=seed)

    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3)
        if mgr.all_steps():
            leaves, manifest = mgr.restore()
            tree = {"params": params, "opt": opt_state}
            restored = jax.tree.unflatten(
                jax.tree.structure(tree), [jnp.asarray(x) for x in leaves]
            )
            params, opt_state = restored["params"], restored["opt"]
            start = manifest["step"]
            print(f"resumed from step {start}")

    monitor = HeartbeatMonitor(n_hosts=1)
    history = []
    for i in range(start, steps):
        t0 = time.time()
        np_batch = make_batch(
            stream, i, cfg.frontend, cfg.n_frontend_tokens, cfg.d_model
        )
        jbatch = {k: jnp.asarray(v) for k, v in np_batch.items()}
        params, opt_state, metrics = step_fn(
            params, opt_state, jbatch, jnp.int32(i)
        )
        dt = time.time() - t0
        monitor.report(0, i, dt, now_s=time.time())
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m, "s_per_step": dt})
            print(
                f"step {i:5d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                f"gnorm={m['grad_norm']:.3f} ({dt:.2f}s)"
            )
        if mgr and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt_state})
    if mgr:
        mgr.wait()
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    train(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        ckpt_dir=args.ckpt_dir,
        smoke=not args.full_config,
    )


if __name__ == "__main__":
    main()
