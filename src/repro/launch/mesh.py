"""Production mesh builders.

Functions, not module-level constants, so importing never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """axis_types=Auto on jax versions that have it; {} on older releases
    (pre-AxisType jax treats every mesh axis as Auto already)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod mesh: (data=16, model=16); multi-pod adds a pure-DP 'pod'
    axis across the DCI: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"), **_axis_type_kwargs(2)
    )


def make_render_mesh(devices: int | None = None):
    """1-D ('data',) mesh for camera-batch sharding (serving/sharded.py).

    Rendering is embarrassingly parallel over the camera axis, so the render
    serving tier uses a pure-DP mesh: ``devices=None`` takes every local
    device (the single-host serving deployment); an explicit count takes a
    prefix (tests pin 1)."""
    n = len(jax.devices()) if devices is None else devices
    if n <= 0:
        raise ValueError(f"render mesh needs >= 1 device, got {n}")
    return jax.make_mesh((n,), ("data",), **_axis_type_kwargs(1))
