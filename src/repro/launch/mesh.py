"""Production mesh builders.

Functions, not module-level constants, so importing never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """axis_types=Auto on jax versions that have it; {} on older releases
    (pre-AxisType jax treats every mesh axis as Auto already)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod mesh: (data=16, model=16); multi-pod adds a pure-DP 'pod'
    axis across the DCI: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"), **_axis_type_kwargs(2)
    )


def render_mesh_shards(n_devices: int, scene_shards: int) -> int:
    """The physical shard count a render mesh over ``n_devices`` can realize:
    ``scene_shards`` when it divides the device count, else 1 (the shard axis
    stays logical — correct results, no per-device memory saving). THE single
    fallback policy: serving/sharded.py, serving/server.py,
    launch/render_serve.py and the benchmarks all route through it."""
    if scene_shards > 1 and n_devices % scene_shards == 0:
        return scene_shards
    return 1


def make_render_mesh(devices: int | None = None, scene_shards: int = 1):
    """Render-serving mesh (serving/sharded.py).

    ``scene_shards == 1``: the classic 1-D ('data',) pure-DP mesh — rendering
    is embarrassingly parallel over the camera axis. ``scene_shards = D > 1``:
    a 2-D ('data', 'model') mesh laying cameras over 'data' and the gaussian
    shard axis of a ShardedScene over 'model' (DESIGN.md §10) — each device
    holds one camera slice x one scene shard, which is what lets a scene
    larger than a single device's replicated budget render at all.

    ``devices=None`` takes every local device (the single-host serving
    deployment); an explicit count takes a prefix (tests pin 1)."""
    n = len(jax.devices()) if devices is None else devices
    if n <= 0:
        raise ValueError(f"render mesh needs >= 1 device, got {n}")
    if scene_shards <= 1:
        return jax.make_mesh((n,), ("data",), **_axis_type_kwargs(1))
    if n % scene_shards:
        raise ValueError(
            f"scene_shards={scene_shards} must divide the device count {n}"
        )
    return jax.make_mesh(
        (n // scene_shards, scene_shards), ("data", "model"),
        **_axis_type_kwargs(2),
    )
