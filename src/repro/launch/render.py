"""GS-TG rendering driver: render paper scenes (synthetic stand-ins) with the
tile-grouping pipeline, report stats + cost-model projections.

  PYTHONPATH=src python -m repro.launch.render --scene train --mode gstg
  PYTHONPATH=src python -m repro.launch.render --scene train --backend pallas
  repro-render --scene train --mode gstg          # console-script entry

Either backend goes through the SAME session-style engine handle
(``repro.engine.open``, DESIGN.md §11): the scene is committed once, the
render is jit-cached per camera geometry, and one render produces both the
image and the RenderStats that feed the accelerator cost model. This is the
CI engine-handle smoke for both backends (scripts/check.sh).
"""
from __future__ import annotations

import argparse
import os
import time
from collections import defaultdict

import numpy as np

from benchmarks.common import scene_and_camera
from repro import engine
from repro.core.cost_model import GSTG_ASIC, estimate
from repro.core.pipeline import RenderConfig, render_cache_info
from repro.obs import get_tracer, trace_env_enabled


def _stage_table(events) -> str:
    """Per-stage device-time table from the tracer's ``category == "stage"``
    spans (ms, aggregated by span name over however many renders ran)."""
    agg = defaultdict(lambda: [0, 0.0])
    for e in events:
        if e.category == "stage":
            agg[e.name][0] += 1
            agg[e.name][1] += e.duration_s
    if not agg:
        return "  (no stage spans recorded)"
    lines = [f"  {'stage':<18s} {'calls':>5s} {'total ms':>9s} {'mean ms':>9s}"]
    for name, (calls, tot) in sorted(agg.items(), key=lambda kv: -kv[1][1]):
        lines.append(f"  {name:<18s} {calls:>5d} {tot * 1e3:>9.3f} "
                     f"{tot * 1e3 / calls:>9.3f}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="train")
    ap.add_argument("--mode", default="gstg",
                    choices=["gstg", "tile_baseline", "group_baseline"])
    ap.add_argument("--tile", type=int, default=16)
    ap.add_argument("--group", type=int, default=64)
    ap.add_argument("--boundary-group", default="ellipse")
    ap.add_argument("--boundary-tile", default="ellipse")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="stage implementation the engine dispatches to")
    ap.add_argument("--use-kernels", action="store_true",
                    help="deprecated alias for --backend pallas")
    ap.add_argument("--scene-shards", type=int, default=1,
                    help="commit the scene gaussian-sharded D ways "
                         "(DESIGN.md §10/§11)")
    ap.add_argument("--gaussians", type=int, default=None)
    ap.add_argument("--width", type=int, default=None,
                    help="override camera width (smoke renders)")
    ap.add_argument("--height", type=int, default=None,
                    help="override camera height (smoke renders)")
    ap.add_argument("--capacity", type=int, default=1024,
                    help="group/tile table capacity")
    ap.add_argument("--autotune", action="store_true",
                    help="ignore --tile/--group/--capacity and open the "
                         "handle with tile_params='auto' (DESIGN.md §13): "
                         "the first render pays the tuning sweep — or hits "
                         "the persisted autotune cache — and commits the "
                         "tuned knobs")
    ap.add_argument("--stats", action="store_true",
                    help="print executable-cache statistics after the render "
                         "(+ a per-stage device-time table when timing is on "
                         "via REPRO_TRACE=1 or --trace-json)")
    ap.add_argument("--trace-json", default=None,
                    help="write a Chrome trace (Perfetto-loadable) of the "
                         "measured render's per-stage device spans; implies "
                         "fenced per-stage timing (DESIGN.md §14)")
    args = ap.parse_args()

    backend = "pallas" if args.use_kernels else args.backend
    # Fenced per-stage timing: each backend stage becomes its own jit'd
    # program with a block_until_ready fence (bitwise-identical image; the
    # fences serialize stages, so the end-to-end walltime is NOT the headline
    # number while timing is on).
    timing = trace_env_enabled() or bool(args.trace_json)
    tracer = get_tracer()
    if timing:
        tracer.enable()
    scene, cam = scene_and_camera(
        args.scene, args.gaussians, width=args.width, height=args.height
    )
    cfg = RenderConfig(
        mode=args.mode,
        tile=args.tile,
        group=args.group,
        boundary_group=args.boundary_group,
        boundary_tile=args.boundary_tile,
        tile_capacity=args.capacity,
        group_capacity=args.capacity,
        span=6,
        backend=backend,
        scene_shards=args.scene_shards,
        timing=timing,
    )
    with engine.open(
        scene, cfg, tile_params="auto" if args.autotune else None
    ) as renderer:
        if timing:
            # Warm render pays the per-stage compiles; clear its spans so the
            # measured render's table/trace shows steady-state device time.
            renderer.render(cam)
            tracer.clear()
        t0 = time.time()
        out = renderer.render(cam)   # ONE render: image + stats, any backend
        img, stats = np.asarray(out.image), out.stats
        dt = time.time() - t0

        print(f"scene={args.scene} mode={args.mode} backend={backend} "
              f"{img.shape} in {dt:.2f}s"
              + (f" tile_params={renderer.tile_params}"
                 if args.autotune else ""))
        print(f"  visible gaussians : {int(stats.n_visible)}")
        print(f"  sort keys         : {int(stats.n_pairs_sort)}")
        print(f"  alpha ops         : {int(stats.alpha_ops)}")
        print(f"  overflow          : {int(stats.overflow)}")
        cost = estimate(
            stats, GSTG_ASIC,
            boundary_group=args.boundary_group,
            boundary_tile=args.boundary_tile,
            mode=args.mode, execution="asic",
        )
        print(f"  accelerator model : total={cost.total_s*1e3:.3f}ms "
              f"(pre={cost.preprocess_s*1e3:.3f} sort={cost.sort_s*1e3:.3f} "
              f"bgm={cost.bitmask_s*1e3:.3f} raster={cost.raster_s*1e3:.3f} "
              f"dram={cost.dram_s*1e3:.3f})  energy={cost.energy_j*1e3:.2f}mJ")
        if args.stats:
            for kind, info in render_cache_info().items():
                print(f"  jit cache [{kind:6s}] : hits={info['hits']} "
                      f"misses={info['misses']} currsize={info['currsize']}/"
                      f"{info['maxsize']}")
            if timing:
                print("  per-stage device time (fenced, steady-state):")
                print(_stage_table(tracer.events()))
        if args.trace_json:
            os.makedirs(os.path.dirname(args.trace_json) or ".", exist_ok=True)
            tracer.write_chrome_trace(args.trace_json)
            print(f"  wrote {args.trace_json}")
    # save a PPM for quick eyeballing (no image deps offline)
    out_path = f"results/render_{args.scene}_{args.mode}_{backend}.ppm"
    os.makedirs("results", exist_ok=True)
    with open(out_path, "wb") as f:
        h, w, _ = img.shape
        f.write(f"P6\n{w} {h}\n255\n".encode())
        f.write((np.clip(img, 0, 1) * 255).astype(np.uint8).tobytes())
    print(f"  wrote {out_path}")


if __name__ == "__main__":
    main()
