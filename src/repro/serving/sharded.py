"""Device-sharded batched rendering: cameras x gaussians over a render mesh.

``render_batch_sharded`` is a drop-in superset of ``core.pipeline.
render_batch``: same arguments plus an optional mesh, same ``RenderResult``
(image ``(B, H, W, 3)``, stats ``(B,)``). Two sharding dimensions compose
(DESIGN.md §9/§10):

  * the CAMERA batch axis lays over the mesh's 'data' axis
    (``camera_batch_pspec``) — embarrassingly parallel, scales with traffic;
  * the GAUSSIAN axis lays over the mesh's 'model' axis when
    ``cfg.scene_shards > 1``: the scene is put in the canonical padded/
    sharded layout (``sharding/scene.py``) and device_put with
    ``scene_shard_pspec``, so each device holds 1/D of the scene — the
    engine's per-shard frontend + stable merge keeps results
    bitwise-identical to the replicated path, and scenes beyond one
    device's replicated HBM budget become servable.

XLA partitions the vmapped renderer by propagating the input shardings — no
renderer changes, the SAME lru-cached executable wrapper from
core/pipeline.py serves replicated and sharded calls, so the serving cache
counters see one signature either way. The one private cache this module
adds — the padded/sharded scene LAYOUT per (scene, D) — is registered with
``core.pipeline.register_render_cache`` so ``render_cache_clear()`` /
``render_cache_info()`` cover it and the server's cache-hit stats stay
truthful.

Ragged batches (B not divisible by the data extent) are padded by
replicating the last camera (serving/bucketing.py ``pad_indices``) and the
padded tail is sliced off the result tree — mask-correct because camera
renders are independent (DESIGN.md §9).

On a 1-device mesh the padded batch IS the batch and the program XLA builds
is the unpartitioned one, so results are bitwise-identical to
``render_batch`` (asserted in benchmarks/bench_serving.py and
tests/test_serving.py); scene-sharded parity on 1..4 (virtual) devices is
asserted in tests/test_sharding.py.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.pipeline import (
    CameraBatch,
    RenderConfig,
    RenderResult,
    _background_array,
    _batch_renderer,
    batch_signature,
    register_render_cache,
)
from repro.launch.mesh import make_render_mesh, render_mesh_shards
from repro.serving.bucketing import pad_indices_to, padded_size
from repro.sharding.policies import (
    camera_batch_pspec,
    data_extent,
    render_replicated_pspec,
    scene_shard_pspec,
)
from repro.sharding.scene import ShardedScene, shard_scene_host


def pad_camera_batch(batch: CameraBatch, target: int) -> CameraBatch:
    """Pad the batch axis up to ``target`` lanes by replicating the last
    camera (the ``pad_indices_to`` policy); identity when already there."""
    n = len(batch)
    idx = pad_indices_to(n, target)
    if len(idx) == n:
        return batch
    take = np.asarray(idx)
    return dataclasses.replace(
        batch,
        R=batch.R[take],
        t=batch.t[take],
        fx=batch.fx[take],
        fy=batch.fy[take],
        cx=batch.cx[take],
        cy=batch.cy[take],
    )


# ---------------------------------------------------------------------------
# Scene-layout cache (registered with the engine's cache registry)
# ---------------------------------------------------------------------------

_LAYOUT_CACHE_MAX = 16
_layout_cache: dict = {}           # (id(scene), D) -> ShardedScene
_layout_stats = {"hits": 0, "misses": 0}


def _layout_info() -> dict:
    return {
        "hits": _layout_stats["hits"],
        "misses": _layout_stats["misses"],
        "currsize": len(_layout_cache),
        "maxsize": _LAYOUT_CACHE_MAX,
    }


def _layout_clear() -> None:
    _layout_cache.clear()
    _layout_stats["hits"] = 0
    _layout_stats["misses"] = 0


register_render_cache("scene_layout", info=_layout_info, clear=_layout_clear)


def shard_scene_cached(scene: GaussianScene, num_shards: int) -> ShardedScene:
    """Host-side ``shard_scene_host`` memoized per (scene identity, D).

    The padded/sharded layout of a served scene is rebuilt at most once per
    dispatch stream and held as HOST arrays (numpy): it never pins device
    memory — ``device_put`` with ``scene_shard_pspec`` transfers each shard
    to its own device, with no full-scene allocation on any single device.
    Entries are evicted when the source scene is garbage collected (weakref
    finalizer — id() keys alone could alias a recycled object) or by FIFO
    once the cache holds ``_LAYOUT_CACHE_MAX`` layouts. Covered by
    ``render_cache_clear``/``render_cache_info`` ("scene_layout").
    """
    key = (id(scene), int(num_shards))
    hit = _layout_cache.get(key)
    if hit is not None:
        _layout_stats["hits"] += 1
        return hit
    _layout_stats["misses"] += 1
    out = shard_scene_host(scene, num_shards)
    while len(_layout_cache) >= _LAYOUT_CACHE_MAX:
        _layout_cache.pop(next(iter(_layout_cache)))
    _layout_cache[key] = out
    weakref.finalize(scene, _layout_cache.pop, key, None)
    return out


# ---------------------------------------------------------------------------
# Sharded dispatch
# ---------------------------------------------------------------------------


def render_batch_sharded(
    scene: Union[GaussianScene, ShardedScene],
    cams: Union[CameraBatch, Sequence[Camera]],
    cfg: RenderConfig,
    background=None,
    *,
    mesh: Optional[Mesh] = None,
    pad_to: Optional[int] = None,
    scene_shards: Optional[int] = None,
) -> RenderResult:
    """Render B cameras in ONE jit call, cameras (and optionally gaussians)
    sharded over ``mesh``.

    ``scene_shards`` (default: ``cfg.scene_shards``, or the layout of an
    already-sharded scene) selects the gaussian-axis shard count D;
    ``mesh=None`` builds the matching render mesh over all local devices
    (2-D when D > 1). A mesh without a 'model' axis is allowed with D > 1:
    the shard axis then stays logical (single-device tests, benchmarks). The
    batch is padded to ``max(B, pad_to)`` rounded up to the mesh's DATA
    extent; a serving loop passes its max batch as ``pad_to`` so EVERY
    dispatch of a signature has one fixed shape (one compiled program even
    for ragged max_wait flushes). Returns exactly B images/stats regardless
    of padding.
    """
    if scene_shards is None:
        scene_shards = (
            scene.num_shards
            if isinstance(scene, ShardedScene)
            else cfg.scene_shards
        )
    if cfg.scene_shards != scene_shards:
        cfg = dataclasses.replace(cfg, scene_shards=scene_shards)

    batch = cams if isinstance(cams, CameraBatch) else CameraBatch.from_cameras(cams)
    if mesh is None:
        # Logical shard axis when D does not divide the local device count
        # (the docstring's single-device contract); an explicit mesh keeps
        # make_render_mesh's loud error.
        mesh = make_render_mesh(
            scene_shards=render_mesh_shards(len(jax.devices()), scene_shards)
        )
    model_extent = dict(mesh.shape).get("model", 1)
    if scene_shards > 1 and model_extent not in (1, scene_shards):
        raise ValueError(
            f"mesh model axis ({model_extent}) must match scene_shards="
            f"{scene_shards} (or be absent for a logical-only shard axis)"
        )

    orig = len(batch)
    lanes = data_extent(mesh)
    padded = pad_camera_batch(batch, padded_size(max(orig, pad_to or 0), lanes))

    if scene_shards > 1 and isinstance(scene, GaussianScene):
        scene = shard_scene_cached(scene, scene_shards)
    scene_spec = (
        scene_shard_pspec(mesh)
        if isinstance(scene, ShardedScene)
        else render_replicated_pspec()
    )

    shard = NamedSharding(mesh, camera_batch_pspec(mesh))
    repl = NamedSharding(mesh, render_replicated_pspec())
    put_b = lambda a: jax.device_put(a, shard)

    fn = _batch_renderer(*batch_signature(cfg, padded))
    out = fn(
        jax.device_put(scene, NamedSharding(mesh, scene_spec)),
        put_b(padded.R), put_b(padded.t),
        put_b(padded.fx), put_b(padded.fy),
        put_b(padded.cx), put_b(padded.cy),
        jax.device_put(_background_array(background), repl),
    )
    if len(padded) != orig:
        out = jax.tree.map(lambda x: x[:orig], out)
    return out
