"""Device-sharded batched rendering: ``render_batch`` over a 1-D mesh.

``render_batch_sharded`` is a drop-in superset of ``core.pipeline.
render_batch``: same arguments plus an optional mesh, same ``RenderResult``
(image ``(B, H, W, 3)``, stats ``(B,)``). The camera batch axis is laid over
the mesh's data axis (sharding/policies.py) while the scene and background
stay replicated; XLA partitions the vmapped renderer by propagating the
input shardings — no renderer changes, the SAME lru-cached executable
wrapper from core/pipeline.py serves sharded and unsharded calls, so the
serving cache counters see one signature either way.

Ragged batches (B not divisible by the device count) are padded by
replicating the last camera (serving/bucketing.py ``pad_indices``) and the
padded tail is sliced off the result tree — mask-correct because camera
renders are independent (DESIGN.md §9).

On a 1-device mesh the padded batch IS the batch and the program XLA builds
is the unpartitioned one, so results are bitwise-identical to
``render_batch`` (asserted in benchmarks/bench_serving.py and
tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.pipeline import (
    CameraBatch,
    RenderConfig,
    RenderResult,
    _background_array,
    _batch_renderer,
    batch_signature,
)
from repro.launch.mesh import make_render_mesh
from repro.serving.bucketing import pad_indices_to, padded_size
from repro.sharding.policies import camera_batch_pspec, render_replicated_pspec


def pad_camera_batch(batch: CameraBatch, target: int) -> CameraBatch:
    """Pad the batch axis up to ``target`` lanes by replicating the last
    camera (the ``pad_indices_to`` policy); identity when already there."""
    n = len(batch)
    idx = pad_indices_to(n, target)
    if len(idx) == n:
        return batch
    take = np.asarray(idx)
    return dataclasses.replace(
        batch,
        R=batch.R[take],
        t=batch.t[take],
        fx=batch.fx[take],
        fy=batch.fy[take],
        cx=batch.cx[take],
        cy=batch.cy[take],
    )


def render_batch_sharded(
    scene: GaussianScene,
    cams: Union[CameraBatch, Sequence[Camera]],
    cfg: RenderConfig,
    background=None,
    *,
    mesh: Optional[Mesh] = None,
    pad_to: Optional[int] = None,
) -> RenderResult:
    """Render B cameras in ONE jit call, batch axis sharded over ``mesh``.

    ``mesh=None`` builds a 1-D mesh over all local devices. The batch is
    padded to ``max(B, pad_to)`` rounded up to the device count; a serving
    loop passes its max batch as ``pad_to`` so EVERY dispatch of a signature
    has one fixed shape (one compiled program even for ragged max_wait
    flushes). Returns exactly B images/stats regardless of padding.
    """
    batch = cams if isinstance(cams, CameraBatch) else CameraBatch.from_cameras(cams)
    if mesh is None:
        mesh = make_render_mesh()
    orig = len(batch)
    padded = pad_camera_batch(
        batch, padded_size(max(orig, pad_to or 0), mesh.size)
    )

    shard = NamedSharding(mesh, camera_batch_pspec(mesh))
    repl = NamedSharding(mesh, render_replicated_pspec())
    put_b = lambda a: jax.device_put(a, shard)

    fn = _batch_renderer(*batch_signature(cfg, padded))
    out = fn(
        jax.device_put(scene, repl),
        put_b(padded.R), put_b(padded.t),
        put_b(padded.fx), put_b(padded.fy),
        put_b(padded.cx), put_b(padded.cy),
        jax.device_put(_background_array(background), repl),
    )
    if len(padded) != orig:
        out = jax.tree.map(lambda x: x[:orig], out)
    return out
