"""Serving-side scene staging + the legacy sharded-dispatch shim.

The actual device-sharded dispatch (cameras over 'data', gaussians over
'model') lives in the engine handle now (``repro.engine``, DESIGN.md §11):
a ``Renderer`` commits the scene layout once — and, with it, the
projected-feature gather strategy (DESIGN.md §12: the owner-masked psum
form when the 'model' axis is physical, so per-camera features stay at N/D
per device) — and every ``render_batch`` reuses both. This module keeps the
two serving-side pieces the handle builds on, plus the deprecated
free-function entry:

  * ``pad_camera_batch`` — the array-level ragged-batch padding built on the
    ``pad_indices_to`` policy (mask-correct: the padded tail replicates the
    last camera and is sliced off after the dispatch, DESIGN.md §9);
  * the scene-LAYOUT cache (``shard_scene_cached``): the host-staged
    padded/sharded layout per (scene identity, D), registered with
    ``core.pipeline.register_render_cache`` so ``render_cache_clear()`` /
    ``render_cache_info()`` cover it and the server's cache-hit stats stay
    truthful; ``evict_scene_layouts`` is the handle-lifecycle eviction hook;
  * ``render_batch_sharded`` — a DeprecationWarning shim delegating to the
    module-default handle, bitwise-identical to the handle path by
    construction.
"""
from __future__ import annotations

import dataclasses
import warnings
import weakref
from typing import Optional, Sequence, Union

import numpy as np
from jax.sharding import Mesh

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.pipeline import (
    CameraBatch,
    RenderConfig,
    RenderResult,
    register_render_cache,
)
from repro.serving.bucketing import pad_indices_to
from repro.sharding.scene import ShardedScene, shard_scene_host


def pad_camera_batch(batch: CameraBatch, target: int) -> CameraBatch:
    """Pad the batch axis up to ``target`` lanes by replicating the last
    camera (the ``pad_indices_to`` policy); identity when already there."""
    n = len(batch)
    idx = pad_indices_to(n, target)
    if len(idx) == n:
        return batch
    take = np.asarray(idx)
    return dataclasses.replace(
        batch,
        R=batch.R[take],
        t=batch.t[take],
        fx=batch.fx[take],
        fy=batch.fy[take],
        cx=batch.cx[take],
        cy=batch.cy[take],
    )


# ---------------------------------------------------------------------------
# Scene-layout cache (registered with the engine's cache registry)
# ---------------------------------------------------------------------------

_LAYOUT_CACHE_MAX = 16
_layout_cache: dict = {}           # (id(scene), D) -> ShardedScene
_layout_stats = {"hits": 0, "misses": 0}


def _layout_info() -> dict:
    return {
        "hits": _layout_stats["hits"],
        "misses": _layout_stats["misses"],
        "currsize": len(_layout_cache),
        "maxsize": _LAYOUT_CACHE_MAX,
    }


def _layout_clear() -> None:
    _layout_cache.clear()
    _layout_stats["hits"] = 0
    _layout_stats["misses"] = 0


register_render_cache("scene_layout", info=_layout_info, clear=_layout_clear)


def shard_scene_cached(scene: GaussianScene, num_shards: int) -> ShardedScene:
    """Host-side ``shard_scene_host`` memoized per (scene identity, D).

    The padded/sharded layout of a served scene is rebuilt at most once per
    dispatch stream and held as HOST arrays (numpy): it never pins device
    memory — ``device_put`` with ``scene_shard_pspec`` transfers each shard
    to its own device, with no full-scene allocation on any single device.
    Entries are evicted when the source scene is garbage collected (weakref
    finalizer — id() keys alone could alias a recycled object) or by FIFO
    once the cache holds ``_LAYOUT_CACHE_MAX`` layouts. Covered by
    ``render_cache_clear``/``render_cache_info`` ("scene_layout").
    """
    key = (id(scene), int(num_shards))
    hit = _layout_cache.get(key)
    if hit is not None:
        _layout_stats["hits"] += 1
        return hit
    _layout_stats["misses"] += 1
    out = shard_scene_host(scene, num_shards)
    while len(_layout_cache) >= _LAYOUT_CACHE_MAX:
        _layout_cache.pop(next(iter(_layout_cache)))
    _layout_cache[key] = out
    weakref.finalize(scene, _layout_cache.pop, key, None)
    return out


def evict_scene_layouts(scene: GaussianScene) -> int:
    """Drop EVERY cached layout of ``scene``, at any shard count.

    The lifecycle hook ``repro.engine.Renderer.close()`` calls: before it,
    re-committing one scene at a different ``scene_shards`` left the old
    layout resident until the scene itself was garbage collected (the
    weakref finalizer is per-scene, not per-layout). Returns the number of
    layouts evicted; the finalizers registered by ``shard_scene_cached``
    tolerate the missing keys."""
    sid = id(scene)
    keys = [k for k in _layout_cache if k[0] == sid]
    for k in keys:
        _layout_cache.pop(k, None)
    return len(keys)


# ---------------------------------------------------------------------------
# Sharded dispatch
# ---------------------------------------------------------------------------


def render_batch_sharded(
    scene: Union[GaussianScene, ShardedScene],
    cams: Union[CameraBatch, Sequence[Camera]],
    cfg: RenderConfig,
    background=None,
    *,
    mesh: Optional[Mesh] = None,
    pad_to: Optional[int] = None,
    scene_shards: Optional[int] = None,
) -> RenderResult:
    """Deprecated: ``repro.engine.open(scene, cfg, mesh=mesh).render_batch``.

    Delegates to the module-default handle for ``(scene, cfg, mesh)``
    (``repro.engine.default_renderer``), preserving the legacy semantics:
    ``scene_shards`` (default: ``cfg.scene_shards``, or the layout of an
    already-sharded scene) selects the gaussian-axis shard count D;
    ``mesh=None`` builds the matching render mesh over all local devices
    with the ``render_mesh_shards`` logical fallback; the batch is padded to
    ``max(B, pad_to)`` rounded up to the mesh's DATA extent and exactly B
    images/stats come back. The handle is what now owns the committed scene
    placement and the compiled-renderer cache (DESIGN.md §11).
    """
    warnings.warn(
        "render_batch_sharded() is deprecated; open a handle with "
        "repro.engine.open(scene, cfg, mesh=...) and call "
        ".render_batch(cams, pad_to=...)",
        DeprecationWarning,
        stacklevel=2,
    )
    if scene_shards is None:
        scene_shards = (
            scene.num_shards
            if isinstance(scene, ShardedScene)
            else cfg.scene_shards
        )
    if cfg.scene_shards != scene_shards:
        cfg = dataclasses.replace(cfg, scene_shards=scene_shards)

    from repro import engine

    handle = engine.default_renderer(scene, cfg, mesh=mesh)
    return handle.render_batch(cams, pad_to=pad_to, background=background)
